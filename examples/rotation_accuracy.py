"""Reproduce the paper's section-4.2 accuracy table at container scale:
FP16 baseline vs FP8 attention without rotation vs FP8 + rotation
(reference path and hadacore kernel path).

    PYTHONPATH=src python examples/rotation_accuracy.py
"""
from benchmarks import bench_quant_accuracy

if __name__ == "__main__":
    csv = []
    bench_quant_accuracy.run(csv)
    print("\n== section 4.2 proxy (lower CE / higher agreement is better) ==")
    for line in csv:
        print(line)
