"""End-to-end driver: train a ~100M-parameter llama3-family model for a few
hundred steps with the paper's rotation-quantization enabled, with
checkpointing (kill and re-run: it resumes).

    PYTHONPATH=src python examples/train_100m.py [--steps 300]
"""
import argparse

from repro.launch.train import main as train_main

if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_100m_ckpt")
    ap.add_argument("--quant", default="int8")
    args = ap.parse_args()

    # scale 0.12 of llama3-8b ~= 110M params (24 layers scaled to ~11,
    # d_model 1408); seq/batch sized for a CPU container -- on a real
    # slice drop the overrides and use the full train_4k shape.
    train_main([
        "--arch", "llama3-8b", "--scale", "0.12",
        "--steps", str(args.steps),
        "--seq", "512", "--batch", "8",
        "--quant", args.quant, "--rotate", "hadamard",
        "--ckpt-dir", args.ckpt_dir, "--ckpt-every", "50",
        "--log-every", "10",
    ])
