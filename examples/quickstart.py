"""Quickstart: the HadaCore Hadamard transform and rotation-quantization.

    PYTHONPATH=src python examples/quickstart.py            # full sizes
    PYTHONPATH=src python examples/quickstart.py --smoke    # CI-sized
"""
import math
import sys

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.api import QuantDotSpec, QuantEpilogue, hadamard, plan_for
from repro.core.hadamard import hadamard_transform
from repro.core.quant import QuantConfig
from repro.core.rotations import fuse_rotation_lhs, rotation_matrix
from repro.core import wquant
from repro.kernels.hadacore import hadacore
from repro.kernels.ref import fwht

SMOKE = "--smoke" in sys.argv
N = 512 if SMOKE else 4096          # transform size
D = 128 if SMOKE else 512           # matmul out-channels
ROWS = 16 if SMOKE else 64

rng = np.random.default_rng(0)

# 1. The transform itself: three equivalent implementations -------------
x = jnp.asarray(rng.standard_normal((8, N)), dtype=jnp.float32)
y_kernel = hadacore(x)                      # Pallas TPU kernel (interpret on CPU)
y_xla = hadamard_transform(x)               # MXU-factored pure JAX
y_ref = fwht(x, scale=1 / math.sqrt(N))     # the paper's Listing-1 oracle
print("kernel vs oracle max err:",
      float(jnp.abs(y_kernel - y_ref).max()))
print("xla    vs oracle max err:",
      float(jnp.abs(y_xla - y_ref).max()))

# 2. The unified API: one entry point, plans cached per shape -----------
# hadamard(x) builds (and caches) a plan keyed on (n, dtype, backend,
# epilogue, scale, mesh axes); prebuild one to pin every decision.
plan = plan_for(N, backend="pallas")
print("plan:", f"n={plan.n} backend={plan.backend} passes={plan.num_passes}")
print("plan vs oracle max err:", float(jnp.abs(hadamard(x, plan) - y_ref).max()))

# It is a rotation: orthonormal, self-inverse
print("self-inverse err:", float(jnp.abs(hadamard(hadamard(x)) - x).max()))
print("norm ratio:", float(jnp.linalg.norm(hadamard(x)) / jnp.linalg.norm(x)))

# Composable quantize epilogues: rotate + quantize in ONE kernel; the
# quantized tensor and per-token scales are the only HBM outputs.
q, s = hadamard(x, epilogue=QuantEpilogue("int8"))
print("fused int8:", q.dtype, q.shape, "scales:", s.shape)
qf, sf = hadamard(x, epilogue=QuantEpilogue("fp8_e4m3"))
print("fused fp8_e4m3:", qf.dtype,
      "dequant err:", float(jnp.abs(qf.astype(jnp.float32) * sf - y_ref).max()))

# 3. Why LLM quantization wants it: outlier smearing --------------------
acts = rng.standard_normal((ROWS, N)).astype(np.float32)
acts[:, 17] *= 80.0                          # one outlier channel
rot = np.asarray(hadamard(jnp.asarray(acts)))
print(f"abs-max before rotation: {np.abs(acts).max():8.1f}  "
      f"after: {np.abs(rot).max():8.1f}")

# 4. The declarative consumer site: QuantDotSpec + QTensor --------------
# Declare the rotation-consumer once (size, mode, sharding axes), then
# bind weights: a raw weight quantizes on the fly (training), a
# pre-quantized QTensor is consumed directly (serving).
w = jnp.asarray(rng.standard_normal((N, D)) * 0.02, jnp.float32)
spec = QuantDotSpec.for_config(
    N, QuantConfig(mode="int8", rotate="hadamard", backend="pallas"),
    weight_axes=("dff", "fsdp"))
y_train = spec.bind(w)(jnp.asarray(acts))          # on-the-fly weight quant

qt = wquant.quantize_weight(w, "int8")             # ONCE, at load time
print("QTensor:", qt.q.dtype, qt.q.shape, "scales:", qt.scale.shape,
      "mode:", qt.mode)
wquant.reset_quantize_weight_calls()
y_serve = spec.bind(qt)(jnp.asarray(acts))         # zero per-forward quant
print("serving bind quantize_weight calls:", wquant.QUANTIZE_WEIGHT_CALLS,
      " train-vs-serve bitwise:",
      bool((np.asarray(y_train) == np.asarray(y_serve)).all()))

# 5. Why rotation helps the int8 grid: offline weight fusion ------------
ref = acts @ np.asarray(w)
spec_plain = QuantDotSpec.for_config(
    N, QuantConfig(mode="int8", backend="xla"))    # quantize, no rotation
Q = rotation_matrix(N)
wr = fuse_rotation_lhs(w, Q)                       # W <- Q^T W (offline, free)
err0 = float(np.abs(np.asarray(spec_plain.bind(w)(jnp.asarray(acts))) - ref).mean())
err1 = float(np.abs(np.asarray(spec.bind(wr)(jnp.asarray(acts))) - ref).mean())
print(f"int8 matmul error: plain {err0:.4f} -> rotated {err1:.4f} "
      f"({err0 / err1:.1f}x better)")
