"""Quickstart: the HadaCore Hadamard transform and rotation-quantization.

    PYTHONPATH=src python examples/quickstart.py
"""
import math

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.api import QuantEpilogue, hadamard, plan_for
from repro.core.hadamard import hadamard_transform
from repro.core.quant import QuantConfig, quant_dot
from repro.core.rotations import fuse_rotation_lhs, online_hadamard, rotation_matrix
from repro.kernels.hadacore import hadacore
from repro.kernels.ref import fwht, hadamard_matrix

rng = np.random.default_rng(0)

# 1. The transform itself: three equivalent implementations -------------
x = jnp.asarray(rng.standard_normal((8, 4096)), dtype=jnp.float32)
y_kernel = hadacore(x)                      # Pallas TPU kernel (interpret on CPU)
y_xla = hadamard_transform(x)               # MXU-factored pure JAX
y_ref = fwht(x, scale=1 / math.sqrt(4096))  # the paper's Listing-1 oracle
print("kernel vs oracle max err:",
      float(jnp.abs(y_kernel - y_ref).max()))
print("xla    vs oracle max err:",
      float(jnp.abs(y_xla - y_ref).max()))

# 2. The unified API: one entry point, plans cached per shape -----------
# hadamard(x) builds (and caches) a plan keyed on (n, dtype, backend,
# epilogue, scale); prebuild one to pin every decision for a hot path.
plan = plan_for(4096, backend="pallas")
print("plan:", f"n={plan.n} backend={plan.backend} passes={plan.num_passes}")
print("plan vs oracle max err:", float(jnp.abs(hadamard(x, plan) - y_ref).max()))

# It is a rotation: orthonormal, self-inverse
print("self-inverse err:", float(jnp.abs(hadamard(hadamard(x)) - x).max()))
print("norm ratio:", float(jnp.linalg.norm(hadamard(x)) / jnp.linalg.norm(x)))

# Composable quantize epilogues: rotate + quantize in ONE kernel; the
# quantized tensor and per-token scales are the only HBM outputs.
q, s = hadamard(x, epilogue=QuantEpilogue("int8"))
print("fused int8:", q.dtype, q.shape, "scales:", s.shape)
qf, sf = hadamard(x, epilogue=QuantEpilogue("fp8_e4m3"))
print("fused fp8_e4m3:", qf.dtype,
      "dequant err:", float(jnp.abs(qf.astype(jnp.float32) * sf - y_ref).max()))

# 3. Why LLM quantization wants it: outlier smearing --------------------
acts = rng.standard_normal((64, 4096)).astype(np.float32)
acts[:, 17] *= 80.0                          # one outlier channel
rot = np.asarray(hadamard(jnp.asarray(acts)))
print(f"abs-max before rotation: {np.abs(acts).max():8.1f}  "
      f"after: {np.abs(rot).max():8.1f}")

# 4. INT8 matmul error with offline-fused weight rotation ---------------
w = (rng.standard_normal((4096, 512)) * 0.02).astype(np.float32)
ref = acts @ w
cfg = QuantConfig(mode="int8")
cfg_rot = QuantConfig(mode="int8", rotate="hadamard", backend="xla")
Q = rotation_matrix(4096)
err0 = float(np.abs(np.asarray(quant_dot(jnp.asarray(acts), jnp.asarray(w), cfg)) - ref).mean())
xr = online_hadamard(jnp.asarray(acts), cfg_rot)
wr = fuse_rotation_lhs(jnp.asarray(w), Q)
err1 = float(np.abs(np.asarray(quant_dot(xr, wr, cfg_rot)) - ref).mean())
print(f"int8 matmul error: plain {err0:.4f} -> rotated {err1:.4f} "
      f"({err0/err1:.1f}x better)")
