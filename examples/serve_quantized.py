"""Serve a small model with batched requests and the paper's FP8 +
Hadamard-rotation KV-cache path (prefill -> decode loop), end to end on
the PR 4 serving stack: weights are pre-quantized ONCE at load into
``QTensor`` leaves (``--prequant``, on by default when quantizing), so
the jitted forward contracts the rotated activations against int8/fp8
weights directly -- zero per-forward weight quantization.

    PYTHONPATH=src python examples/serve_quantized.py            # full demo
    PYTHONPATH=src python examples/serve_quantized.py --smoke    # CI-sized
"""
import sys

from repro.launch.serve import main as serve_main

if __name__ == "__main__":
    smoke = "--smoke" in sys.argv
    args = ["--arch", "llama3-8b",
            "--quant", "fp8_e4m3", "--rotate", "hadamard",
            "--prequant"]
    if smoke:
        # tiny shapes: CPU interpret-mode guard that the pre-quantized
        # QTensor serving path keeps running, not a measurement
        args += ["--scale", "0.005", "--batch", "2",
                 "--prompt-len", "16", "--gen", "4"]
    else:
        args += ["--scale", "0.05", "--batch", "8",
                 "--prompt-len", "128", "--gen", "32"]
    serve_main(args)
