"""Serve a small model with batched requests and the paper's FP8 +
Hadamard-rotation KV-cache path (prefill -> decode loop).

    PYTHONPATH=src python examples/serve_quantized.py
"""
from repro.launch.serve import main as serve_main

if __name__ == "__main__":
    serve_main([
        "--arch", "llama3-8b", "--scale", "0.05",
        "--batch", "8", "--prompt-len", "128", "--gen", "32",
        "--quant", "fp8_e4m3", "--rotate", "hadamard",
    ])
