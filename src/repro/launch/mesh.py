"""Production mesh construction.

A function, not a module-level constant: importing this module never
touches jax device state (device count locks on first jax init)."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256-chip single pod (data, model), or 2 pods = 512 chips
    with a leading 'pod' axis. data+pod are the DP/FSDP axes; 'model' is
    tensor/expert parallel (DESIGN.md section 4)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh(model_parallel: int = 1):
    """Mesh over whatever devices exist (CPU CI: 1 device; a real slice:
    all chips) -- used by train.py/serve.py for actually-running jobs."""
    n = len(jax.devices())
    assert n % model_parallel == 0
    return jax.make_mesh((n // model_parallel, model_parallel), ("data", "model"))
