"""Step builders: train_step / prefill_step / serve_step as pure functions,
plus the sharding trees that pjit them onto a mesh.

These are shared by the real launchers (train.py, serve.py) and the
multi-pod dry-run (dryrun.py): the SAME functions and the SAME shardings
are lowered in both paths, so a dry-run pass is evidence about the real
configuration, not about a parallel implementation.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.distributed import sharding as shd
from repro.launch import shapes as shp
from repro.models import init_lm, lm_decode_step, lm_forward, lm_loss, lm_param_specs
from repro.models.config import ModelConfig
from repro.optim import OptConfig, apply_updates, init_opt_state
from repro.optim.qstate import qstate_specs


# ------------------------------------------------------------- spec trees
def _resolve_tree(spec_tree, shape_tree, mesh: Mesh):
    """logical-axis tuples + ShapeDtypeStructs -> NamedShardings (with the
    divisibility guard from distributed.sharding)."""
    resolver = shd.make_resolver(mesh)

    def one(spec, sds):
        return resolver(spec, sds.shape)

    return jax.tree.map(one, spec_tree, shape_tree,
                        is_leaf=lambda x: isinstance(x, tuple) and all(
                            isinstance(e, (str, type(None))) for e in x))


def make_param_init(cfg: ModelConfig):
    """The parameter-init fn the launchers jit: init, then (for serving
    configs with ``weight_quant='int8'``) pre-quantize the weight tree
    into QTensors ONCE -- storage leaves in int8, rotation-consumer
    leaves in ``cfg.quant.mode`` so the forward's quant_dot contracts
    against them directly, with each leaf's logical sharding axes
    attached for the QTensor-aware sharding trees."""
    def init(key):
        p = init_lm(key, cfg)
        if cfg.weight_quant == "int8":
            from repro.core.wquant import quantize_lm_weights
            p = quantize_lm_weights(p, cfg, lm_param_specs(cfg))
        return p
    return init


def param_shapes(cfg: ModelConfig):
    return jax.eval_shape(make_param_init(cfg), jax.random.PRNGKey(0))


def param_specs(cfg: ModelConfig):
    specs = lm_param_specs(cfg)
    if cfg.weight_quant == "int8":
        from repro.core.wquant import qweight_specs
        specs = qweight_specs(specs, param_shapes(cfg))
    return specs


def param_shardings(cfg: ModelConfig, mesh: Mesh):
    return _resolve_tree(param_specs(cfg), param_shapes(cfg), mesh)


def opt_state_specs(cfg: ModelConfig, opt_cfg: OptConfig):
    pspecs = lm_param_specs(cfg)
    is_spec = lambda x: isinstance(x, tuple) and all(
        isinstance(e, (str, type(None))) for e in x)
    if opt_cfg.state_dtype == "int8":
        moments = jax.tree.map(qstate_specs, pspecs, is_leaf=is_spec)
    else:
        moments = pspecs
    state = {"m": moments, "v": moments, "step": ()}
    if opt_cfg.grad_compression == "int8_ef":
        state["ef"] = pspecs
    return state


def opt_state_shapes(cfg: ModelConfig, opt_cfg: OptConfig):
    pshapes = param_shapes(cfg)
    return jax.eval_shape(lambda: init_opt_state(pshapes, opt_cfg))


def opt_state_shardings(cfg: ModelConfig, opt_cfg: OptConfig, mesh: Mesh):
    return _resolve_tree(opt_state_specs(cfg, opt_cfg),
                         opt_state_shapes(cfg, opt_cfg), mesh)


def batch_shardings(cfg: ModelConfig, shape: shp.ShapeSpec, mesh: Mesh):
    return _resolve_tree(shp.batch_logical_specs(cfg),
                         shp.batch_specs(cfg, shape), mesh)


def cache_shardings(cfg: ModelConfig, batch: int, seq: int, mesh: Mesh):
    return _resolve_tree(shp.cache_logical_specs(cfg),
                         shp.cache_specs(cfg, batch, seq), mesh)


# ------------------------------------------------------------------ steps
def make_train_step(cfg: ModelConfig, opt_cfg: OptConfig, microbatches: int = 1):
    """microbatches > 1: gradient accumulation -- the global batch is split
    into M sequential microbatches inside one jit step (lax.scan), dividing
    activation memory by M at the cost of M smaller matmuls. The standard
    way a 405B × 1M-token step fits a 512-chip slice."""
    grad_fn = jax.value_and_grad(lm_loss, has_aux=True, argnums=1)

    def train_step(params, opt_state, batch):
        if microbatches == 1:
            (loss, metrics), grads = grad_fn(cfg, params, batch)
        else:
            def split(x):
                b = x.shape[0]
                if x.ndim >= 2 and b % microbatches == 0:
                    return x.reshape(microbatches, b // microbatches, *x.shape[1:])
                # leading-dim-first tensors (e.g. M-RoPE positions (3,B,S))
                return jnp.broadcast_to(x, (microbatches,) + x.shape) \
                    if x.shape[0] != batch["tokens"].shape[0] else x
            mb = {k: split(v) for k, v in batch.items()}
            if cfg.mrope and "positions" in batch:
                pos = batch["positions"]  # (3, B, S) -> (M, 3, B/M, S)
                B = pos.shape[1]
                mb["positions"] = pos.reshape(
                    3, microbatches, B // microbatches, -1).swapaxes(0, 1)

            def acc_body(carry, micro):
                g_acc, loss_acc = carry
                (loss, metrics), g = grad_fn(cfg, params, micro)
                g_acc = jax.tree.map(jnp.add, g_acc, g)
                return (g_acc, loss_acc + loss), metrics

            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (grads, loss), metrics_all = jax.lax.scan(
                acc_body, (g0, jnp.zeros((), jnp.float32)), mb)
            grads = jax.tree.map(lambda g: (g / microbatches).astype(jnp.float32), grads)
            loss = loss / microbatches
            metrics = jax.tree.map(lambda m: m.mean(), metrics_all)
        params, opt_state, opt_metrics = apply_updates(params, grads, opt_state, opt_cfg)
        metrics = dict(metrics, loss=loss, **opt_metrics)
        return params, opt_state, metrics

    return train_step


def make_prefill_step(cfg: ModelConfig):
    def prefill_step(params, batch):
        logits, _, caches = lm_forward(cfg, params, batch, want_cache=True)
        return logits[:, -1:], caches

    return prefill_step


def make_serve_step(cfg: ModelConfig, guard: bool = False):
    """``guard=True`` builds the numerically-guarded form: the logits slot
    of the return tuple is replaced by a (batch,) bool ok-vector (per-slot
    logits finiteness; quant-scale failures arrive here too, as NaN
    poison from ``core.guards.guard_dequant`` at the quantize sites).
    Same arity and out-structure as the unguarded step so
    ``jit_serve_step`` is shared; the tokens are bitwise identical
    (guards observe, never perturb healthy values -- asserted in
    tests/test_faults.py).

    ABFT serving (``repro.verify``, DESIGN.md section 14) reuses this
    guarded step UNCHANGED: the kernel checksum residual surfaces as
    NaN-poisoned logit rows in the ok-vector, and the KV conservation
    check runs as separate ``verify.kv_check``/``kv_roll`` executables
    the engine dispatches around this one -- folding a whole-cache read
    into the donated decode program would force defensive copies of the
    donated cache buffers (see ``verify.kv_check``)."""

    def serve_step(params, caches, tokens, cache_pos):
        logits, new_caches = lm_decode_step(cfg, params, caches, tokens, cache_pos)
        new_tokens = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
        return new_tokens, logits, new_caches

    if not guard:
        return serve_step

    from repro.core import guards

    def guarded_serve_step(params, caches, tokens, cache_pos):
        logits, new_caches = lm_decode_step(
            cfg, params, caches, tokens, cache_pos)
        ok = guards.rows_ok(logits[:, -1], tokens.shape[0])
        new_tokens = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
        return new_tokens, ok, new_caches

    return guarded_serve_step


# ------------------------------------------------------- jitted assemblies
def jit_train_step(cfg, opt_cfg, shape, mesh, *, rules_overrides=None, donate=True,
                   microbatches: int = 1):
    """jit(train_step) with full sharding trees; also returns the sharding
    trees so callers can device_put params/batches consistently.

    NOTE: the sharding trees are resolved INSIDE the rules context so that
    per-cell overrides (e.g. long-context KV-cache seq sharding) apply to
    the jit in/out shardings, not only to in-graph constraints."""
    with shd.sharding_rules(mesh, rules_overrides):
        ps = param_shardings(cfg, mesh)
        os_ = opt_state_shardings(cfg, opt_cfg, mesh)
        bs = batch_shardings(cfg, shape, mesh)
    fn = make_train_step(cfg, opt_cfg, microbatches)

    def wrapped(params, opt_state, batch):
        with shd.sharding_rules(mesh, rules_overrides):
            return fn(params, opt_state, batch)

    jitted = jax.jit(
        wrapped,
        in_shardings=(ps, os_, bs),
        out_shardings=(ps, os_, None),
        donate_argnums=(0, 1) if donate else (),
    )
    return jitted, (ps, os_, bs)


def jit_serve_step(cfg, batch_size, cache_seq, mesh, *, rules_overrides=None,
                   donate=True, per_slot=False, guard=False):
    """jit(serve_step). ``per_slot=True`` is the continuous-batching form:
    cache_pos is a (batch,) int32 vector (one position per request slot,
    sharded with the slots) instead of a batch-wide scalar. ``guard=True``
    compiles the numerically-guarded step (middle output becomes the
    (batch,) ok-vector; replicated, like the logits it replaces)."""
    with shd.sharding_rules(mesh, rules_overrides):
        ps = param_shardings(cfg, mesh)
        cs = cache_shardings(cfg, batch_size, cache_seq, mesh)
        tok_s = shd.make_resolver(mesh)(("batch", None), (batch_size, 1))
        pos_s = (shd.make_resolver(mesh)(("batch",), (batch_size,))
                 if per_slot else NamedSharding(mesh, P()))
    fn = make_serve_step(cfg, guard=guard)

    def wrapped(params, caches, tokens, cache_pos):
        with shd.sharding_rules(mesh, rules_overrides):
            return fn(params, caches, tokens, cache_pos)

    jitted = jax.jit(
        wrapped,
        in_shardings=(ps, cs, tok_s, pos_s),
        out_shardings=(tok_s, None, cs),
        donate_argnums=(1,) if donate else (),
    )
    return jitted, (ps, cs, tok_s)


def jit_prefill_step(cfg, shape, mesh, *, rules_overrides=None):
    with shd.sharding_rules(mesh, rules_overrides):
        ps = param_shardings(cfg, mesh)
        bs = batch_shardings(cfg, shape, mesh)
    fn = make_prefill_step(cfg)

    def wrapped(params, batch):
        with shd.sharding_rules(mesh, rules_overrides):
            return fn(params, batch)

    jitted = jax.jit(wrapped, in_shardings=(ps, bs), out_shardings=None)
    return jitted, (ps, bs)
