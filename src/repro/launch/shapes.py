"""Assigned input shapes and ShapeDtypeStruct builders.

``input_specs`` returns weak-type-correct, shardable stand-ins for every
model input -- no device allocation -- for lowering; ``make_batch``
materializes small real batches for smoke tests and examples.

LM shapes (per the assignment):
    train_4k     seq 4,096   global_batch 256   (train_step)
    prefill_32k  seq 32,768  global_batch 32    (prefill_step)
    decode_32k   seq 32,768  global_batch 128   (serve_step: 1 new token,
                                                 KV cache of seq_len)
    long_500k    seq 524,288 global_batch 1     (serve_step; SSM/hybrid only)
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ModelConfig


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str        # train | prefill | decode
    seq: int
    batch: int


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524288, 1),
}


def shape_applicable(cfg: ModelConfig, shape: ShapeSpec) -> Optional[str]:
    """None if the (arch, shape) cell runs; else the reason for the skip."""
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return "long_500k needs sub-quadratic attention (pure full-attention arch)"
    if shape.kind == "decode" and not cfg.has_decoder:
        return "encoder-only arch has no decode step"
    return None


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def batch_specs(cfg: ModelConfig, shape: ShapeSpec) -> Dict[str, Any]:
    """Model-input ShapeDtypeStructs for a train/prefill forward pass."""
    B, S = shape.batch, shape.seq
    dt = jnp.dtype(cfg.dtype)
    specs: Dict[str, Any] = {}
    if cfg.family == "vlm":
        P = cfg.vlm_patches
        specs["tokens"] = _sds((B, S - P), jnp.int32)
        specs["labels"] = _sds((B, S - P), jnp.int32)
        specs["patch_embeds"] = _sds((B, P, cfg.d_model), dt)
        specs["positions"] = _sds((3, B, S), jnp.int32)
    else:
        specs["tokens"] = _sds((B, S), jnp.int32)
        specs["labels"] = _sds((B, S), jnp.int32)
    if cfg.is_encdec:
        specs["frames"] = _sds((B, cfg.encoder_seq, cfg.d_model), dt)
    return specs


def cache_specs(cfg: ModelConfig, batch: int, seq: int):
    """ShapeDtypeStruct tree matching lm_decode_step's cache layout."""
    dt = jnp.dtype(cfg.dtype)
    kvdt = cfg.quant.kv_cache_dtype(dt)
    KH, hd = cfg.num_kv_heads, cfg.head_dim
    caches = []
    for pattern, repeats in cfg.groups:
        stack = {}
        for j, kind in enumerate(pattern):
            if kind in ("attn", "moe"):
                c = {"k": _sds((repeats, batch, seq, KH, hd), kvdt),
                     "v": _sds((repeats, batch, seq, KH, hd), kvdt)}
            elif kind == "xattn":
                c = {"k": _sds((repeats, batch, seq, KH, hd), kvdt),
                     "v": _sds((repeats, batch, seq, KH, hd), kvdt),
                     "xk": _sds((repeats, batch, cfg.encoder_seq, KH, hd), kvdt),
                     "xv": _sds((repeats, batch, cfg.encoder_seq, KH, hd), kvdt)}
            elif kind == "mamba":
                d_inner = cfg.ssm_expand * cfg.d_model
                H = d_inner // cfg.ssm_head_dim
                c = {"ssm": _sds((repeats, batch, H, cfg.ssm_head_dim, cfg.ssm_state), jnp.float32),
                     "conv_x": _sds((repeats, batch, 3, d_inner), dt),
                     "conv_bc": _sds((repeats, batch, 3, 2 * cfg.ssm_state), dt)}
            elif kind == "rwkv":
                K = cfg.rwkv_head_dim
                H = cfg.d_model // K
                c = {"S": _sds((repeats, batch, H, K, K), jnp.float32),
                     "xp_t": _sds((repeats, batch, cfg.d_model), dt),
                     "xp_c": _sds((repeats, batch, cfg.d_model), dt)}
            else:
                raise ValueError(kind)
            stack[f"p{j}"] = c
        caches.append(stack)
    return caches


def cache_logical_specs(cfg: ModelConfig):
    """Logical sharding axes mirroring cache_specs."""
    caches = []
    for pattern, repeats in cfg.groups:
        stack = {}
        for j, kind in enumerate(pattern):
            if kind in ("attn", "moe"):
                c = {"k": ("layers", "batch", "kvseq", "kv", None),
                     "v": ("layers", "batch", "kvseq", "kv", None)}
            elif kind == "xattn":
                c = {"k": ("layers", "batch", "kvseq", "kv", None),
                     "v": ("layers", "batch", "kvseq", "kv", None),
                     "xk": ("layers", "batch", None, "kv", None),
                     "xv": ("layers", "batch", None, "kv", None)}
            elif kind == "mamba":
                c = {"ssm": ("layers", "batch", "heads", None, None),
                     "conv_x": ("layers", "batch", None, "dff"),
                     "conv_bc": ("layers", "batch", None, None)}
            elif kind == "rwkv":
                c = {"S": ("layers", "batch", "heads", None, None),
                     "xp_t": ("layers", "batch", None),
                     "xp_c": ("layers", "batch", None)}
            else:
                raise ValueError(kind)
            stack[f"p{j}"] = c
        caches.append(stack)
    return caches


def batch_logical_specs(cfg: ModelConfig) -> Dict[str, Any]:
    specs: Dict[str, Any] = {"tokens": ("batch", "seq"), "labels": ("batch", "seq")}
    if cfg.family == "vlm":
        specs["patch_embeds"] = ("batch", "seq", None)
        specs["positions"] = (None, "batch", "seq")
    if cfg.is_encdec:
        specs["frames"] = ("batch", None, None)
    return specs


def make_batch(cfg: ModelConfig, shape: ShapeSpec, seed: int = 0) -> Dict[str, Any]:
    """Real (host) batch for smoke tests / examples. Next-token labels."""
    rng = np.random.default_rng(seed)
    B, S = shape.batch, shape.seq
    dt = jnp.dtype(cfg.dtype)
    out: Dict[str, Any] = {}
    if cfg.family == "vlm":
        P = cfg.vlm_patches
        toks = rng.integers(0, cfg.vocab_size, (B, S - P + 1), dtype=np.int32)
        out["tokens"] = jnp.asarray(toks[:, :-1])
        out["labels"] = jnp.asarray(toks[:, 1:])
        out["patch_embeds"] = jnp.asarray(
            rng.standard_normal((B, P, cfg.d_model)), dtype=dt)
        pos = np.broadcast_to(np.arange(S, dtype=np.int32), (3, B, S)).copy()
        out["positions"] = jnp.asarray(pos)
    else:
        toks = rng.integers(0, cfg.vocab_size, (B, S + 1), dtype=np.int32)
        out["tokens"] = jnp.asarray(toks[:, :-1])
        out["labels"] = jnp.asarray(toks[:, 1:])
    if cfg.is_encdec:
        out["frames"] = jnp.asarray(
            rng.standard_normal((B, cfg.encoder_seq, cfg.d_model)), dtype=dt)
    return out
