import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x input shape)
on the production meshes and extract roofline terms.

The two lines above MUST run before any jax import: jax locks the device
count at first init, and the dry-run needs 512 placeholder host devices to
build the 2x16x16 production mesh. (Smoke tests and benches see 1 device;
this env var is set here and ONLY here.)

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch mixtral-8x7b \
      --shape train_4k --mesh multi
  PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both \
      --out artifacts/dryrun
Artifacts: one JSON per cell with memory_analysis, cost_analysis, the
while-corrected HLO analysis (flops / HBM bytes / collective wire bytes),
and the derived three-term roofline (TPU v5e constants).
"""
import argparse
import dataclasses
import json
import time
import traceback

import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, get_config
from repro.core.quant import QuantConfig
from repro.launch import shapes as shp
from repro.launch.flops import count_params, model_flops
from repro.launch.hlo_analysis import analyze_hlo
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import (
    batch_shardings,
    cache_shardings,
    jit_prefill_step,
    jit_serve_step,
    jit_train_step,
    param_shapes,
    param_shardings,
    opt_state_shapes,
    opt_state_shardings,
)
from repro.optim import OptConfig

# ------------------------------------------------- TPU v5e roofline model
PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # bytes/s / chip
ICI_BW = 50e9                # bytes/s / link (per-chip injection, 1 link)


def roofline_terms(per_device: dict, n_chips: int) -> dict:
    """Three roofline terms in seconds (per-step), from per-device costs."""
    t_compute = per_device["flops_per_device"] / PEAK_FLOPS
    t_memory = per_device["hbm_bytes_per_device"] / HBM_BW
    t_coll = per_device["collective_total_bytes_per_device"] / ICI_BW
    dom = max((t_compute, "compute"), (t_memory, "memory"), (t_coll, "collective"))
    return {
        "compute_s": t_compute,
        "memory_s": t_memory,
        "collective_s": t_coll,
        "dominant": dom[1],
        "bound_s": dom[0],
    }


def _cell_step(cfg, shape, mesh, opt_cfg, rules, microbatches=1):
    """Return (jitted fn, example ShapeDtypeStruct args) for the cell."""
    if shape.kind == "train":
        step, _ = jit_train_step(cfg, opt_cfg, shape, mesh, rules_overrides=rules,
                                 microbatches=microbatches)
        args = (param_shapes(cfg), opt_state_shapes(cfg, opt_cfg),
                shp.batch_specs(cfg, shape))
        return step, args
    if shape.kind == "prefill":
        step, _ = jit_prefill_step(cfg, shape, mesh, rules_overrides=rules)
        args = (param_shapes(cfg), shp.batch_specs(cfg, shape))
        return step, args
    # decode
    step, _ = jit_serve_step(cfg, shape.batch, shape.seq, mesh, rules_overrides=rules)
    args = (param_shapes(cfg), shp.cache_specs(cfg, shape.batch, shape.seq),
            jax.ShapeDtypeStruct((shape.batch, 1), jnp.int32),
            jax.ShapeDtypeStruct((), jnp.int32))
    return step, args


def decode_rules(cfg, shape):
    """Per-cell sharding-rule overrides.

    decode: the KV cache shards its sequence dim over 'model'
    (flash-decoding style); batch=1 long-context also spans 'data'.

    Serving weight layout (Perf iteration C): FSDP-sharded weights must be
    all-gathered EVERY decode step (63 GB/step/device for llama4 -- the
    dominant collective in the baseline table). So at serve time:
      * MoE archs shard experts over 'data' (EP) x expert-ffn over 'model'
        (TP) -- dispatch all-to-alls move activations (KBs at decode), not
        weights;
      * dense archs replicate the 'fsdp' dims IF the model-sharded weights
        fit comfortably (<6 GB/device); giant dense models (405B) keep
        FSDP storage and pay the gather -- or use weight-only INT8
        (--quant int8) to halve it.
    """
    if shape.kind != "decode":
        return None
    rules = {"kvseq": "model", "kv": None}
    if shape.batch < 32:
        rules["kvseq"] = ("data", "model")
        rules["heads"] = "model"
    from repro.launch.flops import count_params
    if cfg.num_experts:
        rules.update({"experts": "data", "dff": "model", "fsdp": None,
                      "moebatch": None})
    else:
        per_dev_gb = count_params(cfg)["total"] * 2 / 16 / 1e9  # TP-sharded bf16
        if per_dev_gb < 6.0:
            rules["fsdp"] = None
    return rules


FSDP_ONLY_RULES = {
    "heads": None, "kv": None, "dff": None, "experts": None,
    "vocab": ("pod", "data", "model"),
    "fsdp": ("pod", "data", "model"),
}


def run_cell(arch: str, shape_name: str, multi_pod: bool, quant: QuantConfig,
             opt_cfg: OptConfig, verbose: bool = True, remat: str = None,
             seqpar: bool = False, rules_preset: str = None,
             rwkv_chunk: int = None, microbatches: int = 1,
             weight_quant: str = "none") -> dict:
    cfg = get_config(arch).with_quant(quant)
    if remat:
        cfg = dataclasses.replace(cfg, remat=remat)
    if rwkv_chunk:
        cfg = dataclasses.replace(cfg, rwkv_chunk=rwkv_chunk)
    if weight_quant != "none":
        cfg = dataclasses.replace(cfg, weight_quant=weight_quant)
    shape = shp.SHAPES[shape_name]
    skip = shp.shape_applicable(cfg, shape)
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    result = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
              "quant": dataclasses.asdict(quant)}
    if skip:
        result["status"] = "skipped"
        result["reason"] = skip
        return result

    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.devices.size
    rules = decode_rules(cfg, shape)
    if seqpar:
        rules = dict(rules or {}, seqpar="model")
        result["seqpar"] = True
    if rules_preset == "fsdp_only":
        rules = dict(rules or {}, **FSDP_ONLY_RULES)
        result["rules_preset"] = rules_preset
    if microbatches > 1:
        result["microbatches"] = microbatches
    t0 = time.time()
    try:
        step, args = _cell_step(cfg, shape, mesh, opt_cfg, rules, microbatches)
        lowered = step.lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
        mem = compiled.memory_analysis()
        ca = compiled.cost_analysis() or {}
        hlo = analyze_hlo(compiled.as_text())
        params = count_params(cfg)
        mf = model_flops(cfg, shape)
        rt = roofline_terms(hlo, n_chips)
        hlo_global_flops = hlo["flops_per_device"] * n_chips
        result.update({
            "status": "ok",
            "lower_s": round(t_lower, 1),
            "compile_s": round(t_compile, 1),
            "n_chips": n_chips,
            "memory": {
                "argument_bytes": mem.argument_size_in_bytes,
                "output_bytes": mem.output_size_in_bytes,
                "temp_bytes": mem.temp_size_in_bytes,
                "alias_bytes": mem.alias_size_in_bytes,
                "per_device_total_gb": round(
                    (mem.argument_size_in_bytes + mem.temp_size_in_bytes
                     - mem.alias_size_in_bytes) / 1e9, 3),
            },
            "xla_cost_analysis": {k: ca.get(k) for k in
                                  ("flops", "bytes accessed") if k in ca},
            "hlo_analysis": hlo,
            "params": params,
            "model_flops": mf,
            "useful_flops_ratio": mf / max(hlo_global_flops, 1.0),
            "roofline": rt,
        })
    except Exception as e:  # noqa: BLE001 -- a failing cell is a recorded bug
        result["status"] = "error"
        result["error"] = f"{type(e).__name__}: {e}"
        result["traceback"] = traceback.format_exc()[-2000:]
    if verbose:
        _print_cell(result)
    return result


def _print_cell(r: dict):
    hdr = f"[{r['mesh']}] {r['arch']} x {r['shape']}"
    if r["status"] == "skipped":
        print(f"{hdr}: SKIP ({r['reason']})")
    elif r["status"] == "error":
        print(f"{hdr}: ERROR {r['error']}")
    else:
        rt = r["roofline"]
        print(f"{hdr}: ok lower={r['lower_s']}s compile={r['compile_s']}s "
              f"mem/dev={r['memory']['per_device_total_gb']}GB "
              f"compute={rt['compute_s']*1e3:.2f}ms memory={rt['memory_s']*1e3:.2f}ms "
              f"coll={rt['collective_s']*1e3:.2f}ms dom={rt['dominant']} "
              f"useful={r['useful_flops_ratio']:.2f}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(shp.SHAPES) + [None])
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true", help="all assigned (arch, shape) cells")
    ap.add_argument("--quant", default="none",
                    choices=["none", "int8", "fp8_e4m3", "fp8_e5m2"])
    ap.add_argument("--rotate", default="none", choices=["none", "hadamard"])
    ap.add_argument("--opt-state", default="f32", choices=["f32", "int8"])
    ap.add_argument("--remat", default=None, choices=[None, "none", "dots", "full"])
    ap.add_argument("--seqpar", action="store_true",
                    help="sequence-shard the residual stream over the TP axis")
    ap.add_argument("--rules-preset", default=None, choices=[None, "fsdp_only"],
                    help="fsdp_only: no tensor parallelism -- params sharded "
                         "over every mesh axis (ZeRO-3), activations batch-"
                         "sharded; trades per-layer weight all-gathers for "
                         "the elimination of TP activation all-reduces")
    ap.add_argument("--rwkv-chunk", type=int, default=None)
    ap.add_argument("--microbatch", type=int, default=1,
                    help="gradient-accumulation microbatches per step")
    ap.add_argument("--weight-quant", default="none", choices=["none", "int8"],
                    help="weight-only int8 storage (serving)")
    ap.add_argument("--tag", default="", help="artifact filename suffix")
    ap.add_argument("--out", default=None, help="artifact directory (JSON per cell)")
    args = ap.parse_args()

    quant = QuantConfig(mode=args.quant, rotate=args.rotate,
                        kv_quant=args.quant != "none", backend="xla")
    opt_cfg = OptConfig(state_dtype=args.opt_state)

    archs = ARCH_IDS[:10] if args.all else [args.arch]
    shapes = list(shp.SHAPES) if (args.all or args.shape is None) else [args.shape]
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    results = []
    for arch in archs:
        for shape_name in shapes:
            for multi in meshes:
                r = run_cell(arch, shape_name, multi, quant, opt_cfg,
                             remat=args.remat, seqpar=args.seqpar,
                             rules_preset=args.rules_preset,
                             rwkv_chunk=args.rwkv_chunk,
                             microbatches=args.microbatch,
                             weight_quant=args.weight_quant)
                results.append(r)
                if args.out:
                    os.makedirs(args.out, exist_ok=True)
                    tag = f"{arch}__{shape_name}__{r['mesh']}"
                    if args.quant != "none" or args.rotate != "none":
                        tag += f"__{args.quant}_{args.rotate}"
                    if args.tag:
                        tag += f"__{args.tag}"
                    with open(os.path.join(args.out, tag + ".json"), "w") as f:
                        json.dump(r, f, indent=1)
    n_ok = sum(r["status"] == "ok" for r in results)
    n_skip = sum(r["status"] == "skipped" for r in results)
    n_err = sum(r["status"] == "error" for r in results)
    print(f"\ndry-run cells: {n_ok} ok, {n_skip} skipped, {n_err} errors")
    if n_err:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
