"""Continuous-batching serving launcher: admit and retire requests
mid-decode over pre-quantized QTensor weights (the production serving
loop from the ROADMAP; subsystem in ``repro.serving``).

    PYTHONPATH=src python -m repro.launch.serve_loop --arch llama3-8b \
        --scale 0.02 --slots 8 --max-len 192 --prefill-len 64 \
        --requests 32 --rate 0.5 --quant fp8_e4m3 --rotate hadamard

Serves a seeded Poisson arrival stream (mixed prompt/generation
lengths) and reports tokens/s, slot occupancy, p50/p99 per-token
latency, and the admission/retirement/stall counters. All jit compiles
are paid in a warm-up step before the first request, so the reported
latencies are steady-state.

``REPRO_ABFT=1`` serves checksum-VERIFIED steps (silent-data-corruption
detection; ``repro.verify``, DESIGN.md section 14); the run's health
counters -- guard/ABFT trips, degradations, SDC retirements -- print as
the structured ``health`` line of the summary.
"""
from __future__ import annotations

import argparse
import dataclasses

import jax

from repro.configs import get_config
from repro.core.quant import QuantConfig
from repro.launch.env import harden_host_env
from repro.launch.mesh import make_local_mesh
from repro.launch.steps import make_param_init, param_shardings
from repro.launch.train import scaled_config
from repro.serving import ServeEngine, synthetic_stream


def build_engine(args, cfg=None):
    """Config -> (engine, cfg): shared by the CLI and the bench suite."""
    if cfg is None:
        quant = QuantConfig(mode=args.quant, rotate=args.rotate,
                            backend=args.kernel,
                            kv_quant=args.quant != "none")
        cfg = scaled_config(get_config(args.arch),
                            args.scale).with_quant(quant)
        prequant = (args.quant != "none" if args.prequant is None
                    else args.prequant)
        if prequant:
            cfg = dataclasses.replace(cfg, weight_quant="int8")
    mesh = make_local_mesh(args.mp)
    with mesh:
        ps = param_shardings(cfg, mesh)
        params = jax.jit(make_param_init(cfg), out_shardings=ps)(
            jax.random.PRNGKey(args.seed))
    engine = ServeEngine(cfg, params, mesh, num_slots=args.slots,
                         max_len=args.max_len,
                         prefill_len=args.prefill_len,
                         eos_id=args.eos_id,
                         max_queue=getattr(args, "max_queue", None),
                         watchdog_ms=getattr(args, "watchdog_ms", None))
    return engine, cfg


def main(argv=None):
    harden_host_env()                 # flags only; re-exec is __main__'s
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3-8b")
    ap.add_argument("--scale", type=float, default=0.02)
    ap.add_argument("--slots", type=int, default=8)
    ap.add_argument("--max-len", type=int, default=192)
    ap.add_argument("--prefill-len", type=int, default=64)
    ap.add_argument("--requests", type=int, default=32)
    ap.add_argument("--rate", type=float, default=0.5,
                    help="mean arrivals per decode step (Poisson)")
    ap.add_argument("--prompt-min", type=int, default=8)
    ap.add_argument("--prompt-max", type=int, default=0,
                    help="0 = prefill-len")
    ap.add_argument("--gen-min", type=int, default=8)
    ap.add_argument("--gen-max", type=int, default=32)
    ap.add_argument("--quant", default="none",
                    choices=["none", "int8", "fp8_e4m3", "fp8_e5m2"])
    ap.add_argument("--rotate", default="none", choices=["none", "hadamard"])
    ap.add_argument("--kernel", default="xla", choices=["xla", "pallas"])
    ap.add_argument("--prequant", dest="prequant", action="store_true",
                    default=None,
                    help="pre-quantize weights ONCE at load into QTensors; "
                         "default: on whenever --quant is not 'none'")
    ap.add_argument("--no-prequant", dest="prequant", action="store_false")
    ap.add_argument("--eos-id", type=int, default=None)
    ap.add_argument("--mp", type=int, default=1)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--max-queue", type=int, default=None,
                    help="bounded admission queue: submits beyond this "
                         "depth are rejected immediately (backpressure)")
    ap.add_argument("--deadline-slack", type=float, default=None,
                    help="per-request TTL = arrival + max_new_tokens + "
                         "slack steps; expired queued requests are shed, "
                         "expired in-flight slots retired as timed_out")
    ap.add_argument("--watchdog-ms", type=float, default=None,
                    help="decode-step wall-clock bound; two consecutive "
                         "trips degrade the engine one ladder rung")
    args = ap.parse_args(argv)

    engine, cfg = build_engine(args)
    if cfg.weight_quant == "int8":
        print("weights pre-quantized once at load (QTensor tree; "
              f"consumer mode={cfg.quant.mode})")
    t_compile = engine.warmup()
    print(f"warmup: prefill/insert/decode compiled in {t_compile:.2f}s")

    stream = synthetic_stream(
        args.requests, vocab_size=cfg.vocab_size,
        prompt_len=(args.prompt_min, args.prompt_max or args.prefill_len),
        max_new_tokens=(args.gen_min, args.gen_max),
        rate=args.rate, seed=args.seed,
        deadline_slack=args.deadline_slack)
    engine.run(stream)
    s = engine.summary()
    print(f"served {s['requests']:.0f} requests / "
          f"{s['generated_tokens']:.0f} tokens in "
          f"{s['decode_steps']:.0f} decode steps "
          f"({s['idle_steps']:.0f} idle)")
    print(f"throughput: {s['tokens_per_s']:.1f} tok/s, "
          f"occupancy {s['occupancy'] * 100:.0f}%, per-token latency "
          f"p50 {s['p50_token_ms']:.1f} ms / p99 {s['p99_token_ms']:.1f} ms")
    print(f"scheduler: admitted={s.get('admitted', 0):.0f} "
          f"retired={s.get('retired', 0):.0f} "
          f"prefill_inserts={s.get('prefill_inserts', 0):.0f} "
          f"queue_full_stalls={s.get('queue_full_stalls', 0):.0f}")
    print(f"robustness: ok={s.get('status_ok', 0):.0f} "
          f"timed_out={s.get('status_timed_out', 0):.0f} "
          f"rejected={s.get('status_rejected', 0):.0f} "
          f"degraded={s.get('status_degraded', 0):.0f} "
          f"(shed={s.get('shed', 0):.0f} watchdog_trips="
          f"{s.get('watchdog_trips', 0):.0f} degrades="
          f"{s.get('degrades', 0):.0f} rung={s.get('rung', 0):.0f} "
          f"guards={'on' if s.get('guards_enabled') else 'off'})")
    h = s["health"]
    print("health: " + " ".join(f"{k}={v}" for k, v in h.items()))
    print(f"invariants: decode_executables={s['decode_executables']:.0f} "
          f"(constant across admissions/retirements), "
          f"quantize_weight_calls={s['quantize_weight_calls']:.0f} "
          f"during serve")
    return engine


if __name__ == "__main__":
    harden_host_env(reexec=True)
    main()
