"""Host-level launch hardening (ROADMAP; SNIPPETS.md 1-2,
HomebrewNLP-Jax / olmax ``run.sh``).

The related repos wrap their launchers in a shell script that preloads
tcmalloc and silences the TF/XLA host stack before python starts. We do
the equivalent in-process so ``python -m repro.launch.serve_loop`` needs
no wrapper:

  * env flags (``TF_CPP_MIN_LOG_LEVEL=4``,
    ``TCMALLOC_LARGE_ALLOC_REPORT_THRESHOLD``) are set if absent --
    these are read at backend init, which is lazy, so setting them at
    the top of ``main()`` is early enough;
  * ``REPRO_XLA_HOST_DEVICES=N`` (explicit opt-in, mirroring run.sh's
    ``--xla_force_host_platform_device_count``) is appended to
    ``XLA_FLAGS`` -- never set implicitly, because the fake-device count
    locks at first jax init and tests own that knob;
  * tcmalloc's ``LD_PRELOAD`` only takes effect at process start, so
    when a known tcmalloc exists and the process was not already
    preloaded, the CLI entry points re-exec themselves once
    (``reexec=True``; guarded by a marker env var). Library callers and
    tests use ``reexec=False``: flags only, never a re-exec.

Opt-out: ``REPRO_NO_ENV_HARDEN=1`` makes the whole thing a no-op.
"""
from __future__ import annotations

import os
import sys
from typing import Dict, Optional

_TCMALLOC_CANDIDATES = (
    "/usr/lib/x86_64-linux-gnu/libtcmalloc.so.4",
    "/usr/lib/x86_64-linux-gnu/libtcmalloc_minimal.so.4",
    "/usr/lib/libtcmalloc.so.4",
)
_MARKER = "REPRO_ENV_HARDENED"

_DEFAULT_FLAGS = {
    "TF_CPP_MIN_LOG_LEVEL": "4",                    # silence TF host stack
    "TCMALLOC_LARGE_ALLOC_REPORT_THRESHOLD": "60000000000",
}


def find_tcmalloc() -> Optional[str]:
    for path in _TCMALLOC_CANDIDATES:
        if os.path.exists(path):
            return path
    return None


def harden_host_env(*, reexec: bool = False,
                    environ: Optional[Dict[str, str]] = None) -> Dict[str, str]:
    """Apply the launch-hardening env. Returns {name: value} of every
    variable this call actually set (empty when opted out or nothing was
    missing). ``environ`` defaults to ``os.environ`` (injectable for
    tests). With ``reexec=True`` (CLI ``__main__`` blocks ONLY -- never
    from a library/test, it replaces the process image) the process
    re-execs once with tcmalloc preloaded when available."""
    env = os.environ if environ is None else environ
    if env.get("REPRO_NO_ENV_HARDEN") == "1":
        return {}
    applied: Dict[str, str] = {}
    for name, value in _DEFAULT_FLAGS.items():
        if name not in env:
            env[name] = value
            applied[name] = value
    ndev = env.get("REPRO_XLA_HOST_DEVICES")
    if ndev:
        flag = f"--xla_force_host_platform_device_count={int(ndev)}"
        flags = env.get("XLA_FLAGS", "")
        if "xla_force_host_platform_device_count" not in flags:
            env["XLA_FLAGS"] = f"{flags} {flag}".strip()
            applied["XLA_FLAGS"] = env["XLA_FLAGS"]

    tcmalloc = find_tcmalloc()
    if tcmalloc and tcmalloc not in env.get("LD_PRELOAD", "") \
            and _MARKER not in env:
        preload = " ".join(p for p in (env.get("LD_PRELOAD"), tcmalloc) if p)
        env["LD_PRELOAD"] = preload
        env[_MARKER] = "1"
        applied["LD_PRELOAD"] = preload
        if reexec and environ is None:
            # LD_PRELOAD is consumed by the dynamic loader at process
            # start; apply it by replacing this process once (marker
            # guards against loops)
            os.execv(sys.executable, [sys.executable] + sys.argv)
    return applied
