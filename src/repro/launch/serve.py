"""Batched serving launcher: prefill a prompt batch, then decode with the
(optionally FP8-quantized, Hadamard-rotated) KV cache -- the paper's
deployment scenario.

    PYTHONPATH=src python -m repro.launch.serve --arch llama3-8b \
        --scale 0.02 --batch 8 --prompt-len 128 --gen 32 \
        --quant fp8_e4m3 --rotate hadamard
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core.quant import QuantConfig
from repro.launch import shapes as shp
from repro.launch.env import harden_host_env
from repro.launch.mesh import make_local_mesh
from repro.launch.steps import (
    jit_prefill_step,
    jit_serve_step,
    make_param_init,
    param_shardings,
)
from repro.launch.train import scaled_config
from repro.models.lm import pad_kv_caches


def main(argv=None):
    harden_host_env()                 # flags only; re-exec is __main__'s
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3-8b")
    ap.add_argument("--scale", type=float, default=0.02)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=128)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--quant", default="none",
                    choices=["none", "int8", "fp8_e4m3", "fp8_e5m2"])
    ap.add_argument("--rotate", default="none", choices=["none", "hadamard"])
    ap.add_argument("--kernel", default="xla", choices=["xla", "pallas"])
    ap.add_argument("--prequant", dest="prequant", action="store_true",
                    default=None,
                    help="pre-quantize weights ONCE at load into QTensors "
                         "(storage int8; rotation-consumer weights in the "
                         "serving quant mode, consumed by quant_dot with "
                         "zero per-forward weight quantization). Default: "
                         "on whenever --quant is not 'none'.")
    ap.add_argument("--no-prequant", dest="prequant", action="store_false")
    ap.add_argument("--mp", type=int, default=1)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    quant = QuantConfig(mode=args.quant, rotate=args.rotate,
                        backend=args.kernel, kv_quant=args.quant != "none")
    cfg = scaled_config(get_config(args.arch), args.scale).with_quant(quant)
    prequant = args.quant != "none" if args.prequant is None else args.prequant
    if prequant:
        cfg = dataclasses.replace(cfg, weight_quant="int8")
    mesh = make_local_mesh(args.mp)
    max_len = args.prompt_len + args.gen

    with mesh:
        # param_shardings / make_param_init are QTensor-aware: with
        # --prequant the weights come out of this one jit already
        # quantized and never re-quantize per forward
        ps = param_shardings(cfg, mesh)
        params = jax.jit(make_param_init(cfg), out_shardings=ps)(
            jax.random.PRNGKey(args.seed))
    if prequant:
        print("weights pre-quantized once at load (QTensor tree; "
              f"consumer mode={args.quant})")

    shape = shp.ShapeSpec("serve", "prefill", args.prompt_len, args.batch)
    prefill, (ps_, bs) = jit_prefill_step(cfg, shape, mesh)
    serve, _ = jit_serve_step(cfg, args.batch, max_len, mesh, donate=True)

    batch = shp.make_batch(cfg, shape, seed=args.seed)
    t0 = time.time()
    logits, caches = prefill(params, batch)
    caches = pad_kv_caches(cfg, caches, max_len)
    tok = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)[:, None]
    t_prefill = time.time() - t0
    print(f"prefill: B={args.batch} S={args.prompt_len} in {t_prefill:.2f}s")

    out_tokens = [np.asarray(tok)]
    pos = args.prompt_len + (cfg.vlm_patches if cfg.family == "vlm" else 0)
    # the first serve() call pays the jit compile -- warm it up OUTSIDE
    # the timed loop (its token is still step 0's real output) so the
    # reported tok/s is steady-state decode, not compile-dominated
    t0 = time.time()
    steps = 0
    if args.gen > 1:
        tok, _, caches = serve(params, caches, tok,
                               jnp.asarray(pos, jnp.int32))
        out_tokens.append(np.asarray(tok))
        t_warm = time.time() - t0
        t0 = time.time()
        for i in range(1, args.gen - 1):
            tok, _, caches = serve(params, caches, tok,
                                   jnp.asarray(pos + i, jnp.int32))
            out_tokens.append(np.asarray(tok))
        steps = args.gen - 2
    dt = time.time() - t0
    toks = np.concatenate(out_tokens, axis=1)
    if steps > 0:
        print(f"decode: first step {t_warm:.2f}s (incl. jit compile); "
              f"{steps} steady-state steps in {dt:.2f}s "
              f"({steps * args.batch / max(dt, 1e-9):.1f} tok/s)")
    else:
        print(f"decode: {args.gen - 1} steps in {dt:.2f}s (0.0 tok/s "
              "steady-state; too few steps to separate compile)")
    print("sample token ids:", toks[0, :16].tolist())


if __name__ == "__main__":
    harden_host_env(reexec=True)
    main()
