"""Fault-tolerant training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch llama3-8b \
        --shape train_4k --steps 200 --scale 0.02 --quant fp8_e4m3 \
        --rotate hadamard --ckpt-dir /tmp/ckpt

Fault-tolerance story (designed for 1000+-node fleets, exercised here on
one host -- every mechanism is the single-controller JAX pattern):

  * checkpoint/restart: async sharded checkpoints every --ckpt-every
    steps; on launch the newest valid checkpoint is restored and the data
    pipeline (stateless, step-keyed) resumes bit-identically.
  * preemption: SIGTERM/SIGINT triggers a synchronous final checkpoint
    before exit (the TPU preemption-notice pattern).
  * node failure: on a real fleet the controller re-schedules and restarts
    from the last checkpoint -- identical code path to restart, which is
    what this launcher tests.
  * elastic rescaling: checkpoints are mesh-agnostic; --mp can differ
    between runs and restore re-shards (tests cover a mesh change).
  * straggler mitigation: per-step wall-clock is tracked; steps slower
    than --straggler-z sigma above the running mean are logged with the
    step's device set so a fleet scheduler can quarantine hosts. (With
    one host this is observability-only, as real detection needs per-host
    timing telemetry.)
"""
from __future__ import annotations

import argparse
import dataclasses
import signal
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import latest_step, restore_checkpoint, save_checkpoint
from repro.checkpoint.store import wait_for_writes
from repro.configs import get_config
from repro.core.quant import QuantConfig
from repro.data import SyntheticDataset
from repro.launch import shapes as shp
from repro.launch.mesh import make_local_mesh
from repro.launch.steps import jit_train_step, param_shardings
from repro.models import init_lm
from repro.optim import OptConfig, init_opt_state


def scaled_config(cfg, scale: float):
    """Shrink a config by ~scale in parameter count for examples/CI
    (keeps family structure; used for the ~100M-class training example)."""
    if scale >= 1.0:
        return cfg
    import math
    f = max(0.05, math.sqrt(scale))
    d = max(128, int(cfg.d_model * f) // 128 * 128)
    heads = max(2, int(cfg.num_heads * f))
    ratio = max(1, cfg.num_heads // cfg.num_kv_heads)
    groups = tuple((p, max(1, int(r * f))) for p, r in cfg.groups)
    enc = tuple((p, max(1, int(r * f))) for p, r in cfg.encoder_groups)
    return dataclasses.replace(
        cfg, d_model=d, num_heads=heads, num_kv_heads=max(1, heads // ratio),
        d_ff=max(256, int(cfg.d_ff * f) // 128 * 128),
        vocab_size=min(cfg.vocab_size, 32768),
        groups=groups, encoder_groups=enc, head_dim=None,
        num_experts=min(cfg.num_experts, 8) if cfg.num_experts else 0,
    )


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3-8b")
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq", type=int, default=None, help="override seq len")
    ap.add_argument("--batch", type=int, default=None, help="override global batch")
    ap.add_argument("--scale", type=float, default=1.0,
                    help="model scale factor (e.g. 0.02 for a ~100M llama)")
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--quant", default="none",
                    choices=["none", "int8", "fp8_e4m3", "fp8_e5m2"])
    ap.add_argument("--rotate", default="none", choices=["none", "hadamard"])
    ap.add_argument("--kernel", default="xla", choices=["xla", "pallas"],
                    help="online-rotation backend (pallas = hadacore)")
    ap.add_argument("--opt-state", default="f32", choices=["f32", "int8"])
    ap.add_argument("--grad-compression", default="none", choices=["none", "int8_ef"])
    ap.add_argument("--mp", type=int, default=1, help="model-parallel size")
    ap.add_argument("--microbatch", type=int, default=1,
                    help="gradient-accumulation microbatches per step")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--straggler-z", type=float, default=3.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    quant = QuantConfig(mode=args.quant, rotate=args.rotate,
                        backend=args.kernel, kv_quant=args.quant != "none")
    cfg = scaled_config(get_config(args.arch), args.scale).with_quant(quant)
    shape = shp.SHAPES[args.shape]
    if args.seq or args.batch:
        shape = dataclasses.replace(shape, seq=args.seq or shape.seq,
                                    batch=args.batch or shape.batch)
    opt_cfg = OptConfig(lr=args.lr, total_steps=args.steps,
                        warmup_steps=max(1, args.steps // 20),
                        state_dtype=args.opt_state,
                        grad_compression=args.grad_compression)

    mesh = make_local_mesh(args.mp)
    print(f"mesh {dict(zip(mesh.axis_names, mesh.devices.shape))} | "
          f"arch {cfg.name} scale {args.scale} | {shape}")

    step_fn, (ps, os_, bs) = jit_train_step(cfg, opt_cfg, shape, mesh,
                                            microbatches=args.microbatch)

    start_step = 0
    if args.ckpt_dir and (lk := latest_step(args.ckpt_dir)) is not None:
        print(f"restoring checkpoint step {lk}")
        import functools
        pshapes = jax.eval_shape(functools.partial(init_lm, cfg=cfg),
                                 jax.random.PRNGKey(args.seed))
        oshapes = jax.eval_shape(lambda: init_opt_state(pshapes, opt_cfg))
        params = restore_checkpoint(args.ckpt_dir, lk, pshapes, ps)
        opt_state = restore_checkpoint(args.ckpt_dir + "/opt", lk, oshapes, os_)
        start_step = lk
    else:
        with mesh:
            params = jax.jit(lambda k: init_lm(k, cfg), out_shardings=ps)(
                jax.random.PRNGKey(args.seed))
            opt_state = jax.jit(lambda: init_opt_state(params, opt_cfg),
                                out_shardings=os_)()
    n_params = sum(np.prod(x.shape) for x in jax.tree.leaves(params))
    print(f"params: {n_params/1e6:.1f}M")

    ds = SyntheticDataset(cfg, shape, seed=args.seed)
    stop = {"now": False}

    def handle(sig, frame):
        print(f"signal {sig}: checkpointing and exiting")
        stop["now"] = True

    signal.signal(signal.SIGTERM, handle)
    signal.signal(signal.SIGINT, handle)

    times = []
    t_train0 = time.time()
    for step in range(start_step, args.steps):
        batch = {k: jax.device_put(v, bs[k]) for k, v in ds.batch(step).items()}
        t0 = time.time()
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        metrics = jax.tree.map(float, jax.device_get(metrics))
        dt = time.time() - t0
        times.append(dt)
        if len(times) > 5:
            mu, sd = np.mean(times[1:]), np.std(times[1:]) + 1e-9
            if dt > mu + args.straggler_z * sd:
                print(f"[straggler] step {step}: {dt:.2f}s vs mean {mu:.2f}s "
                      f"(z={ (dt-mu)/sd:.1f}) -- flagging host set for quarantine")
        if step % args.log_every == 0 or step == args.steps - 1:
            print(f"step {step:5d} loss {metrics['loss']:.4f} "
                  f"gnorm {metrics['gnorm']:.3f} lr {metrics['lr']:.2e} {dt:.2f}s")
        if args.ckpt_dir and ((step + 1) % args.ckpt_every == 0 or stop["now"]
                              or step == args.steps - 1):
            save_checkpoint(args.ckpt_dir, step + 1, params)
            save_checkpoint(args.ckpt_dir + "/opt", step + 1, opt_state)
        if stop["now"]:
            wait_for_writes()
            sys.exit(0)
    wait_for_writes()
    total = time.time() - t_train0
    print(f"done: {args.steps - start_step} steps in {total:.1f}s "
          f"({np.mean(times[1:]) if len(times) > 1 else times[0]:.2f}s/step)")


if __name__ == "__main__":
    main()
