"""Analytic MODEL_FLOPS per (architecture, shape) -- the 'useful work'
denominator for the roofline table's MODEL_FLOPS / HLO_FLOPS ratio.

Conventions (PaLM-style MFU accounting):
  * matmul params count 2 FLOPs/param/token forward; train = 3x forward
    (activation grads + weight grads).
  * MoE counts only routed-active experts (6 * N_active * D).
  * attention scores/context add 4*B*S^2*H*hd per full-attention layer
    forward (full square -- XLA materializes the causal square too);
    sliding-window uses S*W.
  * decode counts one token against the full KV cache.
"""
from __future__ import annotations

from typing import Dict

import jax

from repro.launch import shapes as shp
from repro.models.config import ModelConfig


def _param_sizes(cfg: ModelConfig) -> Dict[str, float]:
    from repro.launch.steps import param_shapes
    tree = param_shapes(cfg)
    # jax.tree.flatten_with_path only exists in newer jax; tree_util's
    # spelling works across the pinned range
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    total = emb = experts = 0.0
    for path, leaf in flat:
        sz = 1.0
        for d in leaf.shape:
            sz *= d
        total += sz
        keys = [getattr(k, "key", getattr(k, "name", "")) for k in path]
        if "experts" in keys:
            experts += sz
        if keys and keys[-1] == "emb":
            emb += sz
    return {"total": total, "emb": emb, "experts": experts}


def count_params(cfg: ModelConfig) -> Dict[str, float]:
    s = _param_sizes(cfg)
    E, K = max(cfg.num_experts, 1), max(cfg.experts_per_token, 1)
    active = s["total"] - s["experts"] * (1.0 - K / E)
    return {"total": s["total"], "active": active, "emb": s["emb"],
            "experts": s["experts"]}


def _attn_layers(cfg: ModelConfig) -> int:
    n = 0
    for pattern, reps in tuple(cfg.groups) + tuple(cfg.encoder_groups):
        n += sum(1 for k in pattern if k in ("attn", "moe", "xattn", "enc_attn")) * reps
    return n


def _matmul_params(cfg: ModelConfig, active: bool = True) -> float:
    c = count_params(cfg)
    n = c["active"] if active else c["total"]
    n -= c["emb"]                     # token gather is not a matmul
    if cfg.tie_embeddings:
        n += c["emb"]                 # ...but the tied unembed matmul is
    return n


def model_flops(cfg: ModelConfig, shape: shp.ShapeSpec) -> float:
    B, S = shape.batch, shape.seq
    H, hd = cfg.num_heads, cfg.head_dim
    La = _attn_layers(cfg)
    n_mm = _matmul_params(cfg, active=True)

    if shape.kind in ("train", "prefill"):
        tokens = B * S
        fwd = 2.0 * n_mm * tokens
        eff_kv = min(S, cfg.sliding_window) if cfg.sliding_window else S
        fwd += 4.0 * B * S * eff_kv * H * hd * La
        if cfg.is_encdec:
            fwd += 4.0 * B * S * cfg.encoder_seq * H * hd * sum(
                1 for p, r in cfg.groups for k in p if k == "xattn") * 1.0
        return fwd * (3.0 if shape.kind == "train" else 1.0)

    # decode: one token, full cache
    eff_kv = min(S, cfg.sliding_window) if cfg.sliding_window else S
    fwd = 2.0 * n_mm * B
    fwd += 4.0 * B * eff_kv * H * hd * La
    return fwd
