"""While-aware post-optimization HLO analysis for roofline terms.

``compiled.cost_analysis()`` on the host platform reports the partitioned
module's FLOPs with every while (scan) body counted ONCE and gives no
collective breakdown. This module parses ``compiled.as_text()`` into
computations, resolves operand shapes, multiplies while bodies by their
trip counts (recovered from the loop-condition constants), and produces:

  * flops          -- dot/conv FLOPs per device (trip-corrected)
  * hbm_bytes      -- sum of operand+result bytes of top-level ops
                      (post-fusion: each op reads/writes HBM once -- the
                      standard HLO traffic model), trip-corrected
  * collectives    -- per-kind op counts and wire bytes per device using
                      ring cost models:
                        all-reduce       2 * size * (n-1)/n
                        all-gather       out_size * (n-1)/n
                        reduce-scatter   in_size * (n-1)/n
                        all-to-all       size * (n-1)/n
                        collective-permute  size
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "f64": 8, "s64": 8, "u64": 8, "c64": 8, "c128": 16,
    "f32": 4, "s32": 4, "u32": 4,
    "bf16": 2, "f16": 2, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1, "f8e5m2fnuz": 1, "f8e4m3fnuz": 1,
    "s4": 0.5, "u4": 0.5,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")


def _shape_bytes(shape_str: str) -> float:
    """'f32[128,512]' or tuple '(s32[], bf16[1,2])' -> total bytes."""
    total = 0.0
    for m in re.finditer(r"([a-z]+[0-9]*[a-z0-9]*)\[([\d,]*)\]", shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_dims(shape_str: str) -> Tuple[List[int], str]:
    m = re.search(r"([a-z]+[0-9]*[a-z0-9]*)\[([\d,]*)\]", shape_str)
    if not m:
        return [], "f32"
    dims = [int(d) for d in m.group(2).split(",")] if m.group(2) else []
    return dims, m.group(1)


@dataclasses.dataclass
class Instr:
    name: str
    shape_str: str
    opcode: str
    operands: List[str]
    attrs: str
    line: str


@dataclasses.dataclass
class Computation:
    name: str
    params: Dict[str, str]           # param name -> shape str
    instrs: List[Instr]
    by_name: Dict[str, Instr]


_COMP_RE = re.compile(r"^(ENTRY\s+)?%?([\w\.\-]+)\s*\((.*)\)\s*->\s*(.+)\s*\{\s*$")
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*((?:\([^)]*\))|(?:[a-z][a-z0-9]*\[[\d,]*\]\S*))\s+"
    r"([\w\-]+)\((.*)$")


def _split_operands(argstr: str) -> List[str]:
    """Names of %operands up to the closing paren of the call."""
    depth = 0
    out = []
    cur = []
    for ch in argstr:
        if ch == "(":
            depth += 1
        elif ch == ")":
            if depth == 0:
                break
            depth -= 1
        cur.append(ch)
    body = "".join(cur)
    return re.findall(r"%([\w\.\-]+)", body)


def parse_hlo(text: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    entry_name = None
    for line in text.splitlines():
        m = _COMP_RE.match(line)
        if m and ("->" in line):
            params = {}
            for pm in re.finditer(r"([\w\.\-]+):\s*((?:\([^)]*\))|(?:[a-z][a-z0-9]*\[[\d,]*\]))",
                                  m.group(3)):
                params[pm.group(1)] = pm.group(2)
            cur = Computation(m.group(2), params, [], {})
            comps[cur.name] = cur
            if m.group(1):
                entry_name = cur.name
            continue
        if cur is None:
            continue
        if line.strip() == "}":
            cur = None
            continue
        im = _INSTR_RE.match(line)
        if im:
            ins = Instr(im.group(1), im.group(2), im.group(3),
                        _split_operands(im.group(4)), im.group(4), line)
            cur.instrs.append(ins)
            cur.by_name[ins.name] = ins
    if entry_name:
        comps["__entry__"] = comps[entry_name]
    return comps


def _operand_shape(comp: Computation, name: str) -> str:
    if name in comp.by_name:
        return comp.by_name[name].shape_str
    if name in comp.params:
        return comp.params[name]
    return ""


def _dot_flops(comp: Computation, ins: Instr) -> float:
    out_dims, _ = _shape_dims(ins.shape_str)
    lhs_shape = _operand_shape(comp, ins.operands[0]) if ins.operands else ""
    lhs_dims, _ = _shape_dims(lhs_shape)
    cm = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", ins.attrs)
    contract = 1
    if cm and cm.group(1):
        for d in cm.group(1).split(","):
            contract *= lhs_dims[int(d)] if int(d) < len(lhs_dims) else 1
    n_out = 1
    for d in out_dims:
        n_out *= d
    return 2.0 * n_out * contract


def _trip_count_from_config(ins: Instr) -> Optional[int]:
    """XLA records known trip counts: backend_config={"known_trip_count":
    {"n":"6"}, ...} on the while instruction itself."""
    m = re.search(r'known_trip_count[^0-9]*(\d+)', ins.line)
    return int(m.group(1)) if m else None


def _trip_count(comps, cond_name: str) -> int:
    cond = comps.get(cond_name)
    if cond is None:
        return 1
    best = 1
    for ins in cond.instrs:
        m = re.search(r"constant\((\d+)\)", ins.line)
        if m:
            best = max(best, int(m.group(1)))
        # constants may live in a fused compare computation
        cm = re.search(r"calls=%([\w\.\-]+)", ins.attrs)
        if cm and cm.group(1) in comps:
            for ins2 in comps[cm.group(1)].instrs:
                m2 = re.search(r"constant\((\d+)\)", ins2.line)
                if m2:
                    best = max(best, int(m2.group(1)))
    return best


_SKIP_BYTES_OPS = {"parameter", "constant", "get-tuple-element", "tuple",
                   "bitcast", "after-all", "partition-id", "replica-id",
                   "while", "conditional", "call"}


def _fusion_window_bytes(comp: Computation):
    """For each fusion parameter consumed ONLY by dynamic-slice /
    dynamic-update-slice ops inside the fused computation, the effective
    HBM bytes are the accessed window(s), not the whole buffer."""
    out = {}
    param_names = list(comp.params.keys())
    for idx, pname in enumerate(param_names):
        uses = [i for i in comp.instrs if pname in i.operands]
        if not uses:
            continue
        win = 0.0
        ok = True
        for u in uses:
            if u.opcode == "dynamic-slice" and u.operands and u.operands[0] == pname:
                win += _shape_bytes(u.shape_str)
            elif (u.opcode == "dynamic-update-slice" and u.operands
                  and u.operands[0] == pname):
                if len(u.operands) > 1:
                    win += _shape_bytes(_operand_shape(comp, u.operands[1]))
            else:
                ok = False
                break
        if ok:
            out[idx] = win
    return out


def _ring_factor(kind: str, nrep: int) -> float:
    return (nrep - 1) / max(nrep, 1)


def _replica_group_size(attrs: str) -> int:
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]", attrs)
    if m:
        return int(m.group(2))
    m = re.search(r"replica_groups=\{\{([\d,]+)\}", attrs)
    if m:
        return len(m.group(1).split(","))
    return 2


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    hbm_bytes: float = 0.0
    coll_bytes: Dict[str, float] = dataclasses.field(
        default_factory=lambda: defaultdict(float))
    coll_count: Dict[str, int] = dataclasses.field(
        default_factory=lambda: defaultdict(int))

    def add(self, other: "Cost", mult: float = 1.0):
        self.flops += other.flops * mult
        self.hbm_bytes += other.hbm_bytes * mult
        for k, v in other.coll_bytes.items():
            self.coll_bytes[k] += v * mult
        for k, v in other.coll_count.items():
            self.coll_count[k] += int(v * mult)


def _comp_cost(comps: Dict[str, Computation], name: str,
               memo: Dict[str, Cost]) -> Cost:
    if name in memo:
        return memo[name]
    comp = comps[name]
    cost = Cost()
    memo[name] = cost  # placeholder against cycles
    for ins in comp.instrs:
        op = ins.opcode
        if op == "while":
            bm = re.search(r"body=%([\w\.\-]+)", ins.attrs)
            cm = re.search(r"condition=%([\w\.\-]+)", ins.attrs)
            trips = _trip_count_from_config(ins)
            if trips is None:
                trips = _trip_count(comps, cm.group(1)) if cm else 1
            if bm and bm.group(1) in comps:
                cost.add(_comp_cost(comps, bm.group(1), memo), trips)
            continue
        if op in ("call", "conditional"):
            for target in re.findall(r"(?:to_apply|calls|branch_computations)=.*?%([\w\.\-]+)", ins.attrs):
                if target in comps:
                    cost.add(_comp_cost(comps, target, memo))
            continue
        if op == "fusion":
            cm = re.search(r"calls=%([\w\.\-]+)", ins.attrs)
            inner_comp = comps.get(cm.group(1)) if cm else None
            if inner_comp is not None:
                inner = _comp_cost(comps, inner_comp.name, memo)
                cost.flops += inner.flops        # fused dots still compute
            # bytes: fusion reads operands once, writes result once --
            # except operands the fused computation only dynamic-slices,
            # which read their window, not the full buffer
            b = _shape_bytes(ins.shape_str)
            window = _fusion_window_bytes(inner_comp) if inner_comp else {}
            for oi, o in enumerate(ins.operands):
                b += window.get(oi, _shape_bytes(_operand_shape(comp, o)))
            cost.hbm_bytes += b
            continue
        if op == "dynamic-slice":
            # reads only the sliced window (+indices), not the operand
            cost.hbm_bytes += 2 * _shape_bytes(ins.shape_str)
            continue
        if op == "dynamic-update-slice":
            # writes only the updated window; the rest is aliased in place
            upd = (_shape_bytes(_operand_shape(comp, ins.operands[1]))
                   if len(ins.operands) > 1 else 0.0)
            cost.hbm_bytes += 2 * upd
            continue
        if op in ("dot", "convolution"):
            cost.flops += _dot_flops(comp, ins)
        kind = next((c for c in _COLLECTIVES if op.startswith(c)), None)
        if kind:
            out_b = _shape_bytes(ins.shape_str)
            in_b = sum(_shape_bytes(_operand_shape(comp, o)) for o in ins.operands)
            n = _replica_group_size(ins.attrs)
            if kind == "all-reduce":
                wire = 2 * out_b * _ring_factor(kind, n)
            elif kind == "all-gather":
                wire = out_b * _ring_factor(kind, n)
            elif kind == "reduce-scatter":
                wire = in_b * _ring_factor(kind, n)
            elif kind == "all-to-all":
                wire = out_b * _ring_factor(kind, n)
            else:  # collective-permute
                wire = out_b
            cost.coll_bytes[kind] += wire
            cost.coll_count[kind] += 1
            cost.hbm_bytes += in_b + out_b
            continue
        if op in _SKIP_BYTES_OPS:
            continue
        b = _shape_bytes(ins.shape_str)
        for o in ins.operands:
            b += _shape_bytes(_operand_shape(comp, o))
        cost.hbm_bytes += b
    memo[name] = cost
    return cost


_ALIAS_PAIR_RE = re.compile(
    r"\{\s*([\d,\s]*)\}\s*:\s*\(\s*(\d+)\s*,\s*\{\s*([\d,\s]*)\}")


def parse_input_output_aliases(text: str) -> List[Tuple[Tuple[int, ...],
                                                        int,
                                                        Tuple[int, ...]]]:
    """The donation aliasing pairs from the HloModule header:
    ``input_output_alias={ {0}: (1, {0}, may-alias), ... }`` ->
    ``[(out_index, param_number, param_index), ...]``.

    An executable compiled with ``donate_argnums`` that actually reuses
    the donated buffers carries one pair per donated leaf; an empty list
    means the donation was dropped (every step would allocate fresh
    output buffers). Note XLA prunes unused parameters, so
    ``param_number`` need not equal the Python-level argnum."""
    start = text.find("input_output_alias={")
    if start < 0:
        return []
    # the attribute value nests braces ({0}: (...), ...) -- scan to the
    # balancing close instead of regexing for the first '}'
    i = text.index("{", start)
    depth = 0
    for j in range(i, len(text)):
        if text[j] == "{":
            depth += 1
        elif text[j] == "}":
            depth -= 1
            if depth == 0:
                break
    body = text[i + 1:j]

    def _idx(s: str) -> Tuple[int, ...]:
        return tuple(int(d) for d in s.replace(" ", "").split(",") if d)

    return [(_idx(om), int(pn), _idx(pi))
            for om, pn, pi in _ALIAS_PAIR_RE.findall(body)]


def analyze_hlo(text: str) -> Dict[str, object]:
    """Per-DEVICE trip-corrected flops / hbm bytes / collective wire bytes."""
    comps = parse_hlo(text)
    if "__entry__" not in comps:
        raise ValueError("no ENTRY computation found")
    memo: Dict[str, Cost] = {}
    c = _comp_cost(comps, comps["__entry__"].name, memo)
    return {
        "flops_per_device": c.flops,
        "hbm_bytes_per_device": c.hbm_bytes,
        "collective_wire_bytes_per_device": dict(c.coll_bytes),
        "collective_counts": dict(c.coll_count),
        "collective_total_bytes_per_device": float(sum(c.coll_bytes.values())),
    }
