"""Algorithm-based fault tolerance (ABFT) runtime verification.

Opt-in (``REPRO_ABFT=1`` / ``QuantConfig.abft``) checksum verification of
the fused rotate->quantize->GEMM path, the pure Hadamard rotation sites,
and the serving KV cache -- silent-data-corruption detection for the
faults the PR 8 numeric guards cannot see (finite-but-wrong values from
weight bit-flips, KV row corruption, mis-DMA'd streamed tiles).
DESIGN.md section 14."""
from repro.verify.abft import (  # noqa: F401
    ABFT_ENV,
    abft_enabled,
    abft_tolerance,
    kv_check,
    kv_roll,
    kv_row_delta,
    kv_slot_reset,
    kv_sums_ok,
    kv_tree_sums,
    params_ok,
    residual_ok,
    with_checks,
)
