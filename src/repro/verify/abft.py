"""ABFT primitives: checksum math, tolerances, and KV-cache integrity.

The quantized GEMM ``y = (s * q) @ (W_q * s_w)`` is linear in the
weight, so a single precomputed f32 vector -- the column checksum
``check[k] = sum_d W_q[k, d] * s_w[d]`` stored on the :class:`QTensor`
at ``quantize_weight`` time -- verifies every output row:

    sum_d y[i, d]  ==  s[i] * sum_k q[i, k] * check[k]

exactly in real arithmetic (checksums commute with contraction; Navarro
et al., arXiv:2001.05585; Ootomo & Yokota, arXiv:2203.03341). The fused
Pallas kernels accumulate the left side tile-by-tile alongside the real
output and emit the per-row RESIDUAL (left minus right) as a second
kernel output; the unfused XLA path recomputes ``check`` from the live
weight with the identical op order and contracts the difference. Either
way a healthy run's residual is float-rounding small, while a corrupted
weight element, a mis-DMA'd tile, or a broken accumulation shifts it by
the (large) corruption magnitude times the activation -- every affected
output row trips, and ONLY affected rows trip.

Tolerance: both residual sides are f32 summation chains of ~(n + d)
terms over the same values, so their difference is bounded by
C * eps_f32 * sqrt(n + d) relative to the row's absolute output mass
(sqrt because rounding errors of random-signed terms cancel; C = 4 is
calibrated with ~500x headroom over the measured healthy worst case --
see ``abft_tolerance``). The bound is mode-independent -- int8 tiles
accumulate exactly in int32 and the fp8 grids embed exactly in bf16, so
quantization contributes no error to the COMPARISON (both sides see the
same quantized values); it is property-tested across 3 modes x
f32/bf16/fp16 x all schedules in tests/test_abft.py.

KV-cache integrity is a running per-slot conservation law: the engine
carries ``[sum, abs_sum]`` over each slot's valid rows and the decode
step recomputes it from the cache it was handed -- any off-path mutation
of already-written rows (bit flips, buffer clobbers) breaks the match.
Non-finite mismatches are deliberately NOT flagged here: NaN/Inf already
announce themselves through the logits guard seam (``core.guards``), and
keeping the channels separate is what lets the engine attribute a trip
to silent corruption vs. numeric overflow (DESIGN.md section 14).
"""
from __future__ import annotations

import dataclasses
import math
import os
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import wquant

__all__ = [
    "ABFT_ENV",
    "abft_enabled",
    "abft_tolerance",
    "residual_ok",
    "with_checks",
    "params_ok",
    "kv_tree_sums",
    "kv_row_delta",
    "kv_sums_ok",
    "kv_slot_reset",
    "kv_check",
    "kv_roll",
]

ABFT_ENV = "REPRO_ABFT"


def abft_enabled() -> bool:
    return os.environ.get(ABFT_ENV, "").lower() in ("1", "true", "on")


# ------------------------------------------------------------ GEMM residual
def abft_tolerance(n: int, d: int) -> Tuple[float, float]:
    """(rtol, atol) for the quant_dot checksum residual at contraction
    width n and out-channel width d. rtol scales the row's absolute
    output mass; atol only breaks ties for exactly-zero rows.

    The constant 4.0 is calibrated, not worst-case: across 3 modes x 3
    io dtypes x 3 shapes x 3 schedules the measured healthy residual
    never exceeds 0.008 * eps * sqrt(n + d) relative to the row mass
    (the int8 path accumulates exactly in int32 and the fp8 grids embed
    exactly in bf16, so only the f32 scale-multiply + row-sum chains
    disagree between the residual's two sides) -- 4.0 is ~500x that.
    Keeping it tight is what buys detection: a single LSB flip of one
    int8 weight element shifts an affected row's residual by
    |q_act| * scale -- typically >10x this threshold even at delta=1."""
    eps = float(jnp.finfo(jnp.float32).eps)
    return 4.0 * eps * math.sqrt(n + d), 1e-20


def residual_ok(y: jnp.ndarray, resid: jnp.ndarray, *,
                n: int, d: int) -> jnp.ndarray:
    """Per-row verdict: y (..., d) kernel output, resid (..., 1) f32
    checksum residual -> bool (..., 1), True = row verified."""
    rtol, atol = abft_tolerance(n, d)
    scale = jnp.sum(jnp.abs(y.astype(jnp.float32)), axis=-1, keepdims=True)
    return jnp.abs(resid) <= rtol * scale + atol


# ------------------------------------------------------------ weight checks
def with_checks(params):
    """Attach the ABFT column checksum to every QTensor leaf that lacks
    one (leaves that already carry a check are kept verbatim). Pure
    tree_map -- jit it once at engine init."""
    def fix(t):
        if wquant.is_qleaf(t) and t.check is None:
            return dataclasses.replace(
                t, check=wquant.weight_checksum(t.q, t.scale))
        return t

    return jax.tree.map(fix, params, is_leaf=wquant.is_qleaf)


def params_ok(params, *, rtol: float = 1e-5) -> bool:
    """On-demand host diagnostic: recompute every stored checksum from
    the LIVE weight (same op order as ``wquant.weight_checksum``) and
    compare. False means the weights themselves are corrupt -- the
    engine uses this to attribute a logits-level trip to silent weight
    corruption vs. a transient numeric event. Zero steady-state cost:
    only called after a trip."""
    oks = []

    def one(t):
        if wquant.is_qleaf(t) and t.check is not None:
            rec = wquant.weight_checksum(t.q, t.scale)
            bound = rtol * jnp.max(jnp.abs(t.check)) + 1e-12
            oks.append(jnp.max(jnp.abs(rec - t.check)) <= bound)
        return t

    jax.tree.map(one, params, is_leaf=wquant.is_qleaf)
    if not oks:
        return True
    return bool(np.all(np.asarray(jax.device_get(oks))))


# ---------------------------------------------------------- KV conservation
def _leaf_sums(leaf, keep) -> jnp.ndarray:
    """[sum, abs_sum] per slot of a (repeats, slots, T, KH, hd) cache
    leaf under a (slots, T) bool row mask, f32 -> (slots, 2)."""
    m = keep[None, :, :, None, None]
    v = jnp.where(m, leaf.astype(jnp.float32), 0.0)
    s = jnp.sum(v, axis=(0, 2, 3, 4))
    a = jnp.sum(jnp.abs(v), axis=(0, 2, 3, 4))
    return jnp.stack([s, a], axis=-1)


def kv_tree_sums(caches, pos: jnp.ndarray) -> jnp.ndarray:
    """Per-slot [sum, abs_sum] over the valid rows [0, pos[slot]) of
    every cache leaf -> (slots, 2) f32. Rows at/after pos (prefill
    padding, retired-slot leftovers) are masked with ``where`` so stale
    garbage -- even non-finite garbage -- cannot leak into the sums."""
    pos = pos.astype(jnp.int32)
    total = None
    for leaf in jax.tree.leaves(caches):
        t = leaf.shape[2]
        keep = jnp.arange(t, dtype=jnp.int32)[None, :] < pos[:, None]
        cur = _leaf_sums(leaf, keep)
        total = cur if total is None else total + cur
    return total


def kv_row_delta(caches, pos: jnp.ndarray) -> jnp.ndarray:
    """Per-slot [sum, abs_sum] of the single row at index pos[slot] of
    every cache leaf -> (slots, 2) f32. This is the row the decode step
    just wrote; adding it to the pre-step sums rolls the conservation
    state forward without a second full reduction."""
    pos = pos.astype(jnp.int32)
    total = None
    for leaf in jax.tree.leaves(caches):
        t = leaf.shape[2]
        idx = jnp.clip(pos, 0, t - 1)[None, :, None, None, None]
        idx = jnp.broadcast_to(idx, leaf.shape[:2] + (1,) + leaf.shape[3:])
        row = jnp.take_along_axis(leaf, idx, axis=2).astype(jnp.float32)
        s = jnp.sum(row, axis=(0, 2, 3, 4))
        a = jnp.sum(jnp.abs(row), axis=(0, 2, 3, 4))
        cur = jnp.stack([s, a], axis=-1)
        total = cur if total is None else total + cur
    return total


def kv_sums_ok(cur: jnp.ndarray, expected: jnp.ndarray, *,
               rtol: float = 1e-4, atol: float = 1e-3) -> jnp.ndarray:
    """Per-slot verdict (slots,) bool: does the recomputed conservation
    state match the carried one? Trips ONLY on finite mismatches --
    NaN/Inf deltas are left to the logits guard channel so the engine
    can tell silent corruption from numeric blow-up. rtol covers the
    reduction-order nondeterminism between the fused recompute and the
    sum+delta rollforward."""
    mass = jnp.maximum(cur[:, 1], expected[:, 1])
    bad = None
    for c in (0, 1):
        diff = cur[:, c] - expected[:, c]
        b = jnp.isfinite(diff) & (jnp.abs(diff) > rtol * mass + atol)
        bad = b if bad is None else bad | b
    return ~bad


def kv_check(caches, pos: jnp.ndarray,
             kv_sums: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Pre-decode integrity gate: recompute the conservation state from
    the caches the step is about to consume and compare it against the
    carried one. Returns (ok (slots,) bool, cur (slots, 2) f32); ``cur``
    feeds :func:`kv_roll` after the step so the full reduction runs once.

    Deliberately a SEPARATE executable from the decode step: the decode
    donates its cache operands for in-place reuse, and folding a
    whole-cache read into that same program forces XLA to defensively
    copy the donated buffers (and materializes cache-shaped f32
    intermediates inside the serving hot path) -- both outlawed by the
    serving lint contracts. Dispatched back-to-back from the engine, the
    read completes before the donated step consumes the buffers."""
    cur = kv_tree_sums(caches, pos)
    return kv_sums_ok(cur, kv_sums), cur


def kv_roll(caches, pos: jnp.ndarray, cur: jnp.ndarray) -> jnp.ndarray:
    """Post-decode rollforward: the step wrote exactly one new KV row
    per slot (at the PRE-step ``pos``); fold it into the recomputed
    pre-step sums to get the state the next step must reproduce."""
    return cur + kv_row_delta(caches, pos)


def kv_slot_reset(kv_sums: jnp.ndarray, caches, slot: jnp.ndarray,
                  upto: jnp.ndarray) -> jnp.ndarray:
    """Rebase one slot's conservation state from the cache itself over
    rows [0, upto) -- called after prefill-insert, which rewrites the
    slot's block wholesale. Prefill PADDING rows (>= the real prompt
    length) stay excluded: they hold garbage the causal mask never
    attends."""
    slots = kv_sums.shape[0]
    pos = jnp.where(jnp.arange(slots, dtype=jnp.int32) == slot,
                    jnp.asarray(upto, jnp.int32), 0)
    fresh = kv_tree_sums(caches, pos)
    return kv_sums.at[slot].set(fresh[slot])
