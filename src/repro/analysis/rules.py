"""The kernel contract rules.

Each rule is a class decorated with :func:`register_rule` (the same
instantiate-into-a-dict idiom as ``kernels/registry.py``'s backend
registry): ``applies(site)`` keys off what the :class:`~.sites.Site`
carries, ``check(site)`` returns the violations. :func:`run_rules`
drives every registered rule over every site into a
:class:`~.report.Report`.

What each rule proves:

* ``fusion-contract``    -- a bound kernel site is ONE ``pallas_call``
  with no contraction escaping it, and serving traces never call
  ``quantize_weight``.
* ``rotate-once-contract`` -- the transform's pass matmuls live only
  under the ``j == 0`` cond; exactly one top-level contraction.
* ``dma-safety``         -- the streamed ring warms up before it waits,
  every start is guarded (so the ring drains at region end), and no
  start is left unmatched by a wait.
* ``dtype-flow``         -- 16-bit pass compute never silently upcasts
  to f32, and decode never materializes a cache-shaped dequantized
  (wider-than-io) tensor.
* ``vmem-budget``        -- the kernel's VMEM residents, re-charged
  from the jaxpr's memory spaces, fit the planner's decision and the
  device limit.
* ``donation``           -- donated serving executables actually alias
  their cache buffers in the compiled HLO (no defensive copy).
* ``deprecated-shim-in-trace`` -- no site traces through the
  deprecated ``kernels.ops`` / ``kernels.fused_quant`` shims.
"""
from __future__ import annotations

from typing import Dict, Iterable, List, Optional

from repro.analysis import jaxpr_utils as ju
from repro.analysis.report import Report, Violation
from repro.analysis.sites import Site

__all__ = ["Rule", "register_rule", "all_rules", "run_rules",
           "DEVICE_VMEM_BYTES"]

# per-core VMEM capacity the static re-charge is held under (16 MiB --
# the common floor across TPU generations; the planner's own working
# budget in kernels/registry.py is half this)
DEVICE_VMEM_BYTES = 16 * 1024 * 1024

_RULES: Dict[str, "Rule"] = {}


def register_rule(cls):
    """Class decorator: instantiate and register (mirrors
    ``kernels.registry.register_backend``)."""
    inst = cls()
    _RULES[inst.name] = inst
    return cls


def all_rules() -> Dict[str, "Rule"]:
    return dict(_RULES)


class Rule:
    """One invariant. ``applies`` gates on the facts the site carries;
    ``check`` returns Violations (empty == contract holds)."""

    name = "unnamed"

    def applies(self, site: Site) -> bool:
        raise NotImplementedError

    def check(self, site: Site) -> List[Violation]:
        raise NotImplementedError

    def _v(self, site: Site, msg: str) -> Violation:
        return Violation(rule=self.name, site=site.name, message=msg)


def run_rules(sites: Iterable[Site],
              rules: Optional[Iterable[str]] = None) -> Report:
    """Every (applicable) registered rule over every site."""
    picked = ([_RULES[r] for r in rules] if rules is not None
              else list(_RULES.values()))
    rep = Report()
    for site in sites:
        for rule in picked:
            if not rule.applies(site):
                continue
            rep.checked.append((site.name, rule.name))
            rep.violations.extend(rule.check(site))
    return rep


# --------------------------------------------------------------- fusion
@register_rule
class FusionContract(Rule):
    """Bound kernel/model sites lower to exactly ONE pallas_call with
    zero contraction work escaping it; no trace (serving included)
    quantizes weights on the fly."""

    name = "fusion-contract"

    def applies(self, site: Site) -> bool:
        return site.jaxpr is not None

    def check(self, site: Site) -> List[Violation]:
        out = []
        if site.expect_fused and site.kind in ("kernel", "model"):
            n = ju.count_pallas_calls(site.jaxpr)
            if n != 1:
                out.append(self._v(
                    site, f"expected exactly 1 pallas_call, traced {n} "
                    "(rotate/quantize/GEMM split across kernels or fell "
                    "back to the unfused path)"))
        if site.kind == "kernel":
            esc = ju.dots_outside_pallas(site.jaxpr)
            if esc:
                out.append(self._v(
                    site, f"{esc} dot_general(s) outside the pallas_call "
                    "-- contraction work escaped the fused kernel"))
        if site.kind == "serving" and site.qw_calls:
            out.append(self._v(
                site, f"{site.qw_calls} quantize_weight call(s) in a "
                "serving trace -- serving weights must be pre-quantized "
                "QTensors, never re-quantized per step"))
        return out


# ---------------------------------------------------------- rotate-once
@register_rule
class RotateOnceContract(Rule):
    """The transform's pass matmuls run only under the ``j == 0`` cond
    (once per row block) and exactly one top-level contraction runs per
    out-channel tile."""

    name = "rotate-once-contract"

    def applies(self, site: Site) -> bool:
        return (site.kind == "kernel" and site.plan is not None
                and site.schedule in ("rotate_once", "streamed"))

    def check(self, site: Site) -> List[Violation]:
        kernels = ju.kernel_jaxprs(site.jaxpr)
        if len(kernels) != 1:
            return [self._v(site, f"expected one kernel body, found "
                            f"{len(kernels)}")]
        top, in_cond = ju.dots_by_region(kernels[0])
        want = (1, site.plan.num_passes)
        if (top, in_cond) == want:
            return []
        return [self._v(
            site, f"(top-level dots, in-cond dots) = ({top}, {in_cond}), "
            f"expected {want} -- transform matmuls must sit under the "
            "j == 0 guard with a single top-level contraction "
            "(unguarded rotate re-transforms every revisit)")]


# ----------------------------------------------------------- DMA safety
@register_rule
class DmaSafety(Rule):
    """The streamed two-slot ring: warm-up + prefetch starts precede
    the first wait, both ring waits precede the single contraction, no
    start after the contraction (the ring drains at region end), every
    start guarded by a cond, and no start left without any wait."""

    name = "dma-safety"

    def applies(self, site: Site) -> bool:
        return site.kind == "kernel" and site.schedule == "streamed"

    def check(self, site: Site) -> List[Violation]:
        kernels = ju.kernel_jaxprs(site.jaxpr)
        if len(kernels) != 1:
            return [self._v(site, f"expected one kernel body, found "
                            f"{len(kernels)}")]
        kj = kernels[0]
        out = []
        starts = sum(1 for e in ju.iter_eqns(kj)
                     if e.primitive.name == "dma_start")
        waits = sum(1 for e in ju.iter_eqns(kj)
                    if e.primitive.name == "dma_wait")
        if starts == 0:
            return [self._v(site, "streamed kernel issues no dma_start -- "
                            "the ring is gone")]
        if waits == 0:
            out.append(self._v(
                site, f"{starts} dma_start(s) with NO dma_wait -- "
                "unmatched starts race the contraction"))
        unguarded = sum(1 for e in kj.eqns
                        if e.primitive.name == "dma_start")
        if unguarded:
            out.append(self._v(
                site, f"{unguarded} unguarded top-level dma_start(s) -- "
                "an unconditional start fires on EVERY grid step, so a "
                "copy is in flight when the row block's j loop ends "
                "(the ring never drains)"))
        events = ju.stream_events(kj)
        if events.count("dot") != 1:
            out.append(self._v(
                site, f"{events.count('dot')} top-level contractions in "
                "the streamed body, expected exactly 1"))
            return out
        dot_at = events.index("dot")
        if "wait" in events:
            first_wait = events.index("wait")
            if events[:first_wait].count("start_cond") < 2:
                out.append(self._v(
                    site, "fewer than 2 guarded copy-starts before the "
                    "first wait -- the j+1 prefetch must be in flight "
                    "before the kernel blocks on slot j (event order: "
                    f"{events})"))
            if events[first_wait:dot_at].count("wait") < 2:
                out.append(self._v(
                    site, "fewer than 2 waits before the contraction -- "
                    "the weight AND scale slots must both be settled "
                    f"(event order: {events})"))
        if "start_cond" in events[dot_at:]:
            out.append(self._v(
                site, "copy-start after the contraction -- the prefetch "
                "must precede the wait/dot so the overlap window exists "
                f"and the ring drains (event order: {events})"))
        return out


# ----------------------------------------------------------- dtype flow
@register_rule
class DtypeFlow(Rule):
    """16-bit pass compute stays 16-bit inside the kernel's transform
    cond (no silent f32 upcast of the pass matmuls), and decode traces
    never materialize a cache-shaped tensor WIDER than the io dtype
    (a dequantized KV cache copy would double decode bandwidth)."""

    name = "dtype-flow"

    def applies(self, site: Site) -> bool:
        return site.jaxpr is not None and (
            site.kind == "kernel" or
            (site.kind == "serving" and bool(site.cache_leaves)))

    def check(self, site: Site) -> List[Violation]:
        import jax.numpy as jnp

        out = []
        if site.kind == "kernel" and site.plan is not None:
            cd = jnp.dtype(site.plan.compute_dtype)
            if cd.itemsize == 2:
                for kj in ju.kernel_jaxprs(site.jaxpr):
                    for e in ju.as_jaxpr(kj).eqns:
                        if e.primitive.name != "cond":
                            continue
                        for br in e.params["branches"]:
                            for q in ju.as_jaxpr(br).eqns:
                                if q.primitive.name != "dot_general":
                                    continue
                                dts = {q.invars[0].aval.dtype,
                                       q.invars[1].aval.dtype}
                                wide = [str(d) for d in dts
                                        if jnp.dtype(d).itemsize > 2]
                                if wide:
                                    out.append(self._v(
                                        site, "transform pass matmul has "
                                        f"{wide} operand(s) under a "
                                        f"{cd.name} compute plan -- "
                                        "silent f32 upcast of the pass "
                                        "compute"))
        if site.kind == "serving" and site.cache_leaves:
            cache_shapes = {tuple(s) for s, _ in site.cache_leaves}
            io = jnp.dtype(site.io_dtype)
            for e in ju.iter_eqns(site.jaxpr):
                if e.primitive.name not in ("convert_element_type", "mul"):
                    continue
                if len(e.outvars) != 1:
                    continue
                aval = e.outvars[0].aval
                shape = tuple(getattr(aval, "shape", ()))
                dt = getattr(aval, "dtype", None)
                if (shape in cache_shapes and dt is not None
                        and jnp.issubdtype(dt, jnp.floating)
                        and jnp.dtype(dt).itemsize > io.itemsize):
                    out.append(self._v(
                        site, f"cache-shaped {shape} tensor materialized "
                        f"as {jnp.dtype(dt).name} (> io dtype {io.name}) "
                        f"by {e.primitive.name} -- dequantized cache "
                        "copy in the decode trace"))
        return out


# ---------------------------------------------------------- VMEM budget
@register_rule
class VmemBudget(Rule):
    """Re-charge the kernel's VMEM residents straight from the jaxpr's
    ref memory spaces (operand/output tiles + scratch + DMA rings; ANY
    refs live in HBM, semaphores in the register file) and hold them
    against (a) the planner's own budget, (b) the device limit, and
    (c) the ``BlockDecision.vmem_bytes`` the planner charged -- a
    kernel edit that grows a resident the planner doesn't know about
    fails (c) before it OOMs on hardware."""

    name = "vmem-budget"

    def applies(self, site: Site) -> bool:
        return (site.kind == "kernel" and site.decision is not None
                and site.plan is not None)

    @staticmethod
    def _ref_bytes(aval) -> int:
        import math

        import jax.numpy as jnp

        return math.prod(aval.shape) * jnp.dtype(aval.dtype).itemsize

    def check(self, site: Site) -> List[Violation]:
        from repro.kernels.registry import _VMEM_BUDGET_BYTES, _plan_mats

        kernels = ju.kernel_jaxprs(site.jaxpr)
        if len(kernels) != 1:
            return [self._v(site, f"expected one kernel body, found "
                            f"{len(kernels)}")]
        out = []
        dec = site.decision
        if dec.vmem_bytes > _VMEM_BUDGET_BYTES:
            out.append(self._v(
                site, f"planner charged {dec.vmem_bytes} B, over its own "
                f"{_VMEM_BUDGET_BYTES} B budget"))
        mats_shape = tuple(_plan_mats(site.plan).shape)
        total = 0
        tiles = 0
        for v in kernels[0].invars:
            aval = v.aval
            if not hasattr(aval, "memory_space"):
                continue
            ms = aval.memory_space
            ms_name = "vmem_block" if ms is None else str(ms).lower()
            if "any" in ms_name or "semaphore" in ms_name:
                continue  # HBM-resident ref / register-file semaphore
            b = self._ref_bytes(aval)
            total += b
            if tuple(aval.shape) != mats_shape:
                tiles += b  # the planner charges tiles, not the mats
        if total > DEVICE_VMEM_BYTES:
            out.append(self._v(
                site, f"kernel refs charge {total} B of VMEM, over the "
                f"{DEVICE_VMEM_BYTES} B device limit"))
        if tiles > dec.vmem_bytes:
            out.append(self._v(
                site, f"jaxpr re-charge of operand/scratch/ring tiles = "
                f"{tiles} B exceeds the planner's BlockDecision."
                f"vmem_bytes = {dec.vmem_bytes} B -- a VMEM resident "
                "the planner never charged"))
        return out


# ------------------------------------------------------------- donation
@register_rule
class Donation(Rule):
    """Serving executables compiled with donated caches must alias a
    buffer per cache leaf in the compiled HLO (``input_output_alias``)
    and must not defensively ``copy`` any cache-shaped buffer -- either
    failure means a fresh cache allocation every step."""

    name = "donation"

    def applies(self, site: Site) -> bool:
        return (site.kind == "serving" and site.donated
                and site.hlo_text is not None and bool(site.cache_leaves))

    def check(self, site: Site) -> List[Violation]:
        from repro.launch.hlo_analysis import (_shape_dims, parse_hlo,
                                               parse_input_output_aliases)

        out = []
        aliases = parse_input_output_aliases(site.hlo_text)
        n_cache = len(site.cache_leaves)
        if len(aliases) < n_cache:
            out.append(self._v(
                site, f"compiled HLO aliases {len(aliases)} output "
                f"buffer(s) but the cache pytree has {n_cache} leaves "
                "-- donation was dropped (fresh cache allocation every "
                "step)"))
        cache_dims = {tuple(s) for s, _ in site.cache_leaves}
        comps = parse_hlo(site.hlo_text)
        entry = comps.get("__entry__")
        if entry is not None:
            for ins in entry.instrs:
                if ins.opcode != "copy" or not ins.operands:
                    continue
                dims, _ = _shape_dims(ins.shape_str)
                if tuple(dims) not in cache_dims:
                    continue
                # a DEFENSIVE copy duplicates the donated input itself
                # (the param, or a get-tuple-element of it). Copies of
                # loop results into output buffers are how CPU XLA
                # plumbs while-carried state -- aliasing, asserted
                # above, is the donation signal there.
                src = ins.operands[0]
                producer = entry.by_name.get(src)
                if producer is not None and producer.opcode == \
                        "get-tuple-element" and producer.operands:
                    src, producer = producer.operands[0], \
                        entry.by_name.get(producer.operands[0])
                if src in entry.params or (
                        producer is not None
                        and producer.opcode == "parameter"):
                    out.append(self._v(
                        site, f"defensive copy of the donated cache "
                        f"input ({ins.shape_str}) in the entry "
                        "computation -- the buffer is duplicated "
                        "instead of updated in place"))
        return out


# ----------------------------------------------------- deprecated shims
@register_rule
class DeprecatedShim(Rule):
    """No lint site traces through the deprecated ``kernels.ops`` /
    ``kernels.fused_quant`` shims -- new code importing them fails the
    lint leg instead of warning once at runtime."""

    name = "deprecated-shim-in-trace"

    def applies(self, site: Site) -> bool:
        return bool(site.shim_calls)

    def check(self, site: Site) -> List[Violation]:
        return [self._v(
            site, f"deprecated shim {shim} called {n}x during trace -- "
            "route through the plan API (core.api.hadamard / "
            "online_hadamard_quantize) instead")
            for shim, n in sorted(site.shim_calls.items()) if n]
