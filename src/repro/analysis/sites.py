"""Site tracing for the kernel contract linter.

A ``Site`` is one traced artifact the rules run over: a fused-kernel
dispatch (its jaxpr + the ``BlockDecision`` the planner charged), a
model forward (MLP down-projection through the bound spec), or a
serving executable (decode step / prefill-insert: jaxpr + compiled HLO
+ the cache leaf shapes the donation contract covers).

Builders trace through the SAME entry points the model/serving layers
use (``pallas_quant_dot``, ``apply_mlp``, ``ServeEngine``) so the lint
asserts the code paths production takes, not a lookalike. Every trace
records the ``quantize_weight`` call delta and the deprecated-shim
``TRACE_COUNTS`` deltas, which the fusion and deprecated-shim rules
consume.
"""
from __future__ import annotations

import contextlib
import dataclasses
import os
from typing import Any, Dict, List, Optional, Tuple

__all__ = ["Site", "kernel_sites", "model_sites", "serving_sites",
           "default_sites", "traced"]

_SHIM_KEYS = (
    ("deprecated", "kernels.ops.hadamard"),
    ("deprecated", "kernels.fused_quant.fused_hadamard_quantize"),
)


@dataclasses.dataclass
class Site:
    """One traced artifact plus the static facts the rules check it
    against. Fields are optional by design: each rule's ``applies()``
    keys off what the site carries (a kernel site has a plan+decision,
    a serving site HLO + cache leaves, ...)."""

    name: str
    kind: str                               # "kernel" | "model" | "serving"
    jaxpr: Any = None                       # ClosedJaxpr of the trace
    schedule: Optional[str] = None          # resolved kernel schedule
    plan: Any = None                        # HadamardPlan
    decision: Any = None                    # BlockDecision actually charged
    io_dtype: Any = None
    hlo_text: Optional[str] = None          # compiled HLO (serving sites)
    cache_leaves: Tuple[Tuple[Tuple[int, ...], str], ...] = ()
    donated: bool = False                   # cache donation is contractual
    qw_calls: int = 0                       # quantize_weight delta in-trace
    shim_calls: Dict[str, int] = dataclasses.field(default_factory=dict)
    expect_fused: bool = True


def traced(fn, *args):
    """``jax.make_jaxpr`` of ``fn(*args)``, returning the jaxpr plus the
    in-trace ``quantize_weight`` call delta and deprecated-shim call
    deltas (the counters the fusion / deprecated-shim rules read)."""
    import jax

    from repro.core import wquant
    from repro.kernels.registry import TRACE_COUNTS

    qw0 = wquant.QUANTIZE_WEIGHT_CALLS
    shim0 = {k: TRACE_COUNTS[k] for k in _SHIM_KEYS}
    jaxpr = jax.make_jaxpr(fn)(*args)
    shim = {"/".join(k): TRACE_COUNTS[k] - shim0[k] for k in _SHIM_KEYS}
    return jaxpr, wquant.QUANTIZE_WEIGHT_CALLS - qw0, shim


@contextlib.contextmanager
def _stream_interpret_forced():
    """Run the real streamed kernel bodies on the interpreter's
    synchronous DMA simulation (the force flag CI's streamed leg uses),
    restoring the env afterwards."""
    from repro.kernels.quant_dot import STREAM_INTERPRET_ENV

    prev = os.environ.get(STREAM_INTERPRET_ENV)
    os.environ[STREAM_INTERPRET_ENV] = "1"
    try:
        yield
    finally:
        if prev is None:
            os.environ.pop(STREAM_INTERPRET_ENV, None)
        else:
            os.environ[STREAM_INTERPRET_ENV] = prev


def _scaled(config_name: str):
    from repro.configs import get_config
    from repro.launch.train import scaled_config

    return scaled_config(get_config(config_name), 0.004)


def kernel_sites(config_name: str, schedule: str = "rotate_once",
                 *, block_n: int = 128, abft: bool = False) -> List[Site]:
    """The fused quant_dot dispatches for ``config_name``: the 2-D
    dense kernel and the 3-D stacked-expert kernel, traced at the
    config's io dtype on a lint-sized problem (n = the 0.004-scaled
    d_model, d = 5 out-channel tiles so the streamed ring actually
    cycles). ``abft=True`` traces the checksum-VERIFIED twins instead
    (stored column checksum in, (out, residual) out) -- the lint proof
    that the verification column does not break the one-pallas_call
    fusion, the rotate-once dot counts, or the streamed DMA ring."""
    import jax.numpy as jnp

    from repro.core.api import QuantEpilogue, plan_for
    from repro.core.wquant import weight_checksum
    from repro.kernels.quant_dot import (pallas_quant_dot,
                                         pallas_quant_dot_experts,
                                         quant_dot_blocks)

    cfg = _scaled(config_name)
    n, d, m = cfg.d_model, 5 * block_n, 8
    io = jnp.dtype(cfg.dtype)
    plan = plan_for(n, dtype=io, backend="pallas",
                    epilogue=QuantEpilogue("int8"))
    tag = f"{schedule}/abft" if abft else schedule
    ctx = (_stream_interpret_forced() if schedule == "streamed"
           else contextlib.nullcontext())
    sites = []
    with ctx:
        x = jnp.zeros((m, n), io)
        wq = jnp.zeros((n, d), jnp.int8)
        sw = jnp.ones((1, d), jnp.float32)
        decision = quant_dot_blocks(n, d, m, io, plan.compute_dtype,
                                    "int8", block_m=plan.block_m,
                                    block_n=block_n, schedule=schedule,
                                    abft=abft)
        if abft:
            cw = weight_checksum(wq, sw)
            jaxpr, qw, shim = traced(
                lambda a, q, s, c: pallas_quant_dot(a, q, s, plan, True,
                                                    schedule, block_n,
                                                    check=c),
                x, wq, sw, cw)
        else:
            jaxpr, qw, shim = traced(
                lambda a, q, s: pallas_quant_dot(a, q, s, plan, True,
                                                 schedule, block_n),
                x, wq, sw)
        sites.append(Site(
            name=f"quant_dot[{config_name}/{tag}]", kind="kernel",
            jaxpr=jaxpr, schedule=schedule, plan=plan, decision=decision,
            io_dtype=io, qw_calls=qw, shim_calls=shim))

        xe = jnp.zeros((1, 2, m, n), io)
        wqe = jnp.zeros((2, n, d), jnp.int8)
        swe = jnp.ones((2, 1, d), jnp.float32)
        if abft:
            cwe = weight_checksum(wqe, swe)
            jaxpr, qw, shim = traced(
                lambda a, q, s, c: pallas_quant_dot_experts(
                    a, q, s, plan, True, schedule, block_n, check=c),
                xe, wqe, swe, cwe)
        else:
            jaxpr, qw, shim = traced(
                lambda a, q, s: pallas_quant_dot_experts(a, q, s, plan,
                                                         True, schedule,
                                                         block_n),
                xe, wqe, swe)
        sites.append(Site(
            name=f"quant_dot_experts[{config_name}/{tag}]",
            kind="kernel", jaxpr=jaxpr, schedule=schedule, plan=plan,
            decision=decision, io_dtype=io, qw_calls=qw, shim_calls=shim))
    return sites


def model_sites(config_name: str) -> List[Site]:
    """The bound-spec model forward: the scaled config's MLP with a
    fusable pow-2 down-projection, int8 pallas quantization -- the
    PR 4 spec path every model site routes through."""
    import jax
    import jax.numpy as jnp

    from repro.configs import get_config
    from repro.core.quant import QuantConfig
    from repro.models.mlp import apply_mlp, init_mlp

    cfg = get_config(config_name).scaled_down(
        d_model=256, d_ff=512).with_quant(
        QuantConfig(mode="int8", rotate="hadamard", backend="pallas"))
    p = init_mlp(jax.random.PRNGKey(0), cfg)
    x = jnp.zeros((2, 4, cfg.d_model), jnp.dtype(cfg.dtype))
    jaxpr, qw, shim = traced(lambda a: apply_mlp(cfg, p, a), x)
    return [Site(name=f"mlp_down_proj[{config_name}]", kind="model",
                 jaxpr=jaxpr, io_dtype=jnp.dtype(cfg.dtype),
                 qw_calls=qw, shim_calls=shim)]


def _cache_leaves(caches) -> Tuple[Tuple[Tuple[int, ...], str], ...]:
    import jax

    return tuple((tuple(leaf.shape), str(leaf.dtype))
                 for leaf in jax.tree_util.tree_leaves(caches))


def serving_sites(config_name: str, *, backend: str = "xla",
                  engine=None) -> List[Site]:
    """The serving executables: the donated per-slot decode step and
    the donated prefill-insert, traced + compiled from a real (scaled)
    ``ServeEngine`` so the donation contract is checked on the exact
    executables the engine dispatches. Pass ``engine=`` to lint an
    already-built (possibly degraded/re-warmed) engine instead."""
    import dataclasses as _dc

    import jax
    import jax.numpy as jnp

    if engine is None:
        from repro.configs import get_config
        from repro.core.quant import QuantConfig
        from repro.launch.mesh import make_local_mesh
        from repro.launch.steps import make_param_init, param_shardings
        from repro.launch.train import scaled_config
        from repro.serving import ServeEngine

        quant = QuantConfig(mode="fp8_e4m3", rotate="hadamard",
                            backend=backend, kv_quant=True)
        cfg = scaled_config(get_config(config_name), 0.004).with_quant(quant)
        cfg = _dc.replace(cfg, weight_quant="int8")
        mesh = make_local_mesh(1)
        with mesh:
            ps = param_shardings(cfg, mesh)
            params = jax.jit(make_param_init(cfg), out_shardings=ps)(
                jax.random.PRNGKey(0))
        engine = ServeEngine(cfg, params, mesh, num_slots=2, max_len=32,
                             prefill_len=8)

    leaves = _cache_leaves(engine.caches)
    tok = jnp.asarray(engine.tokens_h)
    pos = jnp.asarray(engine.positions_h)
    decode_args = (engine.params, engine.caches, tok, pos)
    jaxpr, qw, shim = traced(engine._decode, *decode_args)
    hlo = engine._decode.lower(*decode_args).compile().as_text()
    decode = Site(
        name=f"serve_decode[{config_name}/rung{engine._rung}]",
        kind="serving", jaxpr=jaxpr, io_dtype=jnp.dtype(engine.cfg.dtype),
        hlo_text=hlo, cache_leaves=leaves, donated=True,
        qw_calls=qw, shim_calls=shim)

    batch = {"tokens": jnp.zeros((1, engine.prefill_len), jnp.int32)}
    out = engine._prefill(engine.params, batch, jnp.asarray(1, jnp.int32))
    kv = out[-1]
    insert_args = (engine.caches, kv, jnp.asarray(0, jnp.int32))
    ijaxpr, iqw, ishim = traced(engine._insert, *insert_args)
    ihlo = engine._insert.lower(*insert_args).compile().as_text()
    insert = Site(
        name=f"serve_insert[{config_name}]", kind="serving", jaxpr=ijaxpr,
        io_dtype=jnp.dtype(engine.cfg.dtype), hlo_text=ihlo,
        cache_leaves=leaves, donated=True, qw_calls=iqw, shim_calls=ishim,
        expect_fused=False)  # insert is a cache scatter: no kernel, no dot
    return [decode, insert]


def default_sites(config_name: str, schedule: str = "rotate_once",
                  *, serving: bool = True, abft: bool = False) -> List[Site]:
    """Every lintable site for one (config, schedule) pair. ``abft=True``
    additionally lints the checksum-verified kernel twins."""
    sites = kernel_sites(config_name, schedule)
    if abft:
        sites += kernel_sites(config_name, schedule, abft=True)
    sites += model_sites(config_name)
    if serving:
        sites += serving_sites(config_name)
    return sites
