"""Kernel contract lint CLI.

    PYTHONPATH=src python -m repro.analysis.lint \\
        --config llama3_8b --config mixtral_8x7b \\
        --schedule rotate_once --schedule streamed --json report.json

Traces the model sites of each named config from ``src/repro/configs/``
(the fused 2-D/3-D quant_dot dispatches, the bound-spec MLP forward,
and the serving decode/insert executables), runs every registered rule,
and exits nonzero on any violation. ``--mutation`` lints the committed
broken-kernel fixtures instead; since those are intentionally broken, a
healthy linter exits nonzero there -- the CI leg inverts that gate to
prove the rules have teeth.
"""
from __future__ import annotations

import argparse
import sys
from typing import List, Optional

__all__ = ["main"]


def _build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis.lint",
        description="Static kernel-contract linter over traced jaxprs "
                    "and compiled HLO.")
    ap.add_argument("--config", action="append", default=None,
                    help="config name from repro.configs (repeatable; "
                    "default: llama3_8b)")
    ap.add_argument("--schedule", action="append", default=None,
                    choices=["rotate_once", "streamed"],
                    help="quant_dot grid schedule(s) to lint "
                    "(repeatable; default: rotate_once)")
    ap.add_argument("--rule", action="append", default=None,
                    help="run only the named rule(s) (default: all)")
    ap.add_argument("--no-serving", action="store_true",
                    help="skip the serving-engine sites (faster; no "
                    "donation/decode checks)")
    ap.add_argument("--abft", action="store_true",
                    help="also lint the checksum-verified (ABFT) kernel "
                    "twins: the verification column must not break the "
                    "fusion/rotate-once/DMA contracts")
    ap.add_argument("--mutation", action="store_true",
                    help="lint the committed broken-kernel fixtures "
                    "instead of the model sites; a healthy linter exits "
                    "nonzero (both mutants flagged)")
    ap.add_argument("--json", metavar="PATH",
                    help="write the report as JSON ('-' for stdout)")
    ap.add_argument("--list-rules", action="store_true",
                    help="print registered rules and exit")
    return ap


def _emit(report, path: Optional[str]) -> None:
    if not path:
        return
    text = report.to_json()
    if path == "-":
        print(text)
    else:
        with open(path, "w") as f:
            f.write(text + "\n")


def _lint_mutants(args) -> int:
    from repro.analysis.mutations import mutant_sites
    from repro.analysis.rules import run_rules

    sites = mutant_sites()
    report = run_rules(sites, rules=args.rule)
    print(report.format_text())
    _emit(report, args.json)
    flagged = {v.site for v in report.violations}
    missed = [s.name for s in sites if s.name not in flagged]
    if missed:
        print(f"WARNING: mutant(s) passed the lint: {missed} -- the "
              "rules lost their teeth (CI inverts this gate and fails)",
              file=sys.stderr)
    # plain lint semantics: the fixtures are broken kernels, so a
    # healthy linter exits NONZERO here; CI asserts that, plus that
    # every mutant name appears in the JSON violations
    return 1 if report.violations else 0


def main(argv: Optional[List[str]] = None) -> int:
    args = _build_parser().parse_args(argv)

    from repro.analysis.rules import all_rules, run_rules

    if args.list_rules:
        for name, rule in all_rules().items():
            doc = (rule.__doc__ or "").strip().split("\n")[0]
            print(f"{name:26s} {doc}")
        return 0
    if args.rule:
        unknown = [r for r in args.rule if r not in all_rules()]
        if unknown:
            print(f"unknown rule(s): {unknown}; --list-rules to see "
                  "what's registered", file=sys.stderr)
            return 2
    if args.mutation:
        return _lint_mutants(args)

    from repro.analysis.sites import default_sites

    configs = args.config or ["llama3_8b"]
    schedules = args.schedule or ["rotate_once"]
    report = None
    for config in configs:
        for i, schedule in enumerate(schedules):
            # serving sites are schedule-independent (the engine's own
            # ladder owns its schedule); trace them once per config
            part = run_rules(default_sites(
                config, schedule, serving=not args.no_serving and i == 0,
                abft=args.abft),
                rules=args.rule)
            report = part if report is None else report.merge(part)
    print(report.format_text())
    _emit(report, args.json)
    return 0 if report.ok else 1


if __name__ == "__main__":
    sys.exit(main())
