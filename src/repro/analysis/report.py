"""Result model for the kernel contract linter: ``Violation`` (one
broken invariant at one site) and ``Report`` (the full run: every
(site, rule) pair checked, plus the violations)."""
from __future__ import annotations

import dataclasses
import json
from typing import List, Tuple

__all__ = ["Violation", "Report"]


@dataclasses.dataclass(frozen=True)
class Violation:
    """One invariant broken at one traced site.

    ``rule`` is the registered rule name (``fusion-contract``, ...),
    ``site`` the site name it fired on, ``message`` the human-readable
    account of what the jaxpr/HLO actually showed vs. the contract."""

    rule: str
    site: str
    message: str

    def __str__(self) -> str:
        return f"[{self.rule}] {self.site}: {self.message}"


@dataclasses.dataclass
class Report:
    """Outcome of one lint run: ``checked`` lists every (site, rule)
    pair that ran (so a vacuous run -- zero sites traced -- is visibly
    different from a clean one), ``violations`` what failed."""

    checked: List[Tuple[str, str]] = dataclasses.field(default_factory=list)
    violations: List[Violation] = dataclasses.field(default_factory=list)

    @property
    def ok(self) -> bool:
        return not self.violations

    def merge(self, other: "Report") -> "Report":
        self.checked.extend(other.checked)
        self.violations.extend(other.violations)
        return self

    def to_dict(self) -> dict:
        return {
            "ok": self.ok,
            "checked": [list(c) for c in self.checked],
            "violations": [dataclasses.asdict(v) for v in self.violations],
        }

    def to_json(self, **kw) -> str:
        kw.setdefault("indent", 1)
        return json.dumps(self.to_dict(), **kw)

    def format_text(self) -> str:
        lines = [f"checked {len(self.checked)} (site, rule) pairs"]
        if self.ok:
            lines.append("OK: no contract violations")
        else:
            lines.append(f"{len(self.violations)} violation(s):")
            lines.extend(f"  {v}" for v in self.violations)
        return "\n".join(lines)
