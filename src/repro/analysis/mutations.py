"""Mutation-test fixture: intentionally broken kernel variants the
linter MUST flag (proof the rules have teeth, wired into CI's lint
job via ``python -m repro.analysis.lint --mutation``).

Two committed mutants, one per headline rule family:

* :func:`_mutant_unguarded_rotate` drops the ``j == 0`` guard from the
  rotate-once kernel -- every out-channel revisit re-transforms the row
  block, the exact regression PR 5 eliminated. The
  ``rotate-once-contract`` rule must fire.
* :func:`_mutant_dangling_dma` issues the ring's copy-starts
  UNGUARDED at top level and never waits on the semaphores -- the
  contraction races the DMA and a copy is in flight when the j loop
  ends. The ``dma-safety`` rule must fire (unmatched + unguarded).

The mutants only need to TRACE (``jax.make_jaxpr`` runs abstract
evaluation, never the kernel), so the broken bodies are never
executed.
"""
from __future__ import annotations

import functools
from typing import List

from repro.analysis.sites import Site, traced

__all__ = ["mutant_sites"]


def _mutant_unguarded_rotate(x_ref, mats_ref, wq_ref, sw_ref, o_ref,
                             q_ref, s_ref, *, n, mode, compute_dtype):
    """BROKEN rotate-once body: the rotate+quantize stage runs on EVERY
    grid step (no ``pl.when(j == 0)``), so the transform matmuls sit at
    top level instead of under the cond."""
    from repro.kernels.quant_dot import (_operand_dot, _operand_from_q,
                                         _rotate_quantize_block)

    q, s = _rotate_quantize_block(x_ref[...], mats_ref, n=n, mode=mode,
                                  compute_dtype=compute_dtype)
    q_ref[...] = _operand_from_q(q, mode)
    s_ref[...] = s
    acc = _operand_dot(q_ref[...], wq_ref[...], mode)
    o_ref[...] = (acc * s_ref[...] * sw_ref[...]).astype(o_ref.dtype)


def _mutant_dangling_dma(x_ref, mats_ref, wq_hbm, sw_hbm, o_ref,
                         q_ref, s_ref, w_ring, sw_ring, w_sem, s_sem,
                         *, n, mode, compute_dtype, bn, nj):
    """BROKEN streamed body: the weight/scale copy-starts are issued
    unconditionally (no warm-up/prefetch guards) and NEVER waited on --
    the contraction reads the ring slot while the DMA is still in
    flight, and a start dangles at the end of every row block."""
    import jax.experimental.pallas as pl
    from jax.experimental.pallas import tpu as pltpu

    from repro.kernels.quant_dot import (_operand_dot, _operand_from_q,
                                         _rotate_quantize_block)

    j = pl.program_id(1)

    @pl.when(j == 0)
    def _rotate():
        q, s = _rotate_quantize_block(x_ref[...], mats_ref, n=n, mode=mode,
                                      compute_dtype=compute_dtype)
        q_ref[...] = _operand_from_q(q, mode)
        s_ref[...] = s

    pltpu.make_async_copy(wq_hbm.at[:, pl.ds(j * bn, bn)], w_ring.at[0],
                          w_sem.at[0]).start()
    pltpu.make_async_copy(sw_hbm.at[:, pl.ds(j * bn, bn)], sw_ring.at[0],
                          s_sem.at[0]).start()
    acc = _operand_dot(q_ref[...], w_ring[0], mode)
    o_ref[...] = (acc * s_ref[...] * sw_ring[0]).astype(o_ref.dtype)


def _launch(kernel, schedule: str, *, n=256, d=640, m=8, bn=128):
    """pallas_call plumbing identical to ``_pallas_quant_dot``'s for the
    given schedule, with the broken body swapped in."""
    import jax
    import jax.experimental.pallas as pl
    import jax.numpy as jnp
    from jax.experimental.pallas import tpu as pltpu

    from repro.core.api import QuantEpilogue, plan_for
    from repro.kernels.quant_dot import _scratch_dtype, quant_dot_blocks
    from repro.kernels.registry import _plan_mats

    plan = plan_for(n, backend="pallas", epilogue=QuantEpilogue("int8"))
    mats = _plan_mats(plan)
    dec = quant_dot_blocks(n, d, m, jnp.float32, plan.compute_dtype,
                           "int8", block_n=bn, schedule=schedule)
    bm = dec.block_m
    mp = -(-m // bm) * bm
    common = dict(n=n, mode="int8", compute_dtype=jnp.dtype(
        plan.compute_dtype))
    wq_spec = pl.BlockSpec((n, bn), lambda i, j: (0, j))
    sw_spec = pl.BlockSpec((1, bn), lambda i, j: (0, j))
    scratch = [pltpu.VMEM((bm, n), _scratch_dtype("int8")),
               pltpu.VMEM((bm, 1), jnp.float32)]
    if schedule == "streamed":
        body = functools.partial(kernel, **common, bn=bn, nj=d // bn)
        scratch += [pltpu.VMEM((2, n, bn), jnp.int8),
                    pltpu.VMEM((2, 1, bn), jnp.float32),
                    pltpu.SemaphoreType.DMA((2,)),
                    pltpu.SemaphoreType.DMA((2,))]
        wq_spec = pl.BlockSpec(memory_space=pltpu.ANY)
        sw_spec = pl.BlockSpec(memory_space=pltpu.ANY)
    else:
        body = functools.partial(kernel, **common)

    def call(x, wq, sw):
        return pl.pallas_call(
            body,
            grid=(mp // bm, d // bn),
            in_specs=[
                pl.BlockSpec((bm, n), lambda i, j: (i, 0)),
                pl.BlockSpec((mats.shape[0],) + mats.shape[1:],
                             lambda i, j: (0, 0, 0)),
                wq_spec,
                sw_spec,
            ],
            out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
            out_shape=jax.ShapeDtypeStruct((mp, d), jnp.float32),
            scratch_shapes=scratch,
            interpret=True,
        )(x, mats, wq, sw)

    x = jnp.zeros((mp, n), jnp.float32)
    wq = jnp.zeros((n, d), jnp.int8)
    sw = jnp.ones((1, d), jnp.float32)
    jaxpr, qw, shim = traced(call, x, wq, sw)
    return jaxpr, plan, dec, qw, shim


def mutant_sites() -> List[Site]:
    """The committed mutants as lint sites; a healthy linter reports
    violations on BOTH (CI runs ``lint --mutation`` and requires a
    nonzero exit)."""
    jaxpr, plan, dec, qw, shim = _launch(_mutant_unguarded_rotate,
                                         "rotate_once")
    broken_rotate = Site(
        name="mutant[unguarded_rotate]", kind="kernel", jaxpr=jaxpr,
        schedule="rotate_once", plan=plan, decision=dec,
        qw_calls=qw, shim_calls=shim)
    jaxpr, plan, dec, qw, shim = _launch(_mutant_dangling_dma, "streamed")
    broken_dma = Site(
        name="mutant[dangling_dma]", kind="kernel", jaxpr=jaxpr,
        schedule="streamed", plan=plan, decision=dec,
        qw_calls=qw, shim_calls=shim)
    return [broken_rotate, broken_dma]
