"""Shared jaxpr walkers for the kernel contract linter (PR 9).

These started life as ad-hoc helpers copy-pasted across
``tests/test_plan_api.py`` and ``tests/test_quant_dot.py``; every
structural invariant the repo asserts -- one-pallas_call fusion, the
rotate-once cond signature, the streamed DMA-ring event order -- now
reads through this one module, so the tests and the ``repro.analysis``
rules literally share an implementation.

All walkers recurse through ``eqn.params.values()`` (``ClosedJaxpr`` /
``Jaxpr`` / list / tuple), which covers cond branches, scan/while
bodies, pjit calls and remat -- anywhere jax 0.4.x stashes a subjaxpr.
"""
from __future__ import annotations

from typing import Iterator, List, Tuple

from jax.core import ClosedJaxpr, Jaxpr

__all__ = [
    "as_jaxpr",
    "count_pallas_calls",
    "count_primitive",
    "dots_by_region",
    "dots_outside_pallas",
    "iter_eqns",
    "kernel_jaxpr",
    "kernel_jaxprs",
    "pallas_call_eqns",
    "stream_events",
]


def as_jaxpr(j):
    """Unwrap a ``ClosedJaxpr`` to its ``Jaxpr`` (identity otherwise)."""
    return j.jaxpr if isinstance(j, ClosedJaxpr) else j


def iter_eqns(jaxpr, *, into_pallas: bool = True) -> Iterator:
    """Yield every eqn in ``jaxpr`` and (recursively) every subjaxpr
    reachable through eqn params. ``into_pallas=False`` stops at
    ``pallas_call`` boundaries (the eqn itself is still yielded)."""

    def walk(v):
        if isinstance(v, (ClosedJaxpr, Jaxpr)):
            yield from scan(as_jaxpr(v))
        elif isinstance(v, (list, tuple)):
            for u in v:
                yield from walk(u)

    def scan(j):
        for eqn in j.eqns:
            yield eqn
            if eqn.primitive.name == "pallas_call" and not into_pallas:
                continue
            for param in eqn.params.values():
                yield from walk(param)

    yield from scan(as_jaxpr(jaxpr))


def count_primitive(jaxpr, name: str) -> int:
    """Occurrences of primitive ``name`` anywhere in ``jaxpr``."""
    return sum(1 for e in iter_eqns(jaxpr) if e.primitive.name == name)


def count_pallas_calls(jaxpr) -> int:
    """Number of ``pallas_call`` eqns anywhere in ``jaxpr`` -- the
    fusion contract asserts this is exactly 1 per bound kernel site."""
    return count_primitive(jaxpr, "pallas_call")


def pallas_call_eqns(jaxpr) -> List:
    """Every ``pallas_call`` eqn in ``jaxpr``, outermost-first."""
    return [e for e in iter_eqns(jaxpr) if e.primitive.name == "pallas_call"]


def kernel_jaxprs(jaxpr) -> List[Jaxpr]:
    """The kernel-body jaxprs of every ``pallas_call`` in ``jaxpr``
    (``params["jaxpr"]`` is a raw ``Jaxpr`` in jax 0.4.x)."""
    return [e.params["jaxpr"] for e in pallas_call_eqns(jaxpr)]


def kernel_jaxpr(jaxpr) -> Jaxpr:
    """The kernel jaxpr of the single ``pallas_call`` inside ``jaxpr``;
    raises if the trace fused into anything other than exactly one."""
    found = kernel_jaxprs(jaxpr)
    if len(found) != 1:
        raise AssertionError(
            f"expected exactly one pallas_call, got {found}")
    return found[0]


def dots_by_region(kjaxpr) -> Tuple[int, int]:
    """(top-level dot_general count, dot_general count inside cond
    branches) of a kernel jaxpr -- the structural signature of the
    rotate-once schedule: the transform's pass matmuls live under the
    ``j == 0`` cond, the contraction outside it."""
    kjaxpr = as_jaxpr(kjaxpr)
    top = sum(1 for e in kjaxpr.eqns if e.primitive.name == "dot_general")
    in_cond = 0
    for e in kjaxpr.eqns:
        if e.primitive.name == "cond":
            for br in e.params["branches"]:
                in_cond += sum(1 for q in as_jaxpr(br).eqns
                               if q.primitive.name == "dot_general")
    return top, in_cond


def dots_outside_pallas(jaxpr) -> int:
    """dot_general count anywhere in the jaxpr EXCEPT inside pallas_call
    kernel bodies -- nonzero means contraction work escaped the fused
    kernel (e.g. the einsum fallback ran)."""
    return sum(1 for e in iter_eqns(jaxpr, into_pallas=False)
               if e.primitive.name == "dot_general")


def stream_events(kjaxpr) -> List[str]:
    """Ordered top-level event list of a streamed kernel jaxpr:
    ``start_cond`` (a cond whose branch issues an async-copy start --
    the warm-up at j == 0 or the j+1 prefetch), ``wait`` (a top-level
    dma_wait), ``dot`` (a top-level dot_general, the contraction)."""

    def _has_dma_start(br):
        return any(q.primitive.name == "dma_start"
                   for q in as_jaxpr(br).eqns)

    events = []
    for e in as_jaxpr(kjaxpr).eqns:
        if e.primitive.name == "cond" and any(
                _has_dma_start(br) for br in e.params["branches"]):
            events.append("start_cond")
        elif e.primitive.name == "dma_wait":
            events.append("wait")
        elif e.primitive.name == "dot_general":
            events.append("dot")
    return events
