"""Static analysis of the repo's kernel contracts (PR 9).

One declarative subsystem for every structural invariant the perf work
depends on: jaxpr walkers (shared with the test suites), a decorator-
registered rule registry, traced lint sites, a mutation fixture, and
the ``python -m repro.analysis.lint`` CLI. See DESIGN.md section 13.
"""
from repro.analysis.jaxpr_utils import (as_jaxpr, count_pallas_calls,
                                        count_primitive, dots_by_region,
                                        dots_outside_pallas, iter_eqns,
                                        kernel_jaxpr, kernel_jaxprs,
                                        pallas_call_eqns, stream_events)
from repro.analysis.report import Report, Violation
from repro.analysis.rules import (Rule, all_rules, register_rule,
                                  run_rules)
from repro.analysis.sites import (Site, default_sites, kernel_sites,
                                  model_sites, serving_sites)

__all__ = [
    "Report", "Rule", "Site", "Violation",
    "all_rules", "as_jaxpr", "count_pallas_calls", "count_primitive",
    "default_sites", "dots_by_region", "dots_outside_pallas",
    "iter_eqns", "kernel_jaxpr", "kernel_jaxprs", "kernel_sites",
    "model_sites", "pallas_call_eqns", "register_rule", "run_rules",
    "serving_sites", "stream_events",
]
