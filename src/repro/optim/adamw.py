"""AdamW with global-norm clipping, warmup+cosine schedule, optional
blockwise-int8 moments (8-bit Adam), and optional error-feedback int8
gradient compression for the cross-pod data-parallel all-reduce.

Everything is a pure function over pytrees -- pjit shards the update the
same way it shards the model (FSDP: moments live sharded on the fsdp axes).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.optim.qstate import dequantize_state, quantize_state, zeros_like_qstate


@dataclasses.dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10000
    min_lr_frac: float = 0.1
    state_dtype: str = "f32"        # f32 | int8 (blockwise 8-bit Adam)
    grad_compression: str = "none"  # none | int8_ef (error-feedback int8)


def schedule(cfg: OptConfig, step: jnp.ndarray) -> jnp.ndarray:
    s = step.astype(jnp.float32)
    warm = s / jnp.maximum(cfg.warmup_steps, 1)
    t = (s - cfg.warmup_steps) / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1)
    t = jnp.clip(t, 0.0, 1.0)
    cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (1 + jnp.cos(jnp.pi * t))
    return cfg.lr * jnp.where(s < cfg.warmup_steps, warm, cos)


def init_opt_state(params, cfg: OptConfig):
    if cfg.state_dtype == "int8":
        m = jax.tree.map(zeros_like_qstate, params)
        v = jax.tree.map(zeros_like_qstate, params)
    else:
        m = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        v = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    state = {"m": m, "v": v, "step": jnp.zeros((), jnp.int32)}
    if cfg.grad_compression == "int8_ef":
        state["ef"] = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return state


def _global_norm(tree) -> jnp.ndarray:
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in jax.tree.leaves(tree)))


def compress_grads(grads, ef):
    """Error-feedback int8 compression: g_q = Q(g + e); e' = (g + e) - g_q.
    The quantized values are what crosses the slow (cross-pod) link; the
    residual stays local and is re-injected next step, so the compression
    is unbiased over time."""
    def one(g, e):
        x = g.astype(jnp.float32) + e
        s = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / 127.0
        q = jnp.clip(jnp.round(x / s), -127, 127)
        gq = q * s
        return gq, x - gq
    flat_g, tdef = jax.tree.flatten(grads)
    flat_e = jax.tree.leaves(ef)
    out = [one(g, e) for g, e in zip(flat_g, flat_e)]
    gq = jax.tree.unflatten(tdef, [o[0] for o in out])
    ef_new = jax.tree.unflatten(tdef, [o[1] for o in out])
    return gq, ef_new


def apply_updates(params, grads, state, cfg: OptConfig) -> Tuple[Any, Any]:
    step = state["step"] + 1
    lr = schedule(cfg, step)

    new_state = {"step": step}
    if cfg.grad_compression == "int8_ef":
        grads, ef_new = compress_grads(grads, state["ef"])
        new_state["ef"] = ef_new

    gnorm = _global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-12))

    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    if cfg.state_dtype == "int8":
        flat_m = tdef.flatten_up_to(state["m"])
        flat_v = tdef.flatten_up_to(state["v"])
    else:
        flat_m = jax.tree.leaves(state["m"])
        flat_v = jax.tree.leaves(state["v"])

    new_p, new_m, new_v = [], [], []
    for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v):
        gf = g.astype(jnp.float32) * scale
        mf = dequantize_state(m, p.shape) if cfg.state_dtype == "int8" else m
        vf = dequantize_state(v, p.shape) if cfg.state_dtype == "int8" else v
        mf = cfg.b1 * mf + (1 - cfg.b1) * gf
        vf = cfg.b2 * vf + (1 - cfg.b2) * jnp.square(gf)
        upd = (mf / b1c) / (jnp.sqrt(vf / b2c) + cfg.eps)
        pf = p.astype(jnp.float32)
        decay = cfg.weight_decay if p.ndim >= 2 else 0.0
        pf = pf - lr * (upd + decay * pf)
        new_p.append(pf.astype(p.dtype))
        new_m.append(quantize_state(mf) if cfg.state_dtype == "int8" else mf)
        new_v.append(quantize_state(vf) if cfg.state_dtype == "int8" else vf)

    new_state["m"] = jax.tree.unflatten(tdef, new_m)
    new_state["v"] = jax.tree.unflatten(tdef, new_v)
    new_params = jax.tree.unflatten(tdef, new_p)
    return new_params, new_state, {"gnorm": gnorm, "lr": lr}
