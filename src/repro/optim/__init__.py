from repro.optim.adamw import OptConfig, init_opt_state, apply_updates  # noqa: F401
