"""Blockwise-int8 optimizer-state quantization (8-bit Adam).

At 405B-dense scale, f32 Adam moments are the single largest HBM consumer
(8 bytes/param = 6.3 GB/chip on the 512-chip mesh). Blockwise int8 with
per-256-block f32 absmax scales cuts that 4x -- thematically the same
outlier-vs-dynamic-range trade the paper's rotations address for
activations. dynamic range of Adam moments within a 256-block is narrow,
so plain absmax int8 holds training quality (8-bit Adam, Dettmers et al.).
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

_BLOCK = 256


def quantize_state(x: jnp.ndarray) -> Dict[str, jnp.ndarray]:
    """f32 tensor -> {'q': int8, 's': f32 per-block scales, 'n': orig last dim}."""
    shape = x.shape
    last = shape[-1] if shape else 1
    pad = (-last) % _BLOCK
    xf = x.astype(jnp.float32).reshape(-1, last)
    if pad:
        xf = jnp.pad(xf, ((0, 0), (0, pad)))
    xb = xf.reshape(xf.shape[0], -1, _BLOCK)
    s = jnp.maximum(jnp.max(jnp.abs(xb), axis=-1, keepdims=True), 1e-12) / 127.0
    q = jnp.clip(jnp.round(xb / s), -127, 127).astype(jnp.int8)
    return {"q": q.reshape(xf.shape[0], -1), "s": s[..., 0].reshape(xf.shape[0], -1)}


def dequantize_state(t: Dict[str, jnp.ndarray], shape) -> jnp.ndarray:
    q = t["q"].astype(jnp.float32).reshape(t["q"].shape[0], -1, _BLOCK)
    x = (q * t["s"][..., None]).reshape(t["q"].shape[0], -1)
    last = shape[-1] if shape else 1
    return x[:, :last].reshape(shape)


def zeros_like_qstate(x: jnp.ndarray) -> Dict[str, jnp.ndarray]:
    return quantize_state(jnp.zeros(x.shape, jnp.float32))


def qstate_specs(param_spec: tuple) -> Dict[str, Any]:
    """Logical sharding for a quantized-state leaf. The moment tensors are
    stored flattened to (rows, cols); rows merge all leading dims, so we
    shard rows on the param's first SHARDABLE logical axis (skipping
    'layers'=None stacking axes -- picking the first axis blindly left
    405B moments replicated: a measured 94->256 GB/device regression)."""
    lead = next((a for a in param_spec[:-1] if a is not None), None)
    last = param_spec[-1] if len(param_spec) > 1 else None
    if lead is None and param_spec and len(param_spec) == 1:
        lead = param_spec[-1]
        last = None
    # 2D sharding: rows on the first shardable leading axis, cols on the
    # param's last axis (405B f32 moments shard 512-way; the flattened int8
    # layout must too, or it LOSES memory vs f32 -- measured 94->256 GB).
    return {"q": (lead, last), "s": (lead, last)}
