"""Fault-injection harness for the hardened serving engine.

Chaos testing with surgical faults: every recovery path in
``serving.engine`` (deadline shed, queue rejection, watchdog retry,
schedule-degrade re-warm, NaN-guard retirement) is exercised by injecting
the triggering fault at a *chosen step* of a real serve run, then
asserting the run completes with the right per-request
``Completion.status`` and bitwise-identical ``ok`` outputs.

Faults are injected at the HOST dispatch boundary, on purpose:

  * ``maybe_raise`` fires BEFORE the jitted decode call, so the donated
    cache operand was never consumed -- the caches the engine holds are
    intact and the retry path re-dispatches on valid state. (Raising
    *inside* a donated jit would leave the caches in a consumed/undefined
    state; real kernel failures surface at dispatch too -- XLA raises
    from the blocking host call.)
  * ``poke_nan`` writes NaN into already-written KV rows of a live slot
    (slot axis 1, row axis 2 of every ``(repeats, slots, T, KH, hd)``
    leaf -- see ``serving.cache.alloc_kv_caches``). Row ``pos - 1`` is
    attended by the very next decode step, so the poison propagates to
    that slot's logits and trips the numeric guard; the row is rewritten
    by prefill-insert before any successor request can attend it, so the
    fault stays request-local.
  * ``delay_s`` sleeps on the host around the step, simulating a stuck
    device/step for the watchdog without touching numerics.
  * the SILENT injectors (``flip_weight_bit``, ``perturb_kv_row``,
    ``clobber_stream_tile``; scheduled via ``corrupt_at_step``) mutate
    live weights/KV with finite wrong values -- invisible to every
    isfinite guard by construction. They close the fault-model gap the
    ABFT layer (``repro.verify``, DESIGN.md section 14) exists for: a
    run with ``REPRO_ABFT=1`` must detect them, a guards-only run must
    NOT (that contrast is asserted in tests/test_faults.py).

Activation is context-scoped (``with inject(plan): ...``) so a leaked
fault can never outlive a test; the engine polls the module-level
``active()`` accessor, keeping the zero-fault hot path one attribute
load + None check.
"""
from __future__ import annotations

import contextlib
import dataclasses
from typing import List, Optional, Sequence, Tuple

import jax
import numpy as np

__all__ = [
    "FaultPlan",
    "InjectedKernelError",
    "inject",
    "active",
    "poke_nan",
    "flip_weight_bit",
    "perturb_kv_row",
    "clobber_stream_tile",
    "arrival_flood",
]


class InjectedKernelError(RuntimeError):
    """The synthetic kernel failure raised by ``FaultPlan.maybe_raise``."""


@dataclasses.dataclass
class FaultPlan:
    """What to break, and when (all steps in engine step-clock units).

    kernel_raise_at_step: raise ``InjectedKernelError`` at decode dispatch
        of this step (None = never).
    kernel_raise_count: how many consecutive dispatch attempts fail
        starting at ``kernel_raise_at_step`` -- 1 exercises the
        retry-once path; 2+ forces a degradation-ladder re-warm.
    step_delay_s / delay_at_steps: artificial per-step host latency, at
        the listed steps (empty = every step once step_delay_s > 0).
        Trips the decode watchdog.
    nan_poke_step / nan_poke_slot: before dispatching this step, write
        NaN into the target slot's most recent KV row.
    corrupt_at_step / corrupt_kind: SILENT corruption -- every injected
        value stays finite, so the isfinite numeric guards never fire
        and only the ABFT checksum layer (``repro.verify``) can catch
        it. 'weight' flips ``corrupt_bit`` of one element of a live
        QTensor ``q`` leaf (a single-event upset in the weight HBM);
        'kv' overwrites the target slot's most recent KV row with a
        large finite value; 'tile' zeroes a 128-wide out-channel slab
        of a weight leaf (the signature a mis-delivered weight-stream
        DMA tile leaves behind). Fires once, at the first dispatch at
        or after ``corrupt_at_step``.
    """

    kernel_raise_at_step: Optional[int] = None
    kernel_raise_count: int = 1
    step_delay_s: float = 0.0
    delay_at_steps: Tuple[int, ...] = ()
    nan_poke_step: Optional[int] = None
    nan_poke_slot: int = 0
    corrupt_at_step: Optional[int] = None
    corrupt_kind: str = "weight"    # 'weight' | 'kv' | 'tile'
    corrupt_bit: int = 6
    kv_corrupt_slot: int = 0

    # mutable bookkeeping (reset by ``inject`` on entry)
    raises_done: int = 0
    corrupt_done: bool = False
    log: List[Tuple[int, str]] = dataclasses.field(default_factory=list)

    # ---------------------------------------------------------- queries
    def maybe_raise(self, step: int) -> None:
        """Called by the engine immediately before decode dispatch."""
        if (self.kernel_raise_at_step is not None
                and step >= self.kernel_raise_at_step
                and self.raises_done < self.kernel_raise_count):
            self.raises_done += 1
            self.log.append((step, "kernel_raise"))
            raise InjectedKernelError(
                f"injected kernel failure at step {step} "
                f"({self.raises_done}/{self.kernel_raise_count})")

    def delay_s(self, step: int) -> float:
        if self.step_delay_s <= 0.0:
            return 0.0
        if self.delay_at_steps and step not in self.delay_at_steps:
            return 0.0
        self.log.append((step, "delay"))
        return self.step_delay_s

    def should_poke(self, step: int) -> bool:
        if self.nan_poke_step is not None and step == self.nan_poke_step:
            self.log.append((step, "nan_poke"))
            return True
        return False

    def should_corrupt(self, step: int) -> bool:
        """One-shot silent-corruption trigger, polled at decode dispatch."""
        if (self.corrupt_at_step is not None and not self.corrupt_done
                and step >= self.corrupt_at_step):
            self.corrupt_done = True
            self.log.append((step, f"corrupt_{self.corrupt_kind}"))
            return True
        return False


# One active plan, context-scoped. The engine reads it through
# ``active()`` so tests never have to thread the plan into the engine.
_ACTIVE: List[Optional[FaultPlan]] = [None]


def active() -> Optional[FaultPlan]:
    return _ACTIVE[0]


@contextlib.contextmanager
def inject(plan: FaultPlan):
    """Scope in which the serving engine sees ``plan``. Resets the plan's
    mutable bookkeeping on entry; always clears the slot on exit."""
    plan.raises_done = 0
    plan.corrupt_done = False
    plan.log = []
    prev, _ACTIVE[0] = _ACTIVE[0], plan
    try:
        yield plan
    finally:
        _ACTIVE[0] = prev


def poke_nan(caches, slot: int, row: int):
    """Write NaN into ``row`` of ``slot`` across every cache leaf (all
    layers/heads). Leaves are (repeats, slots, T, KH, hd); fp8_e4m3fn and
    bf16/f32 all represent NaN, so the write survives the cast."""
    def one(c):
        return c.at[:, slot, row].set(jax.numpy.nan)

    return jax.tree.map(one, caches)


def _map_first_qleaf(params, fn):
    """Apply ``fn(QTensor) -> QTensor`` to the first CHECKSUM-COVERED
    QTensor leaf of the tree: a rotation-consumer site (``w_down``),
    which the serving forward contracts against q/scale directly through
    the verified quant_dot every decode step. Corrupting one of these is
    the fault ABFT exists to catch -- the stored column checksum goes
    stale the moment the live ``q`` mutates. Other QTensors (attention
    projections, embeddings) are dequantized into plain matmuls before
    use, so an in-GEMM checksum never sees them -- only the host-side
    ``verify.params_ok`` scan does -- and the embedding is only read at
    the rows the stream happens to index, so corrupting it may silently
    touch nothing at all. Falls back to stacked per-layer leaves, then
    any QTensor; error when the tree has none."""
    from repro.core import wquant

    flat, treedef = jax.tree_util.tree_flatten_with_path(
        params, is_leaf=wquant.is_qleaf)
    leaves = [t for _, t in flat]
    idxs = [i for i, t in enumerate(leaves) if wquant.is_qleaf(t)]
    if not idxs:
        raise ValueError(
            "params tree has no QTensor leaf to corrupt; build the model "
            "with weight_quant='int8'")

    def keys(i):
        return [str(getattr(k, "key", getattr(k, "name", "")))
                for k in flat[i][0]]

    consumer = [i for i in idxs if wquant._is_consumer(keys(i))]
    hot = [i for i in idxs if leaves[i].q.ndim >= 3]
    pick = (consumer or hot or idxs)[0]
    leaves[pick] = fn(leaves[pick])
    return jax.tree_util.tree_unflatten(treedef, leaves)


def _replace_q(t, q_np):
    """Rebuild a QTensor leaf around a host-mutated ``q`` array, keeping
    the original device placement (and, crucially, the original stored
    ABFT checksum -- the corruption must NOT update it)."""
    import dataclasses as _dc

    newq = jax.numpy.asarray(q_np)
    if getattr(t.q, "sharding", None) is not None:
        newq = jax.device_put(newq, t.q.sharding)
    return _dc.replace(t, q=newq)


def flip_weight_bit(params, *, bit: int = 6, flat_byte: Optional[int] = None):
    """Flip one BIT of one element of the first QTensor weight leaf -- a
    single-event upset in weight memory. The result is a finite, wrong
    value: the isfinite guards cannot see it, the stored ABFT column
    checksum (computed from the pre-flip weight and deliberately left
    stale) can. ``flat_byte`` picks the byte (default: the middle of the
    leaf); ``bit`` the bit within it."""
    def fn(t):
        q = np.array(jax.device_get(t.q))       # writable host copy
        raw = q.view(np.uint8).reshape(-1)
        idx = raw.size // 2 if flat_byte is None else flat_byte
        raw[idx] ^= np.uint8(1 << bit)
        return _replace_q(t, q)

    return _map_first_qleaf(params, fn)


def perturb_kv_row(caches, slot: int, row: int, value: float = 448.0):
    """Overwrite ``row`` of ``slot`` with a large FINITE value across
    every cache leaf -- silent KV corruption. 448 is fp8_e4m3's max
    normal, so the write survives every cache dtype without becoming
    inf/NaN; the numeric guards stay blind and only the ABFT KV
    conservation check (``repro.verify.kv_sums_ok``) trips."""
    def one(c):
        return c.at[:, slot, row].set(jax.numpy.asarray(value, c.dtype))

    return jax.tree.map(one, caches)


def clobber_stream_tile(params, *, width: int = 128):
    """Zero a ``width``-wide out-channel slab of the first QTensor weight
    leaf -- the footprint a mis-delivered/aborted weight-stream DMA tile
    leaves in memory (the streamed quant_dot schedule prefetches the
    weight in (n, block_n) tiles). All-finite, guard-invisible; the ABFT
    checksum column rides OUTSIDE the DMA ring precisely so this class
    of fault stays detectable."""
    def fn(t):
        q = np.array(jax.device_get(t.q))
        d = q.shape[-1]
        w = min(width, d)
        lo = max((d // 2) - w // 2, 0)
        q[..., lo:lo + w] = 0
        return _replace_q(t, q)

    return _map_first_qleaf(params, fn)


def arrival_flood(num: int, *, prompt_len: int, max_new_tokens: int,
                  arrival_time: float = 0.0,
                  deadline: Optional[float] = None,
                  vocab: int = 256, seed: int = 0,
                  rid_base: int = 0) -> list:
    """A burst of ``num`` identical-shape requests all arriving at once --
    the overload pattern that exercises bounded-queue rejection and
    deadline shedding together."""
    from repro.serving.scheduler import Request

    rng = np.random.default_rng(seed)
    out = []
    for i in range(num):
        toks = rng.integers(1, vocab, size=(prompt_len,)).astype(np.int32)
        out.append(Request(
            rid=rid_base + i, tokens=toks, max_new_tokens=max_new_tokens,
            arrival_time=arrival_time, deadline=deadline))
    return out
