"""Fault-injection harness for the hardened serving engine.

Chaos testing with surgical faults: every recovery path in
``serving.engine`` (deadline shed, queue rejection, watchdog retry,
schedule-degrade re-warm, NaN-guard retirement) is exercised by injecting
the triggering fault at a *chosen step* of a real serve run, then
asserting the run completes with the right per-request
``Completion.status`` and bitwise-identical ``ok`` outputs.

Faults are injected at the HOST dispatch boundary, on purpose:

  * ``maybe_raise`` fires BEFORE the jitted decode call, so the donated
    cache operand was never consumed -- the caches the engine holds are
    intact and the retry path re-dispatches on valid state. (Raising
    *inside* a donated jit would leave the caches in a consumed/undefined
    state; real kernel failures surface at dispatch too -- XLA raises
    from the blocking host call.)
  * ``poke_nan`` writes NaN into already-written KV rows of a live slot
    (slot axis 1, row axis 2 of every ``(repeats, slots, T, KH, hd)``
    leaf -- see ``serving.cache.alloc_kv_caches``). Row ``pos - 1`` is
    attended by the very next decode step, so the poison propagates to
    that slot's logits and trips the numeric guard; the row is rewritten
    by prefill-insert before any successor request can attend it, so the
    fault stays request-local.
  * ``delay_s`` sleeps on the host around the step, simulating a stuck
    device/step for the watchdog without touching numerics.

Activation is context-scoped (``with inject(plan): ...``) so a leaked
fault can never outlive a test; the engine polls the module-level
``active()`` accessor, keeping the zero-fault hot path one attribute
load + None check.
"""
from __future__ import annotations

import contextlib
import dataclasses
from typing import List, Optional, Sequence, Tuple

import jax
import numpy as np

__all__ = [
    "FaultPlan",
    "InjectedKernelError",
    "inject",
    "active",
    "poke_nan",
    "arrival_flood",
]


class InjectedKernelError(RuntimeError):
    """The synthetic kernel failure raised by ``FaultPlan.maybe_raise``."""


@dataclasses.dataclass
class FaultPlan:
    """What to break, and when (all steps in engine step-clock units).

    kernel_raise_at_step: raise ``InjectedKernelError`` at decode dispatch
        of this step (None = never).
    kernel_raise_count: how many consecutive dispatch attempts fail
        starting at ``kernel_raise_at_step`` -- 1 exercises the
        retry-once path; 2+ forces a degradation-ladder re-warm.
    step_delay_s / delay_at_steps: artificial per-step host latency, at
        the listed steps (empty = every step once step_delay_s > 0).
        Trips the decode watchdog.
    nan_poke_step / nan_poke_slot: before dispatching this step, write
        NaN into the target slot's most recent KV row.
    """

    kernel_raise_at_step: Optional[int] = None
    kernel_raise_count: int = 1
    step_delay_s: float = 0.0
    delay_at_steps: Tuple[int, ...] = ()
    nan_poke_step: Optional[int] = None
    nan_poke_slot: int = 0

    # mutable bookkeeping (reset by ``inject`` on entry)
    raises_done: int = 0
    log: List[Tuple[int, str]] = dataclasses.field(default_factory=list)

    # ---------------------------------------------------------- queries
    def maybe_raise(self, step: int) -> None:
        """Called by the engine immediately before decode dispatch."""
        if (self.kernel_raise_at_step is not None
                and step >= self.kernel_raise_at_step
                and self.raises_done < self.kernel_raise_count):
            self.raises_done += 1
            self.log.append((step, "kernel_raise"))
            raise InjectedKernelError(
                f"injected kernel failure at step {step} "
                f"({self.raises_done}/{self.kernel_raise_count})")

    def delay_s(self, step: int) -> float:
        if self.step_delay_s <= 0.0:
            return 0.0
        if self.delay_at_steps and step not in self.delay_at_steps:
            return 0.0
        self.log.append((step, "delay"))
        return self.step_delay_s

    def should_poke(self, step: int) -> bool:
        if self.nan_poke_step is not None and step == self.nan_poke_step:
            self.log.append((step, "nan_poke"))
            return True
        return False


# One active plan, context-scoped. The engine reads it through
# ``active()`` so tests never have to thread the plan into the engine.
_ACTIVE: List[Optional[FaultPlan]] = [None]


def active() -> Optional[FaultPlan]:
    return _ACTIVE[0]


@contextlib.contextmanager
def inject(plan: FaultPlan):
    """Scope in which the serving engine sees ``plan``. Resets the plan's
    mutable bookkeeping on entry; always clears the slot on exit."""
    plan.raises_done = 0
    plan.log = []
    prev, _ACTIVE[0] = _ACTIVE[0], plan
    try:
        yield plan
    finally:
        _ACTIVE[0] = prev


def poke_nan(caches, slot: int, row: int):
    """Write NaN into ``row`` of ``slot`` across every cache leaf (all
    layers/heads). Leaves are (repeats, slots, T, KH, hd); fp8_e4m3fn and
    bf16/f32 all represent NaN, so the write survives the cast."""
    def one(c):
        return c.at[:, slot, row].set(jax.numpy.nan)

    return jax.tree.map(one, caches)


def arrival_flood(num: int, *, prompt_len: int, max_new_tokens: int,
                  arrival_time: float = 0.0,
                  deadline: Optional[float] = None,
                  vocab: int = 256, seed: int = 0,
                  rid_base: int = 0) -> list:
    """A burst of ``num`` identical-shape requests all arriving at once --
    the overload pattern that exercises bounded-queue rejection and
    deadline shedding together."""
    from repro.serving.scheduler import Request

    rng = np.random.default_rng(seed)
    out = []
    for i in range(num):
        toks = rng.integers(1, vocab, size=(prompt_len,)).astype(np.int32)
        out.append(Request(
            rid=rid_base + i, tokens=toks, max_new_tokens=max_new_tokens,
            arrival_time=arrival_time, deadline=deadline))
    return out
