"""Test-support packages: fault injection for the serving robustness layer."""
