"""Unified plan-based Hadamard API: one entry point for every transform.

This is the seam the whole repo routes rotations through (DESIGN.md
section 5). Instead of four divergent entry points with string-typed
knobs, callers build (or let us cache) a :class:`HadamardPlan` --
everything shape-dependent is precomputed exactly once per
``(n, dtype, compute_dtype, backend, epilogue, scale, block_m)`` key:

  * the 128-factorization ``n = 128^k * r`` and the stacked per-pass base
    matrices (including the I (x) H_r diagonal tiling for r > 1 and the
    scale folded into pass 0);
  * the resolved backend (registry lookup: explicit > env override >
    auto-by-size/platform);
  * the VMEM row-tile ``block_m``.

and ``hadamard(x, plan)`` dispatches. Composable epilogues make the fused
rotate+quantize kernel the default hot path:

  * ``epilogue=None``                     -> rotated tensor
  * ``QuantEpilogue("int8"|"fp8_e4m3"|"fp8_e5m2", per_token=True)``
                                          -> ``(q, scales)`` from a single
                                             VMEM-resident kernel
  * ``QuantEpilogue(..., dequant=True)``  -> fake-quantized rotated tensor
                                             (training path), same single
                                             kernel

Non-power-of-2 sizes are handled by the grouped transform I_g (x) H_p
with p the largest power-of-2 divisor (DESIGN.md section 3): the plan
carries both ``n`` (full axis) and ``p`` (per-group transform size), and
epilogue scales stay per-FULL-token (computed outside the kernel in that
case, so grouped semantics match the historical two-step path).

Autodiff: the transform is its own adjoint (H symmetric, scale scalar),
so the pullback is one more transform. Epilogue paths carry the
straight-through estimator: quantization is treated as identity in the
backward pass, so ``d(q)/dx ~= H/s`` and ``d(dequant)/dx ~= H``. This is
a DELIBERATE training-numerics upgrade over differentiating the unfused
``quantize(hadamard(x))`` directly, whose ``round()`` has zero gradient
almost everywhere (only the absmax scale branch leaks signal) -- the STE
is the standard QAT estimator and is what the fused path exists to serve.
Forward numerics are unchanged.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Optional, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np
from jax.dtypes import float0

from repro.core.hadamard import (
    base_matrices_np,
    factorize,
    largest_pow2_divisor,
    resolve_compute_dtype,
    resolve_scale,
)
from repro.kernels import registry
from repro.kernels.ref import is_pow2
from repro.kernels.registry import QSPECS, get_backend, select_backend

__all__ = [
    "QuantEpilogue",
    "HadamardPlan",
    "QuantDotSpec",
    "RotationSpec",
    "plan_for",
    "make_plan",
    "hadamard",
    "quant_dot",
    "quant_dot_experts",
    "plan_cache_info",
]


@dataclasses.dataclass(frozen=True)
class QuantEpilogue:
    """Quantization epilogue applied to the rotated tensor before write-back.

    mode:      'int8' | 'fp8_e4m3' | 'fp8_e5m2'
    per_token: one symmetric absmax scale per (full-length) token row;
               False = one scale per tensor (never fusable: needs a
               global reduction, so it always runs as transform +
               XLA epilogue).
    dequant:   return the fake-quantized (quantize->dequantize) rotated
               tensor in the input dtype instead of ``(q, scales)`` --
               the training-path form consumed by fake-quant matmuls.
    """

    mode: str
    per_token: bool = True
    dequant: bool = False

    def __post_init__(self):
        if self.mode not in QSPECS:
            raise ValueError(
                f"unknown quantization mode {self.mode!r}; "
                f"expected one of {sorted(QSPECS)}"
            )


@dataclasses.dataclass(frozen=True)
class HadamardPlan:
    """Everything shape-dependent about one Hadamard configuration,
    computed once and cached. Hashable (the stacked base matrices are
    excluded from eq/hash), so jitted implementations take the plan as a
    static argument and XLA caches per plan."""

    n: int                           # full last-axis size
    p: int                           # per-group pow2 transform size (== n when pow2)
    dtype: str                       # canonical input/output dtype name
    compute_dtype: str               # dtype the matmul passes run in (f32
                                     # accumulation always; see
                                     # hadamard.resolve_compute_dtype)
    backend: str                     # resolved registry backend name
    scale: Optional[float]           # numeric scale folded into pass 0 (None = +-1)
    epilogue: Optional[QuantEpilogue]
    block_m: Optional[int]           # VMEM row tile (None = per-call heuristic)
    k: int                           # number of 128-factors of p
    r: int                           # residual pow2 factor (1 <= r < 128)
    mesh_axes: Optional[Tuple[str, ...]] = None
                                     # mesh axes the quant_dot weight's
                                     # out-channel dim is sharded over --
                                     # part of the cache key, so plans
                                     # built under different meshes never
                                     # alias; None = single-device plan
    mats: np.ndarray = dataclasses.field(repr=False, compare=False, default=None)

    @property
    def grouped(self) -> bool:
        return self.p != self.n

    @property
    def num_passes(self) -> int:
        return 0 if self.p == 1 else int(self.mats.shape[0])


@functools.lru_cache(maxsize=None)
def _build_plan(n, p, dtype_name, compute_dtype, scale_val, backend, epilogue,
                block_m, mesh_axes=None):
    if p == 1:
        k, r, mats = 0, 1, np.ones((1, 1, 1), np.float32)
    else:
        k, r = factorize(p)
        mats = np.stack(base_matrices_np(p, scale_val))
    return HadamardPlan(
        n=n, p=p, dtype=dtype_name, compute_dtype=compute_dtype,
        backend=backend, scale=scale_val, epilogue=epilogue, block_m=block_m,
        k=k, r=r, mesh_axes=mesh_axes, mats=mats,
    )


def plan_for(
    n: int,
    *,
    dtype: Any = jnp.float32,
    scale: Union[str, float, None] = "ortho",
    backend: Optional[str] = None,
    epilogue: Optional[QuantEpilogue] = None,
    block_m: Optional[int] = None,
    compute_dtype: Any = None,
    mesh_axes: Optional[Tuple[str, ...]] = None,
) -> HadamardPlan:
    """Build (or fetch from the cache) the plan for an n-point transform.

    ``backend=None`` resolves via the registry: ``REPRO_HADAMARD_BACKEND``
    env override first, then auto-selection by size/platform. Non-power-
    of-2 ``n`` plans the grouped transform on the largest power-of-2
    divisor. ``compute_dtype=None`` resolves the dtype the matmul passes
    run in: native bf16/fp16 passes with f32 MXU accumulation for 16-bit
    inputs, f32 otherwise (explicitly overridable). ``mesh_axes`` marks
    a quant_dot plan as sharded over those mesh axes (the out-channel dim
    of the weight); it is part of the cache key, so plans built under a
    mesh never alias single-device plans. Repeated calls with the same
    key return the *same* plan object, so downstream jit caches hit.
    """
    if n < 1:
        raise ValueError(f"Hadamard size must be >= 1, got {n}")
    p = n if is_pow2(n) else largest_pow2_divisor(n)
    scale_val = resolve_scale(scale, p)
    resolved = select_backend(p, backend)
    return _build_plan(
        n, p, jnp.dtype(dtype).name,
        resolve_compute_dtype(dtype, compute_dtype), scale_val, resolved,
        epilogue, block_m, mesh_axes
    )


# Alias: ISSUE/API docs name both; plan_for reads better at call sites.
make_plan = plan_for


def plan_cache_info():
    """Plan-cache statistics (functools.lru_cache CacheInfo)."""
    return _build_plan.cache_info()


def _strip(plan: HadamardPlan) -> HadamardPlan:
    """The epilogue-free twin of a plan (used by fallbacks and pullbacks).
    Mesh axes are dropped too: the plain transform never shards."""
    if plan.epilogue is None and plan.mesh_axes is None:
        return plan
    return _build_plan(
        plan.n, plan.p, plan.dtype, plan.compute_dtype, plan.scale,
        plan.backend, None, plan.block_m
    )


# -------------------------------------------------------------- dispatch
def _group(x: jnp.ndarray, plan: HadamardPlan) -> jnp.ndarray:
    return x.reshape(*x.shape[:-1], plan.n // plan.p, plan.p)


def _dispatch_transform(x, plan: HadamardPlan, interpret: bool):
    if plan.p == 1:
        return x if plan.scale is None else x * jnp.asarray(plan.scale, x.dtype)
    be = get_backend(plan.backend)
    if plan.grouped:
        return be.transform(_group(x, plan), plan, interpret).reshape(x.shape)
    return be.transform(x, plan, interpret)


def _apply_epilogue_xla(y, epi: QuantEpilogue, out_dtype):
    """Reference epilogue on an already-rotated tensor (used when the
    backend has no fused path, for per-tensor scales, and for grouped
    transforms where the scale must span the full token row). Shares
    ``registry._quantize_rows`` with the fused kernels so numerics agree
    bit-for-bit."""
    q, s = registry._quantize_rows(
        y.astype(jnp.float32), epi.mode, axis=-1 if epi.per_token else None)
    if epi.dequant:
        return registry._dequantize(q, s, epi.mode).astype(out_dtype)
    return q.astype(QSPECS[epi.mode][1]), s


def _fusable(plan: HadamardPlan) -> bool:
    be = get_backend(plan.backend)
    return (
        not plan.grouped
        and plan.p > 1
        and plan.epilogue.per_token
        and be.fused is not None
        and be.supports(plan.p)
    )


def _dispatch_fused(x, plan: HadamardPlan, interpret: bool):
    if _fusable(plan):
        return get_backend(plan.backend).fused(x, plan, interpret)
    y = _dispatch_transform(x, _strip(plan), interpret)
    return _apply_epilogue_xla(y, plan.epilogue, x.dtype)


def _dispatch_fused_dequant(x, plan: HadamardPlan, interpret: bool):
    if _fusable(plan):
        return get_backend(plan.backend).fused_dequant(x, plan, interpret)
    y = _dispatch_transform(x, _strip(plan), interpret)
    return _apply_epilogue_xla(y, plan.epilogue, x.dtype)


# -------------------------------------------------------------- autodiff
@functools.partial(jax.custom_vjp, nondiff_argnums=(1, 2))
def _transform(x, plan: HadamardPlan, interpret: bool):
    return _dispatch_transform(x, plan, interpret)


def _transform_fwd(x, plan, interpret):
    return _dispatch_transform(x, plan, interpret), None


def _transform_bwd(plan, interpret, _res, g):
    # H^T = H and the scale is scalar: the op is self-adjoint.
    return (_dispatch_transform(g, plan, interpret),)


_transform.defvjp(_transform_fwd, _transform_bwd)


@functools.partial(jax.custom_vjp, nondiff_argnums=(1, 2))
def _fused(x, plan: HadamardPlan, interpret: bool):
    return _dispatch_fused(x, plan, interpret)


def _fused_fwd(x, plan, interpret):
    q, s = _dispatch_fused(x, plan, interpret)
    return (q, s), s


def _fused_bwd(plan, interpret, s, ct):
    """Straight-through: q = had(x)/s with s treated as a statistic, so
    the pullback of gq is had(gq)/s and the scale branch contributes
    nothing. int8 outputs are integer-typed (float0 cotangent): their
    quantized branch is non-differentiable by construction -- use
    ``QuantEpilogue(dequant=True)`` for the training path."""
    gq, _gs = ct
    if gq.dtype == float0:
        return (jnp.zeros(gq.shape, jnp.dtype(plan.dtype)),)
    gy = gq.astype(jnp.float32) / s
    gx = _dispatch_transform(gy, _strip(plan), interpret)
    return (gx.astype(jnp.dtype(plan.dtype)),)


_fused.defvjp(_fused_fwd, _fused_bwd)


@functools.partial(jax.custom_vjp, nondiff_argnums=(1, 2))
def _fused_dequant(x, plan: HadamardPlan, interpret: bool):
    return _dispatch_fused_dequant(x, plan, interpret)


def _fused_dequant_fwd(x, plan, interpret):
    return _dispatch_fused_dequant(x, plan, interpret), None


def _fused_dequant_bwd(plan, interpret, _res, g):
    # Straight-through on quantize-dequantize: the op behaves as the plain
    # rotation in the backward pass (NOT the raw fake-quant grad, whose
    # round() is zero a.e. -- see module docstring).
    return (_dispatch_transform(g, _strip(plan), interpret),)


_fused_dequant.defvjp(_fused_dequant_fwd, _fused_dequant_bwd)


# ----------------------------------------------------------- entry point
_UNSET = object()  # distinguishes "not passed" from an explicit default


def hadamard(
    x: jnp.ndarray,
    plan: Optional[HadamardPlan] = None,
    *,
    scale: Union[str, float, None] = _UNSET,
    backend: Optional[str] = _UNSET,
    epilogue: Optional[QuantEpilogue] = _UNSET,
    block_m: Optional[int] = _UNSET,
    compute_dtype: Any = _UNSET,
    interpret: Optional[bool] = None,
) -> Union[jnp.ndarray, Tuple[jnp.ndarray, jnp.ndarray]]:
    """Walsh-Hadamard transform of the last axis -- THE entry point.

    With ``plan=None`` a plan is built (and cached) from the keyword
    arguments and ``x``'s shape/dtype; passing an explicit plan skips all
    per-call decisions (plan-configuration keywords may then not be
    passed -- the plan already pins them, and silently ignoring a
    conflicting ``epilogue=...`` would change the return type). Returns
    the rotated tensor, or ``(q, scales)`` when the plan carries a
    :class:`QuantEpilogue` (the fake-quantized tensor when the epilogue
    has ``dequant=True``).

    ``interpret=None`` auto-selects Pallas interpret mode off-TPU so CPU
    CI validates the same kernel code path.
    """
    n = x.shape[-1]
    if plan is None:
        plan = plan_for(
            n, dtype=x.dtype,
            scale="ortho" if scale is _UNSET else scale,
            backend=None if backend is _UNSET else backend,
            epilogue=None if epilogue is _UNSET else epilogue,
            block_m=None if block_m is _UNSET else block_m,
            compute_dtype=None if compute_dtype is _UNSET else compute_dtype,
        )
    else:
        passed = [name for name, v in (("scale", scale), ("backend", backend),
                                       ("epilogue", epilogue),
                                       ("block_m", block_m),
                                       ("compute_dtype", compute_dtype))
                  if v is not _UNSET]
        if passed:
            raise ValueError(
                f"hadamard() got both an explicit plan and {passed}; plan "
                "configuration is fixed at plan_for() time"
            )
        if plan.n != n:
            raise ValueError(
                f"plan was built for n={plan.n} but x has last axis {n}"
            )
        if jnp.dtype(plan.dtype) != x.dtype:
            raise ValueError(
                f"plan was built for dtype {plan.dtype} but x is {x.dtype.name}; "
                "build a plan with plan_for(n, dtype=x.dtype, ...)"
            )
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    if plan.epilogue is None:
        return _transform(x, plan, interpret)
    if plan.epilogue.dequant:
        return _fused_dequant(x, plan, interpret)
    return _fused(x, plan, interpret)


# ------------------------------------------------- fused quantized GEMM
def _qd_fusable(plan: HadamardPlan) -> bool:
    """Can the rotate+quantize+dot run as the backend's single kernel?
    Mirrors ``_fusable`` plus the backend must host a ``quant_dot`` and
    the minimal (p, 128) weight tile must fit the kernel's VMEM budget
    (fp8 operands cost 3 bytes/element in VMEM: storage + the exact bf16
    embedding; oversize plans take the unfused fallback instead of
    launching an over-budget kernel)."""
    from repro.kernels.quant_dot import _FP8_OPERAND_BYTES

    be = get_backend(plan.backend)
    wb = 1 if QSPECS[plan.epilogue.mode][2] else _FP8_OPERAND_BYTES
    return (
        not plan.grouped
        and plan.p > 1
        and plan.epilogue.per_token
        and getattr(be, "quant_dot", None) is not None
        and be.supports(plan.p)
        and plan.p * 128 * wb <= registry._VMEM_BUDGET_BYTES
    )


def _resolve_mesh_axes(weight_axes, d: Optional[int]):
    """Resolve a weight's logical out-channel axis -> concrete mesh axes
    for the sharded quant_dot dispatch. Returns None (single-device plan)
    when no mesh is active, the logical axis maps to nothing, the mapped
    axes' total size is 1, or ``d`` is not divisible by it (the same
    divisibility guard ``distributed.sharding.constrain`` applies)."""
    if not weight_axes or d is None:
        return None
    from repro.distributed.sharding import _resolve_axis, current_mesh

    mesh = current_mesh()
    if mesh is None:
        return None
    ax = _resolve_axis(mesh, weight_axes[-1])
    if ax is None:
        return None
    axes = (ax,) if isinstance(ax, str) else tuple(ax)
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    total = 1
    for a in axes:
        total *= sizes[a]
    if total <= 1 or d % total:
        return None
    return axes


# Trace-time record of the last sharded dispatch decision (row axes the
# activation was sharded over, whether the shard-local compute was the
# fused kernel, which backend ran it). Observability hook for tests --
# NOT an API.
_LAST_SHARDED_DISPATCH: dict = {}


def _sharded_fallback(reason: str, msg: str) -> None:
    """Record (and warn once per process per reason, via the shared
    ``registry.warn_once`` idiom) that a mesh plan fell back from the
    sharded/fused hot path. Sharded perf regressions -- a plan silently
    going replicated, or shard-local compute silently going unfused --
    used to be invisible; now they show up in
    ``TRACE_COUNTS[("sharded_quant_dot", reason)]`` and as a one-shot
    ``RuntimeWarning``."""
    registry.warn_once(
        ("sharded_quant_dot", reason),
        f"sharded quant_dot fallback [{reason}]: {msg} (warned once "
        "per process; TRACE_COUNTS[('sharded_quant_dot', "
        f"{reason!r})] keeps counting)")


def _strip_mesh(plan: HadamardPlan) -> HadamardPlan:
    """The single-device twin of a mesh plan (same backend/epilogue/
    tiling, mesh_axes=None) -- the plan the shard-local kernel runs."""
    if plan.mesh_axes is None:
        return plan
    return _build_plan(
        plan.n, plan.p, plan.dtype, plan.compute_dtype, plan.scale,
        plan.backend, plan.epilogue, plan.block_m)


def _row_shard_axes(mesh, plan: HadamardPlan, m: int) -> Tuple[str, ...]:
    """Mesh axes to row-shard the activation over inside the sharded
    quant_dot: the logical 'batch' (data) axes of the active rules table,
    minus axes already spent on the weight's out-channel shards, minus
    axes whose cumulative size does not divide the row count (same guard
    as ``distributed.sharding._build_parts``). Size-1 axes are kept --
    the spec stays structurally row-sharded and costs nothing."""
    from repro.distributed.sharding import _resolve_axis

    ax = _resolve_axis(mesh, "batch")
    if ax is None:
        return ()
    axes = (ax,) if isinstance(ax, str) else tuple(ax)
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    keep, total = [], 1
    for a in axes:
        if a in plan.mesh_axes:
            continue
        if m % (total * sizes[a]) == 0:
            keep.append(a)
            total *= sizes[a]
    return tuple(keep)


def _sharded_quant_dot(x, wq, sw, plan: HadamardPlan, interpret: bool,
                       schedule=None):
    """quant_dot over a mesh via ``shard_map``, fused and data-parallel:

      * the activation is ROW-SHARDED over the mesh data axes (the rules
        table's 'batch' axes, minus any axis the weight already uses,
        divisibility-guarded) -- each shard rotates and quantizes only
        its own rows, so transform work is data-parallel instead of
        replicated per shard;
      * the contraction axis is never split (the Hadamard spans it): each
        shard contracts against ITS slice of the weight columns with ITS
        slice of the per-out-channel scales, so per-shard weight scales
        are used end to end and the assembled result is bitwise the
        single-device int8 output;
      * the shard-local compute is the FUSED rotate-once Pallas kernel
        whenever the (mesh-stripped) plan fuses; otherwise the unfused
        oracle semantics run shard-locally (grouped sizes, per-tensor
        scales, xla backend -- counted + warned via
        ``_sharded_fallback("unfused_local")`` so the regression is
        observable).

    Returns None when the plan's mesh axes are not provided by the
    current mesh (caller falls back to the replicated single-device path
    and records ``mesh_mismatch``)."""
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    from repro.distributed.sharding import current_mesh
    from repro.kernels.quant_dot import epilogue_dot

    mesh = current_mesh()
    if mesh is None or any(a not in mesh.axis_names for a in plan.mesh_axes):
        return None
    spec_d = plan.mesh_axes if len(plan.mesh_axes) > 1 else plan.mesh_axes[0]
    local_plan = _strip_mesh(plan)
    epi = plan.epilogue
    lead, d = x.shape[:-1], wq.shape[-1]
    x2 = x.reshape(-1, plan.n)
    sw2 = sw.reshape(1, d).astype(jnp.float32)
    row_axes = _row_shard_axes(mesh, plan, x2.shape[0])
    spec_m = row_axes if len(row_axes) > 1 else (
        row_axes[0] if row_axes else None)

    be = get_backend(local_plan.backend)
    fused = _qd_fusable(local_plan) and be.quant_dot_fused
    _LAST_SHARDED_DISPATCH.update(
        fused=fused, row_axes=row_axes, mesh_axes=plan.mesh_axes,
        backend=local_plan.backend)
    if fused:
        def local(xl, wl, sl):
            # the fused kernel, shard-local: xl is this shard's rows,
            # wl/sl its weight columns + scales; the grid schedule
            # (rotate_once / revisit / streamed DMA ring) applies
            # per shard unchanged
            return be.quant_dot(xl, wl, sl, local_plan, interpret,
                                schedule)
    else:
        _sharded_fallback(
            "unfused_local",
            f"shard-local compute for the n={plan.n} {epi.mode} plan runs "
            f"the unfused oracle (backend {local_plan.backend!r}, "
            f"grouped={plan.grouped}); the fused rotate-once kernel "
            "requires the pallas backend, a power-of-2 size within the "
            "kernel cap, and per-token scales")

        def local(xl, wl, sl):
            # the unfused oracle, shard-local: factored rotate (grouped
            # sizes included), per-token quantize of the FULL row, then
            # the shared epilogue-dot contraction
            y = _dispatch_transform(xl, _strip(local_plan), interpret)
            q, s = registry._quantize_rows(y.astype(jnp.float32), epi.mode)
            return epilogue_dot(q, s, wl, sl, epi.mode, jnp.dtype(plan.dtype))

    out = shard_map(
        local, mesh=mesh,
        in_specs=(P(spec_m, None), P(None, spec_d), P(None, spec_d)),
        out_specs=P(spec_m, spec_d), check_rep=False,
    )(x2, wq, sw2)
    return out.reshape(*lead, d)


def _dispatch_quant_dot(x, wq, sw, plan: HadamardPlan, interpret: bool,
                        schedule=None):
    """rotate(x) -> per-token quantize -> contract against the offline-
    quantized weight (int8 w/ int32 accumulation, fp8 w/ f32), applying
    ``scale_x * scale_w`` in the epilogue. Mesh plans dispatch through
    shard_map -- row-sharded activations over the data axes, the weight's
    out-channel shards on its mesh axes, the fused rotate-once kernel
    shard-local; fused single-kernel when the plan supports it; otherwise
    the unfused oracle semantics (grouped transforms, per-tensor scales,
    backends without the kernel -- the pjit-shardable fallback).

    ``schedule`` picks the fused kernel's grid schedule (None defers to
    ``REPRO_QUANT_DOT_SCHEDULE``, then ``rotate_once``; ``"streamed"``
    double-buffers the weight DMA) and rides through the sharded
    dispatch to the shard-local kernel; the unfused oracle has no grid,
    so there it only validates."""
    if plan.mesh_axes and wq.ndim == 2 and plan.epilogue.per_token:
        out = _sharded_quant_dot(x, wq, sw, plan, interpret, schedule)
        if out is not None:
            return out
        _sharded_fallback(
            "mesh_mismatch",
            f"plan was built for mesh axes {plan.mesh_axes} but the "
            "current mesh does not provide them; quant_dot runs the "
            "replicated single-device path")
    elif plan.mesh_axes:
        _sharded_fallback(
            "unshardable_site",
            f"plan carries mesh axes {plan.mesh_axes} but the site "
            "cannot shard_map (needs a 2-D weight and per-token scales; "
            f"got wq.ndim={wq.ndim}, "
            f"per_token={plan.epilogue.per_token}); quant_dot runs the "
            "replicated single-device path")
    if _qd_fusable(plan):
        return get_backend(plan.backend).quant_dot(x, wq, sw, plan,
                                                   interpret, schedule)
    from repro.kernels.quant_dot import epilogue_dot

    y = _dispatch_transform(x, _strip(plan), interpret)
    epi = plan.epilogue
    q, s = registry._quantize_rows(
        y.astype(jnp.float32), epi.mode, axis=-1 if epi.per_token else None)
    return epilogue_dot(q, s, wq, sw, epi.mode, jnp.dtype(plan.dtype))


def _dequant_weight(wq, sw):
    return wq.astype(jnp.float32) * sw


def _zero_cotangent(a):
    if jnp.issubdtype(a.dtype, jnp.integer):
        return np.zeros(a.shape, dtype=float0)
    return jnp.zeros(a.shape, a.dtype)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5))
def _quant_dot_qw(x, wq, sw, plan: HadamardPlan, interpret: bool,
                  schedule=None):
    """Serving form: weights pre-quantized offline. Differentiable in x
    only (STE through the activation quantization); the quantized weight
    and its scales are statistics with zero pullback."""
    return _dispatch_quant_dot(x, wq, sw, plan, interpret, schedule)


def _quant_dot_qw_fwd(x, wq, sw, plan, interpret, schedule):
    return _dispatch_quant_dot(x, wq, sw, plan, interpret, schedule), (wq, sw)


def _quant_dot_qw_bwd(plan, interpret, schedule, res, g):
    # STE: out ~= had(x) @ W with W = dequant(wq, sw), so the x-pullback is
    # the (self-adjoint) rotation of g @ W^T.
    wq, sw = res
    W = _dequant_weight(wq, sw)
    gy = jnp.matmul(g.astype(jnp.float32), W.T,
                    preferred_element_type=jnp.float32)
    gx = _dispatch_transform(
        gy.astype(jnp.dtype(plan.dtype)), _strip(plan), interpret)
    return gx, _zero_cotangent(wq), _zero_cotangent(sw)


_quant_dot_qw.defvjp(_quant_dot_qw_fwd, _quant_dot_qw_bwd)


def _abft_quant_dot_impl(x, wq, sw, cw, plan, interpret, schedule):
    """Checksum-verified serving quant_dot (``repro.verify``, DESIGN.md
    section 14). Fused backends emit the per-row checksum residual from
    INSIDE the pallas_call (the verified kernel's real output is graph-
    identical to the unverified one); non-fused paths run the normal
    dispatch and derive the residual from the XLA oracle recompute.
    Rows whose residual exceeds the calibrated tolerance are poisoned
    with NaN -- an exact ``where`` select, so a healthy run is BITWISE
    identical to ABFT-off -- and surface at the serving step's logits
    guard, which retires the slot instead of emitting corrupt tokens."""
    from repro import verify
    from repro.kernels.quant_dot import xla_quant_dot_resid

    registry.TRACE_COUNTS[("abft", "quant_dot_site")] += 1
    be = get_backend(plan.backend)
    if _qd_fusable(plan) and be.quant_dot_fused:
        y, resid = be.quant_dot(x, wq, sw, plan, interpret, schedule,
                                check=cw)
    else:
        y = _dispatch_quant_dot(x, wq, sw, plan, interpret, schedule)
        resid = xla_quant_dot_resid(x, wq, sw, cw, plan, interpret)
    ok = verify.residual_ok(y, resid, n=wq.shape[0], d=wq.shape[-1])
    return jnp.where(ok, y, jnp.asarray(jnp.nan, y.dtype))


@functools.partial(jax.custom_vjp, nondiff_argnums=(4, 5, 6))
def _quant_dot_qw_abft(x, wq, sw, cw, plan: HadamardPlan, interpret: bool,
                       schedule=None):
    """ABFT twin of ``_quant_dot_qw``: same serving semantics plus the
    column-checksum verification of ``_abft_quant_dot_impl``. The
    checksum vector ``cw`` is a statistic of the weight (zero pullback,
    like ``wq``/``sw``); the backward pass is the identical STE."""
    return _abft_quant_dot_impl(x, wq, sw, cw, plan, interpret, schedule)


def _quant_dot_qw_abft_fwd(x, wq, sw, cw, plan, interpret, schedule):
    return (_abft_quant_dot_impl(x, wq, sw, cw, plan, interpret, schedule),
            (wq, sw, cw))


def _quant_dot_qw_abft_bwd(plan, interpret, schedule, res, g):
    wq, sw, cw = res
    W = _dequant_weight(wq, sw)
    gy = jnp.matmul(g.astype(jnp.float32), W.T,
                    preferred_element_type=jnp.float32)
    gx = _dispatch_transform(
        gy.astype(jnp.dtype(plan.dtype)), _strip(plan), interpret)
    return (gx, _zero_cotangent(wq), _zero_cotangent(sw),
            _zero_cotangent(cw))


_quant_dot_qw_abft.defvjp(_quant_dot_qw_abft_fwd, _quant_dot_qw_abft_bwd)


def _quant_dot_w_impl(x, w, plan: HadamardPlan, interpret: bool,
                      schedule=None):
    from repro.core.wquant import quantize_weight

    qt = quantize_weight(w, plan.epilogue.mode)
    return _dispatch_quant_dot(x, qt.q, qt.scale, plan, interpret, schedule)


@functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3, 4))
def _quant_dot_w(x, w, plan: HadamardPlan, interpret: bool, schedule=None):
    """Training form: full-precision weight, quantized per out-channel on
    the fly. STE through BOTH quantizations: out ~= had(x) @ w in the
    backward pass, so both gradients flow (w's raw fake-quant grad would
    be zero a.e. -- see the module docstring)."""
    return _quant_dot_w_impl(x, w, plan, interpret, schedule)


def _quant_dot_w_fwd(x, w, plan, interpret, schedule):
    return _quant_dot_w_impl(x, w, plan, interpret, schedule), (x, w)


def _quant_dot_w_bwd(plan, interpret, schedule, res, g):
    x, w = res
    gf = g.astype(jnp.float32)
    gy = jnp.matmul(gf, w.astype(jnp.float32).T,
                    preferred_element_type=jnp.float32)
    gx = _dispatch_transform(
        gy.astype(jnp.dtype(plan.dtype)), _strip(plan), interpret)
    y = _dispatch_transform(x, _strip(plan), interpret)
    yf = y.reshape(-1, y.shape[-1]).astype(jnp.float32)
    gw = jnp.matmul(yf.T, gf.reshape(-1, gf.shape[-1]),
                    preferred_element_type=jnp.float32)
    return gx, gw.astype(w.dtype)


_quant_dot_w.defvjp(_quant_dot_w_fwd, _quant_dot_w_bwd)


def quant_dot(
    x: jnp.ndarray,
    w: Union[jnp.ndarray, "QTensor", Tuple[jnp.ndarray, jnp.ndarray]],
    plan: Optional[HadamardPlan] = None,
    *,
    mode: str = _UNSET,
    scale: Union[str, float, None] = _UNSET,
    backend: Optional[str] = _UNSET,
    block_m: Optional[int] = _UNSET,
    compute_dtype: Any = _UNSET,
    weight_axes: Optional[Tuple] = _UNSET,
    interpret: Optional[bool] = None,
    schedule: Optional[str] = None,
) -> jnp.ndarray:
    """``quantize(hadamard(x)) @ quantize(w)`` as ONE fused consumer path.

    The quantized hot path end to end: the row block is rotated, per-token
    quantized, and immediately contracted against the offline-quantized
    weight tile inside the same kernel (int8 operands with int32 MXU
    accumulation; fp8 operands multiplied exactly in bf16 with f32
    accumulation), with ``scale_x * scale_w`` applied in the epilogue --
    the rotated/quantized activations never round-trip through HBM.

    ``w`` is either the full-precision weight ``(n, d)`` (quantized per
    out-channel on the fly; differentiable in both operands via the
    straight-through estimator) or a pre-quantized
    :class:`repro.core.wquant.QTensor` (legacy ``(wq, sw)`` tuples are
    still accepted) from :func:`repro.core.wquant.quantize_weight` -- the
    serving form; differentiable in ``x`` only.

    ``weight_axes`` (the weight's logical sharding axes, e.g.
    ``("dff", "fsdp")``) makes the call mesh-aware: under an active
    sharding-rules mesh the out-channel axis resolves to concrete mesh
    axes, the plan is keyed on them, and dispatch goes through
    ``shard_map`` with per-shard weight scales (the xla backend as the
    shard-local oracle). Without a mesh this is a no-op.

    Plans must carry a non-dequant :class:`QuantEpilogue`; ``plan=None``
    builds one from ``mode`` (default ``"int8"``). Grouped (non-power-of-
    2) sizes and per-tensor scales fall back to the unfused oracle
    semantics -- same math, separate XLA ops, pjit-shardable.

    ``schedule`` selects the fused kernel's grid schedule
    (``"rotate_once"`` / ``"revisit"`` / ``"streamed"``; ``None`` defers
    to ``REPRO_QUANT_DOT_SCHEDULE``). It is a dispatch-level knob, not
    plan configuration: every schedule is bitwise-identical, so it may
    be passed alongside an explicit plan. ``"streamed"`` double-buffers
    the weight-tile DMA against the contraction; under interpret mode it
    falls back to ``rotate_once`` (warn-once) unless
    ``REPRO_QUANT_DOT_STREAM_INTERPRET=1``.
    """
    from repro.core.wquant import QTensor

    n = x.shape[-1]
    if isinstance(w, QTensor):
        w = (w.q, w.scale)
    if plan is None:
        d_out = w[0].shape[-1] if isinstance(w, tuple) else w.shape[-1]
        plan = plan_for(
            n, dtype=x.dtype,
            scale="ortho" if scale is _UNSET else scale,
            backend=None if backend is _UNSET else backend,
            epilogue=QuantEpilogue("int8" if mode is _UNSET else mode),
            block_m=None if block_m is _UNSET else block_m,
            compute_dtype=None if compute_dtype is _UNSET else compute_dtype,
            mesh_axes=_resolve_mesh_axes(
                None if weight_axes is _UNSET else weight_axes, d_out),
        )
    else:
        passed = [name for name, v in (("mode", mode), ("scale", scale),
                                       ("backend", backend),
                                       ("block_m", block_m),
                                       ("compute_dtype", compute_dtype),
                                       ("weight_axes", weight_axes))
                  if v is not _UNSET]
        if passed:
            raise ValueError(
                f"quant_dot() got both an explicit plan and {passed}; plan "
                "configuration is fixed at plan_for() time"
            )
        if plan.n != n:
            raise ValueError(
                f"plan was built for n={plan.n} but x has last axis {n}")
        if jnp.dtype(plan.dtype) != x.dtype:
            raise ValueError(
                f"plan was built for dtype {plan.dtype} but x is "
                f"{x.dtype.name}; build a plan with plan_for(n, "
                "dtype=x.dtype, ...)")
    if plan.epilogue is None or plan.epilogue.dequant:
        raise ValueError(
            "quant_dot requires a plan with a non-dequant QuantEpilogue "
            f"(got {plan.epilogue!r}); use plan_for(n, epilogue="
            "QuantEpilogue(mode))"
        )
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    if isinstance(w, tuple):
        wq, sw = w
        if wq.shape[0] != n:
            raise ValueError(
                f"quantized weight has contraction dim {wq.shape[0]}, "
                f"expected {n}")
        want_dt = QSPECS[plan.epilogue.mode][1]
        if wq.dtype != want_dt:
            raise ValueError(
                f"pre-quantized weight dtype {wq.dtype.name} does not "
                f"match the plan's {plan.epilogue.mode!r} storage dtype "
                f"{jnp.dtype(want_dt).name}; quantize with "
                "wquant.quantize_weight(w, mode)")
        return _quant_dot_qw(x, wq, sw, plan, interpret, schedule)
    if w.shape[0] != n:
        raise ValueError(
            f"weight has contraction dim {w.shape[0]}, expected {n}")
    return _quant_dot_w(x, w, plan, interpret, schedule)


# ----------------------------------------------------- expert consumers
def _qd_experts_fusable(plan: HadamardPlan) -> bool:
    """Can the expert site run as the single 3-D rotate-once kernel?
    Needs everything ``_qd_fusable`` needs plus a backend hosting the
    expert kernel, and NO active mesh: under a mesh the expert einsum
    shards via GSPMD/pjit (a pallas_call would not partition), so the
    einsum form stays the sharded path -- counted (not warned: it is the
    designed mesh path, not a regression) in
    ``TRACE_COUNTS[("sharded_quant_dot", "experts_einsum_on_mesh")]``.

    Like every ``sharding_rules`` consumer (``constrain`` included), the
    mesh is read from the ambient context AT TRACE TIME: an outer jit
    traced off-mesh bakes the kernel form, one traced under the mesh
    bakes the einsum. Launchers key their step functions per mesh
    (``launch.steps``), so each mesh context traces its own executable."""
    from repro.distributed.sharding import current_mesh

    be = get_backend(plan.backend)
    kernel_ok = (_qd_fusable(plan)
                 and getattr(be, "quant_dot_experts", None) is not None)
    if kernel_ok and current_mesh() is not None:
        registry.TRACE_COUNTS[
            ("sharded_quant_dot", "experts_einsum_on_mesh")] += 1
        return False
    return kernel_ok


def _experts_einsum_qw(x, wq, sw, plan: HadamardPlan, interpret: bool):
    """The einsum form of the expert consumer: fused rotate+quantize
    kernel on the activation side ((q, scales) epilogue, one kernel --
    all experts share d_ff), then a real low-precision einsum per expert
    against PRE-quantized weights. The GSPMD-shardable path and the
    oracle the fused 3-D kernel is tested against. The scales factor out
    of the einsum exactly (s per token row, sw per
    (expert, out-channel))."""
    q, s = hadamard(x, plan, interpret=interpret)
    if QSPECS[plan.epilogue.mode][2]:
        acc = jnp.einsum("becf,efd->becd", q.astype(jnp.int8),
                         wq.astype(jnp.int8),
                         preferred_element_type=jnp.int32
                         ).astype(jnp.float32)
    else:
        acc = jnp.einsum("becf,efd->becd",
                         q.astype(jnp.bfloat16), wq.astype(jnp.bfloat16),
                         preferred_element_type=jnp.float32)
    out = acc * s * sw[None]                            # (B,E,c,d)*(1,E,1,d)
    return out.astype(x.dtype)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5))
def _quant_dot_experts_qw(x, wq, sw, plan: HadamardPlan, interpret: bool,
                          schedule=None):
    """Serving form for stacked expert weights, PRE-quantized (zero
    per-forward weight quantization), differentiable in x only (STE).

    Dispatch: the single fused 3-D (expert, rows, out-channels)
    rotate-once kernel when the plan fuses off-mesh -- rotation,
    per-token quantize AND the per-expert contraction in ONE pallas_call,
    no HBM round trip of (q, scales); otherwise the einsum form
    (``_experts_einsum_qw``: grouped sizes, active meshes via GSPMD,
    backends without the expert kernel). ``schedule`` picks the fused
    kernel's grid schedule (``"streamed"`` = DMA-ring weight prefetch);
    the einsum form has no grid, so there it is ignored."""
    if _qd_experts_fusable(plan):
        return get_backend(plan.backend).quant_dot_experts(
            x, wq, sw, plan, interpret, schedule)
    return _experts_einsum_qw(x, wq, sw, plan, interpret)


def _qd_experts_qw_fwd(x, wq, sw, plan, interpret, schedule):
    return (_quant_dot_experts_qw(x, wq, sw, plan, interpret, schedule),
            (wq, sw))


def _qd_experts_qw_bwd(plan, interpret, schedule, res, g):
    # STE: out ~= had(x) @ W per expert with W = dequant(wq, sw); the
    # quantized weight and its scales are statistics with zero pullback.
    wq, sw = res
    W = wq.astype(jnp.float32) * sw                     # (E, f, d)
    gf = g.astype(jnp.float32)
    gy = jnp.einsum("becd,efd->becf", gf, W)
    gx = _dispatch_transform(
        gy.astype(jnp.dtype(plan.dtype)), _strip(plan), interpret)
    return gx, _zero_cotangent(wq), _zero_cotangent(sw)


_quant_dot_experts_qw.defvjp(_qd_experts_qw_fwd, _qd_experts_qw_bwd)


def _abft_quant_dot_experts_impl(x, wq, sw, cw, plan, interpret, schedule):
    """Checksum-verified expert consumer: the fused 3-D kernel emits a
    per-(expert, row) residual alongside the real output (DESIGN.md
    section 14); rows that fail verification are NaN-poisoned via an
    exact select (healthy runs stay bitwise identical to ABFT-off).
    Callers gate on ``_qd_experts_fusable`` -- the einsum form has no
    checksum output."""
    from repro import verify

    registry.TRACE_COUNTS[("abft", "quant_dot_experts_site")] += 1
    y, resid = get_backend(plan.backend).quant_dot_experts(
        x, wq, sw, plan, interpret, schedule, check=cw)
    ok = verify.residual_ok(y, resid, n=wq.shape[1], d=wq.shape[-1])
    return jnp.where(ok, y, jnp.asarray(jnp.nan, y.dtype))


@functools.partial(jax.custom_vjp, nondiff_argnums=(4, 5, 6))
def _quant_dot_experts_qw_abft(x, wq, sw, cw, plan: HadamardPlan,
                               interpret: bool, schedule=None):
    """ABFT twin of ``_quant_dot_experts_qw`` (fused form only); ``cw``
    is a weight statistic with zero pullback, backward is the same STE."""
    return _abft_quant_dot_experts_impl(x, wq, sw, cw, plan, interpret,
                                        schedule)


def _qd_experts_qw_abft_fwd(x, wq, sw, cw, plan, interpret, schedule):
    return (_abft_quant_dot_experts_impl(x, wq, sw, cw, plan, interpret,
                                         schedule),
            (wq, sw, cw))


def _qd_experts_qw_abft_bwd(plan, interpret, schedule, res, g):
    wq, sw, cw = res
    W = wq.astype(jnp.float32) * sw                     # (E, f, d)
    gf = g.astype(jnp.float32)
    gy = jnp.einsum("becd,efd->becf", gf, W)
    gx = _dispatch_transform(
        gy.astype(jnp.dtype(plan.dtype)), _strip(plan), interpret)
    return (gx, _zero_cotangent(wq), _zero_cotangent(sw),
            _zero_cotangent(cw))


_quant_dot_experts_qw_abft.defvjp(_qd_experts_qw_abft_fwd,
                                  _qd_experts_qw_abft_bwd)


def _quant_dot_experts_w_impl(x, w, plan, interpret, schedule=None):
    from repro.core.wquant import quantize_weight

    qt = quantize_weight(w, plan.epilogue.mode)         # (E,f,d), (E,1,d)
    return _quant_dot_experts_qw(x, qt.q, qt.scale, plan, interpret,
                                 schedule)


@functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3, 4))
def _quant_dot_experts_w(x, w, plan: HadamardPlan, interpret: bool,
                         schedule=None):
    """Training einsum form: full-precision expert weights, quantized per
    (expert, out-channel) on the fly. STE through BOTH quantizations."""
    return _quant_dot_experts_w_impl(x, w, plan, interpret, schedule)


def _qd_experts_w_fwd(x, w, plan, interpret, schedule):
    return _quant_dot_experts_w_impl(x, w, plan, interpret, schedule), (x, w)


def _qd_experts_w_bwd(plan, interpret, schedule, res, g):
    x, w = res
    stripped = _strip(plan)
    gf = g.astype(jnp.float32)
    gy = jnp.einsum("becd,efd->becf", gf, w.astype(jnp.float32))
    gx = hadamard(gy.astype(x.dtype), stripped, interpret=interpret)
    y = hadamard(x, stripped, interpret=interpret)
    gw = jnp.einsum("becf,becd->efd", y.astype(jnp.float32), gf)
    return gx, gw.astype(w.dtype)


_quant_dot_experts_w.defvjp(_qd_experts_w_fwd, _qd_experts_w_bwd)


def quant_dot_experts(x, w, plan: HadamardPlan,
                      interpret: Optional[bool] = None,
                      schedule: Optional[str] = None) -> jnp.ndarray:
    """Per-expert quant_dot: ``einsum('becf,efd->becd')`` semantics with
    the shared online Hadamard on the dispatched activations (all experts
    share d_ff) and real int8/fp8 expert weights with
    per-(expert, out-channel) scales. Off-mesh fusable plans run the
    single 3-D (expert, rows, out-channels) rotate-once Pallas kernel --
    rotation, quantize and every expert's contraction in ONE pallas_call;
    under a mesh (GSPMD shards the einsum) or for non-fusable plans the
    einsum form runs. ``w`` is the raw (E, f, d) weight (training; STE in
    both operands) or a pre-quantized QTensor (serving; x-only
    gradients)."""
    from repro.core.wquant import QTensor

    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    if isinstance(w, QTensor):
        return _quant_dot_experts_qw(x, w.q, w.scale, plan, interpret,
                                     schedule)
    return _quant_dot_experts_w(x, w, plan, interpret, schedule)


# --------------------------------------------- declarative rotation sites
def _cfg_backend_name(backend: str) -> Optional[str]:
    # "auto" defers to the registry (env override, then size/platform).
    return None if backend == "auto" else backend


@dataclasses.dataclass(frozen=True)
class RotationSpec:
    """A declarative activation-only rotation site (DESIGN.md section 7):
    the attention Q/K/V pre-quantization hook, built once from the model
    config instead of threading a ``QuantConfig`` into free functions.

    n:         transform size (the per-head dim at the QK sites)
    mode:      'none' (no quantization) | 'int8' | 'fp8_e4m3' | 'fp8_e5m2'
    rotate:    apply the online Hadamard (False = quantize-only site, the
               V path: its rotation is fused offline into (W_v, W_o))
    dequant:   return the fake-quantized tensor (the KV-cache form) --
               ``(q, scales)`` when False
    Calling the spec on a tensor dispatches through the cached plan: the
    rotate+quantize site runs as ONE fused kernel when the plan fuses.
    """

    n: int
    mode: str = "none"
    rotate: bool = True
    per_token: bool = True
    dequant: bool = True
    scale: Union[str, float, None] = "ortho"
    backend: Optional[str] = None
    block_m: Optional[int] = None
    compute_dtype: Optional[str] = None
    abft: bool = False

    def __post_init__(self):
        if self.mode != "none" and self.mode not in QSPECS:
            raise ValueError(
                f"unknown quantization mode {self.mode!r}; expected 'none' "
                f"or one of {sorted(QSPECS)}")

    @classmethod
    def for_config(cls, n: int, cfg, *, rotate: Optional[bool] = None,
                   quantize: Optional[bool] = None,
                   per_token: bool = True) -> "RotationSpec":
        """Build the spec a QuantConfig implies for an n-point site.
        ``quantize`` defaults to the KV-site rule (cfg.enabled and
        cfg.kv_quant); ``rotate`` defaults to cfg.rotating."""
        q = (cfg.enabled and cfg.kv_quant) if quantize is None else \
            (quantize and cfg.enabled)
        return cls(
            n=n, mode=cfg.mode if q else "none",
            rotate=cfg.rotating if rotate is None else rotate,
            per_token=per_token, backend=_cfg_backend_name(cfg.backend),
            abft=bool(getattr(cfg, "abft", False)))

    def plan(self, dtype) -> HadamardPlan:
        epi = None
        if self.mode != "none":
            epi = QuantEpilogue(self.mode, per_token=self.per_token,
                                dequant=self.dequant)
        return plan_for(
            self.n, dtype=dtype, scale=self.scale, backend=self.backend,
            epilogue=epi, block_m=self.block_m,
            compute_dtype=self.compute_dtype)

    def __call__(self, x: jnp.ndarray, interpret: Optional[bool] = None):
        if x.shape[-1] != self.n:
            raise ValueError(
                f"RotationSpec was built for n={self.n} but x has last "
                f"axis {x.shape[-1]}")
        if self.rotate:
            y = hadamard(x, self.plan(x.dtype), interpret=interpret)
            if self.mode == "none" and self._abft_verifying():
                # pure-rotation site: the transform-linearity invariant
                # (sum-of-outputs vs transform-of-sum) verifies the whole
                # batch for ~1/m of the site's cost; a failed check
                # NaN-poisons the site via an exact select, so healthy
                # runs stay bitwise identical to ABFT-off and the serving
                # logits guard attributes the trip (DESIGN.md section 14).
                from repro.core.hadamard import hadamard_check

                registry.TRACE_COUNTS[("abft", "rotation_site")] += 1
                ok = hadamard_check(x, y, scale=self.scale,
                                    compute_dtype=self.compute_dtype)
                y = jnp.where(ok, y, jnp.asarray(jnp.nan, y.dtype))
            return y
        if self.mode != "none":
            from repro.core.quant import quantize

            return quantize(x, self.mode,
                            axis=-1 if self.per_token else None)
        return x

    def _abft_verifying(self) -> bool:
        from repro.verify.abft import abft_enabled

        return self.abft or abft_enabled()


@dataclasses.dataclass(frozen=True)
class QuantDotSpec:
    """A declarative rotation-CONSUMER site: ``x @ w`` with the online
    Hadamard on x's contraction axis and low-precision operands, bound to
    a concrete weight with ``spec.bind(w)`` (DESIGN.md section 7).

    The spec pins everything about the site that is not the weight value:
    transform size, quantization mode ('none' = unquantized matmul),
    whether the site rotates, scale granularity, backend/tiling overrides,
    the fused kernel's grid ``schedule`` (``"streamed"`` = DMA-ring weight
    prefetch; ``None`` defers to the env/default),
    and the weight's LOGICAL sharding axes -- which make the bound call
    mesh-aware: under an active sharding-rules mesh the out-channel axis
    resolves to mesh axes, folds into the plan cache key, and dispatch
    goes through ``shard_map`` with per-shard weight scales.

    ``bind`` accepts either the raw full-precision weight (training: the
    weight is quantized per out-channel on the fly, differentiable in
    both operands via the STE) or a pre-quantized
    :class:`~repro.core.wquant.QTensor` (serving: the forward contracts
    against ``q`` directly -- ZERO per-forward weight quantization).
    """

    n: int
    mode: str = "int8"
    rotate: bool = True
    per_token: bool = True
    scale: Union[str, float, None] = "ortho"
    backend: Optional[str] = None
    block_m: Optional[int] = None
    compute_dtype: Optional[str] = None
    weight_axes: Optional[Tuple[Optional[str], ...]] = None
    schedule: Optional[str] = None
    abft: bool = False

    def __post_init__(self):
        if self.mode != "none" and self.mode not in QSPECS:
            raise ValueError(
                f"unknown quantization mode {self.mode!r}; expected 'none' "
                f"or one of {sorted(QSPECS)}")
        if self.schedule is not None:
            from repro.kernels.quant_dot import SCHEDULES

            if self.schedule not in SCHEDULES:
                raise ValueError(
                    f"unknown quant_dot schedule {self.schedule!r}; "
                    f"expected one of {SCHEDULES}")

    @classmethod
    def for_config(cls, n: int, cfg, *,
                   weight_axes: Optional[Tuple] = None) -> "QuantDotSpec":
        """The spec a QuantConfig implies for an n-point consumer site.
        ``cfg.schedule`` (when set) pins the fused-kernel grid schedule --
        the serving degradation ladder relies on this to re-warm one rung
        down without touching the env override."""
        return cls(n=n, mode=cfg.mode, rotate=cfg.rotating,
                   per_token=cfg.per_token,
                   backend=_cfg_backend_name(cfg.backend),
                   schedule=getattr(cfg, "schedule", None),
                   weight_axes=weight_axes,
                   abft=bool(getattr(cfg, "abft", False)))

    @property
    def quantizing(self) -> bool:
        return self.mode != "none"

    def plan(self, dtype, d: Optional[int] = None) -> HadamardPlan:
        """The (cached) quant_dot plan for io dtype ``dtype`` and weight
        out-channels ``d`` -- mesh axes resolved from the spec's logical
        weight axes against the CURRENT mesh, so the same spec yields
        distinct plan-cache entries on and off a mesh."""
        return plan_for(
            self.n, dtype=dtype, scale=self.scale, backend=self.backend,
            epilogue=QuantEpilogue(self.mode, per_token=self.per_token),
            block_m=self.block_m, compute_dtype=self.compute_dtype,
            mesh_axes=_resolve_mesh_axes(self.weight_axes, d))

    def _transform_plan(self, dtype) -> HadamardPlan:
        return plan_for(self.n, dtype=dtype, scale=self.scale,
                        backend=self.backend, block_m=self.block_m,
                        compute_dtype=self.compute_dtype)

    def _coerce_weight(self, w):
        """Normalize the bound weight: QTensor passes through; a legacy
        ``(wq, sw)`` pre-quantized tuple is wrapped into a QTensor in the
        spec's mode (validating the storage dtype); raw arrays return
        unchanged."""
        from repro.core.wquant import QTensor

        if isinstance(w, QTensor) or not isinstance(w, tuple):
            return w
        wq, sw = w
        if self.quantizing:
            want_dt = QSPECS[self.mode][1]
            if wq.dtype != want_dt:
                raise ValueError(
                    f"pre-quantized weight dtype {wq.dtype.name} does not "
                    f"match the spec's {self.mode!r} storage dtype "
                    f"{jnp.dtype(want_dt).name}; quantize with "
                    "wquant.quantize_weight(w, mode)")
        return QTensor(q=wq, scale=sw, mode=self.mode)

    # ------------------------------------------------------------- dense
    def bind(self, w, *, interpret: Optional[bool] = None):
        """Bind the site to a weight; returns ``fn(x) -> (..., d)``.
        ``w``: raw array (training), QTensor, or legacy ``(wq, sw)``."""
        from repro.core.wquant import QTensor

        w = self._coerce_weight(w)
        if isinstance(w, QTensor):
            return functools.partial(self._apply_qtensor, w, interpret)
        return functools.partial(self._apply_raw, w, interpret)

    def __call__(self, x, w, *, interpret: Optional[bool] = None):
        return self.bind(w, interpret=interpret)(x)

    def _abft_verifying(self, w) -> bool:
        """ABFT-verify this site? Needs BOTH the stored checksum (the
        weight was quantized under an abft config / ``REPRO_ABFT``) and
        the runtime switch -- checksums alone are inert metadata."""
        from repro.verify.abft import abft_enabled

        return getattr(w, "check", None) is not None and (
            self.abft or abft_enabled())

    def _apply_qtensor(self, w, interpret, x):
        if not self.quantizing or w.mode != self.mode:
            # storage-only weight at a site whose config does not consume
            # it natively: dequantize (NOT re-quantize) and run raw
            return self._apply_raw(w.dequant(x.dtype), interpret, x)
        if self.rotate:
            if interpret is None:
                interpret = jax.default_backend() != "tpu"
            plan = self.plan(x.dtype, d=w.q.shape[-1])
            if self._abft_verifying(w):
                if plan.mesh_axes is None:
                    return _quant_dot_qw_abft(x, w.q, w.scale, w.check,
                                              plan, interpret,
                                              self.schedule)
                registry.warn_once(
                    ("abft", "sharded_fallback"),
                    "ABFT checksums are present but the plan shards over "
                    f"mesh axes {plan.mesh_axes}; the shard_map dispatch "
                    "has no checksum output, so this site runs UNVERIFIED")
            return _quant_dot_qw(x, w.q, w.scale, plan, interpret,
                                 self.schedule)
        # no rotation site: real quantized matmul, pre-quantized weight
        from repro.kernels.quant_dot import epilogue_dot

        q, s = registry._quantize_rows(
            x.astype(jnp.float32), self.mode,
            axis=-1 if self.per_token else None)
        return epilogue_dot(q, s, w.q, w.scale, self.mode, x.dtype)

    def _apply_raw(self, w, interpret, x):
        if not self.quantizing:
            if self.rotate:
                return hadamard(x, self._transform_plan(x.dtype),
                                interpret=interpret) @ w
            return x @ w
        if not self.rotate:
            # no rotation insertion point: the plain fake-quant matmul
            from repro.core.quant import QuantConfig
            from repro.core.quant import quant_dot as _fake_quant_dot

            return _fake_quant_dot(
                x, w, QuantConfig(mode=self.mode, per_token=self.per_token))
        plan = self.plan(x.dtype, d=w.shape[-1])
        if interpret is None:
            interpret = jax.default_backend() != "tpu"
        return _quant_dot_w(x, w, plan, interpret, self.schedule)

    # ----------------------------------------------------------- experts
    def bind_experts(self, w, *, interpret: Optional[bool] = None):
        """Bind the MoE expert form (``'becf,efd->becd'`` semantics,
        stacked expert weights sharing one d_ff Hadamard); returns
        ``fn(x)``.

        Off-mesh, fusable plans run the single 3-D rotate-once Pallas
        kernel (one pallas_call for rotation + quantize + every expert's
        contraction). Under a mesh the einsum form runs instead and
        shards under GSPMD/pjit via the surrounding constraints (the
        shard_map dispatch is 2-D-only). ``weight_axes`` is carried as
        declarative metadata only at this site today."""
        from repro.core.wquant import QTensor

        w = self._coerce_weight(w)
        if isinstance(w, QTensor):
            return functools.partial(self._apply_experts_qtensor, w,
                                     interpret)
        return functools.partial(self._apply_experts_raw, w, interpret)

    def _apply_experts_qtensor(self, w, interpret, x):
        if not self.quantizing or w.mode != self.mode:
            return self._apply_experts_raw(w.dequant(x.dtype), interpret, x)
        if self.rotate:
            if self._abft_verifying(w):
                if interpret is None:
                    interpret = jax.default_backend() != "tpu"
                plan = self.plan(x.dtype)
                if _qd_experts_fusable(plan):
                    return _quant_dot_experts_qw_abft(
                        x, w.q, w.scale, w.check, plan, interpret,
                        self.schedule)
                registry.warn_once(
                    ("abft", "experts_einsum_fallback"),
                    "ABFT checksums are present but the expert site runs "
                    "the einsum form (active mesh or non-fusable plan), "
                    "which has no checksum output; it runs UNVERIFIED")
            return quant_dot_experts(x, w, self.plan(x.dtype),
                                     interpret=interpret,
                                     schedule=self.schedule)
        from repro.core.quant import quantize

        xq = quantize(x, self.mode, axis=-1 if self.per_token else None)
        return jnp.einsum("becf,efd->becd", xq,
                          w.dequant(x.dtype)).astype(x.dtype)

    def _apply_experts_raw(self, w, interpret, x):
        if not self.quantizing:
            if self.rotate:
                xr = hadamard(x, self._transform_plan(x.dtype),
                              interpret=interpret)
                return jnp.einsum("becf,efd->becd", xr, w)
            return jnp.einsum("becf,efd->becd", x, w)
        if not self.rotate:
            from repro.core.quant import quantize

            xq = quantize(x, self.mode, axis=-1 if self.per_token else None)
            return jnp.einsum("becf,efd->becd", xq,
                              quantize(w, self.mode, axis=-2))
        return quant_dot_experts(x, w, self.plan(x.dtype),
                                 interpret=interpret,
                                 schedule=self.schedule)
