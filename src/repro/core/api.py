"""Unified plan-based Hadamard API: one entry point for every transform.

This is the seam the whole repo routes rotations through (DESIGN.md
section 5). Instead of four divergent entry points with string-typed
knobs, callers build (or let us cache) a :class:`HadamardPlan` --
everything shape-dependent is precomputed exactly once per
``(n, dtype, compute_dtype, backend, epilogue, scale, block_m)`` key:

  * the 128-factorization ``n = 128^k * r`` and the stacked per-pass base
    matrices (including the I (x) H_r diagonal tiling for r > 1 and the
    scale folded into pass 0);
  * the resolved backend (registry lookup: explicit > env override >
    auto-by-size/platform);
  * the VMEM row-tile ``block_m``.

and ``hadamard(x, plan)`` dispatches. Composable epilogues make the fused
rotate+quantize kernel the default hot path:

  * ``epilogue=None``                     -> rotated tensor
  * ``QuantEpilogue("int8"|"fp8_e4m3"|"fp8_e5m2", per_token=True)``
                                          -> ``(q, scales)`` from a single
                                             VMEM-resident kernel
  * ``QuantEpilogue(..., dequant=True)``  -> fake-quantized rotated tensor
                                             (training path), same single
                                             kernel

Non-power-of-2 sizes are handled by the grouped transform I_g (x) H_p
with p the largest power-of-2 divisor (DESIGN.md section 3): the plan
carries both ``n`` (full axis) and ``p`` (per-group transform size), and
epilogue scales stay per-FULL-token (computed outside the kernel in that
case, so grouped semantics match the historical two-step path).

Autodiff: the transform is its own adjoint (H symmetric, scale scalar),
so the pullback is one more transform. Epilogue paths carry the
straight-through estimator: quantization is treated as identity in the
backward pass, so ``d(q)/dx ~= H/s`` and ``d(dequant)/dx ~= H``. This is
a DELIBERATE training-numerics upgrade over differentiating the unfused
``quantize(hadamard(x))`` directly, whose ``round()`` has zero gradient
almost everywhere (only the absmax scale branch leaks signal) -- the STE
is the standard QAT estimator and is what the fused path exists to serve.
Forward numerics are unchanged.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Optional, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np
from jax.dtypes import float0

from repro.core.hadamard import (
    base_matrices_np,
    factorize,
    largest_pow2_divisor,
    resolve_compute_dtype,
    resolve_scale,
)
from repro.kernels import registry
from repro.kernels.ref import is_pow2
from repro.kernels.registry import QSPECS, get_backend, select_backend

__all__ = [
    "QuantEpilogue",
    "HadamardPlan",
    "plan_for",
    "make_plan",
    "hadamard",
    "quant_dot",
    "plan_cache_info",
]


@dataclasses.dataclass(frozen=True)
class QuantEpilogue:
    """Quantization epilogue applied to the rotated tensor before write-back.

    mode:      'int8' | 'fp8_e4m3' | 'fp8_e5m2'
    per_token: one symmetric absmax scale per (full-length) token row;
               False = one scale per tensor (never fusable: needs a
               global reduction, so it always runs as transform +
               XLA epilogue).
    dequant:   return the fake-quantized (quantize->dequantize) rotated
               tensor in the input dtype instead of ``(q, scales)`` --
               the training-path form consumed by fake-quant matmuls.
    """

    mode: str
    per_token: bool = True
    dequant: bool = False

    def __post_init__(self):
        if self.mode not in QSPECS:
            raise ValueError(
                f"unknown quantization mode {self.mode!r}; "
                f"expected one of {sorted(QSPECS)}"
            )


@dataclasses.dataclass(frozen=True)
class HadamardPlan:
    """Everything shape-dependent about one Hadamard configuration,
    computed once and cached. Hashable (the stacked base matrices are
    excluded from eq/hash), so jitted implementations take the plan as a
    static argument and XLA caches per plan."""

    n: int                           # full last-axis size
    p: int                           # per-group pow2 transform size (== n when pow2)
    dtype: str                       # canonical input/output dtype name
    compute_dtype: str               # dtype the matmul passes run in (f32
                                     # accumulation always; see
                                     # hadamard.resolve_compute_dtype)
    backend: str                     # resolved registry backend name
    scale: Optional[float]           # numeric scale folded into pass 0 (None = +-1)
    epilogue: Optional[QuantEpilogue]
    block_m: Optional[int]           # VMEM row tile (None = per-call heuristic)
    k: int                           # number of 128-factors of p
    r: int                           # residual pow2 factor (1 <= r < 128)
    mats: np.ndarray = dataclasses.field(repr=False, compare=False, default=None)

    @property
    def grouped(self) -> bool:
        return self.p != self.n

    @property
    def num_passes(self) -> int:
        return 0 if self.p == 1 else int(self.mats.shape[0])


@functools.lru_cache(maxsize=None)
def _build_plan(n, p, dtype_name, compute_dtype, scale_val, backend, epilogue,
                block_m):
    if p == 1:
        k, r, mats = 0, 1, np.ones((1, 1, 1), np.float32)
    else:
        k, r = factorize(p)
        mats = np.stack(base_matrices_np(p, scale_val))
    return HadamardPlan(
        n=n, p=p, dtype=dtype_name, compute_dtype=compute_dtype,
        backend=backend, scale=scale_val, epilogue=epilogue, block_m=block_m,
        k=k, r=r, mats=mats,
    )


def plan_for(
    n: int,
    *,
    dtype: Any = jnp.float32,
    scale: Union[str, float, None] = "ortho",
    backend: Optional[str] = None,
    epilogue: Optional[QuantEpilogue] = None,
    block_m: Optional[int] = None,
    compute_dtype: Any = None,
) -> HadamardPlan:
    """Build (or fetch from the cache) the plan for an n-point transform.

    ``backend=None`` resolves via the registry: ``REPRO_HADAMARD_BACKEND``
    env override first, then auto-selection by size/platform. Non-power-
    of-2 ``n`` plans the grouped transform on the largest power-of-2
    divisor. ``compute_dtype=None`` resolves the dtype the matmul passes
    run in: native bf16/fp16 passes with f32 MXU accumulation for 16-bit
    inputs, f32 otherwise (explicitly overridable). Repeated calls with
    the same key return the *same* plan object, so downstream jit caches
    hit.
    """
    if n < 1:
        raise ValueError(f"Hadamard size must be >= 1, got {n}")
    p = n if is_pow2(n) else largest_pow2_divisor(n)
    scale_val = resolve_scale(scale, p)
    resolved = select_backend(p, backend)
    return _build_plan(
        n, p, jnp.dtype(dtype).name,
        resolve_compute_dtype(dtype, compute_dtype), scale_val, resolved,
        epilogue, block_m
    )


# Alias: ISSUE/API docs name both; plan_for reads better at call sites.
make_plan = plan_for


def plan_cache_info():
    """Plan-cache statistics (functools.lru_cache CacheInfo)."""
    return _build_plan.cache_info()


def _strip(plan: HadamardPlan) -> HadamardPlan:
    """The epilogue-free twin of a plan (used by fallbacks and pullbacks)."""
    if plan.epilogue is None:
        return plan
    return _build_plan(
        plan.n, plan.p, plan.dtype, plan.compute_dtype, plan.scale,
        plan.backend, None, plan.block_m
    )


# -------------------------------------------------------------- dispatch
def _group(x: jnp.ndarray, plan: HadamardPlan) -> jnp.ndarray:
    return x.reshape(*x.shape[:-1], plan.n // plan.p, plan.p)


def _dispatch_transform(x, plan: HadamardPlan, interpret: bool):
    if plan.p == 1:
        return x if plan.scale is None else x * jnp.asarray(plan.scale, x.dtype)
    be = get_backend(plan.backend)
    if plan.grouped:
        return be.transform(_group(x, plan), plan, interpret).reshape(x.shape)
    return be.transform(x, plan, interpret)


def _apply_epilogue_xla(y, epi: QuantEpilogue, out_dtype):
    """Reference epilogue on an already-rotated tensor (used when the
    backend has no fused path, for per-tensor scales, and for grouped
    transforms where the scale must span the full token row). Shares
    ``registry._quantize_rows`` with the fused kernels so numerics agree
    bit-for-bit."""
    q, s = registry._quantize_rows(
        y.astype(jnp.float32), epi.mode, axis=-1 if epi.per_token else None)
    if epi.dequant:
        return registry._dequantize(q, s, epi.mode).astype(out_dtype)
    return q.astype(QSPECS[epi.mode][1]), s


def _fusable(plan: HadamardPlan) -> bool:
    be = get_backend(plan.backend)
    return (
        not plan.grouped
        and plan.p > 1
        and plan.epilogue.per_token
        and be.fused is not None
        and be.supports(plan.p)
    )


def _dispatch_fused(x, plan: HadamardPlan, interpret: bool):
    if _fusable(plan):
        return get_backend(plan.backend).fused(x, plan, interpret)
    y = _dispatch_transform(x, _strip(plan), interpret)
    return _apply_epilogue_xla(y, plan.epilogue, x.dtype)


def _dispatch_fused_dequant(x, plan: HadamardPlan, interpret: bool):
    if _fusable(plan):
        return get_backend(plan.backend).fused_dequant(x, plan, interpret)
    y = _dispatch_transform(x, _strip(plan), interpret)
    return _apply_epilogue_xla(y, plan.epilogue, x.dtype)


# -------------------------------------------------------------- autodiff
@functools.partial(jax.custom_vjp, nondiff_argnums=(1, 2))
def _transform(x, plan: HadamardPlan, interpret: bool):
    return _dispatch_transform(x, plan, interpret)


def _transform_fwd(x, plan, interpret):
    return _dispatch_transform(x, plan, interpret), None


def _transform_bwd(plan, interpret, _res, g):
    # H^T = H and the scale is scalar: the op is self-adjoint.
    return (_dispatch_transform(g, plan, interpret),)


_transform.defvjp(_transform_fwd, _transform_bwd)


@functools.partial(jax.custom_vjp, nondiff_argnums=(1, 2))
def _fused(x, plan: HadamardPlan, interpret: bool):
    return _dispatch_fused(x, plan, interpret)


def _fused_fwd(x, plan, interpret):
    q, s = _dispatch_fused(x, plan, interpret)
    return (q, s), s


def _fused_bwd(plan, interpret, s, ct):
    """Straight-through: q = had(x)/s with s treated as a statistic, so
    the pullback of gq is had(gq)/s and the scale branch contributes
    nothing. int8 outputs are integer-typed (float0 cotangent): their
    quantized branch is non-differentiable by construction -- use
    ``QuantEpilogue(dequant=True)`` for the training path."""
    gq, _gs = ct
    if gq.dtype == float0:
        return (jnp.zeros(gq.shape, jnp.dtype(plan.dtype)),)
    gy = gq.astype(jnp.float32) / s
    gx = _dispatch_transform(gy, _strip(plan), interpret)
    return (gx.astype(jnp.dtype(plan.dtype)),)


_fused.defvjp(_fused_fwd, _fused_bwd)


@functools.partial(jax.custom_vjp, nondiff_argnums=(1, 2))
def _fused_dequant(x, plan: HadamardPlan, interpret: bool):
    return _dispatch_fused_dequant(x, plan, interpret)


def _fused_dequant_fwd(x, plan, interpret):
    return _dispatch_fused_dequant(x, plan, interpret), None


def _fused_dequant_bwd(plan, interpret, _res, g):
    # Straight-through on quantize-dequantize: the op behaves as the plain
    # rotation in the backward pass (NOT the raw fake-quant grad, whose
    # round() is zero a.e. -- see module docstring).
    return (_dispatch_transform(g, _strip(plan), interpret),)


_fused_dequant.defvjp(_fused_dequant_fwd, _fused_dequant_bwd)


# ----------------------------------------------------------- entry point
_UNSET = object()  # distinguishes "not passed" from an explicit default


def hadamard(
    x: jnp.ndarray,
    plan: Optional[HadamardPlan] = None,
    *,
    scale: Union[str, float, None] = _UNSET,
    backend: Optional[str] = _UNSET,
    epilogue: Optional[QuantEpilogue] = _UNSET,
    block_m: Optional[int] = _UNSET,
    compute_dtype: Any = _UNSET,
    interpret: Optional[bool] = None,
) -> Union[jnp.ndarray, Tuple[jnp.ndarray, jnp.ndarray]]:
    """Walsh-Hadamard transform of the last axis -- THE entry point.

    With ``plan=None`` a plan is built (and cached) from the keyword
    arguments and ``x``'s shape/dtype; passing an explicit plan skips all
    per-call decisions (plan-configuration keywords may then not be
    passed -- the plan already pins them, and silently ignoring a
    conflicting ``epilogue=...`` would change the return type). Returns
    the rotated tensor, or ``(q, scales)`` when the plan carries a
    :class:`QuantEpilogue` (the fake-quantized tensor when the epilogue
    has ``dequant=True``).

    ``interpret=None`` auto-selects Pallas interpret mode off-TPU so CPU
    CI validates the same kernel code path.
    """
    n = x.shape[-1]
    if plan is None:
        plan = plan_for(
            n, dtype=x.dtype,
            scale="ortho" if scale is _UNSET else scale,
            backend=None if backend is _UNSET else backend,
            epilogue=None if epilogue is _UNSET else epilogue,
            block_m=None if block_m is _UNSET else block_m,
            compute_dtype=None if compute_dtype is _UNSET else compute_dtype,
        )
    else:
        passed = [name for name, v in (("scale", scale), ("backend", backend),
                                       ("epilogue", epilogue),
                                       ("block_m", block_m),
                                       ("compute_dtype", compute_dtype))
                  if v is not _UNSET]
        if passed:
            raise ValueError(
                f"hadamard() got both an explicit plan and {passed}; plan "
                "configuration is fixed at plan_for() time"
            )
        if plan.n != n:
            raise ValueError(
                f"plan was built for n={plan.n} but x has last axis {n}"
            )
        if jnp.dtype(plan.dtype) != x.dtype:
            raise ValueError(
                f"plan was built for dtype {plan.dtype} but x is {x.dtype.name}; "
                "build a plan with plan_for(n, dtype=x.dtype, ...)"
            )
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    if plan.epilogue is None:
        return _transform(x, plan, interpret)
    if plan.epilogue.dequant:
        return _fused_dequant(x, plan, interpret)
    return _fused(x, plan, interpret)


# ------------------------------------------------- fused quantized GEMM
def _qd_fusable(plan: HadamardPlan) -> bool:
    """Can the rotate+quantize+dot run as the backend's single kernel?
    Mirrors ``_fusable`` plus the backend must host a ``quant_dot`` and
    the minimal (p, 128) weight tile must fit the kernel's VMEM budget
    (fp8 operands cost 3 bytes/element in VMEM: storage + the exact bf16
    embedding; oversize plans take the unfused fallback instead of
    launching an over-budget kernel)."""
    from repro.kernels.quant_dot import _FP8_OPERAND_BYTES

    be = get_backend(plan.backend)
    wb = 1 if QSPECS[plan.epilogue.mode][2] else _FP8_OPERAND_BYTES
    return (
        not plan.grouped
        and plan.p > 1
        and plan.epilogue.per_token
        and getattr(be, "quant_dot", None) is not None
        and be.supports(plan.p)
        and plan.p * 128 * wb <= registry._VMEM_BUDGET_BYTES
    )


def _dispatch_quant_dot(x, wq, sw, plan: HadamardPlan, interpret: bool):
    """rotate(x) -> per-token quantize -> contract against the offline-
    quantized weight (int8 w/ int32 accumulation, fp8 w/ f32), applying
    ``scale_x * scale_w`` in the epilogue. Fused single-kernel when the
    plan supports it; otherwise the unfused oracle semantics (grouped
    transforms, per-tensor scales, backends without the kernel -- the
    pjit-shardable fallback)."""
    if _qd_fusable(plan):
        return get_backend(plan.backend).quant_dot(x, wq, sw, plan, interpret)
    from repro.kernels.quant_dot import epilogue_dot

    y = _dispatch_transform(x, _strip(plan), interpret)
    epi = plan.epilogue
    q, s = registry._quantize_rows(
        y.astype(jnp.float32), epi.mode, axis=-1 if epi.per_token else None)
    return epilogue_dot(q, s, wq, sw, epi.mode, jnp.dtype(plan.dtype))


def _dequant_weight(wq, sw):
    return wq.astype(jnp.float32) * sw


def _zero_cotangent(a):
    if jnp.issubdtype(a.dtype, jnp.integer):
        return np.zeros(a.shape, dtype=float0)
    return jnp.zeros(a.shape, a.dtype)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4))
def _quant_dot_qw(x, wq, sw, plan: HadamardPlan, interpret: bool):
    """Serving form: weights pre-quantized offline. Differentiable in x
    only (STE through the activation quantization); the quantized weight
    and its scales are statistics with zero pullback."""
    return _dispatch_quant_dot(x, wq, sw, plan, interpret)


def _quant_dot_qw_fwd(x, wq, sw, plan, interpret):
    return _dispatch_quant_dot(x, wq, sw, plan, interpret), (wq, sw)


def _quant_dot_qw_bwd(plan, interpret, res, g):
    # STE: out ~= had(x) @ W with W = dequant(wq, sw), so the x-pullback is
    # the (self-adjoint) rotation of g @ W^T.
    wq, sw = res
    W = _dequant_weight(wq, sw)
    gy = jnp.matmul(g.astype(jnp.float32), W.T,
                    preferred_element_type=jnp.float32)
    gx = _dispatch_transform(
        gy.astype(jnp.dtype(plan.dtype)), _strip(plan), interpret)
    return gx, _zero_cotangent(wq), _zero_cotangent(sw)


_quant_dot_qw.defvjp(_quant_dot_qw_fwd, _quant_dot_qw_bwd)


def _quant_dot_w_impl(x, w, plan: HadamardPlan, interpret: bool):
    from repro.core.wquant import quantize_weight

    wq, sw = quantize_weight(w, plan.epilogue.mode)
    return _dispatch_quant_dot(x, wq, sw, plan, interpret)


@functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3))
def _quant_dot_w(x, w, plan: HadamardPlan, interpret: bool):
    """Training form: full-precision weight, quantized per out-channel on
    the fly. STE through BOTH quantizations: out ~= had(x) @ w in the
    backward pass, so both gradients flow (w's raw fake-quant grad would
    be zero a.e. -- see the module docstring)."""
    return _quant_dot_w_impl(x, w, plan, interpret)


def _quant_dot_w_fwd(x, w, plan, interpret):
    return _quant_dot_w_impl(x, w, plan, interpret), (x, w)


def _quant_dot_w_bwd(plan, interpret, res, g):
    x, w = res
    gf = g.astype(jnp.float32)
    gy = jnp.matmul(gf, w.astype(jnp.float32).T,
                    preferred_element_type=jnp.float32)
    gx = _dispatch_transform(
        gy.astype(jnp.dtype(plan.dtype)), _strip(plan), interpret)
    y = _dispatch_transform(x, _strip(plan), interpret)
    yf = y.reshape(-1, y.shape[-1]).astype(jnp.float32)
    gw = jnp.matmul(yf.T, gf.reshape(-1, gf.shape[-1]),
                    preferred_element_type=jnp.float32)
    return gx, gw.astype(w.dtype)


_quant_dot_w.defvjp(_quant_dot_w_fwd, _quant_dot_w_bwd)


def quant_dot(
    x: jnp.ndarray,
    w: Union[jnp.ndarray, Tuple[jnp.ndarray, jnp.ndarray]],
    plan: Optional[HadamardPlan] = None,
    *,
    mode: str = _UNSET,
    scale: Union[str, float, None] = _UNSET,
    backend: Optional[str] = _UNSET,
    block_m: Optional[int] = _UNSET,
    compute_dtype: Any = _UNSET,
    interpret: Optional[bool] = None,
) -> jnp.ndarray:
    """``quantize(hadamard(x)) @ quantize(w)`` as ONE fused consumer path.

    The quantized hot path end to end: the row block is rotated, per-token
    quantized, and immediately contracted against the offline-quantized
    weight tile inside the same kernel (int8 operands with int32 MXU
    accumulation; fp8 operands multiplied exactly in bf16 with f32
    accumulation), with ``scale_x * scale_w`` applied in the epilogue --
    the rotated/quantized activations never round-trip through HBM.

    ``w`` is either the full-precision weight ``(n, d)`` (quantized per
    out-channel on the fly; differentiable in both operands via the
    straight-through estimator) or a pre-quantized ``(wq, sw)`` pair from
    :func:`repro.core.wquant.quantize_weight` (the serving form;
    differentiable in ``x`` only).

    Plans must carry a non-dequant :class:`QuantEpilogue`; ``plan=None``
    builds one from ``mode`` (default ``"int8"``). Grouped (non-power-of-
    2) sizes and per-tensor scales fall back to the unfused oracle
    semantics -- same math, separate XLA ops, pjit-shardable.
    """
    n = x.shape[-1]
    if plan is None:
        plan = plan_for(
            n, dtype=x.dtype,
            scale="ortho" if scale is _UNSET else scale,
            backend=None if backend is _UNSET else backend,
            epilogue=QuantEpilogue("int8" if mode is _UNSET else mode),
            block_m=None if block_m is _UNSET else block_m,
            compute_dtype=None if compute_dtype is _UNSET else compute_dtype,
        )
    else:
        passed = [name for name, v in (("mode", mode), ("scale", scale),
                                       ("backend", backend),
                                       ("block_m", block_m),
                                       ("compute_dtype", compute_dtype))
                  if v is not _UNSET]
        if passed:
            raise ValueError(
                f"quant_dot() got both an explicit plan and {passed}; plan "
                "configuration is fixed at plan_for() time"
            )
        if plan.n != n:
            raise ValueError(
                f"plan was built for n={plan.n} but x has last axis {n}")
        if jnp.dtype(plan.dtype) != x.dtype:
            raise ValueError(
                f"plan was built for dtype {plan.dtype} but x is "
                f"{x.dtype.name}; build a plan with plan_for(n, "
                "dtype=x.dtype, ...)")
    if plan.epilogue is None or plan.epilogue.dequant:
        raise ValueError(
            "quant_dot requires a plan with a non-dequant QuantEpilogue "
            f"(got {plan.epilogue!r}); use plan_for(n, epilogue="
            "QuantEpilogue(mode))"
        )
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    if isinstance(w, tuple):
        wq, sw = w
        if wq.shape[0] != n:
            raise ValueError(
                f"quantized weight has contraction dim {wq.shape[0]}, "
                f"expected {n}")
        want_dt = QSPECS[plan.epilogue.mode][1]
        if wq.dtype != want_dt:
            raise ValueError(
                f"pre-quantized weight dtype {wq.dtype.name} does not "
                f"match the plan's {plan.epilogue.mode!r} storage dtype "
                f"{jnp.dtype(want_dt).name}; quantize with "
                "wquant.quantize_weight(w, mode)")
        return _quant_dot_qw(x, wq, sw, plan, interpret)
    if w.shape[0] != n:
        raise ValueError(
            f"weight has contraction dim {w.shape[0]}, expected {n}")
    return _quant_dot_w(x, w, plan, interpret)
