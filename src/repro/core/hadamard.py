"""Tensorized Kronecker-factored Walsh-Hadamard transform (pure JAX).

This is the XLA-level embodiment of the paper's idea: instead of log2(n)
scalar butterfly stages, run ceil(log_128(n)) dense matmul passes against a
128-point base Hadamard -- the TPU MXU's native tile -- with axis
rearrangement between passes (DESIGN.md section 2).

The Pallas kernel in ``repro.kernels.hadacore`` implements the same pass
structure with explicit VMEM tiling; this module is the portable path used
inside models (it shards trivially under pjit because every op is a
reshape/transpose/dot) and the reference for the kernel's pass math.

Factorization convention: n = 128^k * r with r = 2^m, 1 <= r < 128, and

    H_n = H_128 (x) ... (x) H_128 (x) H_r        (Kronecker, r minor)

so the minor-axis pass touches contiguous lanes and every pass is a
128-wide MXU matmul (the r-pass uses the paper's diagonal tiling trick:
I_{128/r} (x) H_r as a 128x128 matrix -- section 3.3 of the paper).
"""
from __future__ import annotations

import math
from functools import partial
from typing import List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.ref import hadamard_matrix, is_pow2

__all__ = [
    "MXU_TILE",
    "COMPUTE_DTYPES",
    "factorize",
    "base_matrices",
    "base_matrices_np",
    "hadamard_transform",
    "grouped_hadamard",
    "largest_pow2_divisor",
    "resolve_scale",
    "resolve_compute_dtype",
    "hadamard_check",
]

MXU_TILE = 128

# Dtypes the transform passes may run in. The MXU multiplies 16-bit
# operands at full rate and always accumulates f32 (preferred_element_type)
# -- the paper's Appendix C recipe, and the Markidis/Ootomo low-precision-
# multiply + f32-accumulate setup.
COMPUTE_DTYPES = ("float32", "bfloat16", "float16")


def resolve_compute_dtype(input_dtype, requested=None) -> str:
    """Resolve the dtype the matmul passes run in (canonical name).

    ``requested=None`` picks the native rule: 16-bit inputs (bf16/fp16)
    run the passes in their own dtype -- no f32 VMEM copy, half the
    compute-tile footprint, full-rate MXU multiplies with f32
    accumulation -- while everything else computes in f32. An explicit
    request (one of ``COMPUTE_DTYPES``) overrides the rule, e.g. to force
    f32 passes on bf16 data for an accuracy A/B.
    """
    if requested is not None:
        name = jnp.dtype(requested).name
        if name not in COMPUTE_DTYPES:
            raise ValueError(
                f"unsupported compute dtype {requested!r}; expected one of "
                f"{COMPUTE_DTYPES}"
            )
        return name
    name = jnp.dtype(input_dtype).name
    return name if name in ("bfloat16", "float16") else "float32"


def resolve_scale(scale, n: int) -> Optional[float]:
    """Resolve a user-facing ``scale`` argument to a numeric multiplier.

    Accepted values: ``"ortho"`` (1/sqrt(n), the orthonormal rotation),
    ``None`` (the unnormalized +-1 transform), or an explicit number.
    Anything else -- e.g. the typo ``"orth"`` that used to silently fall
    through to the unscaled transform -- raises ``ValueError``.
    """
    if scale is None:
        return None
    if isinstance(scale, str):
        if scale == "ortho":
            return 1.0 / math.sqrt(n)
        raise ValueError(
            f"unknown Hadamard scale {scale!r}: expected 'ortho', None, "
            "or an explicit numeric scale"
        )
    if isinstance(scale, (int, float)) and not isinstance(scale, bool):
        return float(scale)
    raise ValueError(f"unknown Hadamard scale {scale!r}")


def factorize(n: int) -> Tuple[int, int]:
    """n = 128^k * r with r = 2^m < 128. Returns (k, r)."""
    if not is_pow2(n):
        raise ValueError(f"Hadamard size must be a power of 2, got {n}")
    k = 0
    while n % MXU_TILE == 0 and n > MXU_TILE:
        # peel 128-factors but keep at least one factor (handled below)
        n //= MXU_TILE
        k += 1
    if n == MXU_TILE:
        return k + 1, 1
    return k, n


def base_matrices_np(n: int, scale: Optional[float]) -> List[np.ndarray]:
    """Per-pass base matrices (numpy f32), minor-axis pass FIRST.

    All matrices are 128x128 when n >= 128 (the r-pass is the
    block-diagonal tiling I_{128/r} (x) H_r). For n < 128 a single n x n
    matrix is returned. ``scale`` is folded into the first pass matrix --
    a free normalization, one of the micro-optimizations the scalar
    algorithm pays a full extra pass (or per-stage multiply) for.
    """
    k, r = factorize(n)
    mats: List[np.ndarray] = []
    if n < MXU_TILE:
        mats.append(hadamard_matrix(n))
    else:
        if r > 1:
            tiled = np.kron(np.eye(MXU_TILE // r, dtype=np.float32), hadamard_matrix(r))
            mats.append(tiled)
        else:
            mats.append(hadamard_matrix(MXU_TILE))
            k -= 1
        mats.extend(hadamard_matrix(MXU_TILE) for _ in range(k))
    if scale is not None:
        mats[0] = mats[0] * np.float32(scale)
    return mats


def base_matrices(n: int, scale: Optional[float], dtype=jnp.float32) -> List[jnp.ndarray]:
    """``base_matrices_np`` as device arrays (see DESIGN.md section 2)."""
    return [jnp.asarray(m, dtype=dtype) for m in base_matrices_np(n, scale)]


def _apply_passes(x: jnp.ndarray, n: int, mats: List[jnp.ndarray]) -> jnp.ndarray:
    """Shared pass structure: minor-axis matmul, then one matmul per major
    128-factor with a transpose-in/transpose-out around each. ``x`` has
    shape (M, n) and is already in the COMPUTE dtype (f32, bf16 or fp16);
    every matmul accumulates in f32 on the MXU (``preferred_element_type``)
    and inter-pass intermediates stay in the compute dtype. Runs unchanged
    inside the Pallas kernel body and under plain jit."""
    m = x.shape[0]
    cd = x.dtype
    mats = [mt if mt.dtype == cd else mt.astype(cd) for mt in mats]

    def mm(a, b):
        return jnp.dot(a, b, preferred_element_type=jnp.float32).astype(cd)

    if n < MXU_TILE:
        return mm(x, mats[0])
    # minor pass: contiguous 128-lane chunks
    x = mm(x.reshape(m * (n // MXU_TILE), MXU_TILE), mats[0]).reshape(m, n)
    # major passes: factor i acts on an axis of size 128 with `post`
    # trailing elements; pre * 128 * post == n
    num_major = len(mats) - 1
    post = n // MXU_TILE
    pre = 1
    for i in range(num_major):
        xv = x.reshape(m * pre, MXU_TILE, post)
        xv = jnp.swapaxes(xv, -1, -2).reshape(m * pre * post, MXU_TILE)
        xv = mm(xv, mats[i + 1])
        xv = jnp.swapaxes(xv.reshape(m * pre, post, MXU_TILE), -1, -2)
        x = xv.reshape(m, n)
        pre *= MXU_TILE
        post //= MXU_TILE
    return x


@partial(jax.jit, static_argnames=("scale",))
def _hadamard_transform_jit(x: jnp.ndarray, scale: Optional[float]) -> jnp.ndarray:
    n = x.shape[-1]
    mats = base_matrices(n, scale)
    orig_shape, orig_dtype = x.shape, x.dtype
    y = _apply_passes(x.astype(jnp.float32).reshape(-1, n), n, mats)
    return y.reshape(orig_shape).astype(orig_dtype)


def hadamard_transform(x: jnp.ndarray, scale: Optional[str] = "ortho") -> jnp.ndarray:
    """Right Hadamard transform of the last axis, MXU-factored, pure JAX.

    scale: "ortho" (1/sqrt(n), a rotation), None (+-1 transform), or an
    explicit numeric multiplier. Unknown strings raise ``ValueError``.
    """
    return _hadamard_transform_jit(x, resolve_scale(scale, max(x.shape[-1], 1)))


def hadamard_check(x: jnp.ndarray, y: jnp.ndarray, *, scale="ortho",
                   compute_dtype=None) -> jnp.ndarray:
    """Linearity invariant of a pure-rotation site (ABFT, DESIGN.md s14).

    The transform is linear, so the column-sum of the outputs must equal
    the transform of the column-sum of the inputs:

        sum_i H(x)[i, :]  ==  H(sum_i x[i, :])

    The reference side is recomputed here in f32 on the summed row -- a
    single (1, n) transform regardless of batch size, so the check costs
    ~1/m of the site it guards and adds no extra pallas_call. A corrupted
    output element (bit flip, clobbered tile) shifts one column sum by
    the corruption magnitude while the reference side is untouched.

    Tolerance has two terms, each scaled by the per-column absolute
    output mass: the compute/storage dtype's per-element rounding, whose
    errors over the m summed rows partially cancel (~colmass/sqrt(m),
    the dominant term at bf16/fp16), and the f32 summation/transform
    chains on both sides of the comparison (~eps_f32 * sqrt(m + n) *
    colmass, the dominant term at f32). C = 8 is calibrated with ~10x
    headroom over the measured healthy worst case across dtypes and
    shapes (tests/test_abft.py); detection sensitivity at bf16 is a
    fraction of a typical element, at f32 ~1e-5 relative. Returns a
    scalar bool verdict (True = site verified); non-finite outputs also
    fail (NaN compares unordered).
    """
    n = x.shape[-1]
    xr = x.reshape(-1, n).astype(jnp.float32)
    yr = y.reshape(-1, n).astype(jnp.float32)
    m = max(xr.shape[0], 1)
    cd = resolve_compute_dtype(x.dtype, compute_dtype)
    eps = float(jnp.finfo(jnp.dtype(cd)).eps)
    if jnp.issubdtype(jnp.dtype(y.dtype), jnp.floating):
        eps = max(eps, float(jnp.finfo(jnp.dtype(y.dtype)).eps))
    eps32 = float(jnp.finfo(jnp.float32).eps)
    ref = _apply_passes(jnp.sum(xr, axis=0, keepdims=True), n,
                        base_matrices(n, resolve_scale(scale, n)))
    got = jnp.sum(yr, axis=0, keepdims=True)
    colmass = jnp.sum(jnp.abs(yr), axis=0, keepdims=True)
    tol = 8.0 * (eps * (colmass / math.sqrt(m) + jnp.max(jnp.abs(yr)))
                 + eps32 * math.sqrt(m + n) * colmass) + 1e-30
    return jnp.all(jnp.abs(got - ref) <= tol)


def largest_pow2_divisor(n: int) -> int:
    return n & (-n)


def grouped_hadamard(x: jnp.ndarray, group: Optional[int] = None,
                     scale: Optional[str] = "ortho") -> jnp.ndarray:
    """Hadamard on contiguous groups of the last axis: y = x (I_g (x) H_p).

    This is how rotation-quantization handles non-power-of-2 contraction
    dims (d_ff = 14336 = 7 * 2048, 53248 = 13 * 4096, ...) and
    tensor-parallel shards: the transform stays exact, orthogonal and
    collective-free (DESIGN.md section 3). ``group`` defaults to the
    largest power-of-2 divisor of the axis size.
    """
    n = x.shape[-1]
    p = group if group is not None else largest_pow2_divisor(n)
    if n % p != 0 or not is_pow2(p):
        raise ValueError(f"group {p} must be a power-of-2 divisor of {n}")
    if p == 1:
        return x
    xg = x.reshape(*x.shape[:-1], n // p, p)
    yg = hadamard_transform(xg, scale=scale)
    return yg.reshape(x.shape)
