"""Numeric guardrails for the serving hot path (``REPRO_NUMERIC_GUARDS``).

The paper's end-to-end claim is that the FP16/BF16 Hadamard rotation keeps
FP8/INT8 inference *numerically accurate* -- which silently inverts when a
scale or activation goes non-finite: a single NaN in a decode step poisons
the slot's logits and every subsequent token, and greedy argmax happily
emits garbage ids forever. These guards make that failure loud and local:

  * ``rows_ok(x, batch)``  -- jit-compatible per-slot ``isfinite``
    reduction (used on the decode/prefill logits inside the jitted step;
    the host reads the (slots,) bool vector it returns and retires a
    tripped slot as ``degraded`` instead of emitting its tokens);
  * ``scale_rows_ok(s, batch)`` -- per-token quant scales must be finite
    AND strictly positive (a zero scale would collapse the whole row to
    zero and dequantize to garbage);
  * ``guard_dequant(y, s)`` -- the in-trace scale check wired into
    ``core.quant.quantize``: wherever a per-token scale is non-finite or
    non-positive, the dequantized row is overwritten with NaN so the
    step-boundary logits guard attributes the failure to the right slot.
    Identity (bitwise) on healthy scales.

Placement rule (why the scale check is *trace-local* poisoning rather
than a cross-site collector): quantize runs inside ``jax.checkpoint``
block bodies (remat) and Pallas/custom_vjp sub-jaxprs, whose tracers may
not escape to the step's outer trace -- any scheme that accumulates scale
tensors for an end-of-step reduction leaks tracers the moment remat is
on. Folding the verdict into the data path keeps every check inside the
trace that produced it; scales internal to the fused kernels are covered
transitively (a non-finite kernel scale yields non-finite outputs, which
the logits guard catches at the step boundary).

Everything is opt-in: with ``REPRO_NUMERIC_GUARDS`` unset the serving
step compiles WITHOUT any guard reductions and is bit-identical to the
pre-guard executable -- and the guarded step's tokens are bitwise the
unguarded step's tokens too (guards observe/poison-on-failure, never
perturb healthy values; asserted in tests/test_faults.py).
"""
from __future__ import annotations

import os

import jax.numpy as jnp

__all__ = [
    "GUARDS_ENV",
    "guards_enabled",
    "rows_ok",
    "scale_rows_ok",
    "guard_dequant",
]

GUARDS_ENV = "REPRO_NUMERIC_GUARDS"


def guards_enabled() -> bool:
    """Opt-in flag, read at engine/step construction (and trace) time --
    NOT per executed step: the guard ops are traced into the jitted
    executable."""
    return os.environ.get(GUARDS_ENV, "").lower() in ("1", "true", "on")


def _per_row(ok: jnp.ndarray, batch: int) -> jnp.ndarray:
    """Reduce an elementwise bool array to a (batch,) per-slot vector:
    per-row when the leading axis is the slot axis, otherwise a global
    all() broadcast to every slot (conservative: a poisoned tensor the
    guard cannot attribute flags every in-flight request)."""
    if ok.ndim >= 1 and ok.shape[0] == batch:
        axes = tuple(range(1, ok.ndim))
        return jnp.all(ok, axis=axes) if axes else ok
    return jnp.broadcast_to(jnp.all(ok), (batch,))


def rows_ok(x: jnp.ndarray, batch: int) -> jnp.ndarray:
    """(batch,) bool: every element of slot b's row of ``x`` is finite.
    jit-compatible (a single ``isfinite`` + ``all`` reduction)."""
    return _per_row(jnp.isfinite(x.astype(jnp.float32)), batch)


def scale_rows_ok(s: jnp.ndarray, batch: int) -> jnp.ndarray:
    """(batch,) bool: slot b's per-token quant scales are finite and
    strictly positive (NaN/Inf/zero-scale all trip)."""
    f = s.astype(jnp.float32)
    return _per_row(jnp.isfinite(f) & (f > 0), batch)


def guard_dequant(y: jnp.ndarray, s: jnp.ndarray) -> jnp.ndarray:
    """Scale guard at the quantize site (trace-local, remat-safe): rows
    whose scale is non-finite or non-positive are poisoned with NaN so
    the failure surfaces at the step-boundary logits guard attributed to
    the right slot. ``s`` is the keepdims absmax scale from
    ``_quantize_rows`` (broadcasts against ``y``). Bitwise identity on
    healthy scales; called only when ``guards_enabled()``."""
    f = s.astype(jnp.float32)
    bad = ~(jnp.isfinite(f) & (f > 0))
    return jnp.where(bad, jnp.asarray(jnp.nan, y.dtype), y)
