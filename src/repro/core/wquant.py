"""Weight-only INT8 storage (QuaRot's INT8 deployment, Perf iteration C4).

Matmul weights are stored as int8 with per-output-channel f32 scales and
dequantized INSIDE the layer scan body -- so FSDP weight traffic (the
dominant decode collective for giant dense models, 47 GB/step/device for
405B) moves int8 on the wire and in HBM, halving both vs bf16 storage.

The transform is post-training (pairs with the offline rotation fusion:
rotate first, then quantize -- rotation is exactly what makes the int8
grid safe for weights with outlier rows)."""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

__all__ = ["quantize_weight", "quantize_lm_weights", "dequant_tree",
           "is_qleaf", "qweight_specs"]

_INT8_MAX = 127.0
_MIN_SIZE = 1 << 16   # don't quantize tiny leaves (norms, biases, LoRAs)


def quantize_weight(w: jnp.ndarray, mode: str):
    """Offline weight quantization for ``quant_dot`` consumers: ``(wq,
    sw)`` with ``wq`` in the mode's real storage dtype (int8 / fp8) and
    ``sw`` f32 per-OUT-channel scales (absmax over the contraction axis,
    ``axis=-2``). Delegates to ``kernels.registry._quantize_rows`` -- the
    same math the activation epilogues run -- so ``dequant(wq, sw)``
    reproduces ``core.quant.quantize(w, mode, axis=-2)`` bit-for-bit.

    w: (..., n, d) -- leading dims (e.g. stacked experts) keep their own
    scales: sw is (..., 1, d)."""
    from repro.kernels.registry import QSPECS, _quantize_rows

    q, s = _quantize_rows(w.astype(jnp.float32), mode, axis=-2)
    return q.astype(QSPECS[mode][1]), s


def _should_quantize(path, leaf) -> bool:
    if leaf.ndim < 2 or leaf.size < _MIN_SIZE:
        return False
    if leaf.dtype not in (jnp.bfloat16, jnp.float16, jnp.float32):
        return False
    keys = [str(getattr(k, "key", getattr(k, "name", ""))) for k in path]
    # moments/scales and anything already structured are excluded upstream
    return not any(k in ("norm1", "norm2", "norm_x", "final_norm", "enc_norm")
                   for k in keys)


def _quantize_leaf(w: jnp.ndarray):
    wf = w.astype(jnp.float32)
    s = jnp.maximum(jnp.max(jnp.abs(wf), axis=-2, keepdims=True), 1e-8) / _INT8_MAX
    q = jnp.clip(jnp.round(wf / s), -_INT8_MAX, _INT8_MAX).astype(jnp.int8)
    return {"wq": q, "ws": s.astype(jnp.float32)}


def is_qleaf(x: Any) -> bool:
    return isinstance(x, dict) and set(x.keys()) == {"wq", "ws"}


def quantize_lm_weights(params):
    """Replace every large matmul weight with {'wq': int8, 'ws': f32}."""
    def fix(path, leaf):
        if hasattr(leaf, "ndim") and _should_quantize(path, leaf):
            return _quantize_leaf(leaf)
        return leaf
    return jax.tree_util.tree_map_with_path(fix, params)


def dequant_tree(tree, dtype):
    """Dequantize all {'wq','ws'} leaves (elementwise, shard-local -- runs
    inside the scan body AFTER the per-layer slice is fetched)."""
    def dq(x):
        if is_qleaf(x):
            return (x["wq"].astype(jnp.float32) * x["ws"]).astype(dtype)
        return x
    return jax.tree.map(dq, tree, is_leaf=lambda x: is_qleaf(x) or not isinstance(x, dict))


def qweight_specs(spec_tree, shape_tree):
    """Mirror lm_param_specs onto the quantized structure: wq keeps the
    original leaf's logical axes; ws is (…,1,cols) -- same spec with the
    contraction dim unsharded."""
    is_spec = lambda x: isinstance(x, tuple) and all(
        isinstance(e, (str, type(None))) for e in x)

    def fix(spec, sds):
        if isinstance(sds, dict) and set(sds.keys()) == {"wq", "ws"}:
            ws_spec = tuple(spec[:-2]) + (None, spec[-1])
            return {"wq": spec, "ws": ws_spec}
        return spec

    return jax.tree.map(fix, spec_tree, shape_tree,
                        is_leaf=lambda x: is_spec(x))
