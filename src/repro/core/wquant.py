"""Quantized weight storage: the ``QTensor`` pytree node.

Matmul weights are stored quantized (int8 / fp8) with f32 per-output-
channel scales. Storage-only leaves (attention projections, embeddings)
are dequantized INSIDE the layer scan body -- so FSDP weight traffic (the
dominant decode collective for giant dense models, 47 GB/step/device for
405B) moves 1 byte/element on the wire and in HBM. Rotation-consumer
leaves (the down-projection weights the online Hadamard feeds) are kept
quantized all the way into ``core.api.quant_dot``: the serving forward
contracts against ``q`` directly and NEVER re-quantizes a weight.

``QTensor`` replaces both prior ad-hoc forms -- the ``(wq, sw)`` tuples
the quant_dot consumers threaded and the ``{"wq", "ws"}`` dicts the
int8-storage path used. It is a registered pytree: ``q``/``scale`` are
children (jit, scan-slicing, device_put, checkpointing all see through
it), while ``mode`` and the logical sharding ``axes`` ride along as
static metadata -- the declarative half of the rotation-site API
(DESIGN.md section 7).

The transform is post-training (pairs with the offline rotation fusion:
rotate first, then quantize -- rotation is exactly what makes the low-
precision grid safe for weights with outlier rows)."""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp

__all__ = ["QTensor", "quantize_weight", "quantize_lm_weights",
           "dequant_tree", "is_qleaf", "qweight_specs", "weight_checksum",
           "QUANTIZE_WEIGHT_CALLS", "reset_quantize_weight_calls"]

_MIN_SIZE = 1 << 16   # don't quantize tiny leaves (norms, biases, LoRAs)

# Number of times quantize_weight was invoked (trace-time). Serving-path
# acceptance tests reset this, trace the forward, and assert it stayed 0:
# pre-quantized QTensor weights mean zero per-forward weight quantization.
QUANTIZE_WEIGHT_CALLS: int = 0


def reset_quantize_weight_calls() -> None:
    global QUANTIZE_WEIGHT_CALLS
    QUANTIZE_WEIGHT_CALLS = 0


@dataclasses.dataclass(frozen=True)
class QTensor:
    """A quantized weight: storage-grid values + per-out-channel scales.

    q:     (..., n, d) int8 / fp8 storage-dtype values
    scale: (..., 1, d) f32 absmax scales over the contraction axis
    check: (..., 1, n) f32 ABFT column-checksum vector, or None. Row k
           holds sum_d q[k, d] * scale[d] -- the dequantized row sums --
           so for any activation row a the identity
           ``sum_d (a . W)[d] == a . check`` holds exactly in real
           arithmetic. ``verify.abft`` uses it to detect silent weight /
           compute corruption at run time (DESIGN.md section 14). None
           (the default) is an EMPTY pytree subtree: trees built without
           ABFT keep their leaf count, checkpoints, and shardings
           byte-identical.
    mode:  'int8' | 'fp8_e4m3' | 'fp8_e5m2'   (static metadata)
    axes:  logical sharding axes of the ORIGINAL weight (static metadata;
           None when unknown). ``qweight_specs`` derives both children's
           partition specs from this, so the sharding layer needs no side
           table.

    Registered as a pytree node: q/scale/check are children (scan slices
    the layer axis of all of them together; checkpoints serialize them),
    mode/axes are aux data. Iterable as ``(q, scale)`` for the legacy
    tuple unpack.
    """

    q: Any
    scale: Any
    check: Any = None
    mode: str = "int8"
    axes: Optional[Tuple[Optional[str], ...]] = None

    @property
    def shape(self):
        return self.q.shape

    @property
    def ndim(self) -> int:
        return self.q.ndim

    def dequant(self, dtype=jnp.float32) -> jnp.ndarray:
        return (self.q.astype(jnp.float32) * self.scale).astype(dtype)

    def __iter__(self):
        return iter((self.q, self.scale))


jax.tree_util.register_dataclass(
    QTensor, data_fields=("q", "scale", "check"), meta_fields=("mode", "axes"))


def weight_checksum(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    """The ABFT column-checksum vector of a quantized weight: f32
    ``(..., 1, n)`` with entry k = sum_d q[..., k, d] * scale[..., 0, d]
    (the row sums of the DEQUANTIZED weight). ``verify.params_ok``
    recomputes this expression verbatim against the stored copy, so keep
    the op order stable."""
    return (q.astype(jnp.float32) * scale).sum(axis=-1)[..., None, :]


def quantize_weight(w: jnp.ndarray, mode: str, *,
                    axes: Optional[Tuple] = None,
                    with_check: bool = False) -> QTensor:
    """Offline weight quantization for ``quant_dot`` consumers: a
    :class:`QTensor` with ``q`` in the mode's real storage dtype (int8 /
    fp8) and f32 per-OUT-channel scales (absmax over the contraction
    axis, ``axis=-2``). Delegates to ``kernels.registry._quantize_rows``
    -- the same math the activation epilogues run -- so ``qt.dequant()``
    reproduces ``core.quant.quantize(w, mode, axis=-2)`` bit-for-bit.

    w: (..., n, d) -- leading dims (e.g. stacked experts) keep their own
    scales: scale is (..., 1, d). ``axes`` attaches the weight's logical
    sharding axes as QTensor metadata. ``with_check=True`` additionally
    precomputes the ABFT column checksum (``weight_checksum``) so
    run-time verification never re-reads the healthy weight."""
    from repro.kernels.registry import QSPECS, _quantize_rows

    global QUANTIZE_WEIGHT_CALLS
    QUANTIZE_WEIGHT_CALLS += 1
    q, s = _quantize_rows(w.astype(jnp.float32), mode, axis=-2)
    q = q.astype(QSPECS[mode][1])
    chk = weight_checksum(q, s) if with_check else None
    return QTensor(q=q, scale=s, check=chk, mode=mode, axes=axes)


def _should_quantize(path, leaf) -> bool:
    if leaf.ndim < 2 or leaf.size < _MIN_SIZE:
        return False
    if leaf.dtype not in (jnp.bfloat16, jnp.float16, jnp.float32):
        return False
    keys = [str(getattr(k, "key", getattr(k, "name", ""))) for k in path]
    # moments/scales and anything already structured are excluded upstream
    return not any(k in ("norm1", "norm2", "norm_x", "final_norm", "enc_norm")
                   for k in keys)


def is_qleaf(x: Any) -> bool:
    return isinstance(x, QTensor)


def _is_consumer(keys) -> bool:
    """Is this leaf a quant_dot rotation consumer (down-projection input
    fed by the online Hadamard)? Mirrors rotations.fuse_down_proj_rotations."""
    if not keys:
        return False
    return keys[-1] == "w_down" or (keys[-1] == "wv" and "cmix" in keys)


def quantize_lm_weights(params, cfg=None, specs=None):
    """Replace every large matmul weight with a :class:`QTensor`, ONCE at
    load -- the serving-path pre-quantization pass.

    cfg (a ModelConfig, optional): when its ``quant`` says
    rotating+quantizing, the rotation-consumer leaves (down-projection
    weights) are stored in ``cfg.quant.mode`` so ``quant_dot`` contracts
    against them natively; everything else stores int8. specs (optional,
    the matching ``lm_param_specs`` tree) attaches each leaf's logical
    sharding axes to the QTensor so ``qweight_specs`` can re-derive the
    sharding tree from the params alone."""
    from repro.verify.abft import abft_enabled

    qc = getattr(cfg, "quant", None)
    consuming = qc is not None and qc.rotating and qc.enabled
    # ABFT checksums ride every QTensor leaf when enabled -- by config or
    # by env -- so the spec/sharding trees derived from eval_shape stay
    # structurally coherent with the params actually built
    with_check = bool(getattr(qc, "abft", False)) or abft_enabled()

    def fix(path, leaf, spec=None):
        if not hasattr(leaf, "ndim"):
            return leaf
        keys = [str(getattr(k, "key", getattr(k, "name", "")))
                for k in path]
        axes = tuple(spec) if isinstance(spec, tuple) else None
        if consuming and _is_consumer(keys) and leaf.ndim >= 2 \
                and leaf.dtype in (jnp.bfloat16, jnp.float16, jnp.float32):
            # rotation-consumer site: stored in the serving quant mode
            # regardless of size (quant_dot contracts against it natively)
            return quantize_weight(leaf, qc.mode, axes=axes,
                                   with_check=with_check)
        if _should_quantize(path, leaf):
            return quantize_weight(leaf, "int8", axes=axes,
                                   with_check=with_check)
        return leaf

    if specs is None:
        return jax.tree_util.tree_map_with_path(fix, params)
    return jax.tree_util.tree_map_with_path(fix, params, specs)


def dequant_tree(tree, dtype):
    """Dequantize all QTensor leaves (elementwise, shard-local -- runs
    inside the scan body AFTER the per-layer slice is fetched)."""
    def dq(x):
        return x.dequant(dtype) if is_qleaf(x) else x
    if is_qleaf(tree):
        return tree.dequant(dtype)
    return jax.tree.map(dq, tree, is_leaf=is_qleaf)


def qweight_specs(spec_tree, shape_tree):
    """Mirror lm_param_specs onto the QTensor structure: ``q`` keeps the
    original leaf's logical axes (the QTensor's own ``axes`` metadata
    when attached); ``scale`` is (..., 1, cols) -- the same spec with the
    contraction dim unsharded. The result is a spec tree with QTensor
    nodes whose aux data matches the shape tree's, so generic resolvers
    (``launch.steps._resolve_tree``) map straight over it."""
    is_spec = lambda x: isinstance(x, tuple) and all(
        isinstance(e, (str, type(None))) for e in x)

    def fix(spec, sds):
        if is_qleaf(sds):
            axes = sds.axes if sds.axes is not None else tuple(spec)
            scale_spec = tuple(axes[:-2]) + (None, axes[-1])
            # check is (..., 1, n): the contraction axis lands last, so
            # it inherits axes[-2]; presence tracks the shape tree (the
            # eval_shape of the SAME init the real params ran through),
            # keeping spec and params structurally coherent
            check_spec = (tuple(axes[:-2]) + (None, axes[-2])
                          if getattr(sds, "check", None) is not None
                          else None)
            return QTensor(q=tuple(axes), scale=scale_spec,
                           check=check_spec, mode=sds.mode, axes=sds.axes)
        return spec

    return jax.tree.map(fix, spec_tree, shape_tree, is_leaf=is_spec)
