# The paper's primary contribution — implement the SYSTEM here
# (scheduler, optimizer, data path, serving loop, etc.) in the
# host framework. Add sibling subpackages for substrates.
#
# Public surface: the plan-based API in repro.core.api (DESIGN.md
# section 5) — hadamard / plan_for / HadamardPlan / QuantEpilogue.
# (Not re-exported here: repro.core.hadamard the submodule and
# repro.core.api.hadamard the function would collide, and the
# api -> kernels.registry -> core.hadamard import chain must stay
# acyclic through this package __init__.)
