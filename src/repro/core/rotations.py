"""QuaRot-style rotation plumbing: offline weight fusion + online Hadamard.

The paper's kernel exists to make the *online* rotations (red blocks in
its Fig. 1) cheap. This module provides both halves:

offline (free at runtime -- exact algebraic weight rewrites):
    R1: a global residual-stream rotation Q. Every weight reading from the
        residual stream is pre-multiplied (W <- Q^T W), every weight
        writing to it post-multiplied (W <- W Q), embeddings rotated,
        final LayerNorm folded. We use Q = D H (random-sign diagonal times
        the orthonormal Walsh-Hadamard matrix), QuaRot's choice.
    R2: per-head rotation of (W_v, W_o) pairs.

online (runs every token -- this is where hadacore is deployed):
    * Hadamard on the down_proj input (d_ff contraction dim).
    * Per-head Hadamard on K (and Q) before the quantized KV-cache write /
      FP8 attention -- head_dim-sized transforms.

All online rotations route through ``online_hadamard`` which picks the
Pallas kernel or the factored XLA path, and handles non-power-of-2 dims by
grouped transforms (exactness preserved; see DESIGN.md section 3).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.api import QuantDotSpec, RotationSpec, hadamard, plan_for
from repro.core.hadamard import grouped_hadamard, largest_pow2_divisor
from repro.core.quant import QuantConfig
from repro.kernels.ref import hadamard_matrix

__all__ = [
    "online_hadamard",
    "online_hadamard_quantize",
    "rotated_quant_dot",
    "rotated_quant_dot_experts",
    "rotation_matrix",
    "rotate_activation_in",
    "fuse_rotation_rhs",
    "fuse_rotation_lhs",
    "fuse_down_proj_rotations",
]


def _cfg_backend(cfg: QuantConfig):
    # "auto" defers to the registry (env override, then size/platform).
    return None if cfg.backend == "auto" else cfg.backend


def online_hadamard(x: jnp.ndarray, cfg: QuantConfig) -> jnp.ndarray:
    """Online orthonormal Hadamard rotation of the last axis.

    A thin plan lookup into :mod:`repro.core.api`: the plan (cached per
    shape/dtype/backend) handles kernel-vs-XLA dispatch and non-power-of-2
    sizes via the grouped transform I_g (x) H_p (DESIGN.md sections 3, 5).
    """
    if not cfg.rotating:
        return x
    plan = plan_for(x.shape[-1], dtype=x.dtype, backend=_cfg_backend(cfg))
    return hadamard(x, plan)


# --------------------------------------------------------- DEPRECATED shims
# The QuantConfig-threading consumer entry points predate the declarative
# spec API (DESIGN.md section 7) and are kept only for backward
# compatibility: each is a thin wrapper that builds the equivalent
# RotationSpec / QuantDotSpec and applies it. New code declares the site
# once and binds weights (pre-quantized QTensors on the serving path):
#
#     spec = QuantDotSpec.for_config(n, cfg, weight_axes=("dff", "fsdp"))
#     y = spec.bind(w)(x)
#
def _warn_once(name: str, repl: str):
    # one DeprecationWarning per process per shim, counted every call in
    # TRACE_COUNTS[("deprecated", name)] (shared registry warn-once idiom)
    from repro.kernels.registry import warn_once

    warn_once(
        ("deprecated", name),
        f"repro.core.rotations.{name} is deprecated; use {repl} "
        "(see DESIGN.md section 7)",
        category=DeprecationWarning, stacklevel=4)


def online_hadamard_quantize(
    x: jnp.ndarray, cfg: QuantConfig, *, per_token: Optional[bool] = None
) -> jnp.ndarray:
    """DEPRECATED: use :class:`repro.core.api.RotationSpec`.

    Online rotation + fake quantization of the last axis, fused when the
    plan supports it. Semantics unchanged: the shim builds the equivalent
    RotationSpec and applies it."""
    _warn_once("online_hadamard_quantize",
               "repro.core.api.RotationSpec.for_config(n, cfg)(x)")
    pt = cfg.per_token if per_token is None else per_token
    spec = RotationSpec(
        n=x.shape[-1], mode=cfg.mode if cfg.enabled else "none",
        rotate=cfg.rotating, per_token=pt, dequant=True,
        backend=_cfg_backend(cfg))
    return spec(x)


def rotated_quant_dot(x: jnp.ndarray, w, cfg: QuantConfig) -> jnp.ndarray:
    """DEPRECATED: use :class:`repro.core.api.QuantDotSpec`.

    ``x @ w`` with the online Hadamard on x's contraction axis and REAL
    low-precision operands -- the down-projection hot path. Semantics
    unchanged: the shim builds the equivalent QuantDotSpec and binds
    ``w`` (raw full-precision training form, or a pre-quantized QTensor
    serving form)."""
    _warn_once("rotated_quant_dot",
               "repro.core.api.QuantDotSpec.for_config(n, cfg).bind(w)(x)")
    return QuantDotSpec.for_config(x.shape[-1], cfg).bind(w)(x)


def rotated_quant_dot_experts(x: jnp.ndarray, w,
                              cfg: QuantConfig) -> jnp.ndarray:
    """DEPRECATED: use :meth:`repro.core.api.QuantDotSpec.bind_experts`.

    Per-expert ``rotated_quant_dot``: ``einsum('becf,efd->becd')`` with
    the shared online Hadamard on the dispatched activations and real
    int8/fp8 expert weights. Semantics unchanged via the spec API."""
    _warn_once(
        "rotated_quant_dot_experts",
        "repro.core.api.QuantDotSpec.for_config(n, cfg).bind_experts(w)(x)")
    return QuantDotSpec.for_config(x.shape[-1], cfg).bind_experts(w)(x)


def rotation_matrix(n: int, key: Optional[jax.Array] = None) -> jnp.ndarray:
    """Orthonormal rotation Q used for offline fusion.

    Q = D H with D a random-sign diagonal and H the orthonormal Hadamard
    (QuaRot's randomized Hadamard). For non-power-of-2 n: I_g (x) H_p
    blocked, with the diagonal spanning the full dim. ``key=None`` gives
    the plain (deterministic) Hadamard."""
    p = largest_pow2_divisor(n)
    Hp = hadamard_matrix(p, scale=1.0 / np.sqrt(p))
    H = np.kron(np.eye(n // p, dtype=np.float32), Hp) if p != n else Hp
    Q = jnp.asarray(H)
    if key is not None:
        d = jax.random.rademacher(key, (n,), dtype=jnp.float32)
        Q = d[:, None] * Q
    return Q


def rotate_activation_in(x: jnp.ndarray, Q: Optional[jnp.ndarray]) -> jnp.ndarray:
    """x <- x Q (activations live in rows; residual stream rotation)."""
    if Q is None:
        return x
    return x @ Q


def fuse_rotation_rhs(w: jnp.ndarray, Q: jnp.ndarray) -> jnp.ndarray:
    """W <- W Q for weights *writing* to the rotated stream (out-proj rows
    stay, output columns rotate). w: (..., d_in, d_out_rotated)."""
    return w @ Q


def fuse_rotation_lhs(w: jnp.ndarray, Q: jnp.ndarray) -> jnp.ndarray:
    """W <- Q^T W for weights *reading* from the rotated stream.
    w: (d_in_rotated, ...). Works for stacked (layers, d_in, d_out) too."""
    return jnp.einsum("ij,...jk->...ik", Q.T, w)


def _rotate_rows_grouped(w: jnp.ndarray) -> jnp.ndarray:
    """W <- (I (x) H) W: grouped Hadamard applied along the row
    (contraction) axis -- H symmetric, so this is the exact inverse pairing
    for an online-rotated input. w: (..., d_in, d_out)."""
    wt = jnp.swapaxes(w.astype(jnp.float32), -1, -2)
    wt = grouped_hadamard(wt)
    return jnp.swapaxes(wt, -1, -2).astype(w.dtype)


def fuse_down_proj_rotations(params):
    """Offline half of the paper's online rotation: pre-rotate the rows of
    every down-projection weight so ``had(h) @ W' == h @ W`` exactly.

    Apply this ONCE when enabling rotation on a model trained WITHOUT it
    (the post-training-quantization deployment of QuaRot / this paper).
    Models trained with rotation enabled learn the rotated basis directly
    and must NOT be fused again.

    Matches the online insertion points: 'w_down' (dense MLP + MoE experts
    + shared expert) and the RWKV channel-mix 'wv'."""
    import jax

    def fix(path, leaf):
        keys = [getattr(k, "key", getattr(k, "name", "")) for k in path]
        if not keys:
            return leaf
        if keys[-1] == "w_down":
            return _rotate_rows_grouped(leaf)
        if keys[-1] == "wv" and "cmix" in keys:
            return _rotate_rows_grouped(leaf)
        return leaf

    return jax.tree_util.tree_map_with_path(fix, params)
