"""QuaRot-style rotation plumbing: offline weight fusion + online Hadamard.

The paper's kernel exists to make the *online* rotations (red blocks in
its Fig. 1) cheap. This module provides both halves:

offline (free at runtime -- exact algebraic weight rewrites):
    R1: a global residual-stream rotation Q. Every weight reading from the
        residual stream is pre-multiplied (W <- Q^T W), every weight
        writing to it post-multiplied (W <- W Q), embeddings rotated,
        final LayerNorm folded. We use Q = D H (random-sign diagonal times
        the orthonormal Walsh-Hadamard matrix), QuaRot's choice.
    R2: per-head rotation of (W_v, W_o) pairs.

online (runs every token -- this is where hadacore is deployed):
    * Hadamard on the down_proj input (d_ff contraction dim).
    * Per-head Hadamard on K (and Q) before the quantized KV-cache write /
      FP8 attention -- head_dim-sized transforms.

All online rotations route through ``online_hadamard`` which picks the
Pallas kernel or the factored XLA path, and handles non-power-of-2 dims by
grouped transforms (exactness preserved; see DESIGN.md section 3).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.hadamard import (
    grouped_hadamard,
    hadamard_transform,
    largest_pow2_divisor,
)
from repro.core.quant import QuantConfig
from repro.kernels.ops import hadamard as hadamard_op
from repro.kernels.ref import hadamard_matrix, is_pow2

__all__ = [
    "online_hadamard",
    "rotation_matrix",
    "rotate_activation_in",
    "fuse_rotation_rhs",
    "fuse_rotation_lhs",
    "fuse_down_proj_rotations",
]


def online_hadamard(x: jnp.ndarray, cfg: QuantConfig) -> jnp.ndarray:
    """Online orthonormal Hadamard rotation of the last axis.

    Dispatch: power-of-2 sizes <= 32768 go to the hadacore Pallas kernel
    (cfg.backend == 'pallas') or the MXU-factored XLA path; non-power-of-2
    sizes use the grouped transform I_g (x) H_p with p the largest
    power-of-2 divisor.
    """
    if not cfg.rotating:
        return x
    n = x.shape[-1]
    if is_pow2(n):
        return hadamard_op(x, "ortho", cfg.backend)
    p = largest_pow2_divisor(n)
    xg = x.reshape(*x.shape[:-1], n // p, p)
    return hadamard_op(xg, "ortho", cfg.backend).reshape(x.shape)


def rotation_matrix(n: int, key: Optional[jax.Array] = None) -> jnp.ndarray:
    """Orthonormal rotation Q used for offline fusion.

    Q = D H with D a random-sign diagonal and H the orthonormal Hadamard
    (QuaRot's randomized Hadamard). For non-power-of-2 n: I_g (x) H_p
    blocked, with the diagonal spanning the full dim. ``key=None`` gives
    the plain (deterministic) Hadamard."""
    p = largest_pow2_divisor(n)
    Hp = hadamard_matrix(p, scale=1.0 / np.sqrt(p))
    H = np.kron(np.eye(n // p, dtype=np.float32), Hp) if p != n else Hp
    Q = jnp.asarray(H)
    if key is not None:
        d = jax.random.rademacher(key, (n,), dtype=jnp.float32)
        Q = d[:, None] * Q
    return Q


def rotate_activation_in(x: jnp.ndarray, Q: Optional[jnp.ndarray]) -> jnp.ndarray:
    """x <- x Q (activations live in rows; residual stream rotation)."""
    if Q is None:
        return x
    return x @ Q


def fuse_rotation_rhs(w: jnp.ndarray, Q: jnp.ndarray) -> jnp.ndarray:
    """W <- W Q for weights *writing* to the rotated stream (out-proj rows
    stay, output columns rotate). w: (..., d_in, d_out_rotated)."""
    return w @ Q


def fuse_rotation_lhs(w: jnp.ndarray, Q: jnp.ndarray) -> jnp.ndarray:
    """W <- Q^T W for weights *reading* from the rotated stream.
    w: (d_in_rotated, ...). Works for stacked (layers, d_in, d_out) too."""
    return jnp.einsum("ij,...jk->...ik", Q.T, w)


def _rotate_rows_grouped(w: jnp.ndarray) -> jnp.ndarray:
    """W <- (I (x) H) W: grouped Hadamard applied along the row
    (contraction) axis -- H symmetric, so this is the exact inverse pairing
    for an online-rotated input. w: (..., d_in, d_out)."""
    wt = jnp.swapaxes(w.astype(jnp.float32), -1, -2)
    wt = grouped_hadamard(wt)
    return jnp.swapaxes(wt, -1, -2).astype(w.dtype)


def fuse_down_proj_rotations(params):
    """Offline half of the paper's online rotation: pre-rotate the rows of
    every down-projection weight so ``had(h) @ W' == h @ W`` exactly.

    Apply this ONCE when enabling rotation on a model trained WITHOUT it
    (the post-training-quantization deployment of QuaRot / this paper).
    Models trained with rotation enabled learn the rotated basis directly
    and must NOT be fused again.

    Matches the online insertion points: 'w_down' (dense MLP + MoE experts
    + shared expert) and the RWKV channel-mix 'wv'."""
    import jax

    def fix(path, leaf):
        keys = [getattr(k, "key", getattr(k, "name", "")) for k in path]
        if not keys:
            return leaf
        if keys[-1] == "w_down":
            return _rotate_rows_grouped(leaf)
        if keys[-1] == "wv" and "cmix" in keys:
            return _rotate_rows_grouped(leaf)
        return leaf

    return jax.tree_util.tree_map_with_path(fix, params)
