"""QuaRot-style rotation plumbing: offline weight fusion + online Hadamard.

The paper's kernel exists to make the *online* rotations (red blocks in
its Fig. 1) cheap. This module provides both halves:

offline (free at runtime -- exact algebraic weight rewrites):
    R1: a global residual-stream rotation Q. Every weight reading from the
        residual stream is pre-multiplied (W <- Q^T W), every weight
        writing to it post-multiplied (W <- W Q), embeddings rotated,
        final LayerNorm folded. We use Q = D H (random-sign diagonal times
        the orthonormal Walsh-Hadamard matrix), QuaRot's choice.
    R2: per-head rotation of (W_v, W_o) pairs.

online (runs every token -- this is where hadacore is deployed):
    * Hadamard on the down_proj input (d_ff contraction dim).
    * Per-head Hadamard on K (and Q) before the quantized KV-cache write /
      FP8 attention -- head_dim-sized transforms.

All online rotations route through ``online_hadamard`` which picks the
Pallas kernel or the factored XLA path, and handles non-power-of-2 dims by
grouped transforms (exactness preserved; see DESIGN.md section 3).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import api as _api
from repro.core.api import QuantEpilogue, hadamard, plan_for
from repro.core.hadamard import grouped_hadamard, largest_pow2_divisor
from repro.core.quant import QuantConfig, quantize
from repro.core.quant import quant_dot as _fake_quant_dot
from repro.kernels.ref import hadamard_matrix

__all__ = [
    "online_hadamard",
    "online_hadamard_quantize",
    "rotated_quant_dot",
    "rotated_quant_dot_experts",
    "rotation_matrix",
    "rotate_activation_in",
    "fuse_rotation_rhs",
    "fuse_rotation_lhs",
    "fuse_down_proj_rotations",
]


def _cfg_backend(cfg: QuantConfig):
    # "auto" defers to the registry (env override, then size/platform).
    return None if cfg.backend == "auto" else cfg.backend


def online_hadamard(x: jnp.ndarray, cfg: QuantConfig) -> jnp.ndarray:
    """Online orthonormal Hadamard rotation of the last axis.

    A thin plan lookup into :mod:`repro.core.api`: the plan (cached per
    shape/dtype/backend) handles kernel-vs-XLA dispatch and non-power-of-2
    sizes via the grouped transform I_g (x) H_p (DESIGN.md sections 3, 5).
    """
    if not cfg.rotating:
        return x
    plan = plan_for(x.shape[-1], dtype=x.dtype, backend=_cfg_backend(cfg))
    return hadamard(x, plan)


def online_hadamard_quantize(
    x: jnp.ndarray, cfg: QuantConfig, *, per_token: Optional[bool] = None
) -> jnp.ndarray:
    """Online rotation + fake quantization of the last axis, fused.

    The hot-path form of ``quantize(online_hadamard(x, cfg), ...)``: with
    ``cfg.backend == 'pallas'`` (power-of-2 sizes, per-token scales) the
    rotation, per-token absmax, and quantize-dequantize round trip run in
    ONE VMEM-resident kernel -- the rotated tensor never round-trips
    through HBM. Other configurations fall back to the two-step path with
    identical forward numerics. Both paths are differentiable via the
    straight-through estimator (quantize behaves as identity in the
    pullback -- deliberately NOT the raw fake-quant gradient, whose
    round() is zero almost everywhere; see repro.core.api).
    """
    pt = cfg.per_token if per_token is None else per_token
    if not cfg.enabled:
        return online_hadamard(x, cfg)
    if not cfg.rotating:
        return quantize(x, cfg.mode, axis=-1 if pt else None)
    epi = QuantEpilogue(cfg.mode, per_token=pt, dequant=True)
    plan = plan_for(
        x.shape[-1], dtype=x.dtype, backend=_cfg_backend(cfg), epilogue=epi
    )
    return hadamard(x, plan)


def _quant_dot_plan(n: int, dtype, cfg: QuantConfig):
    return plan_for(
        n, dtype=dtype, backend=_cfg_backend(cfg),
        epilogue=QuantEpilogue(cfg.mode, per_token=cfg.per_token),
    )


def rotated_quant_dot(x: jnp.ndarray, w: jnp.ndarray, cfg: QuantConfig) -> jnp.ndarray:
    """``x @ w`` with the online Hadamard on x's contraction axis and
    REAL low-precision operands -- the down-projection hot path (per-token
    scales on the activation, per-out-channel scales on the weight).

    With a rotating+quantizing config this routes through
    :func:`repro.core.api.quant_dot`: rotate, quantize, and the int8
    (int32-accumulated) / fp8 contraction run as ONE fused kernel when the
    plan supports it (pallas backend, power-of-2 n, per-token scales) --
    the rotated quantized activations never round-trip through HBM, and
    nothing fake-quantizes in f32 on the hot path. Both operands stay
    differentiable via the straight-through estimator."""
    if not cfg.enabled:
        return online_hadamard(x, cfg) @ w
    if not cfg.rotating:
        # no rotation insertion point: the plain fake-quant matmul
        return _fake_quant_dot(x, w, cfg)
    plan = _quant_dot_plan(x.shape[-1], x.dtype, cfg)
    return _api.quant_dot(x, w, plan)


@functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3))
def _rqd_experts(x, w, plan, interpret):
    # einsum form of quant_dot for stacked expert weights: the activation
    # side is the fused rotate+quantize kernel ((q, scales) epilogue); the
    # contraction runs on the real low-precision grids per expert. The
    # scales factor out of the einsum exactly (s per token row, sw per
    # (expert, out-channel)).
    from repro.core.wquant import quantize_weight
    from repro.kernels.registry import QSPECS

    q, s = hadamard(x, plan, interpret=interpret)
    wq, sw = quantize_weight(w, plan.epilogue.mode)     # (E,f,d), (E,1,d)
    if QSPECS[plan.epilogue.mode][2]:
        acc = jnp.einsum("becf,efd->becd", q.astype(jnp.int8),
                         wq.astype(jnp.int8),
                         preferred_element_type=jnp.int32
                         ).astype(jnp.float32)
    else:
        acc = jnp.einsum("becf,efd->becd",
                         q.astype(jnp.bfloat16), wq.astype(jnp.bfloat16),
                         preferred_element_type=jnp.float32)
    out = acc * s * sw[None]                            # (B,E,c,d)*(1,E,1,d)
    return out.astype(x.dtype)


def _rqd_experts_fwd(x, w, plan, interpret):
    return _rqd_experts(x, w, plan, interpret), (x, w)


def _rqd_experts_bwd(plan, interpret, res, g):
    # STE through both quantizations: out ~= had(x) @ w per expert.
    x, w = res
    stripped = _api._strip(plan)
    gf = g.astype(jnp.float32)
    gy = jnp.einsum("becd,efd->becf", gf, w.astype(jnp.float32))
    gx = hadamard(gy.astype(x.dtype), stripped, interpret=interpret)
    y = hadamard(x, stripped, interpret=interpret)
    gw = jnp.einsum("becf,becd->efd", y.astype(jnp.float32), gf)
    return gx, gw.astype(w.dtype)


_rqd_experts.defvjp(_rqd_experts_fwd, _rqd_experts_bwd)


def rotated_quant_dot_experts(x: jnp.ndarray, w: jnp.ndarray,
                              cfg: QuantConfig) -> jnp.ndarray:
    """Per-expert ``rotated_quant_dot``: ``einsum('becf,efd->becd')`` with
    the shared online Hadamard on the dispatched activations (ONE fused
    rotate+quantize kernel -- all experts share d_ff) and real int8/fp8
    expert weights with per-(expert, out-channel) scales. The MoE
    down-projection hot path."""
    if not cfg.enabled:
        return jnp.einsum("becf,efd->becd", online_hadamard(x, cfg), w)
    if not cfg.rotating:
        xq = quantize(x, cfg.mode, axis=-1 if cfg.per_token else None)
        return jnp.einsum("becf,efd->becd", xq,
                          quantize(w, cfg.mode, axis=-2))
    plan = _quant_dot_plan(x.shape[-1], x.dtype, cfg)
    interpret = jax.default_backend() != "tpu"
    return _rqd_experts(x, w, plan, interpret)


def rotation_matrix(n: int, key: Optional[jax.Array] = None) -> jnp.ndarray:
    """Orthonormal rotation Q used for offline fusion.

    Q = D H with D a random-sign diagonal and H the orthonormal Hadamard
    (QuaRot's randomized Hadamard). For non-power-of-2 n: I_g (x) H_p
    blocked, with the diagonal spanning the full dim. ``key=None`` gives
    the plain (deterministic) Hadamard."""
    p = largest_pow2_divisor(n)
    Hp = hadamard_matrix(p, scale=1.0 / np.sqrt(p))
    H = np.kron(np.eye(n // p, dtype=np.float32), Hp) if p != n else Hp
    Q = jnp.asarray(H)
    if key is not None:
        d = jax.random.rademacher(key, (n,), dtype=jnp.float32)
        Q = d[:, None] * Q
    return Q


def rotate_activation_in(x: jnp.ndarray, Q: Optional[jnp.ndarray]) -> jnp.ndarray:
    """x <- x Q (activations live in rows; residual stream rotation)."""
    if Q is None:
        return x
    return x @ Q


def fuse_rotation_rhs(w: jnp.ndarray, Q: jnp.ndarray) -> jnp.ndarray:
    """W <- W Q for weights *writing* to the rotated stream (out-proj rows
    stay, output columns rotate). w: (..., d_in, d_out_rotated)."""
    return w @ Q


def fuse_rotation_lhs(w: jnp.ndarray, Q: jnp.ndarray) -> jnp.ndarray:
    """W <- Q^T W for weights *reading* from the rotated stream.
    w: (d_in_rotated, ...). Works for stacked (layers, d_in, d_out) too."""
    return jnp.einsum("ij,...jk->...ik", Q.T, w)


def _rotate_rows_grouped(w: jnp.ndarray) -> jnp.ndarray:
    """W <- (I (x) H) W: grouped Hadamard applied along the row
    (contraction) axis -- H symmetric, so this is the exact inverse pairing
    for an online-rotated input. w: (..., d_in, d_out)."""
    wt = jnp.swapaxes(w.astype(jnp.float32), -1, -2)
    wt = grouped_hadamard(wt)
    return jnp.swapaxes(wt, -1, -2).astype(w.dtype)


def fuse_down_proj_rotations(params):
    """Offline half of the paper's online rotation: pre-rotate the rows of
    every down-projection weight so ``had(h) @ W' == h @ W`` exactly.

    Apply this ONCE when enabling rotation on a model trained WITHOUT it
    (the post-training-quantization deployment of QuaRot / this paper).
    Models trained with rotation enabled learn the rotated basis directly
    and must NOT be fused again.

    Matches the online insertion points: 'w_down' (dense MLP + MoE experts
    + shared expert) and the RWKV channel-mix 'wv'."""
    import jax

    def fix(path, leaf):
        keys = [getattr(k, "key", getattr(k, "name", "")) for k in path]
        if not keys:
            return leaf
        if keys[-1] == "w_down":
            return _rotate_rows_grouped(leaf)
        if keys[-1] == "wv" and "cmix" in keys:
            return _rotate_rows_grouped(leaf)
        return leaf

    return jax.tree_util.tree_map_with_path(fix, params)
