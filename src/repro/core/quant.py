"""Simulated low-precision quantization (INT8 / FP8) for rotation-quantized
inference, the paper's end-to-end deployment context (QuaRot / SpinQuant /
FlashAttention-3 FP8 attention).

Everything here is *fake quant*: values are quantized and immediately
dequantized so the numerics of INT8/FP8 inference are reproduced exactly
while all matmuls stay in bf16/f32 (the container has no real int8 MXU
path; on a real TPU v5e the same scales feed `lax.dot_general` with int8
inputs). Scales are power-of-two-free, symmetric, per-token or per-channel.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

__all__ = ["QuantConfig", "quantize", "quant_dot", "kv_quantize"]

_INT8_MAX = 127.0
_FP8_E4M3_MAX = 448.0
_FP8_E5M2_MAX = 57344.0


@dataclasses.dataclass(frozen=True)
class QuantConfig:
    """Quantization + rotation feature switches carried by every model config.

    mode:    'none' | 'int8' | 'fp8_e4m3' | 'fp8_e5m2'
    rotate:  'none' | 'hadamard'  (online Hadamard rotations at the QuaRot
             insertion points; offline R1/R2 fusion is applied at init)
    backend: 'pallas' (hadacore kernel) | 'xla' (factored pure-JAX path)
    kv_quant: quantize the KV cache (FP8 attention use-case of the paper)
    """
    mode: str = "none"
    rotate: str = "none"
    backend: str = "xla"
    kv_quant: bool = False
    per_token: bool = True

    @property
    def enabled(self) -> bool:
        return self.mode != "none"

    @property
    def rotating(self) -> bool:
        return self.rotate != "none"

    def kv_cache_dtype(self, model_dtype):
        """Storage dtype for the KV cache: real fp8 when fp8 KV quant is
        on (halves cache HBM + wire traffic -- the rotation keeps the
        direct cast accurate, which is the paper's FP8-attention story)."""
        import jax.numpy as jnp
        if self.kv_quant and self.mode == "fp8_e4m3":
            return jnp.float8_e4m3fn
        if self.kv_quant and self.mode == "fp8_e5m2":
            return jnp.float8_e5m2
        return model_dtype


def _absmax(x: jnp.ndarray, axis: Optional[int], keepdims: bool = True) -> jnp.ndarray:
    m = jnp.max(jnp.abs(x), axis=axis, keepdims=keepdims)
    return jnp.maximum(m, 1e-8)


def quantize(x: jnp.ndarray, mode: str, axis: Optional[int] = -1) -> jnp.ndarray:
    """Symmetric fake-quantize along ``axis`` (None = per-tensor).

    int8: round-to-nearest to [-127, 127]. fp8: scale to the format's max
    then cast through the real fp8 dtype (XLA convert), preserving the
    format's mantissa truncation and dynamic range exactly.
    """
    if mode == "none":
        return x
    dt = x.dtype
    xf = x.astype(jnp.float32)
    if mode == "int8":
        s = _absmax(xf, axis) / _INT8_MAX
        q = jnp.clip(jnp.round(xf / s), -_INT8_MAX, _INT8_MAX)
        return (q * s).astype(dt)
    if mode in ("fp8_e4m3", "fp8_e5m2"):
        fmax = _FP8_E4M3_MAX if mode == "fp8_e4m3" else _FP8_E5M2_MAX
        fdt = jnp.float8_e4m3fn if mode == "fp8_e4m3" else jnp.float8_e5m2
        s = _absmax(xf, axis) / fmax
        q = (xf / s).astype(fdt).astype(jnp.float32)
        return (q * s).astype(dt)
    raise ValueError(f"unknown quant mode {mode!r}")


def quant_dot(x: jnp.ndarray, w: jnp.ndarray, cfg: QuantConfig) -> jnp.ndarray:
    """x @ w with fake-quantized operands: per-token (row) scales on the
    activation, per-out-channel scales on the weight -- the QuaRot setup."""
    if not cfg.enabled:
        return x @ w
    xq = quantize(x, cfg.mode, axis=-1 if cfg.per_token else None)
    wq = quantize(w, cfg.mode, axis=0)
    return xq @ wq


def kv_quantize(k: jnp.ndarray, v: jnp.ndarray, cfg: QuantConfig):
    """Quantize K/V on the head dim before the cache write (FP8 attention)."""
    if not (cfg.enabled and cfg.kv_quant):
        return k, v
    return quantize(k, cfg.mode, axis=-1), quantize(v, cfg.mode, axis=-1)
