"""Simulated low-precision quantization (INT8 / FP8) for rotation-quantized
inference, the paper's end-to-end deployment context (QuaRot / SpinQuant /
FlashAttention-3 FP8 attention).

Everything here is *fake quant*: values are quantized and immediately
dequantized so the numerics of INT8/FP8 inference are reproduced exactly
while all matmuls stay in bf16/f32 (the container has no real int8 MXU
path; on a real TPU v5e the same scales feed `lax.dot_general` with int8
inputs). Scales are power-of-two-free, symmetric, per-token or per-channel.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import guards

__all__ = ["QuantConfig", "quantize", "quant_dot", "kv_quantize"]


@dataclasses.dataclass(frozen=True)
class QuantConfig:
    """Quantization + rotation feature switches carried by every model config.

    mode:    'none' | 'int8' | 'fp8_e4m3' | 'fp8_e5m2'
    rotate:  'none' | 'hadamard'  (online Hadamard rotations at the QuaRot
             insertion points; offline R1/R2 fusion is applied at init)
    backend: 'pallas' (hadacore kernel) | 'xla' (factored pure-JAX path)
             | 'ref' (scalar FWHT oracle) | 'auto' (registry selection:
             REPRO_HADAMARD_BACKEND env override, then size/platform)
    kv_quant: quantize the KV cache (FP8 attention use-case of the paper)
    schedule: fused quant_dot grid schedule for every consumer site this
             config implies ('rotate_once' | 'revisit' | 'streamed';
             None defers to REPRO_QUANT_DOT_SCHEDULE, then the default).
             The serving engine's degradation ladder re-warms on
             config replicas that pin this field one rung down.
    abft:    algorithm-based fault tolerance: store ABFT column checksums
             on every QTensor weight and verify the fused quant_dot
             outputs + serving KV cache at run time (silent-data-
             corruption detection; ``repro.verify``, DESIGN.md section
             14). ``REPRO_ABFT=1`` enables it without a config edit.
    """
    mode: str = "none"
    rotate: str = "none"
    backend: str = "xla"
    kv_quant: bool = False
    per_token: bool = True
    schedule: Optional[str] = None
    abft: bool = False

    _MODES = ("none", "int8", "fp8_e4m3", "fp8_e5m2")
    _ROTATES = ("none", "hadamard")
    _BACKENDS = ("pallas", "xla", "ref", "auto")

    def __post_init__(self):
        if self.mode not in self._MODES:
            raise ValueError(f"unknown quant mode {self.mode!r}; expected one of {self._MODES}")
        if self.rotate not in self._ROTATES:
            raise ValueError(f"unknown rotate {self.rotate!r}; expected one of {self._ROTATES}")
        if self.backend not in self._BACKENDS:
            raise ValueError(f"unknown backend {self.backend!r}; expected one of {self._BACKENDS}")
        if self.schedule is not None:
            from repro.kernels.quant_dot import SCHEDULES  # lazy: no cycle

            if self.schedule not in SCHEDULES:
                raise ValueError(
                    f"unknown quant_dot schedule {self.schedule!r}; "
                    f"expected None or one of {SCHEDULES}")

    @property
    def enabled(self) -> bool:
        return self.mode != "none"

    @property
    def rotating(self) -> bool:
        return self.rotate != "none"

    def kv_cache_dtype(self, model_dtype):
        """Storage dtype for the KV cache: real fp8 when fp8 KV quant is
        on (halves cache HBM + wire traffic -- the rotation keeps the
        direct cast accurate, which is the paper's FP8-attention story)."""
        import jax.numpy as jnp
        if self.kv_quant and self.mode == "fp8_e4m3":
            return jnp.float8_e4m3fn
        if self.kv_quant and self.mode == "fp8_e5m2":
            return jnp.float8_e5m2
        return model_dtype


def quantize(x: jnp.ndarray, mode: str, axis: Optional[int] = -1) -> jnp.ndarray:
    """Symmetric fake-quantize along ``axis`` (None = per-tensor).

    int8: round-to-nearest to [-127, 127]. fp8: scale to the format's max
    then cast through the real fp8 dtype (XLA convert), preserving the
    format's mantissa truncation and dynamic range exactly.

    Delegates to ``kernels.registry._quantize_rows`` -- the same math the
    fused rotate+quantize kernels run in VMEM -- so the two-step and
    fused paths agree bit-for-bit by construction.
    """
    if mode == "none":
        return x
    from repro.kernels.registry import QSPECS, _dequantize, _quantize_rows

    if mode not in QSPECS:
        raise ValueError(f"unknown quant mode {mode!r}")
    q, s = _quantize_rows(x.astype(jnp.float32), mode, axis=axis)
    y = _dequantize(q, s, mode).astype(x.dtype)
    # Numeric-guard seam (opt-in, trace-local so it is remat-safe): rows
    # with a non-finite/non-positive scale are poisoned with NaN, which
    # the serving step's logits guard attributes to the right slot.
    if guards.guards_enabled():
        y = guards.guard_dequant(y, s)
    return y


def quant_dot(x: jnp.ndarray, w: jnp.ndarray, cfg: QuantConfig) -> jnp.ndarray:
    """x @ w with fake-quantized operands: per-token (row) scales on the
    activation, per-out-channel scales on the weight -- the QuaRot setup."""
    if not cfg.enabled:
        return x @ w
    xq = quantize(x, cfg.mode, axis=-1 if cfg.per_token else None)
    wq = quantize(w, cfg.mode, axis=0)
    return xq @ wq


def kv_quantize(k: jnp.ndarray, v: jnp.ndarray, cfg: QuantConfig):
    """Quantize K/V on the head dim before the cache write (FP8 attention)."""
    if not (cfg.enabled and cfg.kv_quant):
        return k, v
    return quantize(k, cfg.mode, axis=-1), quantize(v, cfg.mode, axis=-1)
