"""Mesh-agnostic checkpointing with async writes and elastic restore.

Layout:  <dir>/step_<k>/arr_<i>.npy + tree.json (+ .done marker)

Design points for 1000+-node deployments (scaled to this container):
  * Arrays are written per-leaf; at multi-host scale each host writes its
    addressable shards (here: one host owns everything). The tree manifest
    carries shapes/dtypes so a restore can re-shard onto ANY mesh --
    elastic rescaling is "restore with different shardings", nothing else.
  * Writes happen on a background thread (training continues through the
    serialization of the previous step's state).
  * A checkpoint is only valid once its ``.done`` marker exists; restore
    picks the newest valid step, so a mid-write crash falls back to the
    previous checkpoint.
  * Quantized-weight trees serialize transparently: ``wquant.QTensor`` is
    a registered pytree node, so its ``q``/``scale`` children flatten to
    ordinary leaves (fp8/int8 storage written via the raw-uint view) and
    a restore onto a QTensor template rebuilds the nodes with their
    static mode/axes metadata from the template. Legacy pre-QTensor
    checkpoints ({'wq','ws'} dicts) restore onto QTensor templates
    unchanged -- both flatten to the same (values, scales) leaf order.
  * Content integrity (PR 10): every leaf's manifest entry records a
    CRC-32 of the exact bytes written plus the leaf's tree path; restore
    recomputes the CRC over the bytes it read back and fails LOUDLY,
    naming the leaf path, on any mismatch -- a silently bit-rotted
    weight file must never become a silently wrong model (that is the
    storage-side twin of the runtime ABFT checksums in ``repro.verify``).
    Manifests without CRCs (pre-PR 10) restore unchecked, unchanged.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import zlib
from typing import Any, Optional, Tuple

import jax
import numpy as np

_WRITER: Optional[threading.Thread] = None


def _flatten_with_paths(tree):
    """(leaves, treedef, path strings) -- paths name leaves in manifest
    entries and integrity errors (['groups'][0]['p0']['mlp']['w_down'].q
    beats arr_37.npy when a restore reports corruption)."""
    flat_p, treedef = jax.tree_util.tree_flatten_with_path(tree)
    flat = [leaf for _, leaf in flat_p]
    paths = [jax.tree_util.keystr(path) for path, _ in flat_p]
    return flat, treedef, paths


def _to_numpy(x) -> Tuple[np.ndarray, str]:
    """numpy-ify, viewing non-numpy dtypes (bf16, fp8) as raw uints."""
    a = np.asarray(x)
    logical = str(a.dtype)
    if a.dtype.kind == "V" or "bfloat16" in logical or "float8" in logical:
        a = a.view({1: np.uint8, 2: np.uint16, 4: np.uint32}[a.dtype.itemsize])
    return a, logical


def _from_numpy(a: np.ndarray, want_dtype) -> np.ndarray:
    if a.dtype != np.dtype(want_dtype) and a.dtype.kind == "u":
        import ml_dtypes  # noqa: F401 -- registers bf16/fp8 numpy dtypes
        return a.view(np.dtype(want_dtype))
    return a


def save_checkpoint(ckpt_dir: str, step: int, tree: Any, *, async_write: bool = True):
    """Serialize a pytree of arrays. Returns immediately if async."""
    flat, treedef, paths = _flatten_with_paths(tree)
    host = [_to_numpy(x)[0] for x in flat]        # fetch before backgrounding
    tdef_str = str(treedef)

    def write():
        out = os.path.join(ckpt_dir, f"step_{step:09d}")
        tmp = out + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp, exist_ok=True)
        manifest = {"step": step, "treedef": tdef_str, "leaves": []}
        for i, arr in enumerate(host):
            np.save(os.path.join(tmp, f"arr_{i}.npy"), arr)
            manifest["leaves"].append({
                "shape": list(arr.shape), "dtype": str(arr.dtype),
                "path": paths[i],
                "crc": zlib.crc32(np.ascontiguousarray(arr).tobytes())})
        with open(os.path.join(tmp, "tree.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(out):
            shutil.rmtree(out)
        os.replace(tmp, out)
        open(os.path.join(out, ".done"), "w").close()

    global _WRITER
    if _WRITER is not None and _WRITER.is_alive():
        _WRITER.join()                             # backpressure: one in flight
    if async_write:
        _WRITER = threading.Thread(target=write, daemon=True)
        _WRITER.start()
    else:
        write()


def wait_for_writes():
    if _WRITER is not None and _WRITER.is_alive():
        _WRITER.join()


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = []
    for d in os.listdir(ckpt_dir):
        if d.startswith("step_") and os.path.exists(os.path.join(ckpt_dir, d, ".done")):
            steps.append(int(d.split("_")[1]))
    return max(steps) if steps else None


def restore_checkpoint(ckpt_dir: str, step: int, template: Any,
                       shardings: Any = None) -> Any:
    """Restore onto ``template``'s structure; ``shardings`` (optional tree
    of NamedSharding) re-shards for the *current* mesh -- the elastic path."""
    out = os.path.join(ckpt_dir, f"step_{step:09d}")
    flat_t, treedef = jax.tree.flatten(template)
    with open(os.path.join(out, "tree.json")) as f:
        manifest = json.load(f)
    if len(manifest["leaves"]) != len(flat_t):
        raise ValueError(
            f"checkpoint at {out} has {len(manifest['leaves'])} leaves but "
            f"the restore template flattens to {len(flat_t)} -- the saved "
            "tree structure does not match (e.g. restoring a raw-weight "
            "checkpoint onto a QTensor template or vice versa: re-run "
            "quantize_lm_weights on the restored raw tree instead)")
    arrs = []
    for i, t in enumerate(flat_t):
        a = np.load(os.path.join(out, f"arr_{i}.npy"))
        entry = manifest["leaves"][i]
        if "crc" in entry:      # pre-PR 10 manifests restore unchecked
            name = entry.get("path", f"leaf[{i}]")
            got_crc = zlib.crc32(np.ascontiguousarray(a).tobytes())
            if got_crc != entry["crc"]:
                raise ValueError(
                    f"checkpoint leaf {name} (arr_{i}.npy in {out}) is "
                    f"CORRUPT: stored CRC-32 {entry['crc']:#010x} != "
                    f"recomputed {got_crc:#010x} over {a.nbytes} bytes -- "
                    "the file changed since save_checkpoint wrote it "
                    "(bit rot, truncated write, or off-path mutation); "
                    "restore from an older .done step")
            if list(a.shape) != entry["shape"] \
                    or str(a.dtype) != entry["dtype"]:
                raise ValueError(
                    f"checkpoint leaf {name} (arr_{i}.npy in {out}) has "
                    f"shape {a.shape}/{a.dtype} but its manifest entry "
                    f"says {tuple(entry['shape'])}/{entry['dtype']}")
        arrs.append(_from_numpy(a, t.dtype))
    if shardings is not None:
        flat_s = jax.tree.leaves(shardings, is_leaf=lambda x: hasattr(x, "spec") or x is None)
        arrs = [jax.device_put(a, s) if s is not None else jax.device_put(a)
                for a, s in zip(arrs, flat_s)]
    else:
        arrs = [jax.device_put(a) for a in arrs]
    return jax.tree.unflatten(treedef, arrs)
