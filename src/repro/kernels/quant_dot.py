"""Fused rotate -> quantize -> GEMM consumer kernel (the quantized hot
path, end to end in low precision) with a ROTATE-ONCE grid schedule.

The paper's kernel makes the online rotation cheap; its *consumer* is a
quantized matmul (QuaRot down-proj, FP8 attention). PR 1 fused the
rotation with the quantize epilogue so the quantized tensor is the only
HBM output -- but the consumer GEMM still read it back from HBM and the
models fake-quantized both operands in f32. PR 3 closed that loop with a
2D (row blocks x out-channel blocks) grid whose every step rotated the
row block, quantized it, and contracted it against one weight tile:

  * int8 operands with int32 MXU accumulation (``preferred_element_type``)
  * fp8 operands multiplied exactly in bf16 (both fp8 grids embed exactly:
    <= 5 mantissa bits and products of two fp8 values fit bf16's 8) with
    f32 accumulation

applying ``scale_x * scale_w`` in the epilogue. The rotated/quantized
activations never round-trip through HBM.

PR 3's schedule, however, recomputed the rotate+quantize of each
(block_m, n) row block for EVERY out-channel tile j -- multiplying the
transform work by d/block_n (~8x at n=4096, d=4*4096) when the paper's
roofline argues the transform should cost ~k*128 flops/element ONCE per
row. The default schedule here is **rotate-once**:

  * the out-channel axis j is the INNERMOST grid axis and is declared
    sequential (``dimension_semantics=("parallel", "arbitrary")``): for a
    fixed row block i, the kernel visits j = 0, 1, ..., d/bn - 1 in order;
  * at j == 0 the row block is rotated in the plan's compute dtype
    (bf16/fp16 multiplies, f32 MXU accumulation -- the Markidis / Ootomo
    recipe), per-token quantized, and the DOT-OPERAND form of (q, s) is
    stashed in VMEM ``scratch_shapes`` (int8 for the int path, the exact
    bf16 embedding for fp8 -- so the scratch is also the cheapest legal
    operand representation);
  * every j (including 0) contracts the scratch operand against its
    (n, block_n) weight tile. The scratch outlives the j loop of its row
    block by construction (scratch persists across grid steps; j is
    sequential within each i), so each row is transformed exactly once
    regardless of d.

The PR-3 ``revisit`` schedule is kept selectable (``schedule="revisit"``
or ``REPRO_QUANT_DOT_SCHEDULE=revisit``) as the A/B baseline for the
transform-amortization benchmark; both schedules are bitwise identical
for int8 (the rotation/quantize/contraction math is unchanged -- only
*when* the transform runs differs).

**Streamed weight DMA** (``schedule="streamed"``): rotate-once made the
out-channel axis j sequential, which also made every weight-tile fetch
SYNCHRONOUS -- the implicit BlockSpec pipeline stalls the MXU between
bursts waiting on the (n, bn) tile of step j. The streamed schedule
keeps the rotate-once structure but takes over the weight movement with
a manual two-slot VMEM ring: the weight and scale operands are passed as
HBM/ANY-memory-space refs (no BlockSpec slicing), and at grid step j the
kernel

  * j == 0: starts the async copy of tile 0 into slot 0 (the ring
    warm-up -- the copy flies while the rotation+quantize below it runs,
    so even the first tile's latency hides behind the transform), then
    rotates/quantizes into the scratch exactly as rotate-once does;
  * every j < nj-1: starts the async copy of tile j+1 into slot
    ``(j+1) % 2`` BEFORE contracting tile j -- the DMA of the next tile
    overlaps the current MXU burst;
  * waits on slot ``j % 2``'s semaphore pair (one DMA semaphore per ring
    slot, weight and scale copies tracked separately), then contracts
    from that slot.

Slot parity resets at each new (expert, row block) pair for free: the
slot index is ``j % 2`` of the RESTARTED j loop and the j == 0 warm-up
re-primes slot 0, while the ``j + 1 < nj`` guard drains all in-flight
copies before the row block ends -- no DMA crosses a row-block (or
expert) boundary. ``quant_dot_blocks`` charges the second weight-tile
slot and the scale ring when sizing streamed blocks, so streamed block
sizes never oversubscribe VMEM.

Interpret mode has no real DMA engine (the XLA interpreter simulates
``make_async_copy`` synchronously), so off-TPU dispatch of
``schedule="streamed"`` degrades to ``rotate_once`` -- warned once per
process and counted in ``TRACE_COUNTS[("quant_dot", "stream_fallback")]``
(mirroring the sharded-dispatch ``_sharded_fallback`` observability).
Setting ``REPRO_QUANT_DOT_STREAM_INTERPRET=1`` overrides the fallback
and runs the real streamed body under the interpreter: the simulated
copies are synchronous (no overlap win) but bit-exact, which is how the
schedule-parity tests and the bench A/B exercise the streamed kernels
off-TPU.

``pallas_quant_dot_experts`` extends the same schedule to the stacked
MoE expert weights on a 3-D (expert, row blocks, out-channel blocks)
grid, so the expert consumer stops splitting into a rotate+quantize
kernel plus a per-expert XLA einsum.

``epilogue_dot`` is the single source of truth for the quantized-GEMM
math; the unfused fallback (grouped transforms, per-tensor scales,
``xla_quant_dot`` -- the pjit-shardable path and the test oracle) shares
it so fused and unfused paths agree bit-for-bit in the contraction.
"""
from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.hadamard import _apply_passes
from repro.kernels.registry import (
    QSPECS,
    TRACE_COUNTS,
    _VMEM_BUDGET_BYTES,
    _pad_rows,
    _plan_mats,
    _quantize_rows,
    _rows,
    _xla_transform,
    warn_once,
)

__all__ = ["pallas_quant_dot", "pallas_quant_dot_experts", "xla_quant_dot",
           "xla_quant_dot_resid", "epilogue_dot", "quant_dot_blocks",
           "BlockDecision", "SCHEDULE_ENV_VAR", "SCHEDULES",
           "STREAM_INTERPRET_ENV"]

_CONTRACT = (((1,), (0,)), ((), ()))  # plain (m, k) @ (k, n)

# Largest contraction dim whose worst-case int8 x int8 row sum stays in
# int32: 127 * 127 * 2^17 ~= 2.11e9 < 2^31 - 1 (2^18 would wrap). Only
# the above-cap XLA fallback can exceed this -- the kernel caps at 2^15.
_INT32_SAFE_K = 1 << 17

# fp8 operand bytes/element inside the kernel: the 1-byte storage grid
# plus the exact bf16 embedding the dot runs in.
_FP8_OPERAND_BYTES = 3

SCHEDULE_ENV_VAR = "REPRO_QUANT_DOT_SCHEDULE"
SCHEDULES = ("rotate_once", "revisit", "streamed")

# Set to a truthy value ("1"/"true"/"force") to run the REAL streamed
# kernel body under interpret mode instead of the rotate_once fallback:
# the interpreter simulates each async copy synchronously (no overlap
# win, bit-exact results) -- the hook the schedule-parity tests and the
# bench A/B use to exercise the DMA ring off-TPU.
STREAM_INTERPRET_ENV = "REPRO_QUANT_DOT_STREAM_INTERPRET"

# The streamed->rotate_once interpret fallback warns once per process via
# the shared ``registry.warn_once`` idiom;
# TRACE_COUNTS[("quant_dot", "stream_fallback")] keeps counting every
# dispatch (tests reset the warning via WARN_ONCE_SEEN, never the counter).


def _operand_from_q(q, mode):
    """Cast ``_quantize_rows`` output to the grid the contraction runs on:
    int8 for the int path (int32 MXU accumulation), the exact bf16
    embedding of the fp8 grid otherwise. This is the representation the
    rotate-once schedule stashes in VMEM scratch -- 1 (int8) or 2 (bf16)
    bytes/element, and directly consumable by every subsequent weight
    tile."""
    if QSPECS[mode][2]:
        return q.astype(jnp.int8)
    return q.astype(QSPECS[mode][1]).astype(jnp.bfloat16)


def _operand_dot(a, wq, mode):
    """Contract a dot-operand activation block (``_operand_from_q`` form)
    against the storage-dtype weight tile. Returns f32."""
    if QSPECS[mode][2]:
        acc = jax.lax.dot_general(a, wq.astype(jnp.int8), _CONTRACT,
                                  preferred_element_type=jnp.int32)
        return acc.astype(jnp.float32)
    return jax.lax.dot_general(a, wq.astype(jnp.bfloat16), _CONTRACT,
                               preferred_element_type=jnp.float32)


def _low_precision_dot(q, wq, mode):
    """The quantized contraction on the mode's native arithmetic: int8
    operands accumulate exactly in int32; fp8 operands are embedded in
    bf16 (exact) and accumulate f32. ``q`` comes from ``_quantize_rows``
    pre-cast (f32 values on the grid). Returns f32."""
    is_int = QSPECS[mode][2]
    if is_int and q.shape[-1] > _INT32_SAFE_K:
        # contraction too long for exact int32: f32 accumulation of the
        # exact grid products (values <= 127 are f32-exact)
        return jax.lax.dot_general(
            q, wq.astype(jnp.float32), _CONTRACT,
            preferred_element_type=jnp.float32)
    return _operand_dot(_operand_from_q(q, mode), wq, mode)


def epilogue_dot(q, s, wq, sw, mode: str, out_dtype) -> jnp.ndarray:
    """``(q * s) @ (wq * sw)`` with the scales factored OUT of the matmul:
    ``(q @ wq) * s * sw`` -- exact because s is per row of q and sw per
    column of wq. q: (..., n) grid values, s broadcastable per-token (or
    per-tensor) scales, wq: (n, d) storage-dtype weight, sw: (1, d)."""
    lead = q.shape[:-1]
    n, d = q.shape[-1], wq.shape[-1]
    acc = _low_precision_dot(q.reshape(-1, n), wq, mode).reshape(*lead, d)
    return (acc * s * sw.reshape((1,) * len(lead) + (d,))).astype(out_dtype)


def _operand_bytes(mode: str) -> int:
    """Bytes/element of the scratch-resident dot operand (int8 grid or
    bf16 fp8-embedding)."""
    return 1 if QSPECS[mode][2] else 2


class BlockDecision(tuple):
    """The ``(block_m, block_n)`` tile decision, as a tuple subclass so
    every historical ``bm, bn = quant_dot_blocks(...)`` unpack (and
    ``== (bm, bn)`` comparison) keeps working, carrying the metadata the
    benches log alongside the tiles:

    * ``schedule``   -- the grid schedule the sizes were charged for
      (the streamed DMA ring costs a second weight-tile slot + a scale
      ring, so its block sizes can be narrower);
    * ``vmem_bytes`` -- the estimated VMEM high-water mark of the chosen
      tiles under that schedule (<= the kernel budget by construction).
    """

    schedule: str
    vmem_bytes: int

    def __new__(cls, block_m: int, block_n: int, schedule: str,
                vmem_bytes: int):
        self = super().__new__(cls, (block_m, block_n))
        self.schedule = schedule
        self.vmem_bytes = vmem_bytes
        return self

    @property
    def block_m(self) -> int:
        return self[0]

    @property
    def block_n(self) -> int:
        return self[1]

    def __repr__(self):
        return (f"BlockDecision(block_m={self[0]}, block_n={self[1]}, "
                f"schedule={self.schedule!r}, vmem_bytes={self.vmem_bytes})")


def quant_dot_blocks(n: int, d: int, m: int, dtype, compute_dtype,
                     mode: str, block_m=None, block_n=None,
                     schedule: str = "rotate_once",
                     abft: bool = False) -> BlockDecision:
    """The tile decision for the fused kernel, charging every VMEM
    resident of the requested schedule: the input tile + compute-dtype
    working copy per row, the SCRATCH dot-operand tile (int8 / bf16) + the
    per-row f32 scale that live across the j loop, the weight tile(s),
    the (block_m, block_n) output tile, and the per-out-channel scales.

    ``schedule="streamed"`` charges the DMA ring on top: a SECOND
    (n, block_n) weight-tile slot in the storage dtype plus the two-slot
    f32 scale ring (the DMA semaphores are register-file residents --
    free as far as this budget is concerned), so streamed block sizes
    never oversubscribe VMEM. The chosen schedule and the estimated VMEM
    high-water mark ride along on the returned :class:`BlockDecision`
    (a (block_m, block_n) tuple) so benches can record the decision.

    A user-pinned ``block_m`` (``plan.block_m``) is honored BEFORE any
    sizing decision, so the weight-tile / ``block_n`` tradeoff is
    computed against the row count that will actually run -- not against
    a heuristic ``bm`` that the pin then overrides. ``block_n`` pins the
    out-channel tile the same way (benchmarks use it to hold the revisit
    count fixed across schedules).

    Because the rotate-once schedule makes weight-tile revisits free of
    transform recompute, ``block_n`` is allowed up to 1024 (PR 3 capped
    it at 512 to keep the per-revisit transform bill bounded).

    ``abft=True`` charges the checksum-verified kernel variant: the
    (1, n) f32 column-checksum input tile (block-constant across the
    grid) plus 12 bytes/row for the per-row verification residents (the
    f32 chk + acc scratch columns and the residual output tile). Block
    sizes may therefore differ from the unverified decision -- harmless,
    because every output element is computed from its full n-contraction
    regardless of tiling (the schedule-parity tests assert bitwise
    identity across decisions)."""
    in_b = jnp.dtype(dtype).itemsize
    cb = jnp.dtype(compute_dtype).itemsize
    is_int = QSPECS[mode][2]
    qb = _operand_bytes(mode)       # scratch operand bytes/element
    wb = 1 if is_int else _FP8_OPERAND_BYTES
    swb = 4                         # f32 per-out-channel scale tile
    if schedule == "streamed":
        # the ring's second weight slot holds the 1-byte STORAGE grid for
        # both paths (the fp8 bf16-embedding temporary is made per
        # contraction, never per slot), and the scale tile doubles
        wb += 1
        swb *= 2
    # per-row residents independent of bn: input tile + compute copy +
    # scratch operand + f32 scratch scale
    row_fixed = n * (in_b + cb + qb) + 4
    fixed = 0
    if abft:
        row_fixed += 12             # chk + acc scratch + residual out tile
        fixed = n * 4               # (1, n) f32 column-checksum input

    def vmem(bm_, bn_):
        return fixed + bm_ * row_fixed + bn_ * (n * wb + bm_ * in_b + swb)

    # bn always steps in 128-lane multiples so the BlockSpec last dim
    # stays MXU-tiled
    bn = min(1024, -(-d // 128) * 128) if block_n is None else block_n
    if block_m is not None:
        if block_n is None:
            # pinned rows: the weight/output/sw tiles get everything the
            # rows leave
            avail = _VMEM_BUDGET_BYTES - fixed - block_m * row_fixed
            while bn > 128 and bn * (n * wb + block_m * in_b + swb) > avail:
                bn -= 128
        return BlockDecision(block_m, bn, schedule, vmem(block_m, bn))
    if block_n is None:
        # joint sizing: cap the weight tile at half the budget (oversizing
        # it starves block_m), then size the rows from the remainder
        while n * bn * wb > _VMEM_BUDGET_BYTES // 2 and bn > 128:
            bn -= 128
    per_row = row_fixed + bn * in_b
    bm = max(8, (_VMEM_BUDGET_BYTES - fixed - bn * (n * wb + swb)) // per_row)
    bm = min(bm, 256, m)
    sub = 16 if in_b == 2 else 8
    bm = max(sub, (bm // sub) * sub)
    return BlockDecision(bm, bn, schedule, vmem(bm, bn))


def _rotate_quantize_block(x, mats_ref, *, n: int, mode: str,
                           compute_dtype):
    """The shared transform+quantize stage: rotate a (block_m, n) row
    block in the compute dtype (f32 MXU accumulation) and per-token
    quantize. Returns ``(q, s)`` with q in ``_quantize_rows``'s pre-cast
    f32-grid form."""
    x = x.astype(compute_dtype)
    bm = x.shape[0]
    mats = [mats_ref[p] for p in range(mats_ref.shape[0])]
    y = _apply_passes(x.reshape(bm, n), n, mats)
    return _quantize_rows(y.astype(jnp.float32), mode)


def _quant_dot_kernel_rotate_once(x_ref, mats_ref, wq_ref, sw_ref, o_ref,
                                  q_ref, s_ref, *, n: int, mode: str,
                                  compute_dtype):
    """Rotate-once grid step. The out-channel axis j (innermost,
    sequential) revisits the same row block i with consecutive weight
    tiles; the rotation + per-token quantization run ONLY at j == 0 and
    their dot-operand form is stashed in VMEM scratch (``q_ref``: int8 or
    bf16 fp8-embedding, ``s_ref``: f32 per-row scales). Every j contracts
    the scratch operand against its (n, block_n) weight tile -- so each
    row is transformed exactly once regardless of d. Scratch persists
    across grid steps and j is sequential within each i, so the j == 0
    write is visible to every later j of that row block (and rows blocks
    may still run in parallel across cores: each partition owns its own
    scratch and walks its own j loop in order)."""

    @pl.when(pl.program_id(1) == 0)
    def _rotate():
        q, s = _rotate_quantize_block(x_ref[...], mats_ref, n=n, mode=mode,
                                      compute_dtype=compute_dtype)
        q_ref[...] = _operand_from_q(q, mode)
        s_ref[...] = s

    acc = _operand_dot(q_ref[...], wq_ref[...], mode)
    o_ref[...] = (acc * s_ref[...] * sw_ref[...]).astype(o_ref.dtype)


def _ring_dmas(make_w, make_s, j, nj: int):
    """The two-slot DMA ring protocol shared by the streamed kernels.

    ``make_w(slot, jj)`` / ``make_s(slot, jj)`` build the async-copy
    descriptors for out-channel tile ``jj`` of the weight / scale operand
    into ring slot ``slot`` (each descriptor pairs a VMEM slot with its
    own DMA semaphore, weight and scale copies tracked separately).

    Calling this STARTS the j == 0 warm-up copy into slot 0 (so the
    caller's rotate+quantize below overlaps even the first tile's
    latency) and returns ``finish()``, which the caller invokes right
    before the contraction: it starts the prefetch of tile j+1 into the
    opposite slot (guarded by ``j + 1 < nj``, so no copy is ever in
    flight when the row block's j loop ends -- the slot parity of the
    next (expert, row block) pair resets cleanly to 0), waits on slot
    ``j % 2``'s semaphores, and returns that slot index."""
    slot = jax.lax.rem(j, 2)

    @pl.when(j == 0)
    def _warm_up():
        make_w(0, j).start()
        make_s(0, j).start()

    def finish():
        @pl.when(j + 1 < nj)
        def _prefetch_next():
            make_w(1 - slot, j + 1).start()
            make_s(1 - slot, j + 1).start()

        make_w(slot, j).wait()
        make_s(slot, j).wait()
        return slot

    return finish


def _quant_dot_kernel_streamed(x_ref, mats_ref, wq_hbm, sw_hbm, o_ref,
                               q_ref, s_ref, w_ring, sw_ring, w_sem, s_sem,
                               *, n: int, mode: str, compute_dtype,
                               bn: int, nj: int):
    """Streamed grid step: rotate-once structure + a manual two-slot VMEM
    ring over the weight/scale operands (``wq_hbm``/``sw_hbm`` are
    UNBLOCKED ANY-memory-space refs; the implicit BlockSpec weight
    pipeline is replaced by explicit ``make_async_copy``). Order per
    step j: start the warm-up copy (j == 0 only), rotate+quantize (j == 0
    only -- overlapping the warm-up copy), start the prefetch of tile
    j+1, wait on slot j % 2, contract from that slot. The DMA of tile
    j+1 is therefore in flight DURING the MXU burst of tile j -- the
    overlap rotate-once lost when it made j sequential."""
    j = pl.program_id(1)

    def make_w(slot, jj):
        return pltpu.make_async_copy(
            wq_hbm.at[:, pl.ds(jj * bn, bn)], w_ring.at[slot],
            w_sem.at[slot])

    def make_s(slot, jj):
        return pltpu.make_async_copy(
            sw_hbm.at[:, pl.ds(jj * bn, bn)], sw_ring.at[slot],
            s_sem.at[slot])

    finish = _ring_dmas(make_w, make_s, j, nj)

    @pl.when(j == 0)
    def _rotate():
        q, s = _rotate_quantize_block(x_ref[...], mats_ref, n=n, mode=mode,
                                      compute_dtype=compute_dtype)
        q_ref[...] = _operand_from_q(q, mode)
        s_ref[...] = s

    slot = finish()
    acc = _operand_dot(q_ref[...], w_ring[slot], mode)
    o_ref[...] = (acc * s_ref[...] * sw_ring[slot]).astype(o_ref.dtype)


def _quant_dot_kernel_revisit(x_ref, mats_ref, wq_ref, sw_ref, o_ref, *,
                              n: int, mode: str, compute_dtype):
    """The PR-3 schedule, kept as the A/B baseline: EVERY grid step
    rotates + quantizes its row block before contracting -- d/block_n
    redundant transforms per row. Bitwise identical outputs to the
    rotate-once kernel (same math, different schedule)."""
    q, s = _rotate_quantize_block(x_ref[...], mats_ref, n=n, mode=mode,
                                  compute_dtype=compute_dtype)
    acc = _operand_dot(_operand_from_q(q, mode), wq_ref[...], mode)
    o_ref[...] = (acc * s * sw_ref[...]).astype(o_ref.dtype)


def _abft_check_col(op, cw):
    """The activation-side ABFT checksum of a dot-operand row block:
    ``chk[i] = sum_k op[i, k] * cw[k]`` with ``cw`` the precomputed
    column checksum of the DEQUANTIZED weight (``wquant.weight_checksum``),
    so ``sum_d y[i, d] == s[i] * chk[i]`` exactly in real arithmetic.
    Written as elementwise multiply + reduction -- NOT ``dot_general`` --
    so the rotate-once dot-placement contract (exactly one contraction
    dot per grid step, ``num_passes`` rotation dots in the j == 0 region)
    is untouched by verification. op: (bm, n) scratch operand, cw: (1, n)
    f32 -> (bm, 1) f32."""
    return jnp.sum(op.astype(jnp.float32) * cw, axis=-1, keepdims=True)


def _quant_dot_kernel_rotate_once_abft(x_ref, mats_ref, wq_ref, sw_ref,
                                       cw_ref, o_ref, r_ref, q_ref, s_ref,
                                       chk_ref, acc_ref, *, n: int, mode: str,
                                       compute_dtype):
    """The rotate-once grid step with the ABFT checksum column riding
    INSIDE the same pallas_call (fusion contract intact). j == 0
    additionally stashes the activation checksum ``chk`` (one extra
    n-element reduction per row block) and zeroes the row's output-sum
    accumulator; every j folds the f32 PRE-CAST contribution's row sums
    into the accumulator and rewrites the residual output
    ``r = sum_d y_f32[i, :] - s[i] * chk[i]`` (j is sequential within
    each row block, so the final j's write -- the full-row residual --
    wins). The o_ref math is graph-identical to the unverified kernel:
    ABFT-on outputs are bitwise ABFT-off outputs."""

    @pl.when(pl.program_id(1) == 0)
    def _rotate():
        q, s = _rotate_quantize_block(x_ref[...], mats_ref, n=n, mode=mode,
                                      compute_dtype=compute_dtype)
        op = _operand_from_q(q, mode)
        q_ref[...] = op
        s_ref[...] = s
        chk_ref[...] = _abft_check_col(op, cw_ref[...])
        acc_ref[...] = jnp.zeros_like(acc_ref[...])

    acc = _operand_dot(q_ref[...], wq_ref[...], mode)
    contrib = acc * s_ref[...] * sw_ref[...]
    o_ref[...] = contrib.astype(o_ref.dtype)
    acc_ref[...] += jnp.sum(contrib, axis=-1, keepdims=True)
    r_ref[...] = acc_ref[...] - s_ref[...] * chk_ref[...]


def _quant_dot_kernel_streamed_abft(x_ref, mats_ref, wq_hbm, sw_hbm, cw_ref,
                                    o_ref, r_ref, q_ref, s_ref, chk_ref,
                                    acc_ref, w_ring, sw_ring, w_sem, s_sem,
                                    *, n: int, mode: str, compute_dtype,
                                    bn: int, nj: int):
    """Streamed grid step + ABFT. The column checksum ``cw_ref`` rides as
    a plain VMEM BlockSpec input OUTSIDE the DMA ring on purpose: the
    residual then compares ring-delivered weight tiles against a
    checksum that never travelled through the ring, so a mis-DMA'd or
    clobbered tile (the riskiest failure of this schedule) is exactly
    what trips it."""
    j = pl.program_id(1)

    def make_w(slot, jj):
        return pltpu.make_async_copy(
            wq_hbm.at[:, pl.ds(jj * bn, bn)], w_ring.at[slot],
            w_sem.at[slot])

    def make_s(slot, jj):
        return pltpu.make_async_copy(
            sw_hbm.at[:, pl.ds(jj * bn, bn)], sw_ring.at[slot],
            s_sem.at[slot])

    finish = _ring_dmas(make_w, make_s, j, nj)

    @pl.when(j == 0)
    def _rotate():
        q, s = _rotate_quantize_block(x_ref[...], mats_ref, n=n, mode=mode,
                                      compute_dtype=compute_dtype)
        op = _operand_from_q(q, mode)
        q_ref[...] = op
        s_ref[...] = s
        chk_ref[...] = _abft_check_col(op, cw_ref[...])
        acc_ref[...] = jnp.zeros_like(acc_ref[...])

    slot = finish()
    acc = _operand_dot(q_ref[...], w_ring[slot], mode)
    contrib = acc * s_ref[...] * sw_ring[slot]
    o_ref[...] = contrib.astype(o_ref.dtype)
    acc_ref[...] += jnp.sum(contrib, axis=-1, keepdims=True)
    r_ref[...] = acc_ref[...] - s_ref[...] * chk_ref[...]


def _quant_dot_kernel_revisit_abft(x_ref, mats_ref, wq_ref, sw_ref, cw_ref,
                                   o_ref, r_ref, acc_ref, *, n: int,
                                   mode: str, compute_dtype):
    """Revisit grid step + ABFT: the transform recompute is deterministic
    (same f32-grid values every j), so q/s/chk are simply recomputed per
    step and only the output-sum accumulator needs scratch (zeroed at
    j == 0 -- j is sequential under the 'arbitrary' grid semantics)."""
    j = pl.program_id(1)
    q, s = _rotate_quantize_block(x_ref[...], mats_ref, n=n, mode=mode,
                                  compute_dtype=compute_dtype)
    op = _operand_from_q(q, mode)

    @pl.when(j == 0)
    def _zero():
        acc_ref[...] = jnp.zeros_like(acc_ref[...])

    acc = _operand_dot(op, wq_ref[...], mode)
    contrib = acc * s * sw_ref[...]
    o_ref[...] = contrib.astype(o_ref.dtype)
    acc_ref[...] += jnp.sum(contrib, axis=-1, keepdims=True)
    r_ref[...] = acc_ref[...] - s * _abft_check_col(op, cw_ref[...])


def _stream_interpret_forced() -> bool:
    return os.environ.get(STREAM_INTERPRET_ENV, "").lower() in (
        "1", "true", "force")


def _resolve_schedule(schedule, interpret: bool = False) -> str:
    """Resolve the grid schedule: explicit argument, then the
    ``REPRO_QUANT_DOT_SCHEDULE`` env override, then ``rotate_once`` (the
    default until the bench gate shows the streamed win on hardware).

    ``streamed`` needs a real DMA engine; under ``interpret=True`` (any
    backend without async copies runs the kernels through the XLA
    interpreter) it degrades to ``rotate_once`` -- warned once per
    process, counted in ``TRACE_COUNTS[("quant_dot", "stream_fallback")]``
    on every dispatch -- unless ``REPRO_QUANT_DOT_STREAM_INTERPRET`` is
    set, which runs the real streamed body on the interpreter's
    synchronous DMA simulation (the parity-test / bench hook)."""
    if schedule is None:
        schedule = os.environ.get(SCHEDULE_ENV_VAR) or "rotate_once"
    if schedule not in SCHEDULES:
        raise ValueError(
            f"unknown quant_dot schedule {schedule!r}; expected one of "
            f"{SCHEDULES}")
    if schedule == "streamed" and interpret and not _stream_interpret_forced():
        warn_once(
            ("quant_dot", "stream_fallback"),
            "quant_dot schedule 'streamed' requires a real DMA engine; "
            "interpret mode falls back to 'rotate_once' (same outputs, "
            "no async weight prefetch). Set "
            f"{STREAM_INTERPRET_ENV}=1 to run the streamed kernel on "
            "the interpreter's synchronous DMA simulation. (warned "
            "once per process; TRACE_COUNTS[('quant_dot', "
            "'stream_fallback')] keeps counting)")
        return "rotate_once"
    return schedule


def pallas_quant_dot(x, wq, sw, plan, interpret: bool, schedule=None,
                     block_n=None, check=None):
    """Fused single-kernel rotate+quantize+GEMM over a 2D Pallas grid.

    x: (..., n) with n == plan.p (power of 2); wq: (n, d) storage-dtype
    weight; sw: (1, d) or (d,) f32 per-out-channel scales. Returns
    (..., d) in the plan's io dtype.

    ``schedule`` selects the grid schedule (default ``"rotate_once"``,
    overridable via ``REPRO_QUANT_DOT_SCHEDULE``; ``"streamed"`` under
    interpret mode degrades to ``rotate_once`` -- see
    ``_resolve_schedule``); ``block_n`` pins the out-channel tile
    (benchmark A/Bs hold the revisit count fixed with it). Both are
    static.

    ``check`` (the QTensor's precomputed (1, n) f32 ABFT column checksum,
    ``wquant.weight_checksum``) switches to the checksum-verified kernel
    variant: the SAME single pallas_call additionally emits a per-row f32
    residual ``r[i] = sum_d y_f32[i, :] - s[i] * (q[i, :] . check)`` --
    float-rounding small when healthy, shifted by any silent weight /
    DMA / accumulation corruption -- and the return value becomes
    ``(out, resid)`` with resid shaped (..., 1). Output math is
    graph-identical either way (``out`` is bitwise the check=None
    result); ``verify.residual_ok`` turns resid into a verdict.
    """
    sched = _resolve_schedule(schedule, interpret)
    if check is None:
        return _pallas_quant_dot(x, wq, sw, plan, interpret, sched, block_n)
    return _pallas_quant_dot_abft(x, wq, sw, check, plan, interpret, sched,
                                  block_n)


@functools.partial(jax.jit, static_argnames=("plan", "interpret", "schedule",
                                             "block_n"))
def _pallas_quant_dot(x, wq, sw, plan, interpret: bool, schedule: str,
                      block_n):
    TRACE_COUNTS[("pallas", "quant_dot")] += 1
    n = plan.p
    mode = plan.epilogue.mode
    cd = jnp.dtype(plan.compute_dtype)
    mats = _plan_mats(plan)
    lead = x.shape[:-1]
    x2, m = _rows(x, n)
    d = wq.shape[-1]
    sw2 = sw.reshape(1, d).astype(jnp.float32)
    bm, bn = quant_dot_blocks(n, d, m, x.dtype, cd, mode,
                              block_m=plan.block_m, block_n=block_n,
                              schedule=schedule)
    x2, _ = _pad_rows(x2, bm)
    pad_d = (-d) % bn
    if pad_d:
        wq2 = jnp.pad(wq, ((0, 0), (0, pad_d)))
        sw2 = jnp.pad(sw2, ((0, 0), (0, pad_d)))
    else:
        wq2 = wq
    mp, dp = x2.shape[0], d + pad_d
    common = dict(n=n, mode=mode, compute_dtype=cd)
    # rotate_once/revisit let the BlockSpec pipeline slice the weight;
    # streamed takes the weight movement over (ANY-memory-space refs, the
    # kernel DMAs each tile into its two-slot VMEM ring)
    wq_spec = pl.BlockSpec((n, bn), lambda i, j: (0, j))
    sw_spec = pl.BlockSpec((1, bn), lambda i, j: (0, j))
    if schedule == "rotate_once":
        kernel = functools.partial(_quant_dot_kernel_rotate_once, **common)
        scratch = [pltpu.VMEM((bm, n), _scratch_dtype(mode)),
                   pltpu.VMEM((bm, 1), jnp.float32)]
    elif schedule == "streamed":
        kernel = functools.partial(_quant_dot_kernel_streamed, **common,
                                   bn=bn, nj=dp // bn)
        scratch = [pltpu.VMEM((bm, n), _scratch_dtype(mode)),
                   pltpu.VMEM((bm, 1), jnp.float32),
                   pltpu.VMEM((2, n, bn), wq2.dtype),      # weight ring
                   pltpu.VMEM((2, 1, bn), jnp.float32),    # scale ring
                   pltpu.SemaphoreType.DMA((2,)),          # weight sems
                   pltpu.SemaphoreType.DMA((2,))]          # scale sems
        wq_spec = pl.BlockSpec(memory_space=pltpu.ANY)
        sw_spec = pl.BlockSpec(memory_space=pltpu.ANY)
    else:
        kernel = functools.partial(_quant_dot_kernel_revisit, **common)
        scratch = []
    out = pl.pallas_call(
        kernel,
        grid=(mp // bm, dp // bn),
        in_specs=[
            pl.BlockSpec((bm, n), lambda i, j: (i, 0)),
            pl.BlockSpec((mats.shape[0],) + mats.shape[1:],
                         lambda i, j: (0, 0, 0)),
            wq_spec,
            sw_spec,
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, dp), jnp.dtype(plan.dtype)),
        scratch_shapes=scratch,
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(x2, mats, wq2, sw2)
    return out[:m, :d].reshape(*lead, d)


def _scratch_dtype(mode: str):
    return jnp.int8 if QSPECS[mode][2] else jnp.bfloat16


@functools.partial(jax.jit, static_argnames=("plan", "interpret", "schedule",
                                             "block_n"))
def _pallas_quant_dot_abft(x, wq, sw, cw, plan, interpret: bool,
                           schedule: str, block_n):
    """The checksum-verified twin of :func:`_pallas_quant_dot`: same
    grid, same specs plus the block-constant (1, n) f32 checksum input
    and the (mp, 1) f32 residual output (its (bm, 1) tile at index
    (i, 0) is revisited across the sequential j axis -- the standard
    accumulator-output pattern; the final j's write is the full-row
    residual). Kept a separate traced function so the unverified path's
    jaxpr -- what the lint contracts and bitwise-parity suites pin --
    is untouched by construction."""
    TRACE_COUNTS[("pallas", "quant_dot")] += 1
    TRACE_COUNTS[("abft", "kernel_resid_trace")] += 1
    n = plan.p
    mode = plan.epilogue.mode
    cd = jnp.dtype(plan.compute_dtype)
    mats = _plan_mats(plan)
    lead = x.shape[:-1]
    x2, m = _rows(x, n)
    d = wq.shape[-1]
    sw2 = sw.reshape(1, d).astype(jnp.float32)
    cw2 = cw.reshape(1, n).astype(jnp.float32)
    bm, bn = quant_dot_blocks(n, d, m, x.dtype, cd, mode,
                              block_m=plan.block_m, block_n=block_n,
                              schedule=schedule, abft=True)
    x2, _ = _pad_rows(x2, bm)
    pad_d = (-d) % bn
    if pad_d:
        wq2 = jnp.pad(wq, ((0, 0), (0, pad_d)))
        sw2 = jnp.pad(sw2, ((0, 0), (0, pad_d)))
    else:
        wq2 = wq
    mp, dp = x2.shape[0], d + pad_d
    common = dict(n=n, mode=mode, compute_dtype=cd)
    wq_spec = pl.BlockSpec((n, bn), lambda i, j: (0, j))
    sw_spec = pl.BlockSpec((1, bn), lambda i, j: (0, j))
    # chk + acc f32 columns live across the j loop beside q/s
    verify_scratch = [pltpu.VMEM((bm, n), _scratch_dtype(mode)),
                      pltpu.VMEM((bm, 1), jnp.float32),
                      pltpu.VMEM((bm, 1), jnp.float32),    # chk
                      pltpu.VMEM((bm, 1), jnp.float32)]    # acc
    if schedule == "rotate_once":
        kernel = functools.partial(_quant_dot_kernel_rotate_once_abft,
                                   **common)
        scratch = verify_scratch
    elif schedule == "streamed":
        kernel = functools.partial(_quant_dot_kernel_streamed_abft, **common,
                                   bn=bn, nj=dp // bn)
        scratch = verify_scratch + [
            pltpu.VMEM((2, n, bn), wq2.dtype),      # weight ring
            pltpu.VMEM((2, 1, bn), jnp.float32),    # scale ring
            pltpu.SemaphoreType.DMA((2,)),          # weight sems
            pltpu.SemaphoreType.DMA((2,))]          # scale sems
        wq_spec = pl.BlockSpec(memory_space=pltpu.ANY)
        sw_spec = pl.BlockSpec(memory_space=pltpu.ANY)
    else:
        kernel = functools.partial(_quant_dot_kernel_revisit_abft, **common)
        scratch = [pltpu.VMEM((bm, 1), jnp.float32)]        # acc only
    out, resid = pl.pallas_call(
        kernel,
        grid=(mp // bm, dp // bn),
        in_specs=[
            pl.BlockSpec((bm, n), lambda i, j: (i, 0)),
            pl.BlockSpec((mats.shape[0],) + mats.shape[1:],
                         lambda i, j: (0, 0, 0)),
            wq_spec,
            sw_spec,
            pl.BlockSpec((1, n), lambda i, j: (0, 0)),
        ],
        out_specs=[pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
                   pl.BlockSpec((bm, 1), lambda i, j: (i, 0))],
        out_shape=[jax.ShapeDtypeStruct((mp, dp), jnp.dtype(plan.dtype)),
                   jax.ShapeDtypeStruct((mp, 1), jnp.float32)],
        scratch_shapes=scratch,
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(x2, mats, wq2, sw2, cw2)
    return (out[:m, :d].reshape(*lead, d),
            resid[:m].reshape(*lead, 1))


def _quant_dot_experts_kernel(x_ref, mats_ref, wq_ref, sw_ref, o_ref,
                              q_ref, s_ref, *, n: int, mode: str,
                              compute_dtype):
    """Rotate-once grid step on the 3-D (expert, row blocks, out-channel
    blocks) grid: identical to the dense kernel except every ref carries
    a leading per-expert axis of 1. j (innermost) is sequential, so the
    scratch written at j == 0 serves every weight tile of that
    (expert, row block) pair."""

    @pl.when(pl.program_id(2) == 0)
    def _rotate():
        q, s = _rotate_quantize_block(x_ref[0], mats_ref, n=n, mode=mode,
                                      compute_dtype=compute_dtype)
        q_ref[...] = _operand_from_q(q, mode)
        s_ref[...] = s

    acc = _operand_dot(q_ref[...], wq_ref[0], mode)
    o_ref[0] = (acc * s_ref[...] * sw_ref[0]).astype(o_ref.dtype)


def _quant_dot_experts_kernel_streamed(x_ref, mats_ref, wq_hbm, sw_hbm,
                                       o_ref, q_ref, s_ref, w_ring, sw_ring,
                                       w_sem, s_sem, *, n: int, mode: str,
                                       compute_dtype, bn: int, nj: int):
    """Streamed grid step on the 3-D (expert, row blocks, out-channel
    blocks) grid: the dense streamed kernel with the DMA sources indexed
    by the CURRENT expert (``wq_hbm``/``sw_hbm`` stay whole (E, n, d) /
    (E, 1, d) ANY-memory-space refs; each copy slices expert e's tile
    j). j restarts at every (expert, row block) pair, so the warm-up
    re-primes slot 0 and the ring parity resets -- and the ``j + 1 < nj``
    prefetch guard guarantees no copy is in flight across the pair
    boundary."""
    e, j = pl.program_id(0), pl.program_id(2)

    def make_w(slot, jj):
        return pltpu.make_async_copy(
            wq_hbm.at[e, :, pl.ds(jj * bn, bn)], w_ring.at[slot],
            w_sem.at[slot])

    def make_s(slot, jj):
        return pltpu.make_async_copy(
            sw_hbm.at[e, :, pl.ds(jj * bn, bn)], sw_ring.at[slot],
            s_sem.at[slot])

    finish = _ring_dmas(make_w, make_s, j, nj)

    @pl.when(j == 0)
    def _rotate():
        q, s = _rotate_quantize_block(x_ref[0], mats_ref, n=n, mode=mode,
                                      compute_dtype=compute_dtype)
        q_ref[...] = _operand_from_q(q, mode)
        s_ref[...] = s

    slot = finish()
    acc = _operand_dot(q_ref[...], w_ring[slot], mode)
    o_ref[0] = (acc * s_ref[...] * sw_ring[slot]).astype(o_ref.dtype)


def _quant_dot_experts_kernel_abft(x_ref, mats_ref, wq_ref, sw_ref, cw_ref,
                                   o_ref, r_ref, q_ref, s_ref, chk_ref,
                                   acc_ref, *, n: int, mode: str,
                                   compute_dtype):
    """Rotate-once 3-D expert grid step + ABFT: the dense verified
    kernel with every ref carrying a leading per-expert axis of 1 and
    the checksum tile sliced per CURRENT expert. j restarts per
    (expert, row block), so the j == 0 re-stash also re-zeroes the
    accumulator and re-derives chk against that expert's checksum."""

    @pl.when(pl.program_id(2) == 0)
    def _rotate():
        q, s = _rotate_quantize_block(x_ref[0], mats_ref, n=n, mode=mode,
                                      compute_dtype=compute_dtype)
        op = _operand_from_q(q, mode)
        q_ref[...] = op
        s_ref[...] = s
        chk_ref[...] = _abft_check_col(op, cw_ref[0])
        acc_ref[...] = jnp.zeros_like(acc_ref[...])

    acc = _operand_dot(q_ref[...], wq_ref[0], mode)
    contrib = acc * s_ref[...] * sw_ref[0]
    o_ref[0] = contrib.astype(o_ref.dtype)
    acc_ref[...] += jnp.sum(contrib, axis=-1, keepdims=True)
    r_ref[0] = acc_ref[...] - s_ref[...] * chk_ref[...]


def _quant_dot_experts_kernel_streamed_abft(x_ref, mats_ref, wq_hbm, sw_hbm,
                                            cw_ref, o_ref, r_ref, q_ref,
                                            s_ref, chk_ref, acc_ref, w_ring,
                                            sw_ring, w_sem, s_sem, *, n: int,
                                            mode: str, compute_dtype,
                                            bn: int, nj: int):
    """Streamed 3-D expert grid step + ABFT: DMA ring per (expert, row
    block) exactly as the unverified streamed kernel; the per-expert
    checksum tile arrives through the plain BlockSpec pipeline (outside
    the ring) so ring mis-delivery is detectable."""
    e, j = pl.program_id(0), pl.program_id(2)

    def make_w(slot, jj):
        return pltpu.make_async_copy(
            wq_hbm.at[e, :, pl.ds(jj * bn, bn)], w_ring.at[slot],
            w_sem.at[slot])

    def make_s(slot, jj):
        return pltpu.make_async_copy(
            sw_hbm.at[e, :, pl.ds(jj * bn, bn)], sw_ring.at[slot],
            s_sem.at[slot])

    finish = _ring_dmas(make_w, make_s, j, nj)

    @pl.when(j == 0)
    def _rotate():
        q, s = _rotate_quantize_block(x_ref[0], mats_ref, n=n, mode=mode,
                                      compute_dtype=compute_dtype)
        op = _operand_from_q(q, mode)
        q_ref[...] = op
        s_ref[...] = s
        chk_ref[...] = _abft_check_col(op, cw_ref[0])
        acc_ref[...] = jnp.zeros_like(acc_ref[...])

    slot = finish()
    acc = _operand_dot(q_ref[...], w_ring[slot], mode)
    contrib = acc * s_ref[...] * sw_ring[slot]
    o_ref[0] = contrib.astype(o_ref.dtype)
    acc_ref[...] += jnp.sum(contrib, axis=-1, keepdims=True)
    r_ref[0] = acc_ref[...] - s_ref[...] * chk_ref[...]


def pallas_quant_dot_experts(x, wq, sw, plan, interpret: bool,
                             schedule=None, block_n=None, check=None):
    """Fused rotate+quantize+GEMM for stacked expert weights: ONE kernel
    over a 3-D (expert, row blocks, out-channel blocks) grid with the
    rotate-once schedule per (expert, row block) -- replacing the PR-4
    split into a fused rotate+quantize kernel plus a per-expert XLA
    einsum (which round-tripped (q, scales) through HBM).

    x: (..., E, c, n) dispatched activations; wq: (E, n, d) storage-dtype
    expert weights; sw: (E, 1, d) f32 per-(expert, out-channel) scales.
    Returns (..., E, c, d) in the plan's io dtype.

    ``schedule``/``block_n``/``check`` behave exactly as in
    :func:`pallas_quant_dot` (the streamed DMA ring applies per
    (expert, row block) pair; ``check`` is the stacked (E, 1, n) f32
    per-expert column checksum and makes the return value
    ``(out, resid)`` with resid shaped (..., E, c, 1)).
    """
    sched = _resolve_schedule(schedule, interpret)
    if check is None:
        return _pallas_quant_dot_experts(x, wq, sw, plan, interpret, sched,
                                         block_n)
    return _pallas_quant_dot_experts_abft(x, wq, sw, check, plan, interpret,
                                          sched, block_n)


@functools.partial(jax.jit, static_argnames=("plan", "interpret", "schedule",
                                             "block_n"))
def _pallas_quant_dot_experts(x, wq, sw, plan, interpret: bool,
                              schedule: str, block_n):
    TRACE_COUNTS[("pallas", "quant_dot_experts")] += 1
    n = plan.p
    mode = plan.epilogue.mode
    cd = jnp.dtype(plan.compute_dtype)
    mats = _plan_mats(plan)
    E, _, d = wq.shape
    lead, cap = x.shape[:-3], x.shape[-2]
    # rows of one expert contiguous: (..., E, c, n) -> (E, rows, n)
    x3 = jnp.moveaxis(x.reshape(-1, E, cap, n), 1, 0).reshape(E, -1, n)
    m = x3.shape[1]
    sw3 = sw.reshape(E, 1, d).astype(jnp.float32)
    bm, bn = quant_dot_blocks(n, d, m, x.dtype, cd, mode,
                              block_m=plan.block_m, block_n=block_n,
                              schedule=schedule)
    pad_m, pad_d = (-m) % bm, (-d) % bn
    if pad_m:
        x3 = jnp.pad(x3, ((0, 0), (0, pad_m), (0, 0)))
    wq3 = wq
    if pad_d:
        wq3 = jnp.pad(wq, ((0, 0), (0, 0), (0, pad_d)))
        sw3 = jnp.pad(sw3, ((0, 0), (0, 0), (0, pad_d)))
    mp, dp = m + pad_m, d + pad_d
    scratch = [pltpu.VMEM((bm, n), _scratch_dtype(mode)),
               pltpu.VMEM((bm, 1), jnp.float32)]
    wq_spec = pl.BlockSpec((1, n, bn), lambda e, i, j: (e, 0, j))
    sw_spec = pl.BlockSpec((1, 1, bn), lambda e, i, j: (e, 0, j))
    if schedule == "streamed":
        kernel = functools.partial(_quant_dot_experts_kernel_streamed,
                                   n=n, mode=mode, compute_dtype=cd,
                                   bn=bn, nj=dp // bn)
        scratch += [pltpu.VMEM((2, n, bn), wq3.dtype),     # weight ring
                    pltpu.VMEM((2, 1, bn), jnp.float32),   # scale ring
                    pltpu.SemaphoreType.DMA((2,)),         # weight sems
                    pltpu.SemaphoreType.DMA((2,))]         # scale sems
        wq_spec = pl.BlockSpec(memory_space=pltpu.ANY)
        sw_spec = pl.BlockSpec(memory_space=pltpu.ANY)
    else:
        # revisit never grew a 3-D body (the A/B baseline is 2-D only):
        # anything else runs the rotate-once step
        kernel = functools.partial(_quant_dot_experts_kernel, n=n,
                                   mode=mode, compute_dtype=cd)
    out = pl.pallas_call(
        kernel,
        grid=(E, mp // bm, dp // bn),
        in_specs=[
            pl.BlockSpec((1, bm, n), lambda e, i, j: (e, i, 0)),
            pl.BlockSpec((mats.shape[0],) + mats.shape[1:],
                         lambda e, i, j: (0, 0, 0)),
            wq_spec,
            sw_spec,
        ],
        out_specs=pl.BlockSpec((1, bm, bn), lambda e, i, j: (e, i, j)),
        out_shape=jax.ShapeDtypeStruct((E, mp, dp), jnp.dtype(plan.dtype)),
        scratch_shapes=scratch,
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(x3, mats, wq3, sw3)
    out = jnp.moveaxis(out[:, :m, :d].reshape(E, -1, cap, d), 0, 1)
    return out.reshape(*lead, E, cap, d)


@functools.partial(jax.jit, static_argnames=("plan", "interpret", "schedule",
                                             "block_n"))
def _pallas_quant_dot_experts_abft(x, wq, sw, cw, plan, interpret: bool,
                                   schedule: str, block_n):
    """The checksum-verified twin of :func:`_pallas_quant_dot_experts`
    (see ``_pallas_quant_dot_abft`` for why it is a separate traced
    function): per-expert (1, 1, n) checksum tiles, (E, mp, 1) residual
    output revisited across the sequential j axis."""
    TRACE_COUNTS[("pallas", "quant_dot_experts")] += 1
    TRACE_COUNTS[("abft", "kernel_resid_trace")] += 1
    n = plan.p
    mode = plan.epilogue.mode
    cd = jnp.dtype(plan.compute_dtype)
    mats = _plan_mats(plan)
    E, _, d = wq.shape
    lead, cap = x.shape[:-3], x.shape[-2]
    x3 = jnp.moveaxis(x.reshape(-1, E, cap, n), 1, 0).reshape(E, -1, n)
    m = x3.shape[1]
    sw3 = sw.reshape(E, 1, d).astype(jnp.float32)
    cw3 = cw.reshape(E, 1, n).astype(jnp.float32)
    bm, bn = quant_dot_blocks(n, d, m, x.dtype, cd, mode,
                              block_m=plan.block_m, block_n=block_n,
                              schedule=schedule, abft=True)
    pad_m, pad_d = (-m) % bm, (-d) % bn
    if pad_m:
        x3 = jnp.pad(x3, ((0, 0), (0, pad_m), (0, 0)))
    wq3 = wq
    if pad_d:
        wq3 = jnp.pad(wq, ((0, 0), (0, 0), (0, pad_d)))
        sw3 = jnp.pad(sw3, ((0, 0), (0, 0), (0, pad_d)))
    mp, dp = m + pad_m, d + pad_d
    scratch = [pltpu.VMEM((bm, n), _scratch_dtype(mode)),
               pltpu.VMEM((bm, 1), jnp.float32),
               pltpu.VMEM((bm, 1), jnp.float32),     # chk
               pltpu.VMEM((bm, 1), jnp.float32)]     # acc
    wq_spec = pl.BlockSpec((1, n, bn), lambda e, i, j: (e, 0, j))
    sw_spec = pl.BlockSpec((1, 1, bn), lambda e, i, j: (e, 0, j))
    if schedule == "streamed":
        kernel = functools.partial(_quant_dot_experts_kernel_streamed_abft,
                                   n=n, mode=mode, compute_dtype=cd,
                                   bn=bn, nj=dp // bn)
        scratch += [pltpu.VMEM((2, n, bn), wq3.dtype),     # weight ring
                    pltpu.VMEM((2, 1, bn), jnp.float32),   # scale ring
                    pltpu.SemaphoreType.DMA((2,)),         # weight sems
                    pltpu.SemaphoreType.DMA((2,))]         # scale sems
        wq_spec = pl.BlockSpec(memory_space=pltpu.ANY)
        sw_spec = pl.BlockSpec(memory_space=pltpu.ANY)
    else:
        kernel = functools.partial(_quant_dot_experts_kernel_abft, n=n,
                                   mode=mode, compute_dtype=cd)
    out, resid = pl.pallas_call(
        kernel,
        grid=(E, mp // bm, dp // bn),
        in_specs=[
            pl.BlockSpec((1, bm, n), lambda e, i, j: (e, i, 0)),
            pl.BlockSpec((mats.shape[0],) + mats.shape[1:],
                         lambda e, i, j: (0, 0, 0)),
            wq_spec,
            sw_spec,
            pl.BlockSpec((1, 1, n), lambda e, i, j: (e, 0, 0)),
        ],
        out_specs=[pl.BlockSpec((1, bm, bn), lambda e, i, j: (e, i, j)),
                   pl.BlockSpec((1, bm, 1), lambda e, i, j: (e, i, 0))],
        out_shape=[jax.ShapeDtypeStruct((E, mp, dp), jnp.dtype(plan.dtype)),
                   jax.ShapeDtypeStruct((E, mp, 1), jnp.float32)],
        scratch_shapes=scratch,
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(x3, mats, wq3, sw3, cw3)
    out = jnp.moveaxis(out[:, :m, :d].reshape(E, -1, cap, d), 0, 1)
    r = jnp.moveaxis(resid[:, :m].reshape(E, -1, cap, 1), 0, 1)
    return out.reshape(*lead, E, cap, d), r.reshape(*lead, E, cap, 1)


@functools.partial(jax.jit, static_argnames=("plan", "interpret"))
def xla_quant_dot_resid(x, wq, sw, cw, plan, interpret: bool):
    """The unfused ABFT residual oracle for dispatches that do not run
    the fused kernel (xla backend, above-cap sizes): re-derive the
    rotated/quantized activation with the SAME transform+quantize ops as
    :func:`xla_quant_dot`, recompute the weight's column checksum from
    the LIVE weight with the exact ``wquant.weight_checksum`` op order,
    and contract the activation against the checksum DIFFERENCE:

        resid = s * (q . (recomputed_cw - stored_cw))

    Healthy weights make the difference bitwise zero (same arrays, same
    reduction), so the residual is exactly 0.0 per row; any mutation of
    ``wq``/``sw`` since quantize time shows up as the corruption
    magnitude times the activation row. Costs one extra transform of x
    -- the documented price of verifying the path that cannot carry the
    in-kernel checksum column. Returns (..., 1) f32."""
    from repro.core.api import _dispatch_transform, _strip

    TRACE_COUNTS[("abft", "xla_resid_trace")] += 1
    n, d = wq.shape
    # Same transform dispatch as the unfused oracle (grouped plans block
    # the rotation over p-wide groups; a flat reshape would be wrong).
    y = _dispatch_transform(x, _strip(plan), interpret)
    epi = plan.epilogue
    q, s = _quantize_rows(y.astype(jnp.float32), epi.mode,
                          axis=-1 if epi.per_token else None)
    sw2 = sw.reshape(1, d).astype(jnp.float32)
    cwt = (wq.astype(jnp.float32) * sw2).sum(axis=-1)
    dvec = cwt - cw.reshape(n)
    resid = jnp.einsum("...k,k->...", q.astype(jnp.float32), dvec)[..., None]
    return jnp.asarray(s, jnp.float32) * resid


@functools.partial(jax.jit, static_argnames=("plan", "interpret"))
def xla_quant_dot(x, wq, sw, plan, interpret: bool):
    """Unfused oracle semantics on the factored XLA path: rotate, quantize
    per token, then the SAME ``epilogue_dot`` contraction (int8/int32 or
    fp8-in-bf16/f32). Shards trivially under pjit -- the fallback for
    sizes above the kernel cap and the ground truth the fused kernel is
    tested against."""
    TRACE_COUNTS[("xla", "quant_dot")] += 1
    y = _xla_transform(x, plan)
    q, s = _quantize_rows(y.astype(jnp.float32), mode=plan.epilogue.mode)
    return epilogue_dot(q, s, wq, sw.reshape(1, wq.shape[-1]),
                        plan.epilogue.mode, jnp.dtype(plan.dtype))
