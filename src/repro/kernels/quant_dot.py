"""Fused rotate -> quantize -> GEMM consumer kernel (the quantized hot
path, end to end in low precision).

The paper's kernel makes the online rotation cheap; its *consumer* is a
quantized matmul (QuaRot down-proj, FP8 attention). PR 1 fused the
rotation with the quantize epilogue so the quantized tensor is the only
HBM output -- but the consumer GEMM still read it back from HBM and the
models fake-quantized both operands in f32. This kernel closes the loop:
one grid step rotates a (block_m, n) row block in the plan's compute
dtype (bf16/fp16 multiplies, f32 MXU accumulation -- the
Markidis / Ootomo recipe), quantizes it per token, and immediately
contracts it against an offline-quantized weight tile:

  * int8 operands with int32 MXU accumulation (``preferred_element_type``)
  * fp8 operands multiplied exactly in bf16 (both fp8 grids embed exactly:
    <= 5 mantissa bits and products of two fp8 values fit bf16's 8) with
    f32 accumulation

applying ``scale_x * scale_w`` in the epilogue. The rotated/quantized
activations never round-trip through HBM.

Grid: 2D over (row blocks, out-channel blocks). The rotation+quantize of
a row block is recomputed per out-channel block -- compute the transform
trades for HBM traffic exactly as the paper's roofline argues (the
transform is ~k*128 flops/element vs. an n-element tile re-read).

``epilogue_dot`` is the single source of truth for the quantized-GEMM
math; the unfused fallback (grouped transforms, per-tensor scales,
``xla_quant_dot`` -- the pjit-shardable path and the test oracle) shares
it so fused and unfused paths agree bit-for-bit in the contraction.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.hadamard import _apply_passes
from repro.kernels.registry import (
    QSPECS,
    TRACE_COUNTS,
    _VMEM_BUDGET_BYTES,
    _pad_rows,
    _plan_mats,
    _quantize_rows,
    _rows,
    _xla_transform,
)

__all__ = ["pallas_quant_dot", "xla_quant_dot", "epilogue_dot",
           "quant_dot_blocks"]

_CONTRACT = (((1,), (0,)), ((), ()))  # plain (m, k) @ (k, n)

# Largest contraction dim whose worst-case int8 x int8 row sum stays in
# int32: 127 * 127 * 2^17 ~= 2.11e9 < 2^31 - 1 (2^18 would wrap). Only
# the above-cap XLA fallback can exceed this -- the kernel caps at 2^15.
_INT32_SAFE_K = 1 << 17

# fp8 operand bytes/element inside the kernel: the 1-byte storage grid
# plus the exact bf16 embedding the dot runs in.
_FP8_OPERAND_BYTES = 3


def _low_precision_dot(q, wq, mode):
    """The quantized contraction on the mode's native arithmetic: int8
    operands accumulate exactly in int32; fp8 operands are embedded in
    bf16 (exact) and accumulate f32. ``q`` comes from ``_quantize_rows``
    pre-cast (f32 values on the grid). Returns f32."""
    is_int = QSPECS[mode][2]
    if is_int and q.shape[-1] <= _INT32_SAFE_K:
        acc = jax.lax.dot_general(
            q.astype(jnp.int8), wq.astype(jnp.int8), _CONTRACT,
            preferred_element_type=jnp.int32)
        return acc.astype(jnp.float32)
    if is_int:
        # contraction too long for exact int32: f32 accumulation of the
        # exact grid products (values <= 127 are f32-exact)
        return jax.lax.dot_general(
            q, wq.astype(jnp.float32), _CONTRACT,
            preferred_element_type=jnp.float32)
    qdt = QSPECS[mode][1]
    a = q.astype(qdt).astype(jnp.bfloat16)
    b = wq.astype(jnp.bfloat16)
    return jax.lax.dot_general(a, b, _CONTRACT,
                               preferred_element_type=jnp.float32)


def epilogue_dot(q, s, wq, sw, mode: str, out_dtype) -> jnp.ndarray:
    """``(q * s) @ (wq * sw)`` with the scales factored OUT of the matmul:
    ``(q @ wq) * s * sw`` -- exact because s is per row of q and sw per
    column of wq. q: (..., n) grid values, s broadcastable per-token (or
    per-tensor) scales, wq: (n, d) storage-dtype weight, sw: (1, d)."""
    lead = q.shape[:-1]
    n, d = q.shape[-1], wq.shape[-1]
    acc = _low_precision_dot(q.reshape(-1, n), wq, mode).reshape(*lead, d)
    return (acc * s * sw.reshape((1,) * len(lead) + (d,))).astype(out_dtype)


def quant_dot_blocks(n: int, d: int, m: int, dtype, compute_dtype,
                     mode: str):
    """(block_m, block_n) for the fused kernel, charging every VMEM
    resident: input tile + compute-dtype copy + quantized operand copy per
    row, the (n, block_n) weight tile, the (block_m, block_n) output tile,
    and the per-out-channel scales."""
    in_b = jnp.dtype(dtype).itemsize
    cb = jnp.dtype(compute_dtype).itemsize
    is_int = QSPECS[mode][2]
    # quantized-operand bytes/element: the 1-byte storage grid, plus the
    # exact bf16 embedding both fp8 operands run the dot in
    qb = 1 if is_int else _FP8_OPERAND_BYTES
    wb = 1 if is_int else _FP8_OPERAND_BYTES
    bn = min(512, -(-d // 128) * 128)
    # keep the weight tile at most half the budget (it is revisited per
    # row block, so oversizing it starves block_m); step in 128-lane
    # multiples so the BlockSpec last dim stays MXU-tiled
    while n * bn * wb > _VMEM_BUDGET_BYTES // 2 and bn > 128:
        bn -= 128
    per_row = n * (in_b + cb + qb) + bn * in_b + 4
    bm = max(8, (_VMEM_BUDGET_BYTES - n * bn * wb) // per_row)
    bm = min(bm, 256, m)
    sub = 16 if in_b == 2 else 8
    return max(sub, (bm // sub) * sub), bn


def _quant_dot_kernel(x_ref, mats_ref, wq_ref, sw_ref, o_ref, *, n: int,
                      mode: str, compute_dtype):
    """One grid step: rotate a (block_m, n) row block in the compute
    dtype, per-token quantize, contract against the (n, block_n) weight
    tile, scale, write back -- the (block_m, block_n) output tile is the
    only HBM write."""
    x = x_ref[...].astype(compute_dtype)
    bm = x.shape[0]
    mats = [mats_ref[p] for p in range(mats_ref.shape[0])]
    y = _apply_passes(x.reshape(bm, n), n, mats)
    q, s = _quantize_rows(y.astype(jnp.float32), mode)
    acc = _low_precision_dot(q, wq_ref[...], mode)
    o_ref[...] = (acc * s * sw_ref[...]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("plan", "interpret"))
def pallas_quant_dot(x, wq, sw, plan, interpret: bool):
    """Fused single-kernel rotate+quantize+GEMM over a 2D Pallas grid.

    x: (..., n) with n == plan.p (power of 2); wq: (n, d) storage-dtype
    weight; sw: (1, d) or (d,) f32 per-out-channel scales. Returns
    (..., d) in the plan's io dtype.
    """
    TRACE_COUNTS[("pallas", "quant_dot")] += 1
    n = plan.p
    mode = plan.epilogue.mode
    mats = _plan_mats(plan)
    lead = x.shape[:-1]
    x2, m = _rows(x, n)
    d = wq.shape[-1]
    sw2 = sw.reshape(1, d).astype(jnp.float32)
    bm, bn = quant_dot_blocks(
        n, d, m, x.dtype, jnp.dtype(plan.compute_dtype), mode)
    if plan.block_m:
        bm = plan.block_m
    x2, _ = _pad_rows(x2, bm)
    pad_d = (-d) % bn
    if pad_d:
        wq2 = jnp.pad(wq, ((0, 0), (0, pad_d)))
        sw2 = jnp.pad(sw2, ((0, 0), (0, pad_d)))
    else:
        wq2 = wq
    mp, dp = x2.shape[0], d + pad_d
    kernel = functools.partial(
        _quant_dot_kernel, n=n, mode=mode,
        compute_dtype=jnp.dtype(plan.compute_dtype))
    out = pl.pallas_call(
        kernel,
        grid=(mp // bm, dp // bn),
        in_specs=[
            pl.BlockSpec((bm, n), lambda i, j: (i, 0)),
            pl.BlockSpec((mats.shape[0],) + mats.shape[1:],
                         lambda i, j: (0, 0, 0)),
            pl.BlockSpec((n, bn), lambda i, j: (0, j)),
            pl.BlockSpec((1, bn), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, dp), jnp.dtype(plan.dtype)),
        interpret=interpret,
    )(x2, mats, wq2, sw2)
    return out[:m, :d].reshape(*lead, d)


@functools.partial(jax.jit, static_argnames=("plan", "interpret"))
def xla_quant_dot(x, wq, sw, plan, interpret: bool):
    """Unfused oracle semantics on the factored XLA path: rotate, quantize
    per token, then the SAME ``epilogue_dot`` contraction (int8/int32 or
    fp8-in-bf16/f32). Shards trivially under pjit -- the fallback for
    sizes above the kernel cap and the ground truth the fused kernel is
    tested against."""
    TRACE_COUNTS[("xla", "quant_dot")] += 1
    y = _xla_transform(x, plan)
    q, s = _quantize_rows(y.astype(jnp.float32), mode=plan.epilogue.mode)
    return epilogue_dot(q, s, wq, sw.reshape(1, wq.shape[-1]),
                        plan.epilogue.mode, jnp.dtype(plan.dtype))
