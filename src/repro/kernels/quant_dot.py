"""Fused rotate -> quantize -> GEMM consumer kernel (the quantized hot
path, end to end in low precision) with a ROTATE-ONCE grid schedule.

The paper's kernel makes the online rotation cheap; its *consumer* is a
quantized matmul (QuaRot down-proj, FP8 attention). PR 1 fused the
rotation with the quantize epilogue so the quantized tensor is the only
HBM output -- but the consumer GEMM still read it back from HBM and the
models fake-quantized both operands in f32. PR 3 closed that loop with a
2D (row blocks x out-channel blocks) grid whose every step rotated the
row block, quantized it, and contracted it against one weight tile:

  * int8 operands with int32 MXU accumulation (``preferred_element_type``)
  * fp8 operands multiplied exactly in bf16 (both fp8 grids embed exactly:
    <= 5 mantissa bits and products of two fp8 values fit bf16's 8) with
    f32 accumulation

applying ``scale_x * scale_w`` in the epilogue. The rotated/quantized
activations never round-trip through HBM.

PR 3's schedule, however, recomputed the rotate+quantize of each
(block_m, n) row block for EVERY out-channel tile j -- multiplying the
transform work by d/block_n (~8x at n=4096, d=4*4096) when the paper's
roofline argues the transform should cost ~k*128 flops/element ONCE per
row. The default schedule here is **rotate-once**:

  * the out-channel axis j is the INNERMOST grid axis and is declared
    sequential (``dimension_semantics=("parallel", "arbitrary")``): for a
    fixed row block i, the kernel visits j = 0, 1, ..., d/bn - 1 in order;
  * at j == 0 the row block is rotated in the plan's compute dtype
    (bf16/fp16 multiplies, f32 MXU accumulation -- the Markidis / Ootomo
    recipe), per-token quantized, and the DOT-OPERAND form of (q, s) is
    stashed in VMEM ``scratch_shapes`` (int8 for the int path, the exact
    bf16 embedding for fp8 -- so the scratch is also the cheapest legal
    operand representation);
  * every j (including 0) contracts the scratch operand against its
    (n, block_n) weight tile. The scratch outlives the j loop of its row
    block by construction (scratch persists across grid steps; j is
    sequential within each i), so each row is transformed exactly once
    regardless of d.

The PR-3 ``revisit`` schedule is kept selectable (``schedule="revisit"``
or ``REPRO_QUANT_DOT_SCHEDULE=revisit``) as the A/B baseline for the
transform-amortization benchmark; both schedules are bitwise identical
for int8 (the rotation/quantize/contraction math is unchanged -- only
*when* the transform runs differs).

``pallas_quant_dot_experts`` extends the same schedule to the stacked
MoE expert weights on a 3-D (expert, row blocks, out-channel blocks)
grid, so the expert consumer stops splitting into a rotate+quantize
kernel plus a per-expert XLA einsum.

``epilogue_dot`` is the single source of truth for the quantized-GEMM
math; the unfused fallback (grouped transforms, per-tensor scales,
``xla_quant_dot`` -- the pjit-shardable path and the test oracle) shares
it so fused and unfused paths agree bit-for-bit in the contraction.
"""
from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.hadamard import _apply_passes
from repro.kernels.registry import (
    QSPECS,
    TRACE_COUNTS,
    _VMEM_BUDGET_BYTES,
    _pad_rows,
    _plan_mats,
    _quantize_rows,
    _rows,
    _xla_transform,
)

__all__ = ["pallas_quant_dot", "pallas_quant_dot_experts", "xla_quant_dot",
           "epilogue_dot", "quant_dot_blocks", "SCHEDULE_ENV_VAR",
           "SCHEDULES"]

_CONTRACT = (((1,), (0,)), ((), ()))  # plain (m, k) @ (k, n)

# Largest contraction dim whose worst-case int8 x int8 row sum stays in
# int32: 127 * 127 * 2^17 ~= 2.11e9 < 2^31 - 1 (2^18 would wrap). Only
# the above-cap XLA fallback can exceed this -- the kernel caps at 2^15.
_INT32_SAFE_K = 1 << 17

# fp8 operand bytes/element inside the kernel: the 1-byte storage grid
# plus the exact bf16 embedding the dot runs in.
_FP8_OPERAND_BYTES = 3

SCHEDULE_ENV_VAR = "REPRO_QUANT_DOT_SCHEDULE"
SCHEDULES = ("rotate_once", "revisit")


def _operand_from_q(q, mode):
    """Cast ``_quantize_rows`` output to the grid the contraction runs on:
    int8 for the int path (int32 MXU accumulation), the exact bf16
    embedding of the fp8 grid otherwise. This is the representation the
    rotate-once schedule stashes in VMEM scratch -- 1 (int8) or 2 (bf16)
    bytes/element, and directly consumable by every subsequent weight
    tile."""
    if QSPECS[mode][2]:
        return q.astype(jnp.int8)
    return q.astype(QSPECS[mode][1]).astype(jnp.bfloat16)


def _operand_dot(a, wq, mode):
    """Contract a dot-operand activation block (``_operand_from_q`` form)
    against the storage-dtype weight tile. Returns f32."""
    if QSPECS[mode][2]:
        acc = jax.lax.dot_general(a, wq.astype(jnp.int8), _CONTRACT,
                                  preferred_element_type=jnp.int32)
        return acc.astype(jnp.float32)
    return jax.lax.dot_general(a, wq.astype(jnp.bfloat16), _CONTRACT,
                               preferred_element_type=jnp.float32)


def _low_precision_dot(q, wq, mode):
    """The quantized contraction on the mode's native arithmetic: int8
    operands accumulate exactly in int32; fp8 operands are embedded in
    bf16 (exact) and accumulate f32. ``q`` comes from ``_quantize_rows``
    pre-cast (f32 values on the grid). Returns f32."""
    is_int = QSPECS[mode][2]
    if is_int and q.shape[-1] > _INT32_SAFE_K:
        # contraction too long for exact int32: f32 accumulation of the
        # exact grid products (values <= 127 are f32-exact)
        return jax.lax.dot_general(
            q, wq.astype(jnp.float32), _CONTRACT,
            preferred_element_type=jnp.float32)
    return _operand_dot(_operand_from_q(q, mode), wq, mode)


def epilogue_dot(q, s, wq, sw, mode: str, out_dtype) -> jnp.ndarray:
    """``(q * s) @ (wq * sw)`` with the scales factored OUT of the matmul:
    ``(q @ wq) * s * sw`` -- exact because s is per row of q and sw per
    column of wq. q: (..., n) grid values, s broadcastable per-token (or
    per-tensor) scales, wq: (n, d) storage-dtype weight, sw: (1, d)."""
    lead = q.shape[:-1]
    n, d = q.shape[-1], wq.shape[-1]
    acc = _low_precision_dot(q.reshape(-1, n), wq, mode).reshape(*lead, d)
    return (acc * s * sw.reshape((1,) * len(lead) + (d,))).astype(out_dtype)


def _operand_bytes(mode: str) -> int:
    """Bytes/element of the scratch-resident dot operand (int8 grid or
    bf16 fp8-embedding)."""
    return 1 if QSPECS[mode][2] else 2


def quant_dot_blocks(n: int, d: int, m: int, dtype, compute_dtype,
                     mode: str, block_m=None, block_n=None):
    """(block_m, block_n) for the fused kernel, charging every VMEM
    resident of the rotate-once schedule: the input tile + compute-dtype
    working copy per row, the SCRATCH dot-operand tile (int8 / bf16) + the
    per-row f32 scale that live across the j loop, the (n, block_n)
    weight tile, the (block_m, block_n) output tile, and the
    per-out-channel scales.

    A user-pinned ``block_m`` (``plan.block_m``) is honored BEFORE any
    sizing decision, so the weight-tile / ``block_n`` tradeoff is
    computed against the row count that will actually run -- not against
    a heuristic ``bm`` that the pin then overrides. ``block_n`` pins the
    out-channel tile the same way (benchmarks use it to hold the revisit
    count fixed across schedules).

    Because the rotate-once schedule makes weight-tile revisits free of
    transform recompute, ``block_n`` is allowed up to 1024 (PR 3 capped
    it at 512 to keep the per-revisit transform bill bounded)."""
    in_b = jnp.dtype(dtype).itemsize
    cb = jnp.dtype(compute_dtype).itemsize
    is_int = QSPECS[mode][2]
    qb = _operand_bytes(mode)       # scratch operand bytes/element
    wb = 1 if is_int else _FP8_OPERAND_BYTES
    # per-row residents independent of bn: input tile + compute copy +
    # scratch operand + f32 scratch scale
    row_fixed = n * (in_b + cb + qb) + 4
    # bn always steps in 128-lane multiples so the BlockSpec last dim
    # stays MXU-tiled
    bn = min(1024, -(-d // 128) * 128) if block_n is None else block_n
    if block_m is not None:
        if block_n is None:
            # pinned rows: the weight/output/sw tiles get everything the
            # rows leave
            avail = _VMEM_BUDGET_BYTES - block_m * row_fixed
            while bn > 128 and bn * (n * wb + block_m * in_b + 4) > avail:
                bn -= 128
        return block_m, bn
    if block_n is None:
        # joint sizing: cap the weight tile at half the budget (oversizing
        # it starves block_m), then size the rows from the remainder
        while n * bn * wb > _VMEM_BUDGET_BYTES // 2 and bn > 128:
            bn -= 128
    per_row = row_fixed + bn * in_b
    bm = max(8, (_VMEM_BUDGET_BYTES - n * bn * wb) // per_row)
    bm = min(bm, 256, m)
    sub = 16 if in_b == 2 else 8
    return max(sub, (bm // sub) * sub), bn


def _rotate_quantize_block(x, mats_ref, *, n: int, mode: str,
                           compute_dtype):
    """The shared transform+quantize stage: rotate a (block_m, n) row
    block in the compute dtype (f32 MXU accumulation) and per-token
    quantize. Returns ``(q, s)`` with q in ``_quantize_rows``'s pre-cast
    f32-grid form."""
    x = x.astype(compute_dtype)
    bm = x.shape[0]
    mats = [mats_ref[p] for p in range(mats_ref.shape[0])]
    y = _apply_passes(x.reshape(bm, n), n, mats)
    return _quantize_rows(y.astype(jnp.float32), mode)


def _quant_dot_kernel_rotate_once(x_ref, mats_ref, wq_ref, sw_ref, o_ref,
                                  q_ref, s_ref, *, n: int, mode: str,
                                  compute_dtype):
    """Rotate-once grid step. The out-channel axis j (innermost,
    sequential) revisits the same row block i with consecutive weight
    tiles; the rotation + per-token quantization run ONLY at j == 0 and
    their dot-operand form is stashed in VMEM scratch (``q_ref``: int8 or
    bf16 fp8-embedding, ``s_ref``: f32 per-row scales). Every j contracts
    the scratch operand against its (n, block_n) weight tile -- so each
    row is transformed exactly once regardless of d. Scratch persists
    across grid steps and j is sequential within each i, so the j == 0
    write is visible to every later j of that row block (and rows blocks
    may still run in parallel across cores: each partition owns its own
    scratch and walks its own j loop in order)."""

    @pl.when(pl.program_id(1) == 0)
    def _rotate():
        q, s = _rotate_quantize_block(x_ref[...], mats_ref, n=n, mode=mode,
                                      compute_dtype=compute_dtype)
        q_ref[...] = _operand_from_q(q, mode)
        s_ref[...] = s

    acc = _operand_dot(q_ref[...], wq_ref[...], mode)
    o_ref[...] = (acc * s_ref[...] * sw_ref[...]).astype(o_ref.dtype)


def _quant_dot_kernel_revisit(x_ref, mats_ref, wq_ref, sw_ref, o_ref, *,
                              n: int, mode: str, compute_dtype):
    """The PR-3 schedule, kept as the A/B baseline: EVERY grid step
    rotates + quantizes its row block before contracting -- d/block_n
    redundant transforms per row. Bitwise identical outputs to the
    rotate-once kernel (same math, different schedule)."""
    q, s = _rotate_quantize_block(x_ref[...], mats_ref, n=n, mode=mode,
                                  compute_dtype=compute_dtype)
    acc = _operand_dot(_operand_from_q(q, mode), wq_ref[...], mode)
    o_ref[...] = (acc * s * sw_ref[...]).astype(o_ref.dtype)


def _resolve_schedule(schedule) -> str:
    if schedule is None:
        schedule = os.environ.get(SCHEDULE_ENV_VAR) or "rotate_once"
    if schedule not in SCHEDULES:
        raise ValueError(
            f"unknown quant_dot schedule {schedule!r}; expected one of "
            f"{SCHEDULES}")
    return schedule


def pallas_quant_dot(x, wq, sw, plan, interpret: bool, schedule=None,
                     block_n=None):
    """Fused single-kernel rotate+quantize+GEMM over a 2D Pallas grid.

    x: (..., n) with n == plan.p (power of 2); wq: (n, d) storage-dtype
    weight; sw: (1, d) or (d,) f32 per-out-channel scales. Returns
    (..., d) in the plan's io dtype.

    ``schedule`` selects the grid schedule (default ``"rotate_once"``,
    overridable via ``REPRO_QUANT_DOT_SCHEDULE``); ``block_n`` pins the
    out-channel tile (benchmark A/Bs hold the revisit count fixed with
    it). Both are static.
    """
    return _pallas_quant_dot(x, wq, sw, plan, interpret,
                             _resolve_schedule(schedule), block_n)


@functools.partial(jax.jit, static_argnames=("plan", "interpret", "schedule",
                                             "block_n"))
def _pallas_quant_dot(x, wq, sw, plan, interpret: bool, schedule: str,
                      block_n):
    TRACE_COUNTS[("pallas", "quant_dot")] += 1
    n = plan.p
    mode = plan.epilogue.mode
    cd = jnp.dtype(plan.compute_dtype)
    mats = _plan_mats(plan)
    lead = x.shape[:-1]
    x2, m = _rows(x, n)
    d = wq.shape[-1]
    sw2 = sw.reshape(1, d).astype(jnp.float32)
    bm, bn = quant_dot_blocks(n, d, m, x.dtype, cd, mode,
                              block_m=plan.block_m, block_n=block_n)
    x2, _ = _pad_rows(x2, bm)
    pad_d = (-d) % bn
    if pad_d:
        wq2 = jnp.pad(wq, ((0, 0), (0, pad_d)))
        sw2 = jnp.pad(sw2, ((0, 0), (0, pad_d)))
    else:
        wq2 = wq
    mp, dp = x2.shape[0], d + pad_d
    common = dict(n=n, mode=mode, compute_dtype=cd)
    if schedule == "rotate_once":
        kernel = functools.partial(_quant_dot_kernel_rotate_once, **common)
        scratch = [pltpu.VMEM((bm, n), _scratch_dtype(mode)),
                   pltpu.VMEM((bm, 1), jnp.float32)]
    else:
        kernel = functools.partial(_quant_dot_kernel_revisit, **common)
        scratch = []
    out = pl.pallas_call(
        kernel,
        grid=(mp // bm, dp // bn),
        in_specs=[
            pl.BlockSpec((bm, n), lambda i, j: (i, 0)),
            pl.BlockSpec((mats.shape[0],) + mats.shape[1:],
                         lambda i, j: (0, 0, 0)),
            pl.BlockSpec((n, bn), lambda i, j: (0, j)),
            pl.BlockSpec((1, bn), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, dp), jnp.dtype(plan.dtype)),
        scratch_shapes=scratch,
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(x2, mats, wq2, sw2)
    return out[:m, :d].reshape(*lead, d)


def _scratch_dtype(mode: str):
    return jnp.int8 if QSPECS[mode][2] else jnp.bfloat16


def _quant_dot_experts_kernel(x_ref, mats_ref, wq_ref, sw_ref, o_ref,
                              q_ref, s_ref, *, n: int, mode: str,
                              compute_dtype):
    """Rotate-once grid step on the 3-D (expert, row blocks, out-channel
    blocks) grid: identical to the dense kernel except every ref carries
    a leading per-expert axis of 1. j (innermost) is sequential, so the
    scratch written at j == 0 serves every weight tile of that
    (expert, row block) pair."""

    @pl.when(pl.program_id(2) == 0)
    def _rotate():
        q, s = _rotate_quantize_block(x_ref[0], mats_ref, n=n, mode=mode,
                                      compute_dtype=compute_dtype)
        q_ref[...] = _operand_from_q(q, mode)
        s_ref[...] = s

    acc = _operand_dot(q_ref[...], wq_ref[0], mode)
    o_ref[0] = (acc * s_ref[...] * sw_ref[0]).astype(o_ref.dtype)


def pallas_quant_dot_experts(x, wq, sw, plan, interpret: bool):
    """Fused rotate+quantize+GEMM for stacked expert weights: ONE kernel
    over a 3-D (expert, row blocks, out-channel blocks) grid with the
    rotate-once schedule per (expert, row block) -- replacing the PR-4
    split into a fused rotate+quantize kernel plus a per-expert XLA
    einsum (which round-tripped (q, scales) through HBM).

    x: (..., E, c, n) dispatched activations; wq: (E, n, d) storage-dtype
    expert weights; sw: (E, 1, d) f32 per-(expert, out-channel) scales.
    Returns (..., E, c, d) in the plan's io dtype.
    """
    return _pallas_quant_dot_experts(x, wq, sw, plan, interpret)


@functools.partial(jax.jit, static_argnames=("plan", "interpret"))
def _pallas_quant_dot_experts(x, wq, sw, plan, interpret: bool):
    TRACE_COUNTS[("pallas", "quant_dot_experts")] += 1
    n = plan.p
    mode = plan.epilogue.mode
    cd = jnp.dtype(plan.compute_dtype)
    mats = _plan_mats(plan)
    E, _, d = wq.shape
    lead, cap = x.shape[:-3], x.shape[-2]
    # rows of one expert contiguous: (..., E, c, n) -> (E, rows, n)
    x3 = jnp.moveaxis(x.reshape(-1, E, cap, n), 1, 0).reshape(E, -1, n)
    m = x3.shape[1]
    sw3 = sw.reshape(E, 1, d).astype(jnp.float32)
    bm, bn = quant_dot_blocks(n, d, m, x.dtype, cd, mode,
                              block_m=plan.block_m)
    pad_m, pad_d = (-m) % bm, (-d) % bn
    if pad_m:
        x3 = jnp.pad(x3, ((0, 0), (0, pad_m), (0, 0)))
    wq3 = wq
    if pad_d:
        wq3 = jnp.pad(wq, ((0, 0), (0, 0), (0, pad_d)))
        sw3 = jnp.pad(sw3, ((0, 0), (0, 0), (0, pad_d)))
    mp, dp = m + pad_m, d + pad_d
    kernel = functools.partial(_quant_dot_experts_kernel, n=n, mode=mode,
                               compute_dtype=cd)
    out = pl.pallas_call(
        kernel,
        grid=(E, mp // bm, dp // bn),
        in_specs=[
            pl.BlockSpec((1, bm, n), lambda e, i, j: (e, i, 0)),
            pl.BlockSpec((mats.shape[0],) + mats.shape[1:],
                         lambda e, i, j: (0, 0, 0)),
            pl.BlockSpec((1, n, bn), lambda e, i, j: (e, 0, j)),
            pl.BlockSpec((1, 1, bn), lambda e, i, j: (e, 0, j)),
        ],
        out_specs=pl.BlockSpec((1, bm, bn), lambda e, i, j: (e, i, j)),
        out_shape=jax.ShapeDtypeStruct((E, mp, dp), jnp.dtype(plan.dtype)),
        scratch_shapes=[pltpu.VMEM((bm, n), _scratch_dtype(mode)),
                        pltpu.VMEM((bm, 1), jnp.float32)],
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(x3, mats, wq3, sw3)
    out = jnp.moveaxis(out[:, :m, :d].reshape(E, -1, cap, d), 0, 1)
    return out.reshape(*lead, E, cap, d)


@functools.partial(jax.jit, static_argnames=("plan", "interpret"))
def xla_quant_dot(x, wq, sw, plan, interpret: bool):
    """Unfused oracle semantics on the factored XLA path: rotate, quantize
    per token, then the SAME ``epilogue_dot`` contraction (int8/int32 or
    fp8-in-bf16/f32). Shards trivially under pjit -- the fallback for
    sizes above the kernel cap and the ground truth the fused kernel is
    tested against."""
    TRACE_COUNTS[("xla", "quant_dot")] += 1
    y = _xla_transform(x, plan)
    q, s = _quantize_rows(y.astype(jnp.float32), mode=plan.epilogue.mode)
    return epilogue_dot(q, s, wq, sw.reshape(1, wq.shape[-1]),
                        plan.epilogue.mode, jnp.dtype(plan.dtype))
