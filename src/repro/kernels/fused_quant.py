"""Fused Hadamard-transform + quantization kernel (beyond-paper).

The paper's conclusion names "kernel fusion to support fused Hadamard
transform and quantization" as future work. On TPU the fusion is natural:
the rotated row block is already resident in VMEM after the matmul passes,
so the per-token absmax reduction and int8/fp8 cast happen before the
write-back -- the quantized tensor (plus scales) is the ONLY HBM output,
halving output bytes vs. rotate-then-quantize as two kernels (which writes
the rotated f32/bf16 tensor and re-reads it).

Outputs: (q: int8[..., n], scales: f32[...]) with per-row symmetric scales
-- exactly what a following int8 matmul / FP8 attention consumes.
"""
from __future__ import annotations

import functools
import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.hadamard import _apply_passes, base_matrices
from repro.kernels.hadacore import MAX_KERNEL_SIZE, default_block_m

_INT8_MAX = 127.0


def _fused_kernel(x_ref, mats_ref, q_ref, s_ref, *, n: int):
    x = x_ref[...].astype(jnp.float32)
    bm = x.shape[0]
    mats = [mats_ref[p] for p in range(mats_ref.shape[0])]
    y = _apply_passes(x.reshape(bm, n), n, mats)
    s = jnp.maximum(jnp.max(jnp.abs(y), axis=-1, keepdims=True), 1e-8) / _INT8_MAX
    q = jnp.clip(jnp.round(y / s), -_INT8_MAX, _INT8_MAX)
    q_ref[...] = q.astype(jnp.int8)
    s_ref[...] = s


@functools.partial(jax.jit, static_argnames=("scale_mode", "block_m", "interpret"))
def _fused_call(x, scale_mode: str, block_m: Optional[int], interpret: bool):
    n = x.shape[-1]
    scale = 1.0 / math.sqrt(n) if scale_mode == "ortho" else None
    mats = jnp.stack(base_matrices(n, scale))
    b = mats.shape[-1]

    orig_shape = x.shape
    m = 1
    for d in x.shape[:-1]:
        m *= d
    x2 = x.reshape(m, n)
    bm = block_m or default_block_m(n, m, x.dtype)
    pad = (-m) % bm
    if pad:
        x2 = jnp.pad(x2, ((0, pad), (0, 0)))
    mp = x2.shape[0]

    q, s = pl.pallas_call(
        functools.partial(_fused_kernel, n=n),
        grid=(mp // bm,),
        in_specs=[
            pl.BlockSpec((bm, n), lambda i: (i, 0)),
            pl.BlockSpec((mats.shape[0], b, b), lambda i: (0, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bm, n), lambda i: (i, 0)),
            pl.BlockSpec((bm, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((mp, n), jnp.int8),
            jax.ShapeDtypeStruct((mp, 1), jnp.float32),
        ],
        interpret=interpret,
    )(x2, mats.astype(jnp.float32))
    if pad:
        q, s = q[:m], s[:m]
    return q.reshape(orig_shape), s.reshape(orig_shape[:-1] + (1,))


def fused_hadamard_quantize(
    x: jnp.ndarray,
    scale: Optional[str] = "ortho",
    *,
    block_m: Optional[int] = None,
    interpret: Optional[bool] = None,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Rotate the last axis by the Walsh-Hadamard transform and int8-quantize
    per row, in one VMEM-resident kernel. Returns (int8 values, f32 scales)."""
    n = x.shape[-1]
    if n > MAX_KERNEL_SIZE:
        raise ValueError(f"fused kernel supports n <= {MAX_KERNEL_SIZE}, got {n}")
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    return _fused_call(x, "ortho" if scale == "ortho" else "none",
                       block_m, interpret)


def ref_fused(x: jnp.ndarray, scale: Optional[str] = "ortho"):
    """Pure-jnp oracle: scalar FWHT then per-row int8 quantization."""
    from repro.kernels.ref import fwht
    n = x.shape[-1]
    y = fwht(x.astype(jnp.float32),
             1.0 / math.sqrt(n) if scale == "ortho" else None)
    s = jnp.maximum(jnp.max(jnp.abs(y), axis=-1, keepdims=True), 1e-8) / _INT8_MAX
    q = jnp.clip(jnp.round(y / s), -_INT8_MAX, _INT8_MAX).astype(jnp.int8)
    return q, s
