"""DEPRECATED shim: fused Hadamard-transform + quantization (beyond-paper).

The paper's conclusion names "kernel fusion to support fused Hadamard
transform and quantization" as future work. On TPU the fusion is natural:
the rotated row block is already resident in VMEM after the matmul passes,
so the per-token absmax reduction and int8/fp8 cast happen before the
write-back -- the quantized tensor (plus scales) is the ONLY HBM output.

The kernel now lives in ``repro.kernels.registry`` (the pallas backend's
``fused`` path), generalized from int8-only to fp8_e4m3 / fp8_e5m2, and is
reached through the plan API::

    from repro.core.api import QuantEpilogue, hadamard
    q, s = hadamard(x, epilogue=QuantEpilogue("int8"))

``fused_hadamard_quantize`` is kept as a bitwise-identical int8 wrapper;
``ref_fused`` is the pure-jnp oracle, extended with a ``mode`` argument so
fp8 epilogues validate against the same ground truth.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax.numpy as jnp

from repro.core.api import QuantEpilogue, hadamard
from repro.core.hadamard import resolve_scale
from repro.kernels.ref import is_pow2
from repro.kernels.registry import (MAX_KERNEL_SIZE, QSPECS, _quantize_rows,
                                    warn_once)

__all__ = ["fused_hadamard_quantize", "ref_fused"]

# warn-once key: one DeprecationWarning per process, with a
# TRACE_COUNTS[WARN_KEY] tick on every call (shared registry idiom).
WARN_KEY = ("deprecated", "kernels.fused_quant.fused_hadamard_quantize")


def _warn_once():
    warn_once(
        WARN_KEY,
        "repro.kernels.fused_quant.fused_hadamard_quantize is "
        "deprecated; use repro.core.api.hadamard with a "
        "QuantEpilogue (or repro.core.api.quant_dot for the fused "
        "GEMM consumer)",
        category=DeprecationWarning, stacklevel=4)


def fused_hadamard_quantize(
    x: jnp.ndarray,
    scale: Optional[str] = "ortho",
    *,
    block_m: Optional[int] = None,
    interpret: Optional[bool] = None,
    mode: str = "int8",
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Rotate the last axis by the Walsh-Hadamard transform and quantize
    per row, in one VMEM-resident kernel. Returns (quantized values, f32
    scales). Deprecated: use ``repro.core.api.hadamard`` with a
    ``QuantEpilogue`` (which this wrapper now calls)."""
    _warn_once()
    n = x.shape[-1]
    if n > MAX_KERNEL_SIZE:
        raise ValueError(f"fused kernel supports n <= {MAX_KERNEL_SIZE}, got {n}")
    if not is_pow2(n):
        raise ValueError(f"Hadamard size must be a power of 2, got {n}")
    return hadamard(
        x,
        scale=scale,
        backend="pallas",
        epilogue=QuantEpilogue(mode),
        block_m=block_m,
        interpret=interpret,
    )


def ref_fused(x: jnp.ndarray, scale: Optional[str] = "ortho",
              mode: str = "int8"):
    """Pure-jnp oracle: scalar FWHT then per-row symmetric quantization.

    ``mode`` selects the grid (int8 round+clip, or a cast through the real
    fp8 dtype) -- the ground truth the fused kernel's epilogues are
    validated against for all three modes."""
    from repro.kernels.ref import fwht

    n = x.shape[-1]
    y = fwht(x.astype(jnp.float32), resolve_scale(scale, n))
    q, s = _quantize_rows(y, mode)
    return q.astype(QSPECS[mode][1]), s
