"""Pure-jnp oracle for the Fast Walsh-Hadamard Transform.

This mirrors the paper's Listing 1 (the classic in-place butterfly FWHT),
vectorized over leading axes. It is the ground-truth every kernel is
validated against, and it is also the "scalar algorithm" baseline in the
benchmark harness (the role the Dao-AILab CUDA kernel plays in the paper).
"""
from __future__ import annotations

import math
from typing import Optional

import jax.numpy as jnp
import numpy as np

__all__ = ["fwht", "hadamard_matrix", "is_pow2"]


def is_pow2(n: int) -> bool:
    return n > 0 and (n & (n - 1)) == 0


def fwht(x: jnp.ndarray, scale: Optional[float] = None) -> jnp.ndarray:
    """Right Walsh-Hadamard transform of the last axis: ``y = x @ H_n * scale``.

    ``scale=None`` leaves the +-1 (unnormalized) transform;
    ``scale=1/sqrt(n)`` gives the orthonormal transform (the paper
    normalizes by 1/sqrt(2) per stage, which is the same thing).

    The stage loop is a Python loop over log2(n) butterfly stages -- each
    stage pairs elements at stride ``h`` exactly like the paper's Listing 1.
    """
    n = x.shape[-1]
    if not is_pow2(n):
        raise ValueError(f"FWHT size must be a power of 2, got {n}")
    orig_shape = x.shape
    orig_dtype = x.dtype
    x = x.astype(jnp.float32).reshape(-1, n)
    h = 1
    while h < n:
        # (rows, n) -> (rows, n/(2h), 2, h): axis -2 indexes the (j, j+h) pair
        x = x.reshape(-1, n // (2 * h), 2, h)
        a = x[:, :, 0, :]
        b = x[:, :, 1, :]
        x = jnp.stack([a + b, a - b], axis=2)
        h *= 2
    x = x.reshape(orig_shape)
    if scale is not None:
        x = x * scale
    return x.astype(orig_dtype)


def hadamard_matrix(n: int, scale: Optional[float] = None) -> np.ndarray:
    """Explicit Sylvester-construction Walsh-Hadamard matrix (numpy, f32).

    Used by tests to check kernels against an explicit matmul, exactly like
    the paper's "basic unit tests that check the output of HadaCore against
    the output of an explicit Hadamard matrix multiplication".
    """
    if not is_pow2(n):
        raise ValueError(f"Hadamard size must be a power of 2, got {n}")
    H = np.array([[1.0]], dtype=np.float32)
    while H.shape[0] < n:
        H = np.block([[H, H], [H, -H]])
    if scale is not None:
        H = H * scale
    return H.astype(np.float32)


def ortho_scale(n: int) -> float:
    """The orthonormal scale 1/sqrt(n)."""
    return 1.0 / math.sqrt(n)
