"""DEPRECATED shim: the jit'd Hadamard op with autodiff and dispatch.

``kernels.ops.hadamard`` predates the plan-based API and is kept only for
backward compatibility -- it is now a thin wrapper over
``repro.core.api.hadamard`` (which carries the same ``custom_vjp``
self-adjoint pullback and the same pallas-with-XLA-fallback dispatch,
via the backend registry instead of an if/else chain). New code should
use::

    from repro.core.api import hadamard, plan_for

and optionally prebuild a plan for the hot path.
"""
from __future__ import annotations

from typing import Optional

import jax.numpy as jnp

from repro.core.api import hadamard as _hadamard
from repro.kernels.ref import is_pow2
from repro.kernels.registry import warn_once

__all__ = ["hadamard"]

# warn-once key: one DeprecationWarning per process, with a
# TRACE_COUNTS[WARN_KEY] tick on every call (shared registry idiom).
WARN_KEY = ("deprecated", "kernels.ops.hadamard")


def _warn_once():
    warn_once(
        WARN_KEY,
        "repro.kernels.ops.hadamard is deprecated; use "
        "repro.core.api.hadamard (optionally with a prebuilt plan_for "
        "plan for the hot path)",
        category=DeprecationWarning, stacklevel=4)


def hadamard(x: jnp.ndarray, scale: Optional[str] = "ortho",
             backend: str = "pallas") -> jnp.ndarray:
    """Differentiable right Hadamard transform of the last axis.

    Deprecated: use ``repro.core.api.hadamard``. Dispatch is unchanged --
    ``backend="pallas"`` uses the hadacore kernel up to the paper's 2^15
    cap and falls back to the MXU-factored XLA path above it. Non-power-
    of-2 sizes are rejected as before (the plan API's grouped transform
    is an explicit opt-in, not a silent substitute).
    """
    _warn_once()
    if not is_pow2(x.shape[-1]):
        raise ValueError(f"Hadamard size must be a power of 2, got {x.shape[-1]}")
    return _hadamard(x, scale=scale, backend=backend)
