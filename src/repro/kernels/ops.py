"""Public jit'd Hadamard-transform op with autodiff and backend dispatch.

``hadamard`` is the single entry point models use. It dispatches:

  * n <= 32768 (paper's kernel cap)  ->  Pallas hadacore kernel
    (interpret mode off-TPU, compiled Mosaic on TPU)
  * larger n, or ``backend="xla"``   ->  pure-JAX MXU-factored path

and carries a ``custom_vjp``: the Walsh-Hadamard matrix is symmetric, so
the pullback of ``y = x @ (s H)`` is ``g @ (s H)`` -- the transform is its
own adjoint, which keeps rotation layers cheap in the backward pass (one
more hadacore call instead of a transposed matmul).
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.hadamard import hadamard_transform
from repro.kernels.hadacore import MAX_KERNEL_SIZE, hadacore

__all__ = ["hadamard"]


def _fwd_impl(x: jnp.ndarray, scale: Optional[str], backend: str) -> jnp.ndarray:
    n = x.shape[-1]
    if backend == "pallas" and n <= MAX_KERNEL_SIZE:
        return hadacore(x, scale=scale)
    return hadamard_transform(x, scale=scale)


@partial(jax.custom_vjp, nondiff_argnums=(1, 2))
def hadamard(x: jnp.ndarray, scale: Optional[str] = "ortho",
             backend: str = "pallas") -> jnp.ndarray:
    """Differentiable right Hadamard transform of the last axis."""
    return _fwd_impl(x, scale, backend)


def _hadamard_fwd(x, scale, backend):
    return _fwd_impl(x, scale, backend), None


def _hadamard_bwd(scale, backend, _res, g):
    # H^T = H and the scale is scalar: the op is self-adjoint.
    return (_fwd_impl(g, scale, backend),)


hadamard.defvjp(_hadamard_fwd, _hadamard_bwd)
