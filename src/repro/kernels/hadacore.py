"""HadaCore for TPU: MXU-accelerated Walsh-Hadamard transform Pallas kernel.

Paper mapping (DESIGN.md section 2):

  * GPU 16x16 Tensor Core mma base case  ->  128-point base case on the
    128x128 MXU (``jnp.dot`` with f32 accumulation inside the kernel).
  * warp-shuffle / shared-memory transposes between passes  ->  in-VMEM
    reshape/swapaxes (Mosaic lowers these to vreg/sublane moves; no HBM
    round-trip between passes -- the whole row block stays resident).
  * threadblock-per-row grid  ->  Pallas grid over row blocks with a
    ``(block_m, n)`` BlockSpec VMEM tile.
  * paper section 3.3 non-power-of-16 sizes  ->  the r-pass matrix is the
    block-diagonal tiling I_{128/r} (x) H_r, so every pass stays a
    128-wide MXU matmul.
  * Appendix B in-place rotation  ->  ``input_output_aliases={0: 0}``:
    the output buffer IS the input buffer, halving HBM footprint (the TPU
    analogue of halving the L2 working set).
  * Appendix C BF16  ->  MXU always accumulates f32; we down-convert at
    the very end (conversion cost amortized over all passes).

Like the paper's kernel (and the Dao-AILab kernel it beats), a single
kernel invocation supports transform sizes up to 2^15 = 32768; the wrapper
falls back to the pure-JAX factored path above that.

The kernel bodies and their grid/BlockSpec wrappers now live in
``repro.kernels.registry`` (the ``pallas`` backend of the plan-based API);
``hadacore`` remains the direct, rotation-only entry point for callers
that want the kernel specifically (benchmarks, kernel tests).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.api import plan_for
from repro.kernels.ref import is_pow2
from repro.kernels.registry import (  # noqa: F401  (re-exported: legacy API)
    MAX_KERNEL_SIZE,
    _pallas_transform,
    default_block_m,
)

__all__ = ["hadacore", "MAX_KERNEL_SIZE", "default_block_m"]


def hadacore(
    x: jnp.ndarray,
    scale: Optional[str] = "ortho",
    *,
    block_m: Optional[int] = None,
    interpret: Optional[bool] = None,
    in_place: bool = False,
) -> jnp.ndarray:
    """HadaCore Walsh-Hadamard transform of the last axis (Pallas TPU kernel).

    Args:
      x: (..., n) with n a power of 2, n <= 32768 for the kernel path.
      scale: "ortho" (1/sqrt(n) rotation) or None (+-1 transform).
      block_m: rows per grid step (None = VMEM-budget heuristic).
      interpret: run the kernel body in interpret mode (None = auto: True
        off-TPU so CPU CI validates the same kernel code path).
      in_place: alias the output onto the input buffer (Appendix B).
    """
    n = x.shape[-1]
    if n > MAX_KERNEL_SIZE:
        raise ValueError(
            f"hadacore kernel supports n <= {MAX_KERNEL_SIZE} (paper cap); "
            f"got {n}. Use repro.core.hadamard.hadamard_transform."
        )
    if not is_pow2(n):
        raise ValueError(f"Hadamard size must be a power of 2, got {n}")
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    plan = plan_for(
        n, dtype=x.dtype, scale=scale, backend="pallas", block_m=block_m
    )
    return _pallas_transform(x, plan, interpret, in_place)
