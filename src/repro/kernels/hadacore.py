"""HadaCore for TPU: MXU-accelerated Walsh-Hadamard transform Pallas kernel.

Paper mapping (DESIGN.md section 2):

  * GPU 16x16 Tensor Core mma base case  ->  128-point base case on the
    128x128 MXU (``jnp.dot`` with f32 accumulation inside the kernel).
  * warp-shuffle / shared-memory transposes between passes  ->  in-VMEM
    reshape/swapaxes (Mosaic lowers these to vreg/sublane moves; no HBM
    round-trip between passes -- the whole row block stays resident).
  * threadblock-per-row grid  ->  Pallas grid over row blocks with a
    ``(block_m, n)`` BlockSpec VMEM tile.
  * paper section 3.3 non-power-of-16 sizes  ->  the r-pass matrix is the
    block-diagonal tiling I_{128/r} (x) H_r, so every pass stays a
    128-wide MXU matmul.
  * Appendix B in-place rotation  ->  ``input_output_aliases={0: 0}``:
    the output buffer IS the input buffer, halving HBM footprint (the TPU
    analogue of halving the L2 working set).
  * Appendix C BF16  ->  MXU always accumulates f32; we down-convert at
    the very end (conversion cost amortized over all passes).

Like the paper's kernel (and the Dao-AILab kernel it beats), a single
kernel invocation supports transform sizes up to 2^15 = 32768; the wrapper
falls back to the pure-JAX factored path above that.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.hadamard import MXU_TILE, _apply_passes, base_matrices

__all__ = ["hadacore", "MAX_KERNEL_SIZE", "default_block_m"]

# Same per-invocation cap as the paper's kernel (2^15). Above this the
# (block_m, n) row tile would still fit VMEM only for tiny block_m.
MAX_KERNEL_SIZE = 32768

# VMEM budget we tile for (v5e has 16 MiB more or less reserved for Pallas).
_VMEM_BUDGET_BYTES = 8 * 1024 * 1024


def default_block_m(n: int, m: int, dtype=jnp.float32) -> int:
    """Rows per grid step. Plays the role of the paper's empirically chosen
    warps_per_block x num_chunks: large enough to keep the MXU busy
    (>=128-row matmuls when possible), small enough that x + out + f32
    scratch fit the VMEM budget."""
    bytes_per_row = n * (jnp.dtype(dtype).itemsize + 4)  # io tile + f32 compute copy
    bm = max(8, _VMEM_BUDGET_BYTES // max(bytes_per_row, 1))
    bm = min(bm, 256, m)
    # round down to a multiple of 8 (f32 sublane); keep at least 8
    return max(8, (bm // 8) * 8)


def _hadacore_kernel(x_ref, mats_ref, o_ref, *, n: int):
    """One grid step: transform a (block_m, n) row block entirely in VMEM."""
    x = x_ref[...].astype(jnp.float32)
    bm = x.shape[0]
    mats = [mats_ref[p] for p in range(mats_ref.shape[0])]
    y = _apply_passes(x.reshape(bm, n), n, mats)
    o_ref[...] = y.reshape(x_ref.shape).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("scale_mode", "block_m", "interpret", "in_place"),
)
def _hadacore_call(
    x: jnp.ndarray,
    scale_mode: str,
    block_m: Optional[int],
    interpret: bool,
    in_place: bool,
) -> jnp.ndarray:
    import math

    n = x.shape[-1]
    scale = 1.0 / math.sqrt(n) if scale_mode == "ortho" else None
    mats = jnp.stack(base_matrices(n, scale))  # (P, b, b), b = min(n, 128)
    b = mats.shape[-1]

    orig_shape = x.shape
    m = 1
    for d in x.shape[:-1]:
        m *= d
    x2 = x.reshape(m, n)

    bm = block_m or default_block_m(n, m, x.dtype)
    pad = (-m) % bm
    if pad:
        x2 = jnp.pad(x2, ((0, pad), (0, 0)))
    mp = x2.shape[0]

    grid = (mp // bm,)
    kernel = functools.partial(_hadacore_kernel, n=n)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, n), lambda i: (i, 0)),
            pl.BlockSpec((mats.shape[0], b, b), lambda i: (0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((bm, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((mp, n), x.dtype),
        input_output_aliases={0: 0} if in_place else {},
        interpret=interpret,
    )(x2, mats.astype(jnp.float32))

    if pad:
        out = out[:m]
    return out.reshape(orig_shape)


def hadacore(
    x: jnp.ndarray,
    scale: Optional[str] = "ortho",
    *,
    block_m: Optional[int] = None,
    interpret: Optional[bool] = None,
    in_place: bool = False,
) -> jnp.ndarray:
    """HadaCore Walsh-Hadamard transform of the last axis (Pallas TPU kernel).

    Args:
      x: (..., n) with n a power of 2, n <= 32768 for the kernel path.
      scale: "ortho" (1/sqrt(n) rotation) or None (+-1 transform).
      block_m: rows per grid step (None = VMEM-budget heuristic).
      interpret: run the kernel body in interpret mode (None = auto: True
        off-TPU so CPU CI validates the same kernel code path).
      in_place: alias the output onto the input buffer (Appendix B).
    """
    n = x.shape[-1]
    if n > MAX_KERNEL_SIZE:
        raise ValueError(
            f"hadacore kernel supports n <= {MAX_KERNEL_SIZE} (paper cap); "
            f"got {n}. Use repro.core.hadamard.hadamard_transform."
        )
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    return _hadacore_call(
        x, "ortho" if scale == "ortho" else "none", block_m, interpret, in_place
    )
