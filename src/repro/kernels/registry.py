"""Backend registry for the plan-based Hadamard API (DESIGN.md section 5).

Every transform implementation is a *backend* registered here via the
``@register_backend`` decorator -- replacing the if/else string chains the
old entry points (``kernels.ops.hadamard``, ``core.rotations.
online_hadamard``) each carried their own copy of. A backend exposes:

  * ``transform(x, plan, interpret)``  -- rotate the last axis (== plan.p)
  * ``fused(x, plan, interpret)``      -- rotate + quantize epilogue in one
    kernel, returning ``(q, scales)``; ``None`` when the backend has no
    fused path (the dispatcher falls back to transform + XLA epilogue)
  * ``fused_dequant(x, plan, interpret)`` -- rotate + fake-quantize
    (quantize-dequantize) in one kernel; the training-path variant
  * ``supports(p)``   -- can this backend run a p-point transform?

Selection (``select_backend``): an explicit request wins when supported
(with the historical pallas -> xla fallback above the kernel size cap);
otherwise the ``REPRO_HADAMARD_BACKEND`` environment variable; otherwise
the highest-priority auto-selectable backend that supports the size on
this platform.  Registered backends:

  pallas -- the HadaCore Pallas TPU kernels (VMEM-resident multi-pass
            matmul; interpret mode off-TPU). Hosts the fused
            rotate+quantize kernel: the rotated row block is already in
            VMEM, so the per-token absmax and int8/fp8 cast happen before
            write-back and the quantized tensor is the only HBM output.
  xla    -- the MXU-factored pure-JAX path (shards trivially under pjit;
            no size cap).
  ref    -- the paper's Listing-1 scalar FWHT oracle (never auto-picked).
"""
from __future__ import annotations

import collections
import functools
import os
import warnings
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.hadamard import MXU_TILE, _apply_passes
from repro.kernels.ref import fwht

__all__ = [
    "Backend",
    "register_backend",
    "get_backend",
    "available_backends",
    "select_backend",
    "BACKEND_ENV_VAR",
    "MAX_KERNEL_SIZE",
    "default_block_m",
    "QSPECS",
    "TRACE_COUNTS",
    "warn_once",
    "WARN_ONCE_SEEN",
]

BACKEND_ENV_VAR = "REPRO_HADAMARD_BACKEND"

# Same per-invocation cap as the paper's kernel (2^15). Above this the
# (block_m, n) row tile would still fit VMEM only for tiny block_m.
MAX_KERNEL_SIZE = 32768

# VMEM budget we tile for (v5e has 16 MiB more or less reserved for Pallas).
_VMEM_BUDGET_BYTES = 8 * 1024 * 1024

# mode -> (grid max, storage dtype, integer grid?). The fused kernel and
# the XLA epilogue fallback share this table so all paths agree bit-for-bit.
QSPECS = {
    "int8": (127.0, jnp.int8, True),
    "fp8_e4m3": (448.0, jnp.float8_e4m3fn, False),
    "fp8_e5m2": (57344.0, jnp.float8_e5m2, False),
}

# (backend, kind) -> number of times the jitted implementation was TRACED
# (i.e. compiled). Plan-cache tests assert repeated same-shape calls do not
# grow these counters. The sharded quant_dot dispatcher also counts its
# trace-time fallback decisions here under ("sharded_quant_dot", <reason>)
# keys -- see ``core.api._sharded_fallback`` -- so a mesh plan silently
# losing the fused/sharded hot path is observable in tests and debugging.
TRACE_COUNTS: collections.Counter = collections.Counter()

# Keys already warned about via ``warn_once`` -- one warning per process
# per key, while the companion TRACE_COUNTS entry keeps counting every
# occurrence. Tests reset individual keys with ``WARN_ONCE_SEEN.discard``
# (never the counter).
WARN_ONCE_SEEN: set = set()


def warn_once(key: Tuple[str, str], msg: str, *,
              category=RuntimeWarning, stacklevel: int = 3,
              count: bool = True) -> None:
    """THE warn-once-with-counter idiom (previously copied by the
    quant_dot stream fallback, ``core.api._sharded_fallback``, and the
    ops/fused_quant/rotations deprecation shims): emit ``msg`` as a
    one-shot warning per process per ``key`` and tick
    ``TRACE_COUNTS[key]`` on EVERY call, so the fallback/deprecation
    stays observable after the warning goes quiet."""
    if count:
        TRACE_COUNTS[key] += 1
    if key not in WARN_ONCE_SEEN:
        WARN_ONCE_SEEN.add(key)
        warnings.warn(msg, category, stacklevel=stacklevel)


def _epilogue_out_bytes_per_row(n: int, in_itemsize: int, epilogue) -> int:
    """HBM-output bytes one row contributes inside the kernel's VMEM tile.

    * no epilogue        -> the rotated row in the io dtype
    * (q, scales) form   -> the quantized row + one f32 scale
    * dequant form       -> the fake-quantized row in the io dtype
    """
    if epilogue is None or epilogue.dequant:
        return n * in_itemsize
    q_itemsize = jnp.dtype(QSPECS[epilogue.mode][1]).itemsize
    return n * q_itemsize + 4


def default_block_m(n: int, m: int, dtype=jnp.float32, *,
                    compute_dtype=None, epilogue=None) -> int:
    """Rows per grid step. Plays the role of the paper's empirically chosen
    warps_per_block x num_chunks: large enough to keep the MXU busy
    (>=128-row matmuls when possible), small enough that the ACTUAL VMEM
    residents fit the budget: the input tile, the compute-dtype working
    copy (bf16/fp16 plans skip the old unconditional f32 upcast, so
    16-bit inputs get ~2x larger row tiles), and every epilogue output
    (the fused kernels' q tile + per-row scales used to go uncharged,
    overshooting the budget the docstring promises for large n)."""
    in_b = jnp.dtype(dtype).itemsize
    cb = jnp.dtype(compute_dtype).itemsize if compute_dtype is not None else 4
    bytes_per_row = n * (in_b + cb) + _epilogue_out_bytes_per_row(
        n, in_b, epilogue)
    bm = max(8, _VMEM_BUDGET_BYTES // max(bytes_per_row, 1))
    bm = min(bm, 256, m)
    # round down to the sublane multiple of the io dtype; keep one sublane
    sub = 16 if in_b == 2 else 8
    return max(sub, (bm // sub) * sub)


# ---------------------------------------------------------------- registry
_REGISTRY: Dict[str, "Backend"] = {}


def register_backend(cls):
    """Class decorator: instantiate and register a backend under its name."""
    inst = cls()
    _REGISTRY[inst.name] = inst
    return cls


def get_backend(name: str) -> "Backend":
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown Hadamard backend {name!r}; registered: "
            f"{sorted(_REGISTRY)}"
        ) from None


def available_backends() -> Tuple[str, ...]:
    """Registered backend names, highest selection priority first."""
    return tuple(sorted(_REGISTRY, key=lambda k: -_REGISTRY[k].priority))


def select_backend(p: int, requested: Optional[str] = None) -> str:
    """Resolve the backend for a p-point transform.

    Explicit request > ``REPRO_HADAMARD_BACKEND`` env var > auto (priority
    order over backends whose ``supports(p)`` holds). A requested backend
    that cannot run the size falls through to auto selection -- preserving
    the historical ``hadamard(x, backend="pallas")`` -> XLA fallback for
    n above the kernel cap.
    """
    if requested in (None, "auto"):
        requested = os.environ.get(BACKEND_ENV_VAR) or None
    if requested is not None:
        be = get_backend(requested)  # raises on unknown names
        if be.supports(p):
            return be.name
    for name in available_backends():
        be = _REGISTRY[name]
        if be.auto and be.supports(p):
            return name
    raise ValueError(f"no registered backend supports a {p}-point transform")


class Backend:
    """Base class: a named transform implementation with optional fused
    rotate+quantize paths. Subclasses are registered via
    ``@register_backend`` and selected by ``select_backend``."""

    name: str = "?"
    priority: int = 0
    auto: bool = True  # eligible for automatic selection

    def supports(self, p: int) -> bool:
        raise NotImplementedError

    def transform(self, x, plan, interpret: bool):
        raise NotImplementedError

    # Optional single-kernel epilogue paths (None = dispatcher falls back
    # to transform + XLA epilogue).
    fused = None
    fused_dequant = None
    # Optional rotate+quantize+GEMM consumer path (None = dispatcher falls
    # back to transform + shared unfused epilogue-dot math).
    quant_dot = None
    # Optional fused consumer for stacked (E, n, d) expert weights (the
    # 3-D rotate-once grid); None = per-expert einsum fallback.
    quant_dot_experts = None
    # Does ``quant_dot`` run as ONE kernel (rotation, quantize and GEMM
    # fused)? False means the hosted quant_dot is the unfused oracle
    # semantics (xla) -- the sharded dispatcher uses this to warn when a
    # mesh plan silently loses the fused hot path.
    quant_dot_fused = False


# ---------------------------------------------------------------- kernels
def _hadacore_kernel(x_ref, mats_ref, o_ref, *, n: int, compute_dtype):
    """One grid step: transform a (block_m, n) row block entirely in VMEM.

    The row block is cast to the plan's compute dtype (a no-op for bf16
    inputs on the default native rule -- no f32 VMEM copy); the matmul
    passes accumulate f32 on the MXU (``_apply_passes``)."""
    x = x_ref[...].astype(compute_dtype)
    bm = x.shape[0]
    mats = [mats_ref[p] for p in range(mats_ref.shape[0])]
    y = _apply_passes(x.reshape(bm, n), n, mats)
    o_ref[...] = y.reshape(x_ref.shape).astype(o_ref.dtype)


def _quantize_rows(y: jnp.ndarray, mode: str, axis=-1):
    """THE symmetric-absmax epilogue math: (q on the mode's grid, f32
    scales). Single source of truth -- the fused kernels, the XLA
    epilogue fallback (``core.api``), and the oracle (``ref_fused``) all
    call this so their numerics agree bit-for-bit.

    ``q`` is returned pre-cast (f32 values on the integer grid for int8;
    unconverted quotients for fp8) so callers control the final cast --
    the fused kernel casts at the VMEM->HBM store, the dequant variant
    round-trips through the storage dtype first. ``axis=None`` gives one
    per-tensor scale (never fusable: needs a global reduction).
    """
    qmax, _, is_int = QSPECS[mode]
    s = jnp.maximum(jnp.max(jnp.abs(y), axis=axis, keepdims=True), 1e-8) / qmax
    q = y / s
    if is_int:
        q = jnp.clip(jnp.round(q), -qmax, qmax)
    return q, s


def _dequantize(q: jnp.ndarray, s: jnp.ndarray, mode: str) -> jnp.ndarray:
    """Map ``_quantize_rows`` output back to real values through the
    storage grid (fp8 round-trips through the real dtype so mantissa
    truncation is reproduced exactly). f32 in, f32 out -- the other half
    of the single-source-of-truth epilogue math."""
    _, qdt, is_int = QSPECS[mode]
    if not is_int:
        q = q.astype(qdt).astype(jnp.float32)
    return q * s


def _fused_kernel(x_ref, mats_ref, q_ref, s_ref, *, n: int, mode: str,
                  compute_dtype):
    """Rotate a row block and quantize it before write-back: the quantized
    tensor plus scales are the only HBM outputs (paper's future-work
    fusion, generalized from int8 to fp8_e4m3 / fp8_e5m2). Passes run in
    the plan's compute dtype; the epilogue statistics stay f32."""
    x = x_ref[...].astype(compute_dtype)
    bm = x.shape[0]
    mats = [mats_ref[p] for p in range(mats_ref.shape[0])]
    y = _apply_passes(x.reshape(bm, n), n, mats)
    q, s = _quantize_rows(y.astype(jnp.float32), mode)
    q_ref[...] = q.astype(q_ref.dtype)
    s_ref[...] = s


def _fused_dequant_kernel(x_ref, mats_ref, o_ref, *, n: int, mode: str,
                          compute_dtype):
    """Rotate + quantize-dequantize (fake quant) in one VMEM-resident pass:
    the training-path twin of ``_fused_kernel``. Reproduces
    ``core.quant.quantize`` numerics exactly, including the fp8 cast
    round-trip through the real storage dtype."""
    x = x_ref[...].astype(compute_dtype)
    bm = x.shape[0]
    mats = [mats_ref[p] for p in range(mats_ref.shape[0])]
    y = _apply_passes(x.reshape(bm, n), n, mats)
    q, s = _quantize_rows(y.astype(jnp.float32), mode)
    o_ref[...] = _dequantize(q, s, mode).reshape(x_ref.shape).astype(o_ref.dtype)


def _rows(x: jnp.ndarray, n: int):
    m = 1
    for d in x.shape[:-1]:
        m *= d
    return x.reshape(m, n), m


def _pad_rows(x2: jnp.ndarray, bm: int):
    pad = (-x2.shape[0]) % bm
    if pad:
        x2 = jnp.pad(x2, ((0, pad), (0, 0)))
    return x2, pad


def _plan_mats(plan) -> jnp.ndarray:
    # (P, b, b) in the plan's compute dtype: the base matrices are the
    # multiply operands of every pass, so they ride the low-precision path
    # too (entries are +-scale; for pow-of-4 n the ortho scale is exact in
    # bf16, otherwise it rounds like any bf16 constant).
    return jnp.asarray(plan.mats, dtype=jnp.dtype(plan.compute_dtype))


# ----------------------------------------------------------------- pallas
def _pallas_rows_call(x, plan, interpret: bool, kernel, out_kinds,
                      in_place: bool = False):
    """Shared grid plumbing for every row-tiled kernel: flatten to rows,
    pad to the block_m tile, launch over the row grid, unpad, restore the
    leading shape. ``out_kinds`` is a sequence of ``("tile", dtype)``
    (a (block_m, n) output) or ``("rowscale", f32)`` (a (block_m, 1)
    per-row output, reshaped to ``(..., 1)``)."""
    n = plan.p
    mats = _plan_mats(plan)
    b = mats.shape[-1]
    orig_shape = x.shape
    x2, m = _rows(x, n)
    bm = plan.block_m or default_block_m(
        n, m, x.dtype, compute_dtype=jnp.dtype(plan.compute_dtype),
        epilogue=plan.epilogue)
    x2, pad = _pad_rows(x2, bm)
    mp = x2.shape[0]
    out_specs, out_shape = [], []
    for kind, dt in out_kinds:
        if kind == "tile":
            out_specs.append(pl.BlockSpec((bm, n), lambda i: (i, 0)))
            out_shape.append(jax.ShapeDtypeStruct((mp, n), dt))
        else:
            out_specs.append(pl.BlockSpec((bm, 1), lambda i: (i, 0)))
            out_shape.append(jax.ShapeDtypeStruct((mp, 1), dt))
    single = len(out_kinds) == 1
    res = pl.pallas_call(
        kernel,
        grid=(mp // bm,),
        in_specs=[
            pl.BlockSpec((bm, n), lambda i: (i, 0)),
            pl.BlockSpec((mats.shape[0], b, b), lambda i: (0, 0, 0)),
        ],
        out_specs=out_specs[0] if single else out_specs,
        out_shape=out_shape[0] if single else out_shape,
        input_output_aliases={0: 0} if in_place else {},
        interpret=interpret,
    )(x2, mats)
    outs = (res,) if single else tuple(res)
    if pad:
        outs = tuple(o[:m] for o in outs)
    outs = tuple(
        o.reshape(orig_shape) if kind == "tile"
        else o.reshape(orig_shape[:-1] + (1,))
        for o, (kind, _) in zip(outs, out_kinds)
    )
    return outs[0] if single else outs


@functools.partial(jax.jit, static_argnames=("plan", "interpret", "in_place"))
def _pallas_transform(x, plan, interpret: bool, in_place: bool = False):
    TRACE_COUNTS[("pallas", "transform")] += 1
    kernel = functools.partial(
        _hadacore_kernel, n=plan.p,
        compute_dtype=jnp.dtype(plan.compute_dtype))
    return _pallas_rows_call(x, plan, interpret, kernel,
                             [("tile", x.dtype)], in_place)


@functools.partial(jax.jit, static_argnames=("plan", "interpret"))
def _pallas_fused(x, plan, interpret: bool):
    TRACE_COUNTS[("pallas", "fused")] += 1
    mode = plan.epilogue.mode
    kernel = functools.partial(
        _fused_kernel, n=plan.p, mode=mode,
        compute_dtype=jnp.dtype(plan.compute_dtype))
    return _pallas_rows_call(
        x, plan, interpret, kernel,
        [("tile", QSPECS[mode][1]), ("rowscale", jnp.float32)])


@functools.partial(jax.jit, static_argnames=("plan", "interpret"))
def _pallas_fused_dequant(x, plan, interpret: bool):
    TRACE_COUNTS[("pallas", "fused_dequant")] += 1
    kernel = functools.partial(
        _fused_dequant_kernel, n=plan.p, mode=plan.epilogue.mode,
        compute_dtype=jnp.dtype(plan.compute_dtype))
    return _pallas_rows_call(x, plan, interpret, kernel, [("tile", x.dtype)])


@register_backend
class PallasBackend(Backend):
    name = "pallas"
    priority = 20
    quant_dot_fused = True

    def supports(self, p: int) -> bool:
        return p <= MAX_KERNEL_SIZE

    def transform(self, x, plan, interpret, in_place: bool = False):
        return _pallas_transform(x, plan, interpret, in_place)

    def fused(self, x, plan, interpret):
        return _pallas_fused(x, plan, interpret)

    def fused_dequant(self, x, plan, interpret):
        return _pallas_fused_dequant(x, plan, interpret)

    def quant_dot(self, x, wq, sw, plan, interpret, schedule=None,
                  check=None):
        # lazy import: quant_dot.py imports this module at load time.
        # ``check`` (ABFT column checksum) switches to the verified
        # kernel variant and the return value becomes (out, resid).
        from repro.kernels.quant_dot import pallas_quant_dot

        return pallas_quant_dot(x, wq, sw, plan, interpret,
                                schedule=schedule, check=check)

    def quant_dot_experts(self, x, wq, sw, plan, interpret, schedule=None,
                          check=None):
        from repro.kernels.quant_dot import pallas_quant_dot_experts

        return pallas_quant_dot_experts(x, wq, sw, plan, interpret,
                                        schedule=schedule, check=check)


# -------------------------------------------------------------------- xla
@functools.partial(jax.jit, static_argnames=("plan",))
def _xla_transform(x, plan):
    TRACE_COUNTS[("xla", "transform")] += 1
    n = plan.p
    cd = jnp.dtype(plan.compute_dtype)
    mats = [jnp.asarray(m, dtype=cd) for m in plan.mats]
    orig_shape, orig_dtype = x.shape, x.dtype
    x2, _ = _rows(x.astype(cd), n)
    y = _apply_passes(x2, n, mats)
    return y.reshape(orig_shape).astype(orig_dtype)


@register_backend
class XlaBackend(Backend):
    name = "xla"
    priority = 10

    def supports(self, p: int) -> bool:
        return True

    def transform(self, x, plan, interpret):
        return _xla_transform(x, plan)

    def quant_dot(self, x, wq, sw, plan, interpret, schedule=None):
        # unfused oracle semantics: factored rotate, shared epilogue+dot
        # math (pjit-shardable -- every op is a reshape/dot). Grid
        # schedules do not apply here (there is no kernel grid); the
        # name is still validated so typos fail loudly on every backend.
        from repro.kernels.quant_dot import _resolve_schedule

        _resolve_schedule(schedule)
        from repro.kernels.quant_dot import xla_quant_dot

        return xla_quant_dot(x, wq, sw, plan, interpret)


# -------------------------------------------------------------------- ref
@functools.partial(jax.jit, static_argnames=("plan",))
def _ref_transform(x, plan):
    TRACE_COUNTS[("ref", "transform")] += 1
    y = fwht(x.astype(jnp.float32), plan.scale)
    return y.astype(x.dtype)


@register_backend
class RefBackend(Backend):
    name = "ref"
    priority = 0
    auto = False  # oracle: explicit selection only

    def supports(self, p: int) -> bool:
        return True

    def transform(self, x, plan, interpret):
        return _ref_transform(x, plan)
