"""Deterministic, stateless data pipeline.

Fault-tolerance contract: ``batch(step)`` is a pure function of
(seed, step, shape) -- after a node failure or preemption-restart, resuming
from checkpoint step k regenerates exactly the batches k, k+1, ... with no
loader state to restore, and elastically rescaled meshes re-slice the same
global batch. Two backends:

  * SyntheticDataset -- PRNG token streams (CI, dry-runs, perf work).
  * MemmapDataset    -- flat .bin token file, deterministic strided reads
                        (the "real corpus" path; packing = contiguous).
"""
from __future__ import annotations

import os
from typing import Any, Dict

import numpy as np

from repro.launch.shapes import ShapeSpec
from repro.models.config import ModelConfig


class SyntheticDataset:
    def __init__(self, cfg: ModelConfig, shape: ShapeSpec, seed: int = 0):
        self.cfg, self.shape, self.seed = cfg, shape, seed

    def batch(self, step: int) -> Dict[str, Any]:
        cfg, shape = self.cfg, self.shape
        rng = np.random.default_rng((self.seed, step))
        B, S = shape.batch, shape.seq
        out: Dict[str, Any] = {}
        if cfg.family == "vlm":
            P = cfg.vlm_patches
            toks = rng.integers(0, cfg.vocab_size, (B, S - P + 1), dtype=np.int32)
            out["tokens"], out["labels"] = toks[:, :-1], toks[:, 1:]
            out["patch_embeds"] = rng.standard_normal(
                (B, P, cfg.d_model)).astype(np.float32)
            out["positions"] = np.broadcast_to(
                np.arange(S, dtype=np.int32), (3, B, S)).copy()
        else:
            toks = rng.integers(0, cfg.vocab_size, (B, S + 1), dtype=np.int32)
            out["tokens"], out["labels"] = toks[:, :-1], toks[:, 1:]
        if cfg.is_encdec:
            out["frames"] = rng.standard_normal(
                (B, cfg.encoder_seq, cfg.d_model)).astype(np.float32)
        return out


class MemmapDataset:
    """Flat int32 token file; batch(step) takes deterministic strided
    windows so every step maps to a fixed corpus slice."""

    def __init__(self, cfg: ModelConfig, shape: ShapeSpec, path: str):
        self.cfg, self.shape = cfg, shape
        self.tokens = np.memmap(path, dtype=np.int32, mode="r")
        self.ntok = len(self.tokens)

    def batch(self, step: int) -> Dict[str, Any]:
        B, S = self.shape.batch, self.shape.seq
        need = S + 1
        starts = (np.arange(B, dtype=np.int64) * self.ntok // B
                  + step * need) % max(self.ntok - need, 1)
        toks = np.stack([np.asarray(self.tokens[s:s + need]) for s in starts])
        toks = toks % self.cfg.vocab_size
        return {"tokens": toks[:, :-1].astype(np.int32),
                "labels": toks[:, 1:].astype(np.int32)}


def write_synthetic_corpus(path: str, ntok: int, vocab: int, seed: int = 0):
    rng = np.random.default_rng(seed)
    arr = rng.integers(0, vocab, ntok, dtype=np.int32)
    arr.tofile(path)
    return path
