from repro.data.pipeline import SyntheticDataset, MemmapDataset  # noqa: F401
