"""Logical-axis sharding: one rules table maps model-level axis names to
mesh axes; models annotate activations/params with logical names only.

Mesh layout (DESIGN.md section 4):
  multi-pod: (pod, data, model) = (2, 16, 16)   single-pod: (data, model)

Default rules:
  batch   -> (pod, data)        FSDP/DP axes
  fsdp    -> (pod, data)        parameter & optimizer-state sharding (ZeRO-3)
  heads/kv/dff/vocab/experts -> model   (tensor / expert parallel)
  embed/seq -> replicated (overridable per launch config, e.g. long-context
  decode shards the KV-cache sequence dim)

No mesh context set (CPU smoke tests) -> every constraint is an identity.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Dict, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

Axis = Union[None, str, Tuple[str, ...]]

_state = threading.local()

DEFAULT_RULES: Dict[str, Axis] = {
    "batch": ("pod", "data"),
    "moebatch": ("pod", "data"),  # batch dim of MoE dispatch tensors; serve
                                  # rules set it None so 'experts' wins the
                                  # data axis and dispatch goes all-to-all
    "fsdp": ("pod", "data"),
    "heads": "model",
    "kv": "model",
    "dff": "model",
    "vocab": "model",
    "experts": "model",
    "embed": None,
    "seq": None,
    "seqpar": None,   # residual-stream sequence parallelism (opt-in)
    "kvseq": None,
    "state": None,
    "layers": None,
}


def _ctx():
    if not hasattr(_state, "mesh"):
        _state.mesh = None
        _state.rules = dict(DEFAULT_RULES)
    return _state


@contextlib.contextmanager
def sharding_rules(mesh: Optional[Mesh], overrides: Optional[Dict[str, Axis]] = None):
    st = _ctx()
    prev = (st.mesh, st.rules)
    st.mesh = mesh
    st.rules = dict(DEFAULT_RULES)
    if overrides:
        st.rules.update(overrides)
    try:
        yield
    finally:
        st.mesh, st.rules = prev


def _resolve_axis(mesh: Mesh, logical: Optional[str]) -> Axis:
    if logical is None:
        return None
    st = _ctx()
    ax = st.rules.get(logical, None)
    if ax is None:
        return None
    axes = (ax,) if isinstance(ax, str) else tuple(ax)
    present = tuple(a for a in axes if a in mesh.axis_names)
    if not present:
        return None
    return present if len(present) > 1 else present[0]


def resolve_spec(logical_axes: Sequence[Optional[str]], mesh: Optional[Mesh] = None) -> P:
    mesh = mesh if mesh is not None else _ctx().mesh
    if mesh is None:
        return P()
    return P(*(_resolve_axis(mesh, a) for a in logical_axes))


def constrain(x: jnp.ndarray, *logical_axes: Optional[str]) -> jnp.ndarray:
    """Annotate activation sharding by logical axis names (no-op w/o mesh).

    Divisibility guard: any mesh axis that does not evenly divide the
    corresponding dim is dropped from the constraint (e.g. batch=1
    long-context decode)."""
    mesh = _ctx().mesh
    if mesh is None:
        return x
    assert len(logical_axes) == x.ndim, (logical_axes, x.shape)
    parts = _build_parts(mesh, logical_axes, x.shape)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, P(*parts)))


def _build_parts(mesh: Mesh, logical_axes, shape):
    """Resolve logical axes -> mesh axes with (a) the divisibility guard and
    (b) first-occurrence-wins de-duplication (a mesh axis may shard at most
    one dim; e.g. MoE maps both 'experts' and 'dff' to 'model' -- the
    earlier dim takes it, expert-parallel over ffn-parallel)."""
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    used = set()
    parts = []
    for dim, a in zip(shape, logical_axes):
        r = _resolve_axis(mesh, a)
        if r is None:
            parts.append(None)
            continue
        axes = (r,) if isinstance(r, str) else r
        keep = []
        total = 1
        for ax in axes:
            if ax not in used and dim % (total * sizes[ax]) == 0:
                keep.append(ax)
                used.add(ax)
                total *= sizes[ax]
        parts.append(tuple(keep) if len(keep) > 1 else (keep[0] if keep else None))
    return parts


def make_resolver(mesh: Mesh):
    """Returns ``one(spec, shape) -> NamedSharding`` applying the rules
    table, the divisibility guard, and mesh-axis de-duplication."""
    def one(spec, shape):
        return NamedSharding(mesh, P(*_build_parts(mesh, spec, shape)))
    return one


def current_mesh() -> Optional[Mesh]:
    return _ctx().mesh
