"""Manual collectives for distributed-optimization tricks.

``int8_ring_all_reduce``: XLA's all-reduce runs in the tensor dtype, so
f32 gradients cross the (slow, cross-pod) link at 4 bytes/element. With
error-feedback int8 compression (optim.adamw.compress_grads) the payload
is int8-representable; this shard_map ring moves int8 + one f32 scale per
hop and accumulates in f32 -- a 4x cut of cross-pod gradient traffic.
Validated numerically in tests on a host-device mesh.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from jax.experimental.shard_map import shard_map


def _ring_body(x_local: jnp.ndarray, axis: str):
    """x_local: this shard's (already int8-compressed values as f32)
    contribution. Ring-reduce over `axis` with int8 payload per hop."""
    # jax.lax.axis_size only exists in newer jax; psum(1) is the portable
    # spelling of "number of shards on this axis"
    n = int(jax.lax.psum(1, axis))
    idx = jax.lax.axis_index(axis)
    perm = [(i, (i + 1) % n) for i in range(n)]

    def quant(v):
        s = jnp.maximum(jnp.max(jnp.abs(v)), 1e-12) / 127.0
        q = jnp.clip(jnp.round(v / s), -127, 127).astype(jnp.int8)
        return q, s

    def body(i, carry):
        acc, send = carry
        q, s = quant(send)
        q = jax.lax.ppermute(q, axis, perm)
        s = jax.lax.ppermute(s, axis, perm)
        recv = q.astype(jnp.float32) * s
        return acc + recv, recv

    acc, _ = jax.lax.fori_loop(0, n - 1, body, (x_local, x_local))
    return acc


def int8_ring_all_reduce(contribs: jnp.ndarray, mesh: Mesh, axis: str) -> jnp.ndarray:
    """Ring all-reduce with int8 wire format over one mesh axis.

    contribs: (n, ...) with the leading dim sharded over ``axis`` -- each
    shard's local summand. Returns (n, ...) where every row is the ring
    sum as accumulated at that shard (f32 accumulation, int8 payload).
    This is the demonstration ring (store-and-forward); the
    bandwidth-optimal variant (reduce-scatter + all-gather in int8) swaps
    the loop body, not the wire format."""

    @functools.partial(
        shard_map, mesh=mesh,
        in_specs=P(axis),
        out_specs=P(axis),
        check_rep=False,
    )
    def run(xs):
        red = _ring_body(xs[0], axis)
        return red[None]

    return run(contribs)
