from repro.models.config import ModelConfig  # noqa: F401
from repro.models.lm import (  # noqa: F401
    init_lm,
    lm_decode_step,
    lm_forward,
    lm_loss,
    lm_param_specs,
    lm_prefill,
)
