"""Model assembly: superblock-scanned LM covering all ten architectures.

A config's ``groups`` is a list of (pattern, repeats); each pattern is a
superblock of layer kinds. Parameters for each position in the pattern are
stacked over ``repeats`` and the whole group runs as one ``lax.scan`` --
126-layer models trace a single superblock body. Heterogeneous stacks
(zamba2, llama4) are exactly why the superblock abstraction exists.

Entry points:
    init_lm / lm_param_specs     parameters + logical sharding tree
    lm_loss                      training forward + CE (+ MoE aux)
    lm_prefill                   forward returning logits + KV/state caches
    lm_decode_step               single-token decode on the caches
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.wquant import dequant_tree
from repro.distributed.sharding import constrain
from repro.models import attention as A
from repro.models import mlp as M
from repro.models import rwkv as R
from repro.models import ssm as S
from repro.models.common import apply_norm, dense_init, init_norm, sinusoidal_positions
from repro.models.config import ModelConfig

# --------------------------------------------------------------- per-kind
_KIND_HAS_ATTN = {"attn": True, "moe": True, "xattn": True, "enc_attn": True,
                  "mamba": False, "rwkv": False}


def _init_block(key, cfg: ModelConfig, kind: str):
    ks = jax.random.split(key, 8)
    d = cfg.d_model
    if kind in ("attn", "moe", "enc_attn"):
        p = {"norm1": init_norm(cfg, d), "attn": A.init_attention(ks[0], cfg),
             "norm2": init_norm(cfg, d)}
        p["moe" if kind == "moe" else "mlp"] = (
            M.init_moe(ks[1], cfg) if kind == "moe" else M.init_mlp(ks[1], cfg))
        return p
    if kind == "xattn":
        return {"norm1": init_norm(cfg, d), "attn": A.init_attention(ks[0], cfg),
                "norm_x": init_norm(cfg, d), "xattn": A.init_attention(ks[1], cfg, cross=True),
                "norm2": init_norm(cfg, d), "mlp": M.init_mlp(ks[2], cfg)}
    if kind == "mamba":
        return {"norm1": init_norm(cfg, d), "mamba": S.init_mamba(ks[0], cfg)}
    if kind == "rwkv":
        return {"norm1": init_norm(cfg, d), "tmix": R.init_rwkv_tmix(ks[0], cfg),
                "norm2": init_norm(cfg, d), "cmix": R.init_rwkv_cmix(ks[1], cfg)}
    raise ValueError(kind)


def _block_specs(cfg: ModelConfig, kind: str):
    n1 = {"scale": (None,)} if cfg.norm == "rmsnorm" else {"scale": (None,), "bias": (None,)}
    if kind in ("attn", "moe", "enc_attn"):
        p = {"norm1": dict(n1), "attn": A.attention_specs(cfg), "norm2": dict(n1)}
        p["moe" if kind == "moe" else "mlp"] = (
            M.moe_specs(cfg) if kind == "moe" else M.mlp_specs(cfg))
        return p
    if kind == "xattn":
        return {"norm1": dict(n1), "attn": A.attention_specs(cfg),
                "norm_x": dict(n1), "xattn": A.attention_specs(cfg, cross=True),
                "norm2": dict(n1), "mlp": M.mlp_specs(cfg)}
    if kind == "mamba":
        return {"norm1": dict(n1), "mamba": S.mamba_specs(cfg)}
    if kind == "rwkv":
        return {"norm1": dict(n1), "tmix": R.rwkv_tmix_specs(cfg),
                "norm2": dict(n1), "cmix": R.rwkv_cmix_specs(cfg)}
    raise ValueError(kind)


def _apply_block_train(cfg, kind, p, x, positions, enc_out, want_cache: bool):
    """Full-seq block. Returns (x, aux, cache_tree_or_None)."""
    cache = None
    aux = jnp.zeros((), jnp.float32)
    if kind in ("attn", "moe", "enc_attn"):
        h = apply_norm(cfg, p["norm1"], x)
        causal = kind != "enc_attn"
        if want_cache and causal:
            y, (ck, cv) = A.apply_attention(cfg, p["attn"], h, positions,
                                            causal=True, return_kv=True)
            cache = {"k": ck, "v": cv}
        else:
            y = A.apply_attention(cfg, p["attn"], h, positions, causal=causal)
        x = x + y
        h = apply_norm(cfg, p["norm2"], x)
        if kind == "moe":
            y, aux = M.apply_moe(cfg, p["moe"], h)
        else:
            y = M.apply_mlp(cfg, p["mlp"], h)
        x = x + y
    elif kind == "xattn":
        h = apply_norm(cfg, p["norm1"], x)
        if want_cache:
            y, (ck, cv) = A.apply_attention(cfg, p["attn"], h, positions,
                                            causal=True, return_kv=True)
        else:
            y = A.apply_attention(cfg, p["attn"], h, positions, causal=True)
        x = x + y
        h = apply_norm(cfg, p["norm_x"], x)
        xkv = A.cross_kv(cfg, p["xattn"], enc_out)
        x = x + A.apply_cross_attention(cfg, p["xattn"], h, xkv)
        if want_cache:
            cache = {"k": ck, "v": cv, "xk": xkv[0], "xv": xkv[1]}
        h = apply_norm(cfg, p["norm2"], x)
        x = x + M.apply_mlp(cfg, p["mlp"], h)
    elif kind == "mamba":
        h = apply_norm(cfg, p["norm1"], x)
        if want_cache:
            y, st = S.apply_mamba(cfg, p["mamba"], h, return_state=True)
            cache = {"ssm": st.ssm, "conv_x": st.conv_x, "conv_bc": st.conv_bc}
        else:
            y = S.apply_mamba(cfg, p["mamba"], h)
        x = x + y
    elif kind == "rwkv":
        h = apply_norm(cfg, p["norm1"], x)
        if want_cache:
            y, (st, xp) = R.apply_rwkv_tmix(cfg, p["tmix"], h, return_state=True)
            cache = {"S": st, "xp_t": xp}
        else:
            y = R.apply_rwkv_tmix(cfg, p["tmix"], h)
        x = x + y
        h = apply_norm(cfg, p["norm2"], x)
        if want_cache:
            y, xpc = R.apply_rwkv_cmix(cfg, p["cmix"], h, return_state=True)
            cache["xp_c"] = xpc
        else:
            y = R.apply_rwkv_cmix(cfg, p["cmix"], h)
        x = x + y
    else:
        raise ValueError(kind)
    return x, aux, cache


def _apply_block_decode(cfg, kind, p, x, cache, cache_pos, positions, enc_out):
    """Single-token block step. Returns (x, new_cache)."""
    if kind in ("attn", "moe"):
        h = apply_norm(cfg, p["norm1"], x)
        y, ck, cv = A.decode_attention(cfg, p["attn"], h, cache["k"], cache["v"],
                                       cache_pos, positions)
        x = x + y
        new = {"k": ck, "v": cv}
        h = apply_norm(cfg, p["norm2"], x)
        if kind == "moe":
            y, _ = M.apply_moe(cfg, p["moe"], h)
        else:
            y = M.apply_mlp(cfg, p["mlp"], h)
        x = x + y
    elif kind == "xattn":
        h = apply_norm(cfg, p["norm1"], x)
        y, ck, cv = A.decode_attention(cfg, p["attn"], h, cache["k"], cache["v"],
                                       cache_pos, positions)
        x = x + y
        h = apply_norm(cfg, p["norm_x"], x)
        x = x + A.apply_cross_attention(cfg, p["xattn"], h, (cache["xk"], cache["xv"]))
        new = {"k": ck, "v": cv, "xk": cache["xk"], "xv": cache["xv"]}
        h = apply_norm(cfg, p["norm2"], x)
        x = x + M.apply_mlp(cfg, p["mlp"], h)
    elif kind == "mamba":
        h = apply_norm(cfg, p["norm1"], x)
        st = S.MambaState(cache["ssm"], cache["conv_x"], cache["conv_bc"])
        y, st = S.decode_mamba(cfg, p["mamba"], h, st)
        x = x + y
        new = {"ssm": st.ssm, "conv_x": st.conv_x, "conv_bc": st.conv_bc}
    elif kind == "rwkv":
        h = apply_norm(cfg, p["norm1"], x)
        y, (st, xp) = R.decode_rwkv_tmix(cfg, p["tmix"], h, (cache["S"], cache["xp_t"]))
        x = x + y
        h = apply_norm(cfg, p["norm2"], x)
        y, xpc = R.decode_rwkv_cmix(cfg, p["cmix"], h, cache["xp_c"])
        x = x + y
        new = {"S": st, "xp_t": xp, "xp_c": xpc}
    else:
        raise ValueError(kind)
    return x, new


# ----------------------------------------------------------------- stacks
def _init_group(key, cfg, pattern, repeats):
    ks = jax.random.split(key, len(pattern))
    g = {}
    for j, kind in enumerate(pattern):
        g[f"p{j}"] = jax.vmap(lambda k, kd=kind: _init_block(k, cfg, kd))(
            jax.random.split(ks[j], repeats))
    return g


def _group_specs(cfg, pattern):
    return {f"p{j}": jax.tree.map(lambda t: ("layers",) + t,
                                  _block_specs(cfg, kind),
                                  is_leaf=lambda t: isinstance(t, tuple))
            for j, kind in enumerate(pattern)}


def _maybe_remat(cfg, fn):
    if cfg.remat == "none":
        return fn
    policy = (jax.checkpoint_policies.nothing_saveable if cfg.remat == "full"
              else jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    return jax.checkpoint(fn, policy=policy)


def _dequant_layer(cfg, lp, specs, dtype):
    """Dequantize a layer slice. Quantized weights are first constrained
    with their fsdp dims dropped, forcing GSPMD to all-gather the 1-byte
    tensor and dequantize shard-locally -- weight wire traffic stays
    1 byte/elem.

    QTensor leaves at quant_dot CONSUMER sites (down-projection weights,
    when the config's rotation-quantization matches their storage mode)
    are kept quantized: the spec-bound quant_dot in the block consumes
    q/scale directly, so the serving forward never re-quantizes (or even
    dequantizes) those weights per step."""
    from repro.core.wquant import _is_consumer, is_qleaf

    qc = cfg.quant

    def keep(keys, p) -> bool:
        return (qc.rotating and qc.enabled and p.mode == qc.mode
                and _is_consumer(keys))

    def one(spec_or_sub, p, keys):
        if is_qleaf(p):
            if keep(keys, p):
                return p
            spec = spec_or_sub.q if is_qleaf(spec_or_sub) else spec_or_sub
            gather_spec = tuple(None if a == "fsdp" else a for a in spec[1:])
            wq = constrain(p.q, *gather_spec)
            return (wq.astype(jnp.float32) * p.scale).astype(dtype)
        if isinstance(p, dict):
            return {k: one(spec_or_sub[k] if isinstance(spec_or_sub, dict) else spec_or_sub,
                           v, keys + (k,)) for k, v in p.items()}
        return p

    return {k: one(specs[k], v, (k,)) for k, v in lp.items()}


def _run_stack(cfg, groups_cfg, gparams, x, positions, enc_out,
               want_cache: bool):
    """Scan every group; returns (x, aux_total, caches or None)."""
    aux_total = jnp.zeros((), jnp.float32)
    caches = []
    for (pattern, repeats), gp in zip(groups_cfg, gparams):
        gspecs = _group_specs(cfg, pattern) if cfg.weight_quant == "int8" else None
        def body(x, layer_params, _pattern=pattern):
            aux_sb = jnp.zeros((), jnp.float32)
            cache_out = {}
            for j, kind in enumerate(_pattern):
                x, aux, cache = _apply_block_train(
                    cfg, kind, layer_params[f"p{j}"], x, positions, enc_out,
                    want_cache)
                aux_sb = aux_sb + aux
                if want_cache:
                    cache_out[f"p{j}"] = cache
            return x, (aux_sb, cache_out)

        body = _maybe_remat(cfg, body)

        def scan_body(carry, lp):
            x = carry
            # int8-stored weights dequantize HERE -- after the per-layer
            # slice is fetched/gathered, so FSDP wire traffic stays int8
            if gspecs is not None:
                lp = _dequant_layer(cfg, lp, gspecs, x.dtype)
            else:
                lp = dequant_tree(lp, x.dtype)
            x, (aux, cache) = body(x, lp)
            # Megatron-SP style: the residual stream carried between layers
            # (and saved for the backward scan) can be sequence-sharded over
            # the TP axis -- rules override {"seqpar": "model"}. Activations
            # are gathered inside the block where attention needs full seq.
            x = constrain(x, "batch", "seqpar", None)
            return x, (aux, cache)

        x, (auxes, cache_stack) = jax.lax.scan(scan_body, x, gp)
        aux_total = aux_total + auxes.sum()
        caches.append(cache_stack if want_cache else None)
    return x, aux_total, caches


# ------------------------------------------------------------------ model
def init_lm(key, cfg: ModelConfig):
    dt = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 4 + len(cfg.groups) + len(cfg.encoder_groups))
    params: Dict[str, Any] = {
        "emb": dense_init(ks[0], cfg.padded_vocab, cfg.d_model, dt, scale=0.02),
        "final_norm": init_norm(cfg, cfg.d_model),
    }
    if not cfg.tie_embeddings:
        params["unemb"] = dense_init(ks[1], cfg.d_model, cfg.padded_vocab, dt)
    params["groups"] = [
        _init_group(ks[4 + i], cfg, pat, rep)
        for i, (pat, rep) in enumerate(cfg.groups)]
    if cfg.is_encdec:
        params["enc_groups"] = [
            _init_group(ks[4 + len(cfg.groups) + i], cfg, pat, rep)
            for i, (pat, rep) in enumerate(cfg.encoder_groups)]
        params["enc_norm"] = init_norm(cfg, cfg.d_model)
    return params


def lm_param_specs(cfg: ModelConfig):
    n1 = {"scale": (None,)} if cfg.norm == "rmsnorm" else {"scale": (None,), "bias": (None,)}
    specs: Dict[str, Any] = {
        "emb": ("vocab", "embed"),
        "final_norm": dict(n1),
    }
    if not cfg.tie_embeddings:
        specs["unemb"] = ("embed", "vocab")
    specs["groups"] = [_group_specs(cfg, pat) for pat, _ in cfg.groups]
    if cfg.is_encdec:
        specs["enc_groups"] = [_group_specs(cfg, pat) for pat, _ in cfg.encoder_groups]
        specs["enc_norm"] = dict(n1)
    return specs


def _embed_inputs(cfg, params, batch):
    """Build (x, positions) for the decoder stack from the input batch."""
    tokens = batch["tokens"]                       # (B, S_tok)
    emb = dequant_tree(params["emb"], jnp.dtype(cfg.dtype))
    x = jnp.take(emb, tokens, axis=0)
    if cfg.family == "vlm" and "patch_embeds" in batch:
        pe = batch["patch_embeds"].astype(x.dtype)  # (B, P, d)
        x = jnp.concatenate([pe, x], axis=1)
    B, St = x.shape[0], x.shape[1]
    if cfg.mrope:
        positions = batch["positions"]             # (3, B, S)
    else:
        positions = jnp.broadcast_to(jnp.arange(St, dtype=jnp.int32)[None], (B, St))
    if cfg.is_encdec:
        x = x + sinusoidal_positions(St, cfg.d_model).astype(x.dtype)[None]
    x = constrain(x, "batch", "seq", None)
    return x, positions


def _run_encoder(cfg, params, frames):
    """Whisper encoder on precomputed frame embeddings (conv frontend stub)."""
    B, T, _ = frames.shape
    x = frames + sinusoidal_positions(T, cfg.d_model).astype(frames.dtype)[None]
    pos = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32)[None], (B, T))
    x, _, _ = _run_stack(cfg, cfg.encoder_groups, params["enc_groups"], x, pos,
                         None, want_cache=False)
    return apply_norm(cfg, params["enc_norm"], x)


def _logits(cfg, params, x):
    x = apply_norm(cfg, params["final_norm"], x)
    w = dequant_tree(params["emb"] if cfg.tie_embeddings else params["unemb"],
                     x.dtype)
    logits = x @ (w.T if cfg.tie_embeddings else w)
    if cfg.padded_vocab != cfg.vocab_size:
        valid = jnp.arange(cfg.padded_vocab, dtype=jnp.int32) < cfg.vocab_size
        logits = jnp.where(valid, logits, jnp.asarray(-jnp.inf, logits.dtype))
    return constrain(logits, "batch", "seq", "vocab")


def lm_forward(cfg: ModelConfig, params, batch, want_cache: bool = False):
    enc_out = None
    if cfg.is_encdec:
        enc_out = _run_encoder(cfg, params, batch["frames"].astype(jnp.dtype(cfg.dtype)))
    x, positions = _embed_inputs(cfg, params, batch)
    x, aux, caches = _run_stack(cfg, cfg.groups, params["groups"], x, positions,
                                enc_out, want_cache)
    return _logits(cfg, params, x), aux, caches


def lm_loss(cfg: ModelConfig, params, batch) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    logits, aux, _ = lm_forward(cfg, params, batch)
    labels = batch["labels"]
    if cfg.family == "vlm" and "patch_embeds" in batch:
        P = batch["patch_embeds"].shape[1]
        logits = logits[:, P:]
    lf = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(lf, axis=-1)
    ll = jnp.take_along_axis(lf, labels[..., None], axis=-1)[..., 0]
    mask = (labels >= 0).astype(jnp.float32)
    ce = ((lse - ll) * mask).sum() / jnp.maximum(mask.sum(), 1.0)
    loss = ce + 0.01 * aux
    return loss, {"ce": ce, "aux": aux}


def lm_prefill(cfg: ModelConfig, params, batch):
    """Forward pass returning (last-position logits, caches, enc_out)."""
    logits, _, caches = lm_forward(cfg, params, batch, want_cache=True)
    return logits[:, -1:], caches


def pad_kv_caches(cfg, caches, max_len: int):
    """Grow attention K/V caches along seq to max_len for generation."""
    out = []
    for cache_stack in caches:
        new = {}
        for k, tree in cache_stack.items():
            if tree is not None and "k" in tree:
                t = dict(tree)
                for key in ("k", "v"):
                    arr = t[key]
                    pad = max_len - arr.shape[2]
                    if pad > 0:
                        t[key] = jnp.pad(arr, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
                new[k] = t
            else:
                new[k] = tree
        out.append(new)
    return out


def lm_decode_step(cfg: ModelConfig, params, caches, tokens, cache_pos):
    """One decode step. tokens: (B,1) int32; cache_pos: () int32 (number of
    tokens already in the cache, shared by the whole batch) OR (B,) int32
    per-slot positions -- the continuous-batching form, where every batch
    row is an independent request slot at its own depth (serving.engine).
    Returns (logits, new_caches)."""
    emb = dequant_tree(params["emb"], jnp.dtype(cfg.dtype))
    x = jnp.take(emb, tokens, axis=0)
    B = x.shape[0]
    if cfg.is_encdec:
        x = x + sinusoidal_positions(1, cfg.d_model).astype(x.dtype)[None]
    if cache_pos.ndim == 1:
        pos = cache_pos[:, None].astype(jnp.int32)     # (B,1) per-slot
    else:
        pos = jnp.broadcast_to(cache_pos[None, None], (B, 1)).astype(jnp.int32)
    if cfg.mrope:
        positions = jnp.broadcast_to(pos[None], (3, B, 1))
    else:
        positions = pos
    x = constrain(x, "batch", "seq", None)

    new_caches = []
    for (pattern, repeats), gp, cache_stack in zip(cfg.groups, params["groups"], caches):
        gspecs = _group_specs(cfg, pattern) if cfg.weight_quant == "int8" else None

        def body(x, inp, _pattern=pattern, _gspecs=gspecs):
            lp, lc = inp
            if _gspecs is not None:
                lp = _dequant_layer(cfg, lp, _gspecs, x.dtype)
            else:
                lp = dequant_tree(lp, x.dtype)
            new_c = {}
            for j, kind in enumerate(_pattern):
                x, nc = _apply_block_decode(cfg, kind, lp[f"p{j}"], x,
                                            lc[f"p{j}"], cache_pos, positions, None)
                new_c[f"p{j}"] = nc
            return x, new_c

        x, new_stack = jax.lax.scan(body, x, (gp, cache_stack))
        new_caches.append(new_stack)
    return _logits(cfg, params, x), new_caches
