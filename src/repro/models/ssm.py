"""Mamba2 (SSD) mixer for the zamba2 hybrid architecture.

Training/prefill uses the chunked SSD algorithm: scalar-per-head decay
makes the pairwise intra-chunk decay matrix exact and stable in log space,
and every term is an MXU matmul (the TPU-friendly formulation). Decode is
the exact O(1)-per-token recurrence on the (P, N) state.

Recurrence (per head, state S in R^{P x N}):
    S_t = exp(dt_t * A) * S_{t-1} + dt_t * x_t (x) B_t
    y_t = S_t C_t + D * x_t
"""
from __future__ import annotations

import math
from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.distributed.sharding import constrain
from repro.models.common import dense_init

_CONV_W = 4
_CHUNK = 128


def _dims(cfg):
    d_inner = cfg.ssm_expand * cfg.d_model
    P = cfg.ssm_head_dim
    H = d_inner // P
    N = cfg.ssm_state
    return d_inner, H, P, N


def init_mamba(key, cfg):
    d = cfg.d_model
    d_inner, H, P, N = _dims(cfg)
    dt = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 8)
    return {
        "w_zx": dense_init(ks[0], d, 2 * d_inner, dt),
        "w_bcdt": dense_init(ks[1], d, 2 * N + H, dt),
        "conv_x": (jax.random.normal(ks[2], (_CONV_W, d_inner), jnp.float32) * 0.2).astype(dt),
        "conv_bc": (jax.random.normal(ks[3], (_CONV_W, 2 * N), jnp.float32) * 0.2).astype(dt),
        "A_log": jnp.zeros((H,), jnp.float32),
        "D": jnp.ones((H,), jnp.float32),
        "dt_bias": jnp.full((H,), math.log(math.e - 1), jnp.float32),  # softplus^-1(1)
        "norm": jnp.ones((d_inner,), jnp.float32),
        "w_out": dense_init(ks[4], d_inner, d, dt, scale=1.0 / math.sqrt(d_inner)),
    }


def mamba_specs(cfg):
    return {
        "w_zx": ("fsdp", "dff"),
        "w_bcdt": ("fsdp", None),
        "conv_x": (None, "dff"),
        "conv_bc": (None, None),
        "A_log": (None,),
        "D": (None,),
        "dt_bias": (None,),
        "norm": ("dff",),
        "w_out": ("dff", "fsdp"),
    }


def _split_proj(cfg, p, x):
    d_inner, H, P, N = _dims(cfg)
    zx = x @ p["w_zx"]
    z, xs = zx[..., :d_inner], zx[..., d_inner:]
    bcdt = x @ p["w_bcdt"]
    b = bcdt[..., :N]
    c = bcdt[..., N:2 * N]
    dt_raw = bcdt[..., 2 * N:]
    return z, xs, b, c, dt_raw


def _causal_depthwise(x, w):
    """x: (B,S,C), w: (W,C) -> causal depthwise conv, silu activation."""
    W = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (W - 1, 0), (0, 0)))
    y = sum(xp[:, i:i + x.shape[1], :] * w[i] for i in range(W))
    return jax.nn.silu(y)


class MambaState(NamedTuple):
    ssm: jnp.ndarray      # (B, H, P, N) f32
    conv_x: jnp.ndarray   # (B, W-1, d_inner)
    conv_bc: jnp.ndarray  # (B, W-1, 2N)


def init_mamba_state(cfg, batch: int, dtype=jnp.float32) -> MambaState:
    d_inner, H, P, N = _dims(cfg)
    return MambaState(
        ssm=jnp.zeros((batch, H, P, N), jnp.float32),
        conv_x=jnp.zeros((batch, _CONV_W - 1, d_inner), dtype),
        conv_bc=jnp.zeros((batch, _CONV_W - 1, 2 * N), dtype),
    )


def apply_mamba(cfg, p, x, *, return_state: bool = False):
    """Full-sequence chunked SSD. x: (B,S,d)."""
    B, S, d = x.shape
    d_inner, H, P, N = _dims(cfg)
    z, xs_raw, b_raw, c_raw, dt_raw = _split_proj(cfg, p, x)
    bc_raw = jnp.concatenate([b_raw, c_raw], -1)
    xs = _causal_depthwise(xs_raw, p["conv_x"])
    bc = _causal_depthwise(bc_raw, p["conv_bc"])
    b, c = bc[..., :N], bc[..., N:]

    Tc = _CHUNK if S % _CHUNK == 0 else (S if S < _CHUNK else None)
    if Tc is None:
        raise ValueError(f"seq {S} not divisible by chunk {_CHUNK}")
    nc = S // Tc

    xh = constrain(xs.reshape(B, nc, Tc, H, P), "batch", None, None, "heads", None).astype(jnp.float32)
    bv = b.reshape(B, nc, Tc, N).astype(jnp.float32)
    cv = c.reshape(B, nc, Tc, N).astype(jnp.float32)
    dtv = jax.nn.softplus(dt_raw.reshape(B, nc, Tc, H).astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])                                  # (H,) negative
    l = dtv * A                                               # (B,nc,Tc,H) log-decay
    L = jnp.cumsum(l, axis=2)                                 # inclusive cumsum

    # intra-chunk: W[t,j] = (C_t.B_j) exp(L_t - L_j) dt_j  (j<=t)
    cb = jnp.einsum("bctn,bcjn->bctj", cv, bv)                # (B,nc,Tc,Tc)
    diff = L[:, :, :, None, :] - L[:, :, None, :, :]          # (B,nc,Tc,Tc,H)
    mask = jnp.tril(jnp.ones((Tc, Tc), bool))
    M = jnp.where(mask[None, None, :, :, None], jnp.exp(diff), 0.0)
    W = cb[..., None] * M * dtv[:, :, None, :, :]             # (B,nc,t,j,H)
    y_intra = jnp.einsum("bctjh,bcjhp->bcthp", W, xh)

    # inter-chunk carry scan
    decay_in = jnp.exp(L)                                     # decay from chunk start
    kx = jnp.einsum("bcjh,bcjhp,bcjn->bchpn",
                    dtv * jnp.exp(L[:, :, -1:, :] - L), xh, bv)  # chunk state contribution
    chunk_decay = jnp.exp(L[:, :, -1, :])                     # (B,nc,H)

    def scan_fn(S0, inp):
        kxc, dc = inp                                         # (B,H,P,N), (B,H)
        S1 = S0 * dc[:, :, None, None] + kxc
        return S1, S0

    kx_t = jnp.moveaxis(kx, 1, 0)                             # (nc,B,H,P,N)
    dc_t = jnp.moveaxis(chunk_decay, 1, 0)                    # (nc,B,H)
    S0 = jnp.zeros((B, H, P, N), jnp.float32)
    S_last, S_starts = jax.lax.scan(scan_fn, S0, (kx_t, dc_t))
    S_starts = jnp.moveaxis(S_starts, 0, 1)                   # (B,nc,H,P,N)

    y_carry = jnp.einsum("bctn,bchpn,bcth->bcthp", cv, S_starts, decay_in)
    y = (y_intra + y_carry).reshape(B, S, H, P)
    y = y + p["D"][None, None, :, None] * xs.reshape(B, S, H, P).astype(jnp.float32)
    y = y.reshape(B, S, d_inner)

    # gated RMSNorm + out-proj
    y = y * jax.nn.silu(z.astype(jnp.float32))
    y = y * jax.lax.rsqrt(jnp.mean(jnp.square(y), -1, keepdims=True) + 1e-6) * p["norm"]
    out = constrain((y.astype(x.dtype) @ p["w_out"]), "batch", "seq", None)
    if return_state:
        state = MambaState(
            ssm=S_last,
            conv_x=_tail(xs_raw, x.dtype),
            conv_bc=_tail(bc_raw, x.dtype),
        )
        return out, state
    return out


def _tail(seq_bsd, dtype):
    """Last W-1 *pre-conv* inputs become the decode conv state."""
    return seq_bsd[:, -(_CONV_W - 1):, :].astype(dtype)


def decode_mamba(cfg, p, x, state: MambaState) -> Tuple[jnp.ndarray, MambaState]:
    """Single-token recurrent step. x: (B,1,d)."""
    B, S, d = x.shape
    d_inner, H, P, N = _dims(cfg)
    z, xs, b, c, dt_raw = _split_proj(cfg, p, x)

    # depthwise conv over [state, new token]
    cx = jnp.concatenate([state.conv_x, xs], axis=1)          # (B,W,dinner)
    xs1 = jax.nn.silu(jnp.einsum("bwc,wc->bc", cx, p["conv_x"]))[:, None, :]
    cbc = jnp.concatenate([state.conv_bc, jnp.concatenate([b, c], -1)], axis=1)
    bc1 = jax.nn.silu(jnp.einsum("bwc,wc->bc", cbc, p["conv_bc"]))[:, None, :]
    b1, c1 = bc1[..., :N], bc1[..., N:]

    dtv = jax.nn.softplus(dt_raw[:, 0].astype(jnp.float32) + p["dt_bias"])  # (B,H)
    A = -jnp.exp(p["A_log"])
    decay = jnp.exp(dtv * A)                                  # (B,H)
    xh = xs1[:, 0].reshape(B, H, P).astype(jnp.float32)
    S1 = state.ssm * decay[:, :, None, None] + jnp.einsum(
        "bh,bhp,bn->bhpn", dtv, xh, b1[:, 0].astype(jnp.float32))
    y = jnp.einsum("bhpn,bn->bhp", S1, c1[:, 0].astype(jnp.float32))
    y = y + p["D"][None, :, None] * xh
    y = y.reshape(B, 1, d_inner)
    y = y * jax.nn.silu(z.astype(jnp.float32))
    y = y * jax.lax.rsqrt(jnp.mean(jnp.square(y), -1, keepdims=True) + 1e-6) * p["norm"]
    out = y.astype(x.dtype) @ p["w_out"]
    new_state = MambaState(
        ssm=S1,
        conv_x=cx[:, 1:, :],
        conv_bc=cbc[:, 1:, :],
    )
    return out, new_state
