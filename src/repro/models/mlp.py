"""MLPs: SwiGLU / GELU dense blocks and top-k MoE, with the QuaRot online
Hadamard on the down-projection input -- the red "online rotation" block in
the paper's Fig. 1, and hadacore's primary insertion point.

MoE uses GShard-style capacity-factor dense dispatch (one-hot dispatch /
combine einsums): it shards cleanly under GSPMD (experts on the 'model'
axis when divisible, expert-ffn otherwise) and needs no ragged ops at
dry-run scale. All experts share one Hadamard (same d_ff), so the online
rotation is applied once to the dispatched activations.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.core.api import QuantDotSpec
from repro.distributed.sharding import constrain
from repro.models.common import dense_init

# Logical sharding axes of the down-projection weights -- the declarative
# half of the consumer spec: under a mesh the out-channel ('fsdp') axis
# folds into the quant_dot plan key and dispatch shards over it.
_DOWN_AXES = ("dff", "fsdp")
_EXPERT_DOWN_AXES = ("experts", "dff", "fsdp")


def _act(cfg, g):
    return jax.nn.silu(g) if cfg.act == "swiglu" else jax.nn.gelu(g)


# -------------------------------------------------------------------- dense
def init_mlp(key, cfg):
    d, f = cfg.d_model, cfg.d_ff
    dt = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 3)
    p = {"w_up": dense_init(ks[1], d, f, dt),
         "w_down": dense_init(ks[2], f, d, dt, scale=1.0 / math.sqrt(f))}
    if cfg.act == "swiglu":
        p["w_gate"] = dense_init(ks[0], d, f, dt)
    return p


def mlp_specs(cfg):
    p = {"w_up": ("fsdp", "dff"), "w_down": ("dff", "fsdp")}
    if cfg.act == "swiglu":
        p["w_gate"] = ("fsdp", "dff")
    return p


def apply_mlp(cfg, p, x):
    qc = cfg.quant
    h = _act(cfg, x @ p["w_gate"]) * (x @ p["w_up"]) if cfg.act == "swiglu" \
        else _act(cfg, x @ p["w_up"])
    h = constrain(h, "batch", "seq", "dff")
    # ---- the paper's online rotation: Hadamard on the down_proj input,
    # fused with the activation quantization AND the int8/fp8 down-proj
    # GEMM in one rotate-once quant_dot kernel when the plan supports it
    # (each row block is transformed exactly once and served to every
    # weight tile from VMEM scratch -- DESIGN.md section 8). The site is
    # declared as a spec and bound to the weight: a raw weight quantizes
    # on the fly (training), a pre-quantized QTensor is consumed directly
    # (serving -- zero per-forward weight quantization). Under a mesh the
    # dispatch shard_maps: activations row-sharded over the data axes,
    # weight columns + scales over 'fsdp', the fused kernel shard-local ----
    spec = QuantDotSpec.for_config(h.shape[-1], qc, weight_axes=_DOWN_AXES)
    y = spec.bind(p["w_down"])(h)
    return constrain(y, "batch", "seq", None)


# ---------------------------------------------------------------------- MoE
def init_moe(key, cfg):
    d, f, E = cfg.d_model, cfg.d_ff, cfg.num_experts
    dt = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 5)

    def expert(k):
        kk = jax.random.split(k, 3)
        return {"w_gate": dense_init(kk[0], d, f, dt),
                "w_up": dense_init(kk[1], d, f, dt),
                "w_down": dense_init(kk[2], f, d, dt, scale=1.0 / math.sqrt(f))}

    p = {"router": dense_init(ks[0], d, E, jnp.float32),
         "experts": jax.vmap(expert)(jax.random.split(ks[1], E))}
    if cfg.moe_shared_expert:
        p["shared"] = init_mlp(ks[2], cfg)
    return p


def moe_specs(cfg):
    p = {"router": ("fsdp", None),
         "experts": {"w_gate": ("experts", "fsdp", "dff"),
                     "w_up": ("experts", "fsdp", "dff"),
                     "w_down": ("experts", "dff", "fsdp")}}
    if cfg.moe_shared_expert:
        p["shared"] = mlp_specs(cfg)
    return p


def apply_moe(cfg, p, x):
    """x: (B,S,d). Top-k routing with capacity-factor dense dispatch."""
    B, S, d = x.shape
    E, K = cfg.num_experts, cfg.experts_per_token
    qc = cfg.quant
    cap = max(1, int(cfg.capacity_factor * S * K / E))

    logits = (x.astype(jnp.float32) @ p["router"])          # (B,S,E)
    gates = jax.nn.softmax(logits, axis=-1)
    topw, topi = jax.lax.top_k(gates, K)                    # (B,S,K)
    topw = topw / jnp.maximum(topw.sum(-1, keepdims=True), 1e-9)

    # expert assignment mask (B,S,K,E) and within-expert position via cumsum
    sel = jax.nn.one_hot(topi, E, dtype=jnp.float32)        # (B,S,K,E)
    flat = sel.reshape(B, S * K, E)
    pos = jnp.cumsum(flat, axis=1) - flat                   # tokens before me
    pos = pos.reshape(B, S, K, E)
    keep = sel * (pos < cap)                                # capacity dropping
    posc = jnp.clip(pos, 0, cap - 1).astype(jnp.int32)
    cap1h = jax.nn.one_hot(posc, cap, dtype=jnp.float32)    # (B,S,K,E,cap)
    dispatch = (keep[..., None] * cap1h).sum(2)             # (B,S,E,cap)
    combine = (keep * topw[..., None])[..., None] * cap1h   # (B,S,K,E,cap)
    combine = combine.sum(2)                                # (B,S,E,cap)

    xin = jnp.einsum("bsec,bsd->becd", dispatch.astype(x.dtype), x)
    xin = constrain(xin, "moebatch", "experts", None, None)
    we = p["experts"]
    g = jnp.einsum("becd,edf->becf", xin, we["w_gate"])
    u = jnp.einsum("becd,edf->becf", xin, we["w_up"])
    h = _act(cfg, g) * u
    h = constrain(h, "moebatch", "experts", None, "dff")
    # shared online Hadamard (all experts share d_ff) + REAL int8/fp8
    # expert down-proj with int32/f32 accumulation -- no f32 fake-quant
    # on the hot path. Off-mesh this is ONE 3-D rotate-once pallas
    # kernel (rotation + quantize + every expert's contraction, no HBM
    # round trip of (q, scales) -- DESIGN.md section 8); under a mesh
    # the einsum form runs and shards under GSPMD (not the 2-D
    # shard_map dispatch). Pre-quantized QTensor expert weights
    # (per-(expert, out-channel) scales) are consumed directly;
    # weight_axes here is declarative metadata for the site.
    spec = QuantDotSpec.for_config(h.shape[-1], qc,
                                   weight_axes=_EXPERT_DOWN_AXES)
    yout = spec.bind_experts(we["w_down"])(h)
    y = jnp.einsum("bsec,becd->bsd", combine.astype(x.dtype), yout)
    y = constrain(y, "batch", "seq", None)

    if cfg.moe_shared_expert:
        y = y + apply_mlp(cfg, p["shared"], x)
    # load-balancing auxiliary loss (Switch-style), returned for training
    density = sel.sum(2).mean(axis=(0, 1))                  # (E,)
    router_prob = gates.mean(axis=(0, 1))
    aux = E * jnp.sum(density * router_prob)
    return y, aux
