"""GQA / sliding-window / cross attention with QuaRot-style rotation hooks.

The paper's end-to-end deployment (section 4.2): FP8 attention where Q and K are
Hadamard-rotated per head before quantization -- the rotation commutes out
of the QK^T product exactly (H H^T = I) while crushing per-head outliers,
and V's rotation is fused offline into (W_v, W_o) so it is free.

Online rotation points in this module (cfg.quant.rotating):
    q_r = had(q), k_r = had(k)      after RoPE, before quantize + cache
which is exactly where hadacore runs in the paper's Llama FP8 pipeline.
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.api import RotationSpec
from repro.distributed.sharding import constrain
from repro.models.common import apply_rope_angles, dense_init, mrope_angles, rope_freqs


# ------------------------------------------------------------------- params
def init_attention(key, cfg, cross: bool = False):
    d, H, KH, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    dt = jnp.dtype(cfg.dtype)
    p = {
        "wq": dense_init(ks[0], d, H * hd, dt),
        "wk": dense_init(ks[1], d, KH * hd, dt),
        "wv": dense_init(ks[2], d, KH * hd, dt),
        "wo": dense_init(ks[3], H * hd, d, dt, scale=1.0 / math.sqrt(H * hd)),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((H * hd,), dt)
        p["bk"] = jnp.zeros((KH * hd,), dt)
        p["bv"] = jnp.zeros((KH * hd,), dt)
    return p


def attention_specs(cfg, cross: bool = False):
    p = {
        "wq": ("fsdp", "heads"),
        "wk": ("fsdp", "kv"),
        "wv": ("fsdp", "kv"),
        "wo": ("heads", "fsdp"),
    }
    if cfg.qkv_bias:
        p.update({"bq": ("heads",), "bk": ("kv",), "bv": ("kv",)})
    return p


# ------------------------------------------------------------------ helpers
def _positions_angles(cfg, positions):
    """positions: (B,S) int32, or (3,B,S) for M-RoPE -> (B,S,half) angles."""
    hd = cfg.head_dim
    if cfg.mrope:
        return mrope_angles(positions, hd, cfg.rope_theta, cfg.mrope_sections)
    ang = positions[..., None].astype(jnp.float32) * rope_freqs(hd, cfg.rope_theta)
    return ang


def _project_qkv(cfg, p, x):
    B, S, d = x.shape
    H, KH, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = constrain(q.reshape(B, S, H, hd), "batch", "seq", "heads", None)
    k = constrain(k.reshape(B, S, KH, hd), "batch", "seq", "kv", None)
    v = constrain(v.reshape(B, S, KH, hd), "batch", "seq", "kv", None)
    return q, k, v


def _qk_spec(cfg, hd: int) -> RotationSpec:
    """The declarative per-head Q/K rotation site: rotate when the config
    rotates, fake-quantize when the KV cache quantizes -- one spec object
    (cached plans) instead of QuantConfig threading into free functions."""
    return RotationSpec.for_config(hd, cfg.quant)


def _v_spec(cfg, hd: int) -> RotationSpec:
    """The V site: quantize-only (V's rotation is fused offline into
    (W_v, W_o), so the online site never rotates)."""
    return RotationSpec.for_config(hd, cfg.quant, rotate=False)


def _rotate_quant_qk(cfg, q, k):
    """Paper deployment point: per-head Hadamard then low-precision Q/K.

    When both rotation and KV quantization are on, each head's rotation +
    per-token quantize run as ONE fused kernel (plan epilogue) instead of
    two HBM round trips. With bf16/fp16 models the plan's compute dtype
    keeps the transform passes in the model dtype (f32 MXU accumulation
    only -- no f32 upcast of the head_dim tiles in VMEM), so the QK path
    never touches f32 activations before the f32-accumulated score
    einsum."""
    spec = _qk_spec(cfg, q.shape[-1])
    return spec(q), spec(k)


def _sdpa(cfg, q, k, v, mask):
    """q: (B,S,H,hd), k/v: (B,T,KH,hd), mask: broadcastable (B,1,S,T) bool."""
    B, S, H, hd = q.shape
    T, KH = k.shape[1], k.shape[2]
    G = H // KH
    qg = q.reshape(B, S, KH, G, hd)
    scores = jnp.einsum("bskgd,btkd->bkgst", qg, k,
                        preferred_element_type=jnp.float32)
    scores = scores / math.sqrt(hd)
    neg = jnp.finfo(jnp.float32).min
    scores = jnp.where(mask[:, :, None] if mask.ndim == 4 else mask, scores, neg)
    w = jax.nn.softmax(scores, axis=-1)
    ctx = jnp.einsum("bkgst,btkd->bskgd", w.astype(v.dtype), v)
    return ctx.reshape(B, S, H * hd)


def _causal_mask(cfg, S: int, T: int):
    """Batch-independent (1,1,S,T) causal (+sliding-window) mask built from
    iota. Keeping the batch dim out of the mask matters at scale: a
    (B,1,S,S) mask becomes a multi-GB loop-carried buffer after XLA hoists
    it out of the layer scan; (1,1,S,S) stays 1/B of that."""
    q = jnp.arange(S, dtype=jnp.int32)[:, None]
    k = jnp.arange(T, dtype=jnp.int32)[None, :]
    m = k <= q
    if cfg.sliding_window:
        m &= k > (q - cfg.sliding_window)
    return m[None, None]


# ------------------------------------------------------------------ forward
def apply_attention(
    cfg,
    p,
    x: jnp.ndarray,
    positions: jnp.ndarray,
    *,
    causal: bool = True,
    return_kv: bool = False,
):
    """Full-sequence attention (training / prefill)."""
    B, S, _ = x.shape
    q, k, v = _project_qkv(cfg, p, x)
    ang = _positions_angles(cfg, positions)
    q = apply_rope_angles(q, ang)
    k = apply_rope_angles(k, ang)
    q, k = _rotate_quant_qk(cfg, q, k)
    v = _v_spec(cfg, v.shape[-1])(v)
    kvdt = cfg.quant.kv_cache_dtype(x.dtype)
    k_cache, v_cache = k.astype(kvdt), v.astype(kvdt)
    if causal:
        mask = _causal_mask(cfg, S, S)                 # (1,1,S,S)
    else:
        mask = jnp.ones((1, 1, 1, 1), bool)
    ctx = _sdpa(cfg, q, k, v, mask)
    y = ctx @ p["wo"]
    y = constrain(y, "batch", "seq", None)
    if return_kv:
        return y, (k_cache, v_cache)
    return y


def apply_cross_attention(cfg, p, x, kv: Tuple[jnp.ndarray, jnp.ndarray]):
    """Decoder->encoder cross attention; kv precomputed (B,T,KH,hd)."""
    B, S, _ = x.shape
    H, KH, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    q = x @ p["wq"]
    if cfg.qkv_bias:
        q = q + p["bq"]
    q = q.reshape(B, S, H, hd)
    k, v = kv
    mask = jnp.ones((1, 1, 1, 1), bool)
    ctx = _sdpa(cfg, q, k, v, mask)
    return constrain(ctx @ p["wo"], "batch", "seq", None)


def cross_kv(cfg, p, enc_out: jnp.ndarray):
    """Precompute cross-attention K/V from encoder output (cached once)."""
    B, T, _ = enc_out.shape
    KH, hd = cfg.num_kv_heads, cfg.head_dim
    k = enc_out @ p["wk"]
    v = enc_out @ p["wv"]
    if cfg.qkv_bias:
        k, v = k + p["bk"], v + p["bv"]
    k = k.reshape(B, T, KH, hd)
    v = v.reshape(B, T, KH, hd)
    # same declarative sites as the decoder QK path: K rotates+quantizes
    # (fused when the plan fuses), V quantizes only
    return _qk_spec(cfg, hd)(k), _v_spec(cfg, hd)(v)


def decode_attention(
    cfg,
    p,
    x: jnp.ndarray,
    cache_k: jnp.ndarray,
    cache_v: jnp.ndarray,
    cache_pos: jnp.ndarray,
    positions: jnp.ndarray,
):
    """Single-token decode. x: (B,1,d); cache_k/v: (B,T,KH,hd) rotated+
    quantized at write time (the FP8 KV-cache path); cache_pos: () int32
    shared by the whole batch (one-shot serving) OR (B,) int32 per-slot
    positions (continuous batching: every slot sits at its own depth in
    its own KV rows, so the write and the causal mask are per-row).

    Returns (y, new_cache_k, new_cache_v)."""
    B, S, _ = x.shape
    assert S == 1
    q, k, v = _project_qkv(cfg, p, x)
    ang = _positions_angles(cfg, positions)
    q = apply_rope_angles(q, ang)
    k = apply_rope_angles(k, ang)
    q, k = _rotate_quant_qk(cfg, q, k)
    v = _v_spec(cfg, v.shape[-1])(v)
    per_slot = cache_pos.ndim == 1
    if per_slot:
        # per-row scatter: slot b writes its token at its own position
        write = jax.vmap(lambda c, u, s: jax.lax.dynamic_update_slice_in_dim(
            c, u, s, axis=0))
        cache_k = write(cache_k, k.astype(cache_k.dtype), cache_pos)
        cache_v = write(cache_v, v.astype(cache_v.dtype), cache_pos)
    else:
        cache_k = jax.lax.dynamic_update_slice_in_dim(cache_k, k.astype(cache_k.dtype), cache_pos, axis=1)
        cache_v = jax.lax.dynamic_update_slice_in_dim(cache_v, v.astype(cache_v.dtype), cache_pos, axis=1)
    T = cache_k.shape[1]
    kpos = jnp.arange(T, dtype=jnp.int32)
    if per_slot:
        m = kpos[None] <= cache_pos[:, None]           # (B,T)
        if cfg.sliding_window:
            m &= kpos[None] > (cache_pos[:, None] - cfg.sliding_window)
        mask = m[:, None, None]                        # (B,1,1,T)
    else:
        m = kpos <= cache_pos
        if cfg.sliding_window:
            m &= kpos > (cache_pos - cfg.sliding_window)
        mask = m[None, None, None]                     # (1,1,1,T)
    ctx = _sdpa(cfg, q, cache_k.astype(q.dtype), cache_v.astype(q.dtype), mask)
    y = constrain(ctx @ p["wo"], "batch", "seq", None)
    return y, cache_k, cache_v
