"""Unified model configuration covering all ten assigned architecture
families (dense / MoE / SSM / hybrid / enc-dec / VLM) plus the paper's own
evaluation model (Llama-3.1-8B).

A model is a list of ``groups``; each group is ``(pattern, repeats)`` where
``pattern`` is a tuple of layer kinds forming a *superblock*. Homogeneous
superblocks let heterogeneous stacks (zamba2's 5-mamba+1-attention rhythm,
llama4's dense/MoE interleave) still compile as ``lax.scan`` over stacked
parameters -- essential for 126-layer dry-run compile times.

Layer kinds:
  'attn'  -- GQA/SWA attention + dense MLP
  'moe'   -- GQA attention + mixture-of-experts MLP
  'mamba' -- Mamba2 (SSD) mixer, no MLP (zamba2 backbone style)
  'rwkv'  -- RWKV6 time-mix + channel-mix (attention-free)
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

from repro.core.quant import QuantConfig

LayerKind = str
Group = Tuple[Tuple[LayerKind, ...], int]


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | encdec | vlm | audio
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    groups: Tuple[Group, ...]        # decoder stack (or the only stack)
    head_dim: Optional[int] = None   # None -> d_model // num_heads

    # --- encoder (whisper) ---
    encoder_groups: Tuple[Group, ...] = ()
    encoder_seq: int = 1500          # precomputed frame embeddings (stub frontend)

    # --- attention flavor ---
    sliding_window: int = 0          # 0 = full attention
    rope_theta: float = 10000.0
    qkv_bias: bool = False           # qwen1.5
    mrope: bool = False              # qwen2-vl M-RoPE (3 position streams)
    mrope_sections: Tuple[int, ...] = (16, 24, 24)

    # --- MoE ---
    num_experts: int = 0
    experts_per_token: int = 0
    moe_shared_expert: bool = False  # llama4
    capacity_factor: float = 1.25

    # --- SSM / RWKV ---
    ssm_state: int = 0               # mamba2 N
    ssm_head_dim: int = 64           # mamba2 P
    ssm_expand: int = 2
    rwkv_head_dim: int = 64
    rwkv_impl: str = "chunked"       # chunked (GLA-style) | scan (reference)
    rwkv_chunk: int = 32             # chunk length for the chunked form

    # --- misc ---
    act: str = "swiglu"              # swiglu | gelu
    norm: str = "rmsnorm"            # rmsnorm | layernorm
    vocab_pad_multiple: int = 256    # pad vocab so it shards on the TP axis
    weight_quant: str = "none"       # none | int8 (weight-only storage, serving)
    tie_embeddings: bool = False
    vlm_patches: int = 1024          # stub patch-embedding count (vlm only)
    quant: QuantConfig = dataclasses.field(default_factory=QuantConfig)
    dtype: str = "bfloat16"
    remat: str = "dots"              # none | dots | full
    sub_quadratic: bool = False      # eligible for long_500k
    has_decoder: bool = True         # encoder-only models skip decode shapes

    def __post_init__(self):
        if self.head_dim is None:
            object.__setattr__(self, "head_dim", self.d_model // max(self.num_heads, 1))

    @property
    def num_layers(self) -> int:
        return sum(len(p) * r for p, r in self.groups)

    @property
    def padded_vocab(self) -> int:
        m = self.vocab_pad_multiple
        return ((self.vocab_size + m - 1) // m) * m

    @property
    def is_encdec(self) -> bool:
        return bool(self.encoder_groups)

    def with_quant(self, quant: QuantConfig) -> "ModelConfig":
        return dataclasses.replace(self, quant=quant)

    def scaled_down(self, **overrides) -> "ModelConfig":
        """Reduced config for CPU smoke tests: shrink every capacity knob
        but keep the family structure (pattern kinds, GQA ratio, MoE
        routing, quant settings) intact."""
        ratio = max(1, self.num_heads // max(self.num_kv_heads, 1))
        heads = max(2, ratio)  # keep GQA grouping representative
        small = dict(
            d_model=64 * heads // max(1, heads // 4),
            num_heads=heads,
            num_kv_heads=max(1, heads // ratio),
            d_ff=128 if self.d_ff & (self.d_ff - 1) == 0 else 96,  # keep non-pow2-ness
            vocab_size=512,
            groups=tuple((p, min(r, 2)) for p, r in self.groups),
            encoder_groups=tuple((p, min(r, 2)) for p, r in self.encoder_groups),
            encoder_seq=16,
            num_experts=min(self.num_experts, 4) if self.num_experts else 0,
            experts_per_token=min(self.experts_per_token, 2) if self.experts_per_token else 0,
            ssm_state=min(self.ssm_state, 16) if self.ssm_state else 0,
            ssm_head_dim=16 if self.ssm_state else 64,
            rwkv_head_dim=16,
            sliding_window=min(self.sliding_window, 8) if self.sliding_window else 0,
            vlm_patches=4,
            head_dim=None,
        )
        small["d_model"] = 32 * heads  # head_dim 32, MXU-unaligned is fine on CPU
        if self.name == "zamba2-7b":
            small["d_model"] = 28 * heads  # keep the non-pow2 head_dim property
        small.update(overrides)
        return dataclasses.replace(self, **small)
