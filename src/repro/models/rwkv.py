"""RWKV6 "Finch" (attention-free): data-dependent-decay time-mix plus
squared-ReLU channel-mix.

Time-mix recurrence (per head, state S in R^{K x V}):
    out_t = r_t (S_t + diag(u) k_t^T v_t)
    S_{t+1} = diag(w_t) S_t + k_t^T v_t
with per-channel data-dependent decay w_t = exp(-exp(w0 + lora_w(x_t))).

Training uses the exact recurrence via lax.scan over time (single while
loop in HLO -- compile-friendly at any depth); decode is the same body on
a carried state. The channel-mix down-projection gets the paper's online
Hadamard rotation (the one QuaRot insertion point an attention-free arch
keeps -- DESIGN.md section Arch-applicability).
"""
from __future__ import annotations

import math
from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.core.api import QuantDotSpec
from repro.distributed.sharding import constrain
from repro.models.common import dense_init

_LORA = 32
_MIXES = 5  # r, k, v, w, g


def _dims(cfg):
    K = cfg.rwkv_head_dim
    H = cfg.d_model // K
    return H, K


def init_rwkv_tmix(key, cfg):
    d = cfg.d_model
    H, K = _dims(cfg)
    dt = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 12)
    return {
        "mu_base": jnp.full((d,), 0.5, jnp.float32),
        "mix_w1": dense_init(ks[0], d, _MIXES * _LORA, dt, scale=0.01),
        "mix_w2": (jax.random.normal(ks[1], (_MIXES, _LORA, d), jnp.float32) * 0.01).astype(dt),
        "mu": jnp.full((_MIXES, d), 0.5, jnp.float32),
        "w0": jnp.full((d,), -2.0, jnp.float32),
        "w_lora_a": dense_init(ks[2], d, 2 * _LORA, dt, scale=0.01),
        "w_lora_b": dense_init(ks[3], 2 * _LORA, d, dt, scale=0.01),
        "u": (jax.random.normal(ks[4], (H, K), jnp.float32) * 0.1),
        "wr": dense_init(ks[5], d, d, dt),
        "wk": dense_init(ks[6], d, d, dt),
        "wv": dense_init(ks[7], d, d, dt),
        "wg": dense_init(ks[8], d, d, dt),
        "wo": dense_init(ks[9], d, d, dt, scale=1.0 / math.sqrt(d)),
        "ln_scale": jnp.ones((d,), jnp.float32),
        "ln_bias": jnp.zeros((d,), jnp.float32),
    }


def rwkv_tmix_specs(cfg):
    return {
        "mu_base": (None,), "mix_w1": ("fsdp", None), "mix_w2": (None, None, None),
        "mu": (None, None), "w0": (None,), "w_lora_a": ("fsdp", None),
        "w_lora_b": (None, None), "u": ("heads", None),
        "wr": ("fsdp", "heads"), "wk": ("fsdp", "heads"), "wv": ("fsdp", "heads"),
        "wg": ("fsdp", "heads"), "wo": ("heads", "fsdp"),
        "ln_scale": (None,), "ln_bias": (None,),
    }


def _ddlerp(p, x, x_prev):
    """Data-dependent token-shift interpolation -> the 5 mixed inputs."""
    dx = x_prev - x                                    # (B,S,d)
    base = x + dx * p["mu_base"]
    lora = jnp.tanh(base @ p["mix_w1"])                # (B,S,5*LORA)
    B, S, _ = lora.shape
    lora = lora.reshape(B, S, _MIXES, _LORA)
    dyn = jnp.einsum("bsml,mld->bsmd", lora, p["mix_w2"])  # (B,S,5,d)
    mix = p["mu"][None, None] + dyn
    out = x[:, :, None, :] + dx[:, :, None, :] * mix   # (B,S,5,d)
    return out.astype(x.dtype)


def _tmix_inputs(cfg, p, x, x_prev):
    H, K = _dims(cfg)
    B, S, d = x.shape
    m = _ddlerp(p, x, x_prev)
    xr, xk, xv, xw, xg = [m[:, :, i, :] for i in range(_MIXES)]
    r = (xr @ p["wr"]).reshape(B, S, H, K)
    k = (xk @ p["wk"]).reshape(B, S, H, K)
    v = (xv @ p["wv"]).reshape(B, S, H, K)
    g = jax.nn.silu(xg @ p["wg"])
    lw = p["w0"] + (jnp.tanh(xw @ p["w_lora_a"]) @ p["w_lora_b"]).astype(jnp.float32)
    w = jnp.exp(-jnp.exp(lw)).reshape(B, S, H, K)      # per-channel decay in (0,1)
    return r, k, v, g, w


def _groupnorm_heads(p, out, B, S, d):
    """Per-head LayerNorm on the wkv output (RWKV's GroupNorm)."""
    mu = out.mean(-1, keepdims=True)
    var = out.var(-1, keepdims=True)
    out = (out - mu) * jax.lax.rsqrt(var + 1e-5)
    out = out.reshape(B, S, d) * p["ln_scale"] + p["ln_bias"]
    return out


_TMIX_CHUNK = 32


def _tmix_scan(B, S, H, K, r, k, v, w, u):
    """Exact per-step recurrence (reference; O(S) sequential state I/O)."""
    rf = jnp.moveaxis(r.astype(jnp.float32), 1, 0)     # (S,B,H,K)
    kf = jnp.moveaxis(k.astype(jnp.float32), 1, 0)
    vf = jnp.moveaxis(v.astype(jnp.float32), 1, 0)
    wf = jnp.moveaxis(w.astype(jnp.float32), 1, 0)

    def step(S0, inp):
        rt, kt, vt, wt = inp                           # (B,H,K) each
        kv = jnp.einsum("bhk,bhv->bhkv", kt, vt)
        out = jnp.einsum("bhk,bhkv->bhv", rt, S0 + u[None, :, :, None] * kv)
        S1 = S0 * wt[..., None] + kv
        return S1, out

    S0 = jnp.zeros((B, H, K, K), jnp.float32)
    S_last, outs = jax.lax.scan(step, S0, (rf, kf, vf, wf))
    return jnp.moveaxis(outs, 0, 1), S_last            # (B,S,H,K)


def _tmix_chunked(B, S, H, K, r, k, v, w, u, C=_TMIX_CHUNK):
    """Chunked parallel form (GLA-style): state crosses HBM once per
    C-token chunk instead of once per token, and the intra-chunk work is
    matmul-shaped. Exact: all decay ratios are exp(<=0) computed pairwise
    in log space -- no divisions, no overflow (see EXPERIMENTS.md Perf/A).

    Per chunk (per head): out_t = (r_t (.) ew_t) S
                                + sum_{j<t} [sum_k r_tk k_jk e^{L_(t-1)k - L_jk}] v_j
                                + (r_t . u . k_t) v_t
                          S' = S (.) e^{L_(C-1)} + sum_j (k_j (.) e^{L_(C-1)-L_j}) v_j
    """
    nc = S // C
    rc = r.astype(jnp.float32).reshape(B, nc, C, H, K)
    kc = k.astype(jnp.float32).reshape(B, nc, C, H, K)
    vc = v.astype(jnp.float32).reshape(B, nc, C, H, K)
    # clamp above the f32 denormal range: CPU/TPU flush-to-zero would turn
    # log() into -inf and poison the masked pairwise differences
    lw = jnp.log(jnp.maximum(w.astype(jnp.float32), 1e-30)).reshape(B, nc, C, H, K)
    # move chunk axis first for the scan: (nc, B, C, H, K)
    rc, kc, vc, lw = (jnp.moveaxis(t, 1, 0) for t in (rc, kc, vc, lw))
    mask = jnp.tril(jnp.ones((C, C), bool), k=-1)      # j < t strictly

    def chunk_step(S0, inp):
        rci, kci, vci, lwi = inp                       # (B,C,H,K) each
        L = jnp.cumsum(lwi, axis=1)                    # inclusive within chunk
        ew = jnp.exp(L - lwi)                          # decay chunk-start -> t
        diff = (L - lwi)[:, :, None] - L[:, None]      # (B,t,j,H,K), <= 0 where valid
        diff = jnp.where(mask[None, :, :, None, None], diff, -1e30)
        D = jnp.exp(diff)                              # masked pairs -> exactly 0
        A = jnp.einsum("bthk,btjhk,bjhk->bhtj", rci, D, kci)
        out = jnp.einsum("bhtj,bjhk->bthk", A, vci)    # intra-chunk
        out += jnp.einsum("bthk,hk,bthk->bth", rci, u, kci)[..., None] * vci
        out += jnp.einsum("bthk,bhkv->bthv", rci * ew, S0)   # carry readout
        kdec = kci * jnp.exp(L[:, -1:] - L)            # k_j decayed to chunk end
        kv = jnp.einsum("bjhk,bjhv->bhkv", kdec, vci)
        S1 = S0 * jnp.exp(L[:, -1])[:, :, :, None] + kv
        return S1, out

    S0 = jnp.zeros((B, H, K, K), jnp.float32)
    S_last, outs = jax.lax.scan(chunk_step, S0, (rc, kc, vc, lw))
    outs = jnp.moveaxis(outs, 0, 1).reshape(B, S, H, K)
    return outs, S_last


def apply_rwkv_tmix(cfg, p, x, x_prev=None, *, return_state: bool = False):
    """Full-sequence time-mix. x: (B,S,d). Uses the chunked parallel form
    when the sequence divides the chunk size (cfg.rwkv_impl='chunked'),
    falling back to the exact scan otherwise."""
    B, S, d = x.shape
    H, K = _dims(cfg)
    if x_prev is None:
        x_prev = jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1]
    r, k, v, g, w = _tmix_inputs(cfg, p, x, x_prev)
    u = p["u"]
    if cfg.rwkv_impl == "chunked" and S % cfg.rwkv_chunk == 0:
        out, S_last = _tmix_chunked(B, S, H, K, r, k, v, w, u, C=cfg.rwkv_chunk)
    else:
        out, S_last = _tmix_scan(B, S, H, K, r, k, v, w, u)
    out = _groupnorm_heads(p, out, B, S, d)
    y = (out.astype(x.dtype) * g) @ p["wo"]
    y = constrain(y, "batch", "seq", None)
    if return_state:
        return y, (S_last, x[:, -1, :])
    return y


def decode_rwkv_tmix(cfg, p, x, state):
    """Single-token step. state = (S (B,H,K,K) f32, x_prev (B,d))."""
    B, S, d = x.shape
    H, K = _dims(cfg)
    S0, xp = state
    r, k, v, g, w = _tmix_inputs(cfg, p, x, xp[:, None, :])
    rt, kt, vt, wt = (t[:, 0].astype(jnp.float32) for t in (r, k, v, w))
    kv = jnp.einsum("bhk,bhv->bhkv", kt, vt)
    out = jnp.einsum("bhk,bhkv->bhv", rt, S0 + p["u"][None, :, :, None] * kv)
    S1 = S0 * wt[..., None] + kv
    out = _groupnorm_heads(p, out[:, None].reshape(B, 1, H, K), B, 1, d)
    y = (out.astype(x.dtype) * g) @ p["wo"]
    return y, (S1, x[:, -1, :])


# ------------------------------------------------------------- channel mix
def init_rwkv_cmix(key, cfg):
    d, f = cfg.d_model, cfg.d_ff
    dt = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 3)
    return {
        "mu_r": jnp.full((d,), 0.5, jnp.float32),
        "mu_k": jnp.full((d,), 0.5, jnp.float32),
        "wr": dense_init(ks[0], d, d, dt),
        "wk": dense_init(ks[1], d, f, dt),
        "wv": dense_init(ks[2], f, d, dt, scale=1.0 / math.sqrt(f)),
    }


def rwkv_cmix_specs(cfg):
    return {"mu_r": (None,), "mu_k": (None,),
            "wr": ("fsdp", None), "wk": ("fsdp", "dff"), "wv": ("dff", "fsdp")}


def apply_rwkv_cmix(cfg, p, x, x_prev=None, *, return_state: bool = False):
    if x_prev is None:
        x_prev = jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1]
    dx = x_prev - x
    xr = (x + dx * p["mu_r"]).astype(x.dtype)
    xk = (x + dx * p["mu_k"]).astype(x.dtype)
    r = jax.nn.sigmoid(xr @ p["wr"])
    k = jnp.square(jax.nn.relu(xk @ p["wk"]))
    k = constrain(k, "batch", "seq", "dff")
    # the paper's online rotation point (down-projection input): rotate +
    # per-token quantize + the real int8/fp8 contraction run as one fused
    # rotate-once quant_dot kernel when the plan supports it (no f32
    # fake-quant, no HBM round trip of the rotated tensor, each row block
    # transformed once for ALL weight tiles -- DESIGN.md section 8).
    # Declared as a spec: a pre-quantized QTensor 'wv' is consumed
    # directly on the serving path; under a mesh the dispatch shard_maps
    # with row-sharded activations and the fused kernel shard-local.
    spec = QuantDotSpec.for_config(k.shape[-1], cfg.quant,
                                   weight_axes=("dff", "fsdp"))
    y = r * spec.bind(p["wv"])(k)
    y = constrain(y, "batch", "seq", None)
    if return_state:
        return y, x[:, -1, :]
    return y


def decode_rwkv_cmix(cfg, p, x, x_prev):
    y = apply_rwkv_cmix(cfg, p, x, x_prev[:, None, :])
    return y, x[:, -1, :]
