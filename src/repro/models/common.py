"""Shared building blocks: norms, initializers, RoPE / M-RoPE, embeddings."""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp


def dtype_of(cfg) -> jnp.dtype:
    return jnp.dtype(cfg.dtype)


# ---------------------------------------------------------------- init utils
def dense_init(key, d_in: int, d_out: int, dtype, scale: Optional[float] = None):
    s = scale if scale is not None else 1.0 / math.sqrt(d_in)
    return (jax.random.normal(key, (d_in, d_out), jnp.float32) * s).astype(dtype)


def stacked(key, n: int, init_fn):
    """vmap an init over a stacked (scanned) leading axis."""
    return jax.vmap(init_fn)(jax.random.split(key, n))


# --------------------------------------------------------------------- norms
def init_norm(cfg, d: int):
    p = {"scale": jnp.ones((d,), jnp.float32)}
    if cfg.norm == "layernorm":
        p["bias"] = jnp.zeros((d,), jnp.float32)
    return p


def apply_norm(cfg, p, x):
    xf = x.astype(jnp.float32)
    if cfg.norm == "layernorm":
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + 1e-5) * p["scale"] + p["bias"]
    else:
        ms = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
        y = xf * jax.lax.rsqrt(ms + 1e-6) * p["scale"]
    return y.astype(x.dtype)


# ---------------------------------------------------------------------- RoPE
def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: (B, S, H, hd), positions: (B, S) -> rotated x (split-half style)."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = rope_freqs(hd, theta)                       # (half,)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (B, S, half)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    return jnp.concatenate(
        [xf1 * cos - xf2 * sin, xf2 * cos + xf1 * sin], axis=-1
    ).astype(x.dtype)


def mrope_angles(positions: jnp.ndarray, hd: int, theta: float,
                 sections: Tuple[int, ...]) -> jnp.ndarray:
    """(3, B, S) positions -> (B, S, half) angles with per-frequency stream
    selection (Qwen2-VL: rotary frequencies are partitioned between the
    temporal / height / width position streams)."""
    half = hd // 2
    freqs = rope_freqs(hd, theta)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (3, B, S, half)
    idx = []
    for i, s in enumerate(sections):
        idx.extend([i] * s)
    idx = (idx + [0] * half)[:half]
    sel = jax.nn.one_hot(jnp.asarray(idx, jnp.int32), 3, dtype=jnp.float32)  # (half, 3)
    return jnp.einsum("tbsh,ht->bsh", ang, sel)


def apply_rope_angles(x: jnp.ndarray, ang: jnp.ndarray) -> jnp.ndarray:
    """x: (B, S, H, hd), ang: (B, S, half)."""
    half = x.shape[-1] // 2
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1 = x[..., :half].astype(jnp.float32)
    x2 = x[..., half:].astype(jnp.float32)
    return jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1
    ).astype(x.dtype)


def sinusoidal_positions(seq: int, d: int) -> jnp.ndarray:
    """Whisper-style sinusoidal embeddings (any length -- our 448->32k
    decode-context adaptation, see DESIGN.md)."""
    pos = jnp.arange(seq, dtype=jnp.float32)[:, None]
    dim = jnp.arange(0, d, 2, dtype=jnp.float32)[None, :]
    inv = jnp.exp(-math.log(10000.0) * dim / d)
    ang = pos * inv
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)
