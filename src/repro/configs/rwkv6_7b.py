"""rwkv6-7b "Finch" [ssm]: attention-free, data-dependent decay.
num_heads is the RWKV head count (d_model / 64). KV-cache rotation point
does not exist (DESIGN.md Arch-applicability); the channel-mix
down-projection keeps the paper's online Hadamard. Sub-quadratic:
eligible for long_500k. [arXiv:2404.05892; hf]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-7b",
    family="ssm",
    d_model=4096,
    num_heads=64,
    num_kv_heads=64,
    d_ff=14336,
    vocab_size=65536,
    groups=((("rwkv",), 32),),
    rwkv_head_dim=64,
    sub_quadratic=True,
)
