"""starcoder2-15b [dense]: GQA kv=4, RoPE, GELU MLP + LayerNorm, biases.
[arXiv:2402.19173; hf]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-15b",
    family="dense",
    d_model=6144,
    num_heads=48,
    num_kv_heads=4,
    d_ff=24576,
    vocab_size=49152,
    groups=((("attn",), 40),),
    act="gelu",
    norm="layernorm",
    qkv_bias=True,
    rope_theta=1e5,
    sub_quadratic=False,
)
