"""phi4-mini-3.8b [dense]: RoPE SwiGLU GQA. [arXiv:2412.08905; hf]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="phi4-mini-3.8b",
    family="dense",
    d_model=3072,
    num_heads=24,
    num_kv_heads=8,
    d_ff=8192,
    vocab_size=200064,
    groups=((("attn",), 32),),
    tie_embeddings=True,
    sub_quadratic=False,
)
