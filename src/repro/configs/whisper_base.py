"""whisper-base [audio]: enc-dec transformer, conv frontend stubbed
(``input_specs`` supplies precomputed mel-frame embeddings).
[arXiv:2212.04356; unverified]

Adaptation notes (DESIGN.md): learned positional embeddings replaced by
sinusoidal so the 32k decode shapes lower (whisper's native decoder ctx is
448); decode_32k/prefill_32k are therefore out-of-family but well-defined.
long_500k skipped: full-attention enc-dec."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="whisper-base",
    family="audio",
    d_model=512,
    num_heads=8,
    num_kv_heads=8,
    d_ff=2048,
    vocab_size=51865,
    groups=((("xattn",), 6),),
    encoder_groups=((("enc_attn",), 6),),
    encoder_seq=1500,
    act="gelu",
    norm="layernorm",
    tie_embeddings=True,
    sub_quadratic=False,
)
