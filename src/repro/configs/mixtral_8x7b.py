"""mixtral-8x7b [moe]: 8 experts top-2, sliding-window attention.
[arXiv:2401.04088; hf]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="mixtral-8x7b",
    family="moe",
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=14336,
    vocab_size=32000,
    groups=((("moe",), 32),),
    num_experts=8,
    experts_per_token=2,
    sliding_window=4096,
    rope_theta=1e6,
    sub_quadratic=False,
)
