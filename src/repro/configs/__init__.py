"""Architecture registry: one module per assigned architecture, each
exporting ``CONFIG`` (the exact published configuration) -- select with
``--arch <id>`` in the launchers."""
from __future__ import annotations

import importlib
from typing import Dict, List

from repro.models.config import ModelConfig

ARCH_IDS: List[str] = [
    "whisper_base",
    "mixtral_8x7b",
    "llama4_maverick_400b_a17b",
    "llama3_405b",
    "phi4_mini_3_8b",
    "starcoder2_15b",
    "qwen1_5_4b",
    "zamba2_7b",
    "rwkv6_7b",
    "qwen2_vl_7b",
    # the paper's own end-to-end evaluation model (Llama-3.1-8B, section 4.2)
    "llama3_8b",
]

_ALIASES = {
    "whisper-base": "whisper_base",
    "mixtral-8x7b": "mixtral_8x7b",
    "llama4-maverick-400b-a17b": "llama4_maverick_400b_a17b",
    "llama3-405b": "llama3_405b",
    "phi4-mini-3.8b": "phi4_mini_3_8b",
    "starcoder2-15b": "starcoder2_15b",
    "qwen1.5-4b": "qwen1_5_4b",
    "zamba2-7b": "zamba2_7b",
    "rwkv6-7b": "rwkv6_7b",
    "qwen2-vl-7b": "qwen2_vl_7b",
    "llama3-8b": "llama3_8b",
}


def get_config(name: str) -> ModelConfig:
    mod_name = _ALIASES.get(name, name.replace("-", "_").replace(".", "_"))
    mod = importlib.import_module(f"repro.configs.{mod_name}")
    return mod.CONFIG


def all_configs() -> Dict[str, ModelConfig]:
    return {a: get_config(a) for a in ARCH_IDS}
