"""zamba2-7b [hybrid]: Mamba2 backbone with a shared-style attention block
every sixth layer (13 superblocks of 5 mamba + 1 attention, 3 mamba tail =
81 layers). head_dim = 3584/32 = 112 (non-power-of-2: the per-head online
rotation uses the grouped Hadamard I_7 (x) H_16). Sub-quadratic: eligible
for long_500k. [arXiv:2411.15242; unverified]"""
from repro.models.config import ModelConfig

_m5a = ("mamba",) * 5 + ("attn",)

CONFIG = ModelConfig(
    name="zamba2-7b",
    family="hybrid",
    d_model=3584,
    num_heads=32,
    num_kv_heads=32,
    d_ff=14336,
    vocab_size=32000,
    groups=((_m5a, 13), (("mamba",), 3)),
    ssm_state=64,
    ssm_head_dim=64,
    ssm_expand=2,
    sub_quadratic=True,
)
