"""qwen2-vl-7b [vlm]: M-RoPE, dynamic resolution. The vision tower is a
stub per the assignment: ``input_specs`` supplies precomputed patch
embeddings which are prepended to the token embeddings.
[arXiv:2409.12191; hf]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-7b",
    family="vlm",
    d_model=3584,
    num_heads=28,
    num_kv_heads=4,
    d_ff=18944,
    vocab_size=152064,
    groups=((("attn",), 28),),
    mrope=True,
    mrope_sections=(16, 24, 24),
    vlm_patches=1024,
    rope_theta=1e6,
    sub_quadratic=False,
)
