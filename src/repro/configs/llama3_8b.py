"""llama3-8b: the paper's own end-to-end evaluation model (section 4.2 runs
Llama-3.1-8B with FP8 attention +- Hadamard rotation). Not part of the
assigned pool; used by examples/ and the quant-accuracy benchmark."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="llama3-8b",
    family="dense",
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=14336,
    vocab_size=128256,
    groups=((("attn",), 32),),
    rope_theta=500000.0,
    sub_quadratic=False,
)
