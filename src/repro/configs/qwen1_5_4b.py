"""qwen1.5-4b [dense]: QKV bias, MHA (kv=20). [hf:Qwen/Qwen1.5-0.5B; hf]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen1.5-4b",
    family="dense",
    d_model=2560,
    num_heads=20,
    num_kv_heads=20,
    d_ff=6912,
    vocab_size=151936,
    groups=((("attn",), 40),),
    qkv_bias=True,
    sub_quadratic=False,
)
