"""llama3-405b [dense]: GQA, 128k vocab. [arXiv:2407.21783; unverified]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="llama3-405b",
    family="dense",
    d_model=16384,
    num_heads=128,
    num_kv_heads=8,
    d_ff=53248,
    vocab_size=128256,
    groups=((("attn",), 126),),
    rope_theta=500000.0,
    sub_quadratic=False,
)
