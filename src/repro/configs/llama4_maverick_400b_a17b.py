"""llama4-maverick-400b-a17b [moe]: 128-expert top-1 MoE interleaved with
dense layers (every other layer, matching the ~400B total / 17B active
parameter split), shared expert. Early-fusion multimodality is a frontend
concern; the assigned backbone is text-shaped.
[hf:meta-llama/Llama-4-Scout-17B-16E; unverified]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="llama4-maverick-400b-a17b",
    family="moe",
    d_model=5120,
    num_heads=40,
    num_kv_heads=8,
    d_ff=8192,
    vocab_size=202048,
    groups=((("attn", "moe"), 24),),   # 48 layers: dense/MoE interleave
    num_experts=128,
    experts_per_token=1,
    moe_shared_expert=True,
    rope_theta=500000.0,
    sub_quadratic=False,
)
