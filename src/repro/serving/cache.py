"""Slot-based KV cache for the continuous-batching engine.

The cache is allocated ONCE at engine start -- (layers, slots, max_len,
KH, hd) per attention site, in the serving KV dtype (``cfg.quant.kv_quant``
grid: real fp8 storage when the config quantizes the cache) -- and then
only ever mutated through donated jit steps:

  * ``make_insert_fn``: scatter a freshly prefilled request's KV rows
    into its slot (prefill-insert). The whole prefill-bucket block
    [0, prefill_len) is written; rows beyond the request's true length
    hold prefill padding garbage, which is safe by construction: the
    per-slot causal mask never attends a row >= the slot's position, and
    the decode step overwrites row ``pos`` before attending it.
  * the per-slot decode step (``launch.steps.jit_serve_step(per_slot=
    True)``): each slot writes its token's K/V at its own position.

Both steps donate the cache operand, so steady-state serving never
reallocates cache storage -- slot retirement and reuse are pure host-side
bookkeeping (``serving.scheduler``) plus these in-place updates.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.launch import shapes as shp
from repro.models.config import ModelConfig


def alloc_kv_caches(cfg: ModelConfig, slots: int, max_len: int):
    """Zero-initialized cache tree matching ``lm_decode_step``'s layout:
    per attention site (repeats, slots, max_len, KH, hd) in the serving
    KV dtype. Called exactly once per engine."""
    specs = shp.cache_specs(cfg, slots, max_len)
    return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), specs)


def cache_bytes(cfg: ModelConfig, slots: int, max_len: int) -> int:
    """Total cache allocation in bytes (observability / bench records)."""
    specs = shp.cache_specs(cfg, slots, max_len)
    return sum(int(s.size) * jnp.dtype(s.dtype).itemsize
               for s in jax.tree.leaves(specs))


def make_insert_fn(cfg: ModelConfig):
    """Prefill-insert: write a (layers, 1, P, ...) prefilled KV tree into
    slot ``slot`` of the (layers, slots, T, ...) engine cache.

    Pure function of (caches, kv, slot) with matching tree structure --
    the engine jits it with ``donate_argnums=(0,)`` so admission does not
    reallocate the cache either."""

    def insert(caches, kv, slot):
        def one(c, p):
            p = p.astype(c.dtype)
            # start indices: layer 0, slot, then 0 on every trailing dim
            start = (jnp.zeros((), jnp.int32), slot) + tuple(
                jnp.zeros((), jnp.int32) for _ in range(c.ndim - 2))
            return jax.lax.dynamic_update_slice(c, p, start)

        return jax.tree.map(one, caches, kv)

    return insert
