"""Synthetic arrival streams for the serving engine.

Seeded Poisson process: exponential inter-arrival gaps (in decode-step
units -- the engine's clock), mixed prompt and generation lengths drawn
uniformly from closed ranges. Deterministic per seed, so parity and
regression tests replay the exact same traffic.
"""
from __future__ import annotations

from typing import List, Optional, Tuple

import numpy as np

from repro.serving.scheduler import Request


def synthetic_stream(
    num_requests: int,
    *,
    vocab_size: int,
    prompt_len: Tuple[int, int],
    max_new_tokens: Tuple[int, int],
    rate: float = 1.0,
    seed: int = 0,
    deadline_slack: Optional[float] = None,
) -> List[Request]:
    """``rate`` is mean arrivals per decode step (lambda of the Poisson
    process); ``prompt_len`` / ``max_new_tokens`` are inclusive (lo, hi)
    ranges. Request ids are 0..num_requests-1 in arrival order.

    ``deadline_slack`` (optional) gives every request an absolute TTL of
    ``arrival_time + max_new_tokens + deadline_slack`` steps -- enough
    budget to finish if admitted promptly, expiring under sustained
    overload (the deadline-shed / timed-out paths of the hardened
    engine)."""
    if rate <= 0:
        raise ValueError(f"rate must be positive, got {rate}")
    rng = np.random.default_rng(seed)
    t = 0.0
    out: List[Request] = []
    for rid in range(num_requests):
        t += float(rng.exponential(1.0 / rate))
        plen = int(rng.integers(prompt_len[0], prompt_len[1] + 1))
        gen = int(rng.integers(max_new_tokens[0], max_new_tokens[1] + 1))
        toks = rng.integers(0, vocab_size, (plen,), dtype=np.int32)
        ddl = (t + gen + deadline_slack
               if deadline_slack is not None else None)
        out.append(Request(rid=rid, tokens=toks, max_new_tokens=gen,
                           arrival_time=t, deadline=ddl))
    return out
