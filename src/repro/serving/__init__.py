"""Continuous-batching serving engine (DESIGN.md section 10).

Request-level serving over pre-quantized QTensor weights: a slot-based
KV cache allocated once in the serving quant dtype, a host-side
scheduler that admits and retires requests mid-decode, and an engine
loop driving three once-compiled jitted steps (prefill / prefill-insert
/ per-slot decode)."""
from repro.serving.cache import alloc_kv_caches, cache_bytes, make_insert_fn  # noqa: F401
from repro.serving.engine import ServeEngine  # noqa: F401
from repro.serving.scheduler import Completion, Request, Scheduler  # noqa: F401
from repro.serving.stream import synthetic_stream  # noqa: F401
