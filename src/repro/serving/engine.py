"""Continuous-batching serving engine over pre-quantized QTensor weights.

The one-shot launcher (``launch/serve.py``) prefills a fixed batch, then
decodes every row in lockstep behind a single scalar ``pos`` until the
whole batch exits together. A production serving loop admits and retires
requests *mid-decode*. This engine does that with three jitted device
functions, each compiled exactly once per engine:

  prefill   (params, {tokens:(1,P)}, length) -> (first token, KV rows)
            -- prompts are right-padded to the fixed prefill bucket P, so
            every admission hits the same compiled executable; under the
            causal mask the padding rows never influence positions
            < length, and the logits are gathered at length-1.
  insert    (caches, kv, slot) -> caches    [donated caches]
            -- scatter the newcomer's KV block into its slot.
  decode    (params, caches, tokens, positions) -> tokens [donated caches]
            -- ``launch.steps.jit_serve_step(per_slot=True)``: one step
            over ALL slots with a (slots,) position vector; every slot
            writes and attends at its own depth.

The KV cache is allocated ONCE (``serving.cache``) in the serving quant
dtype; admissions, retirements, and slot reuse are host-side scheduler
bookkeeping (``serving.scheduler``) plus donated in-place updates -- the
steady-state decode step neither reallocates nor retraces (the decode
executable count stays 1 across the whole run; see
``decode_cache_size``). With ``cfg.weight_quant == 'int8'`` the weights
are pre-quantized QTensors, so the serving forward performs zero
``quantize_weight`` calls after engine construction (tracked via
``wquant.QUANTIZE_WEIGHT_CALLS``).

Timing discipline: ``warmup()`` pays all three compiles on dummy inputs
before any request is admitted, so reported per-token latencies are
steady-state (the same fix applied to ``serve.py``'s timed loop).
"""
from __future__ import annotations

import time
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import wquant
from repro.distributed import sharding as shd
from repro.kernels.registry import TRACE_COUNTS
from repro.launch.steps import jit_serve_step
from repro.models.config import ModelConfig
from repro.models.lm import lm_forward
from repro.serving.cache import alloc_kv_caches, cache_bytes, make_insert_fn
from repro.serving.scheduler import Completion, Request, Scheduler

_SUPPORTED_KINDS = ("attn", "moe")


def _validate_config(cfg: ModelConfig) -> None:
    """Continuous batching needs position-addressable per-token caches;
    right-padded bucket prefill is only exact for causal attention (a
    padded row can never influence an earlier position). Scan-state
    architectures (mamba/rwkv) carry their whole prefix in one state
    tensor, so a padded prefill would fold padding into the state."""
    kinds = {k for pattern, _ in cfg.groups for k in pattern}
    bad = kinds - set(_SUPPORTED_KINDS)
    if bad or cfg.is_encdec or cfg.family == "vlm":
        raise ValueError(
            f"serving engine supports causal attention stacks only "
            f"(kinds {_SUPPORTED_KINDS}); config {cfg.name!r} has "
            f"kinds={sorted(kinds)} family={cfg.family!r} "
            f"encdec={cfg.is_encdec}")


def _make_prefill_fn(cfg: ModelConfig):
    def prefill(params, batch, length):
        logits, _, caches = lm_forward(cfg, params, batch, want_cache=True)
        # right-padded bucket: the request's last real token sits at
        # length-1; everything past it is padding the causal mask keeps
        # out of positions < length
        last = jax.lax.dynamic_slice_in_dim(logits, length - 1, 1, axis=1)
        tok = jnp.argmax(last[:, -1], axis=-1).astype(jnp.int32)
        return tok, caches

    return prefill


class ServeEngine:
    """Drives jitted prefill/insert/decode steps over a request stream.

    params must already be placed with ``launch.steps.param_shardings``
    (the launchers' init path); with ``cfg.weight_quant == 'int8'`` they
    are the pre-quantized QTensor tree."""

    def __init__(self, cfg: ModelConfig, params, mesh, *,
                 num_slots: int, max_len: int, prefill_len: int,
                 eos_id: Optional[int] = None, rules_overrides=None):
        _validate_config(cfg)
        self.cfg = cfg
        self.params = params
        self.mesh = mesh
        self.eos_id = eos_id
        self.prefill_len = prefill_len
        self.max_len = max_len
        self.sched = Scheduler(num_slots, max_len, prefill_len)

        def in_rules(fn):
            def wrapped(*a):
                with shd.sharding_rules(mesh, rules_overrides):
                    return fn(*a)
            return wrapped

        self._prefill = jax.jit(in_rules(_make_prefill_fn(cfg)))
        self._insert = jax.jit(in_rules(make_insert_fn(cfg)),
                               donate_argnums=(0,))
        self._decode, (_, cs, _) = jit_serve_step(
            cfg, num_slots, max_len, mesh, rules_overrides=rules_overrides,
            donate=True, per_slot=True)

        # the ONE cache allocation of the engine's lifetime
        self.caches = jax.device_put(
            alloc_kv_caches(cfg, num_slots, max_len), cs)
        self.tokens_h = np.zeros((num_slots, 1), np.int32)
        self.positions_h = np.zeros((num_slots,), np.int32)

        self.step = 0
        self.completions: List[Completion] = []
        self._step_latencies_ms: List[float] = []
        self._occupancy: List[float] = []
        self._decode_s = 0.0
        self._compile_s: Optional[float] = None
        self._idle_steps = 0
        self._qw_calls_baseline = wquant.QUANTIZE_WEIGHT_CALLS

    # ---------------------------------------------------------- warm-up
    def warmup(self) -> float:
        """Compile prefill/insert/decode on dummy inputs before serving,
        so no request's latency includes a jit compile. Writes garbage
        into cache rows that are by-construction never attended before
        being overwritten (prefill-insert rewrites [0, P) on admission;
        decode rewrites row ``pos`` before attending it)."""
        if self._compile_s is not None:
            return self._compile_s
        t0 = time.perf_counter()
        batch = {"tokens": jnp.zeros((1, self.prefill_len), jnp.int32)}
        tok, kv = self._prefill(self.params, batch,
                                jnp.asarray(1, jnp.int32))
        self.caches = self._insert(self.caches, kv,
                                   jnp.asarray(0, jnp.int32))
        new_tok, _, self.caches = self._decode(
            self.params, self.caches, jnp.asarray(self.tokens_h),
            jnp.asarray(self.positions_h))
        jax.block_until_ready(new_tok)
        self._compile_s = time.perf_counter() - t0
        # everything past this point is steady-state serving
        self._qw_calls_baseline = wquant.QUANTIZE_WEIGHT_CALLS
        return self._compile_s

    # --------------------------------------------------------- lifecycle
    def submit(self, req: Request) -> None:
        self.sched.submit(req)

    def _admit(self, slot: int, req: Request) -> None:
        padded = np.zeros((1, self.prefill_len), np.int32)
        padded[0, :req.prompt_len] = req.tokens
        t0 = time.perf_counter()
        tok, kv = self._prefill(self.params, {"tokens": jnp.asarray(padded)},
                                jnp.asarray(req.prompt_len, jnp.int32))
        self.caches = self._insert(self.caches, kv,
                                   jnp.asarray(slot, jnp.int32))
        tok_h = int(jax.block_until_ready(tok)[0])
        dt_ms = (time.perf_counter() - t0) * 1e3
        TRACE_COUNTS[("serving", "prefill_insert")] += 1
        self.sched.counters["prefill_inserts"] += 1

        st = self.sched.active[slot]
        st.generated.append(tok_h)
        st.latencies_ms.append(dt_ms)
        self.tokens_h[slot, 0] = tok_h
        self.positions_h[slot] = st.pos
        self._maybe_retire(slot, tok_h)

    def _maybe_retire(self, slot: int, last_tok: int) -> bool:
        st = self.sched.active[slot]
        reason = None
        if self.eos_id is not None and last_tok == self.eos_id:
            reason = "eos"
        elif len(st.generated) >= st.max_new_tokens:
            reason = "length"
        elif st.pos >= self.max_len:
            reason = "cache_full"
        if reason is None:
            return False
        self.completions.append(
            self.sched.retire(slot, reason, float(self.step)))
        return True

    # -------------------------------------------------------------- run
    def run(self, requests: Sequence[Request]) -> List[Completion]:
        """Serve a whole arrival stream to completion; returns the
        completion records (also accumulated on ``self.completions``)."""
        self.warmup()
        for req in requests:
            self.submit(req)
        while self.sched.has_work():
            now = float(self.step)
            # admissions: prefill-insert every arrived request a free
            # slot can take, straight into the running decode batch
            while True:
                adm = self.sched.next_admission(now)
                if adm is None:
                    break
                self._admit(*adm)
            if not self.sched.active:
                nxt = self.sched.next_arrival()
                if nxt is None:
                    break
                # idle: jump the step clock to the next arrival
                self.step = max(self.step + 1, int(np.ceil(nxt)))
                self._idle_steps += 1
                continue
            self._decode_step()
        return self.completions

    def _decode_step(self) -> None:
        t0 = time.perf_counter()
        new_tok, _, self.caches = self._decode(
            self.params, self.caches, jnp.asarray(self.tokens_h),
            jnp.asarray(self.positions_h))
        new_tok_h = np.asarray(new_tok)           # blocks until ready
        dt_ms = (time.perf_counter() - t0) * 1e3
        self._decode_s += dt_ms * 1e-3
        self._step_latencies_ms.append(dt_ms)
        self._occupancy.append(self.sched.occupancy)
        self.step += 1
        for slot in sorted(self.sched.active):
            st = self.sched.active[slot]
            tok = int(new_tok_h[slot, 0])
            st.generated.append(tok)
            st.latencies_ms.append(dt_ms)
            st.pos += 1
            self.tokens_h[slot, 0] = tok
            self.positions_h[slot] = st.pos
            self._maybe_retire(slot, tok)

    # ------------------------------------------------------ observability
    def decode_cache_size(self) -> int:
        """Number of compiled decode executables -- stays 1 across
        admissions/retirements (fixed shapes, host-side scheduling)."""
        return self._decode._cache_size()

    def quantize_weight_calls_during_serve(self) -> int:
        """quantize_weight invocations since warmup -- 0 on the prequant
        path (QTensor weights are consumed directly)."""
        return wquant.QUANTIZE_WEIGHT_CALLS - self._qw_calls_baseline

    def summary(self) -> Dict[str, float]:
        # per-token latencies: decode-produced tokens only (index 0 is the
        # prefill-produced first token, whose cost is the admission)
        lat = np.asarray([ms for c in self.completions
                          for ms in c.latencies_ms[1:]] or [0.0])
        gen = sum(len(c.tokens) for c in self.completions)
        gen_decode = sum(max(len(c.tokens) - 1, 0) for c in self.completions)
        return {
            "requests": len(self.completions),
            "generated_tokens": gen,
            "decode_steps": len(self._step_latencies_ms),
            "idle_steps": self._idle_steps,
            "tokens_per_s": (gen_decode / self._decode_s
                            if self._decode_s else 0.0),
            "occupancy": float(np.mean(self._occupancy)) if self._occupancy
            else 0.0,
            "p50_token_ms": float(np.percentile(lat, 50)),
            "p99_token_ms": float(np.percentile(lat, 99)),
            "compile_s": self._compile_s or 0.0,
            "decode_s": self._decode_s,
            "decode_executables": self.decode_cache_size(),
            "quantize_weight_calls": self.quantize_weight_calls_during_serve(),
            "kv_cache_bytes": cache_bytes(self.cfg, self.sched.num_slots,
                                          self.max_len),
            **{k: int(v) for k, v in self.sched.counters.items()},
        }
