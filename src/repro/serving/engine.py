"""Continuous-batching serving engine over pre-quantized QTensor weights.

The one-shot launcher (``launch/serve.py``) prefills a fixed batch, then
decodes every row in lockstep behind a single scalar ``pos`` until the
whole batch exits together. A production serving loop admits and retires
requests *mid-decode*. This engine does that with three jitted device
functions, each compiled exactly once per engine:

  prefill   (params, {tokens:(1,P)}, length) -> (first token, KV rows)
            -- prompts are right-padded to the fixed prefill bucket P, so
            every admission hits the same compiled executable; under the
            causal mask the padding rows never influence positions
            < length, and the logits are gathered at length-1.
  insert    (caches, kv, slot) -> caches    [donated caches]
            -- scatter the newcomer's KV block into its slot.
  decode    (params, caches, tokens, positions) -> tokens [donated caches]
            -- ``launch.steps.jit_serve_step(per_slot=True)``: one step
            over ALL slots with a (slots,) position vector; every slot
            writes and attends at its own depth.

The KV cache is allocated ONCE (``serving.cache``) in the serving quant
dtype; admissions, retirements, and slot reuse are host-side scheduler
bookkeeping (``serving.scheduler``) plus donated in-place updates -- the
steady-state decode step neither reallocates nor retraces (the decode
executable count stays 1 across the whole run unless the degradation
ladder re-warms; see ``decode_cache_size``). With ``cfg.weight_quant ==
'int8'`` the weights are pre-quantized QTensors, so the serving forward
performs zero ``quantize_weight`` calls after engine construction
(tracked via ``wquant.QUANTIZE_WEIGHT_CALLS``).

Robustness layer (PR 8, DESIGN.md section 12):

  * request lifecycle -- per-request deadlines (expired queued requests
    shed before admission; in-flight slots past deadline retired as
    ``timed_out``), bounded admission queue with immediate ``rejected``
    completions (``max_queue``);
  * decode watchdog -- ``watchdog_ms`` bounds per-step wall clock; the
    check is post-hoc (a synchronous jit dispatch cannot be preempted),
    so a slow step's result is still used, and two CONSECUTIVE trips
    trigger a degradation re-warm;
  * graceful degradation ladder -- a decode dispatch that raises is
    retried once on intact caches (faults fire at the host boundary,
    BEFORE the donated operands are consumed), then the engine re-warms
    one rung down: pallas/streamed -> pallas/rotate_once -> xla. Every
    rung is bitwise-identical by construction (asserted by the
    quant_dot parity tests), so mid-run degradation never changes
    emitted tokens. Rung switches tick
    ``TRACE_COUNTS[("serving", "degrade_<rung>")]`` and warn once;
  * numeric guardrails -- with ``REPRO_NUMERIC_GUARDS=1`` the jitted
    steps carry isfinite/positive-scale reductions
    (``core.guards``); a tripped slot is retired as ``degraded``
    (reason ``nan_guard``) at the step boundary instead of emitting
    poisoned tokens. Guard-off and guard-on runs are bitwise identical
    on healthy requests (guards observe, never perturb).

ABFT layer (PR 10, DESIGN.md section 14): with ``REPRO_ABFT=1`` (or
``QuantConfig.abft``) the engine serves checksum-VERIFIED steps --
silent-data-corruption detection for finite-but-wrong values the
isfinite guards cannot see. Weight checksums are attached at init
(``verify.with_checks``); the fused quant_dot kernels verify their own
outputs in-kernel and NaN-poison failing rows into the logits seam; the
decode step carries a per-slot KV conservation state (fifth jit
argument, donated) that recomputes and cross-checks the cache sums
every step. A tripped slot retires as ``sdc_detected``
(``Completion.status`` 'degraded') -- KV trips attribute directly,
logits trips attribute by re-verifying the stored weight checksums
against the live weights (corrupt -> ``sdc_detected``, clean ->
``nan_guard``). Two detections within ``_SDC_WINDOW_STEPS`` re-warm
the degradation ladder one rung. Healthy ABFT-on runs are bitwise
identical to ABFT-off (exact selects only; asserted in
tests/test_faults.py).

Fault injection (tests): ``repro.testing.faults`` installs a context-
scoped ``FaultPlan`` the engine polls at each decode dispatch --
synthetic kernel raises, artificial step latency, NaN pokes into live
KV rows. Zero-fault overhead is one attribute load + None check.

Timing discipline: ``warmup()`` pays all three compiles on dummy inputs
before any request is admitted, so reported per-token latencies are
steady-state (the same fix applied to ``serve.py``'s timed loop).
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import Any, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro import verify
from repro.core import guards, wquant
from repro.distributed import sharding as shd
from repro.kernels.registry import TRACE_COUNTS, warn_once
from repro.launch.steps import jit_serve_step
from repro.models.config import ModelConfig
from repro.models.lm import lm_forward
from repro.serving.cache import alloc_kv_caches, cache_bytes, make_insert_fn
from repro.serving.scheduler import Completion, Request, Scheduler
from repro.testing import faults

_SUPPORTED_KINDS = ("attn", "moe")

# TRACE_COUNTS keys snapshotted at engine construction so ``health()``
# can report per-engine deltas of the process-global counters.
_HEALTH_TRACE_KEYS = (
    ("abft", "kv_trip"),
    ("abft", "sdc_detected"),
    ("abft", "params_check"),
    ("serving", "guard_trip"),
    ("serving", "watchdog_trip"),
    ("serving", "step_retry"),
    ("serving", "deadline_retire"),
)

# ABFT degradation window: >= 2 SDC detections within this many engine
# steps re-warm the ladder one rung (sustained corruption, not a blip).
_SDC_WINDOW_STEPS = 16


def _validate_config(cfg: ModelConfig) -> None:
    """Continuous batching needs position-addressable per-token caches;
    right-padded bucket prefill is only exact for causal attention (a
    padded row can never influence an earlier position). Scan-state
    architectures (mamba/rwkv) carry their whole prefix in one state
    tensor, so a padded prefill would fold padding into the state."""
    kinds = {k for pattern, _ in cfg.groups for k in pattern}
    bad = kinds - set(_SUPPORTED_KINDS)
    if bad or cfg.is_encdec or cfg.family == "vlm":
        raise ValueError(
            f"serving engine supports causal attention stacks only "
            f"(kinds {_SUPPORTED_KINDS}); config {cfg.name!r} has "
            f"kinds={sorted(kinds)} family={cfg.family!r} "
            f"encdec={cfg.is_encdec}")


def _make_prefill_fn(cfg: ModelConfig, guard: bool = False):
    def prefill(params, batch, length):
        logits, _, caches = lm_forward(cfg, params, batch, want_cache=True)
        # right-padded bucket: the request's last real token sits at
        # length-1; everything past it is padding the causal mask keeps
        # out of positions < length
        last = jax.lax.dynamic_slice_in_dim(logits, length - 1, 1, axis=1)
        tok = jnp.argmax(last[:, -1], axis=-1).astype(jnp.int32)
        return tok, caches

    if not guard:
        return prefill

    def guarded_prefill(params, batch, length):
        logits, _, caches = lm_forward(cfg, params, batch, want_cache=True)
        last = jax.lax.dynamic_slice_in_dim(logits, length - 1, 1, axis=1)
        ok = guards.rows_ok(last[:, -1], batch["tokens"].shape[0])
        tok = jnp.argmax(last[:, -1], axis=-1).astype(jnp.int32)
        return tok, ok, caches

    return guarded_prefill


def _degradation_ladder(cfg: ModelConfig) -> List[ModelConfig]:
    """The rungs below ``cfg``, most-capable first. Every rung computes
    bitwise-identical results (schedule/backend parity is asserted by the
    quant_dot tests); each is strictly simpler machinery:

        pallas + streamed  ->  pallas + rotate_once  ->  xla

    A config already on 'xla' has no lower rung: a failure there
    exhausts the ladder and fails the in-flight requests loudly."""
    ladder = [cfg]
    q = cfg.quant
    if q.backend in ("pallas", "auto"):
        if q.schedule != "rotate_once":
            ladder.append(cfg.with_quant(
                dataclasses.replace(q, schedule="rotate_once")))
        ladder.append(cfg.with_quant(
            dataclasses.replace(q, backend="xla", schedule=None)))
    elif q.backend == "ref":
        ladder.append(cfg.with_quant(
            dataclasses.replace(q, backend="xla", schedule=None)))
    return ladder


def _rung_name(cfg: ModelConfig) -> str:
    q = cfg.quant
    if q.backend == "xla":
        return "xla"
    return q.schedule or "default"


class ServeEngine:
    """Drives jitted prefill/insert/decode steps over a request stream.

    params must already be placed with ``launch.steps.param_shardings``
    (the launchers' init path); with ``cfg.weight_quant == 'int8'`` they
    are the pre-quantized QTensor tree."""

    def __init__(self, cfg: ModelConfig, params, mesh, *,
                 num_slots: int, max_len: int, prefill_len: int,
                 eos_id: Optional[int] = None, rules_overrides=None,
                 max_queue: Optional[int] = None,
                 watchdog_ms: Optional[float] = None):
        _validate_config(cfg)
        self.cfg = cfg
        self.params = params
        self.mesh = mesh
        self.eos_id = eos_id
        self.prefill_len = prefill_len
        self.max_len = max_len
        self.sched = Scheduler(num_slots, max_len, prefill_len,
                               max_queue=max_queue)
        self._rules_overrides = rules_overrides
        self._guard = guards.guards_enabled()
        self._abft = (bool(getattr(cfg.quant, "abft", False))
                      or verify.abft_enabled())
        if self._abft:
            # weights quantized without checksums (abft switched on after
            # load) get them attached here, once; check-carrying leaves
            # pass through verbatim
            self.params = verify.with_checks(self.params)
            self._kv_reset = jax.jit(verify.kv_slot_reset,
                                     donate_argnums=(0,))
            # the KV conservation check is deliberately NOT folded into
            # the decode executable: that program donates its cache
            # operands, and a whole-cache read inside it forces XLA to
            # defensively copy the donated buffers (see verify.kv_check)
            self._kv_check = jax.jit(verify.kv_check)
            self._kv_roll = jax.jit(verify.kv_roll)
        self._sdc_trips: collections.deque = collections.deque(maxlen=8)
        self._params_check_step = -1
        self._params_check_ok = True
        self._trace_base = {k: TRACE_COUNTS[k] for k in _HEALTH_TRACE_KEYS}
        self._watchdog_ms = watchdog_ms
        self._watchdog_skip = 0       # steps exempted after a re-warm
        self._consec_slow = 0

        self._ladder = _degradation_ladder(cfg)
        self._rung = 0
        self._decode_jits: list = []

        # insert is rung-independent (a pure cache scatter: its trace
        # never touches quant schedule or backend), so it is compiled
        # once and shared across every rung
        self._insert = jax.jit(self._in_rules(make_insert_fn(cfg)),
                               donate_argnums=(0,))
        self._bind_rung(0)

        # the ONE cache allocation of the engine's lifetime
        cs = self._decode_shardings[1]
        self.caches = jax.device_put(
            alloc_kv_caches(cfg, num_slots, max_len), cs)
        # ABFT KV conservation state: per-slot [sum, abs_sum] over the
        # slot's valid rows, carried across steps and checked/rolled by
        # the kv_check/kv_roll executables dispatched around each decode
        # (repro.verify, DESIGN.md section 14)
        self.kv_sums = (jnp.zeros((num_slots, 2), jnp.float32)
                        if self._abft else None)
        self.tokens_h = np.zeros((num_slots, 1), np.int32)
        self.positions_h = np.zeros((num_slots,), np.int32)

        self.step = 0
        self.completions: List[Completion] = []
        self._step_latencies_ms: List[float] = []
        self._occupancy: List[float] = []
        self._decode_s = 0.0
        self._compile_s: Optional[float] = None
        self._idle_steps = 0
        self._qw_calls_baseline = wquant.QUANTIZE_WEIGHT_CALLS

    def _in_rules(self, fn):
        mesh, overrides = self.mesh, self._rules_overrides

        def wrapped(*a):
            with shd.sharding_rules(mesh, overrides):
                return fn(*a)
        return wrapped

    def _bind_rung(self, i: int) -> None:
        """Compile-bind the jitted prefill/decode for ladder rung ``i``
        (lazily compiled on first call, as all jax.jit wrappers are)."""
        cfg = self._ladder[i]
        self._rung = i
        # ABFT implies the guarded prefill/decode seam: the kernel
        # checksum residual surfaces as NaN-poisoned logit rows there,
        # and the decode executable itself stays the plain guarded step
        # (the KV check rides in separate kv_check/kv_roll programs)
        self._prefill = jax.jit(self._in_rules(
            _make_prefill_fn(cfg, guard=self._guard or self._abft)))
        self._decode, self._decode_shardings = jit_serve_step(
            cfg, self.sched.num_slots, self.max_len, self.mesh,
            rules_overrides=self._rules_overrides,
            donate=True, per_slot=True,
            guard=self._guard or self._abft)
        self._decode_jits.append(self._decode)

    # ---------------------------------------------------------- warm-up
    def warmup(self) -> float:
        """Compile prefill/insert/decode on dummy inputs before serving,
        so no request's latency includes a jit compile. Writes garbage
        into cache rows that are by-construction never attended before
        being overwritten (prefill-insert rewrites [0, P) on admission;
        decode rewrites row ``pos`` before attending it)."""
        if self._compile_s is not None:
            return self._compile_s
        t0 = time.perf_counter()
        batch = {"tokens": jnp.zeros((1, self.prefill_len), jnp.int32)}
        out = self._prefill(self.params, batch, jnp.asarray(1, jnp.int32))
        kv = out[-1]
        self.caches = self._insert(self.caches, kv,
                                   jnp.asarray(0, jnp.int32))
        new_tok, _, self.caches = self._decode(
            self.params, self.caches, jnp.asarray(self.tokens_h),
            jnp.asarray(self.positions_h))
        if self._abft:
            # compile the conservation-check executables too (positions
            # are all zero -> zero valid rows, so the warmup's garbage
            # KV writes are invisible to the sums and ok is all-True)
            pos = jnp.zeros((self.sched.num_slots,), jnp.int32)
            _, cur = self._kv_check(self.caches, pos, self.kv_sums)
            jax.block_until_ready(self._kv_roll(self.caches, pos, cur))
        jax.block_until_ready(new_tok)
        self._compile_s = time.perf_counter() - t0
        # everything past this point is steady-state serving
        self._qw_calls_baseline = wquant.QUANTIZE_WEIGHT_CALLS
        return self._compile_s

    # ------------------------------------------------------- degradation
    def _degrade(self, why: str) -> bool:
        """Re-warm one rung down the ladder; False when exhausted. The
        new rung's prefill is compiled eagerly here (its dummy run
        touches no engine state); the decode executable compiles on its
        first real dispatch -- that step is exempted from the watchdog
        so a compile is not mistaken for a hang."""
        if self._rung + 1 >= len(self._ladder):
            warn_once(
                ("serving", "ladder_exhausted"),
                f"serving degradation ladder exhausted ({why}); failing "
                "in-flight requests (warned once per process; "
                "TRACE_COUNTS[('serving', 'ladder_exhausted')] keeps "
                "counting)")
            return False
        self._bind_rung(self._rung + 1)
        name = _rung_name(self._ladder[self._rung])
        self.sched.counters["degrades"] += 1
        warn_once(
            ("serving", f"degrade_{name}"),
            f"serving engine degraded to rung '{name}' "
            f"({self._rung + 1}/{len(self._ladder)}) after {why}; outputs "
            "are bitwise-unchanged (schedule/backend parity) -- warned "
            f"once per process; TRACE_COUNTS[('serving', 'degrade_{name}')]"
            " keeps counting")
        # eager prefill compile: the result is discarded, no engine
        # state is touched (prefill donates nothing)
        batch = {"tokens": jnp.zeros((1, self.prefill_len), jnp.int32)}
        out = self._prefill(self.params, batch, jnp.asarray(1, jnp.int32))
        jax.block_until_ready(out[0])
        self._watchdog_skip = 1
        self._consec_slow = 0
        return True

    def _fail_inflight(self, why: str) -> None:
        """Ladder exhausted: retire every active slot as degraded and
        drain the queue -- the engine never crashes the caller."""
        now = float(self.step)
        TRACE_COUNTS[("serving", "ladder_exhausted")] += 1
        for slot in sorted(self.sched.active):
            self.completions.append(
                self.sched.retire(slot, "engine_failed", now))
        queued = list(self.sched.queue)
        self.sched.queue.clear()
        self.sched.counters["shed"] += len(queued)
        for req in queued:
            self.completions.append(
                self.sched._unadmitted_completion(req, "shed_engine_failed"))

    # --------------------------------------------------------- lifecycle
    def submit(self, req: Request) -> Optional[Completion]:
        """Returns None on acceptance, or the ``rejected`` completion
        when the bounded queue pushed back (also appended to
        ``self.completions``)."""
        rejected = self.sched.submit(req)
        if rejected is not None:
            self.completions.append(rejected)
        return rejected

    def _admit(self, slot: int, req: Request) -> None:
        padded = np.zeros((1, self.prefill_len), np.int32)
        padded[0, :req.prompt_len] = req.tokens
        t0 = time.perf_counter()
        out = self._prefill(self.params, {"tokens": jnp.asarray(padded)},
                            jnp.asarray(req.prompt_len, jnp.int32))
        if self._guard or self._abft:
            tok, ok, kv = out
            if not bool(np.asarray(ok)[0]):
                # poisoned prefill: never insert, never emit -- retire
                # the freshly admitted slot as degraded on the spot.
                # With ABFT on, attribute first: a stale weight checksum
                # means silent corruption (sdc_detected), a clean one a
                # transient numeric event (nan_guard).
                reason = "nan_guard"
                if self._abft and self._weights_corrupt():
                    reason = "sdc_detected"
                    self._note_sdc()
                else:
                    self.sched.counters["guard_trips"] += 1
                    TRACE_COUNTS[("serving", "guard_trip")] += 1
                self.completions.append(self.sched.retire(
                    slot, reason, float(self.step)))
                return
        else:
            tok, kv = out
        self.caches = self._insert(self.caches, kv,
                                   jnp.asarray(slot, jnp.int32))
        tok_h = int(jax.block_until_ready(tok)[0])
        dt_ms = (time.perf_counter() - t0) * 1e3
        TRACE_COUNTS[("serving", "prefill_insert")] += 1
        self.sched.counters["prefill_inserts"] += 1

        st = self.sched.active[slot]
        st.generated.append(tok_h)
        st.latencies_ms.append(dt_ms)
        self.tokens_h[slot, 0] = tok_h
        self.positions_h[slot] = st.pos
        if self._abft:
            # rebase the slot's conservation state from the freshly
            # inserted KV block (insert rewrites the block wholesale);
            # blocked so this cache read cannot still be in flight when
            # the next decode donates the buffers it walks
            self.kv_sums = jax.block_until_ready(self._kv_reset(
                self.kv_sums, self.caches, jnp.asarray(slot, jnp.int32),
                jnp.asarray(int(st.pos), jnp.int32)))
        self._maybe_retire(slot, tok_h)

    def _maybe_retire(self, slot: int, last_tok: int) -> bool:
        st = self.sched.active[slot]
        reason = None
        if self.eos_id is not None and last_tok == self.eos_id:
            reason = "eos"
        elif len(st.generated) >= st.max_new_tokens:
            reason = "length"
        elif st.pos >= self.max_len:
            reason = "cache_full"
        if reason is None:
            return False
        self.completions.append(
            self.sched.retire(slot, reason, float(self.step)))
        return True

    def _retire_expired_inflight(self, now: float) -> None:
        for slot in sorted(self.sched.active):
            st = self.sched.active[slot]
            if st.deadline is not None and st.deadline <= now:
                self.sched.counters["deadline_retired"] += 1
                TRACE_COUNTS[("serving", "deadline_retire")] += 1
                self.completions.append(
                    self.sched.retire(slot, "deadline", now))

    # -------------------------------------------------------------- run
    def run(self, requests: Sequence[Request]) -> List[Completion]:
        """Serve a whole arrival stream to completion; returns the
        completion records (also accumulated on ``self.completions``)."""
        self.warmup()
        for req in requests:
            self.submit(req)
        while self.sched.has_work():
            now = float(self.step)
            # shed queued requests whose TTL expired before a slot freed
            self.completions.extend(self.sched.shed_expired(now))
            # retire in-flight slots past their deadline (distinct
            # status from a natural finish)
            self._retire_expired_inflight(now)
            # admissions: prefill-insert every arrived request a free
            # slot can take, straight into the running decode batch
            while True:
                adm = self.sched.next_admission(now)
                if adm is None:
                    break
                self._admit(*adm)
            if not self.sched.active:
                nxt = self.sched.next_arrival()
                if nxt is None:
                    break
                # idle: jump the step clock to the next arrival
                self.step = max(self.step + 1, int(np.ceil(nxt)))
                self._idle_steps += 1
                continue
            self._decode_step()
        return self.completions

    def _inject_faults(self) -> None:
        """Apply this step's scheduled state corruptions (NaN pokes,
        silent bit flips / row perturbations / tile clobbers) at the TOP
        of the step, before the ABFT kv_check reads the caches -- so a
        corruption landing at step N is detectable at step N, exactly
        like a cosmic-ray flip that happened between dispatches."""
        plan = faults.active()
        if plan is None:
            return
        if plan.should_poke(self.step):
            row = int(self.positions_h[plan.nan_poke_slot]) - 1
            if row >= 0:
                self.caches = faults.poke_nan(
                    self.caches, plan.nan_poke_slot, row)
        if plan.should_corrupt(self.step):
            self._inject_corruption(plan)

    def _dispatch_decode(self):
        """One decode dispatch at the current rung, with the per-attempt
        fault hooks at the host boundary: an injected raise fires BEFORE
        the jitted call, so the donated caches were not consumed and a
        retry runs on intact state."""
        plan = faults.active()
        if plan is not None:
            d = plan.delay_s(self.step)
            if d > 0.0:
                time.sleep(d)
            plan.maybe_raise(self.step)
        return self._decode(
            self.params, self.caches, jnp.asarray(self.tokens_h),
            jnp.asarray(self.positions_h))

    def _inject_corruption(self, plan) -> None:
        """Apply a scheduled SILENT corruption at the host boundary
        (params are never donated; the cache write goes through the same
        functional update path as ``poke_nan``)."""
        if plan.corrupt_kind == "weight":
            self.params = faults.flip_weight_bit(self.params,
                                                 bit=plan.corrupt_bit)
        elif plan.corrupt_kind == "kv":
            row = int(self.positions_h[plan.kv_corrupt_slot]) - 1
            if row >= 0:
                self.caches = faults.perturb_kv_row(
                    self.caches, plan.kv_corrupt_slot, row)
        elif plan.corrupt_kind == "tile":
            self.params = faults.clobber_stream_tile(self.params)
        else:
            raise ValueError(
                f"unknown corrupt_kind {plan.corrupt_kind!r}")

    def _decode_with_recovery(self):
        """Dispatch; on failure retry ONCE on the same rung (transient
        fault, caches intact), then walk the degradation ladder. None =
        ladder exhausted."""
        try:
            return self._dispatch_decode()
        except Exception as e:
            first = e
        self.sched.counters["step_retries"] += 1
        TRACE_COUNTS[("serving", "step_retry")] += 1
        try:
            return self._dispatch_decode()
        except Exception:
            pass
        while self._degrade(f"decode failure: {first!r}"):
            try:
                return self._dispatch_decode()
            except Exception:
                continue
        return None

    # -------------------------------------------------------------- abft
    def _weights_corrupt(self) -> bool:
        """On-demand weight attribution after a logits-level trip: do the
        live weights still match their stored ABFT checksums? Cached per
        engine step so one corrupted step verifies the tree once however
        many slots tripped."""
        if self._params_check_step != self.step:
            self._params_check_step = self.step
            TRACE_COUNTS[("abft", "params_check")] += 1
            self._params_check_ok = verify.params_ok(self.params)
        return not self._params_check_ok

    def _note_sdc(self) -> None:
        """Record an SDC detection; sustained detections (>= 2 within
        ``_SDC_WINDOW_STEPS`` engine steps) feed the degradation ladder:
        if the corruption lives in one rung's machinery (a sick kernel
        path, a bad stream buffer) the re-warm clears it, and if not the
        ladder eventually exhausts and fails loudly -- never silently."""
        TRACE_COUNTS[("abft", "sdc_detected")] += 1
        self.sched.counters["sdc_retired"] += 1
        self._sdc_trips.append(self.step)
        recent = [s for s in self._sdc_trips
                  if self.step - s <= _SDC_WINDOW_STEPS]
        if len(recent) >= 2:
            self._sdc_trips.clear()
            self._degrade("repeated ABFT SDC detections")

    def _abft_rebase_slot(self, slot: int) -> None:
        """Re-anchor one slot's KV conservation state to the cache as it
        is NOW, over the slot's current row count. Called when a slot is
        retired mid-trip (its position stops advancing, so the carried
        sum+delta rollforward would drift from the recompute) -- after
        this, a dead slot verifies trivially until reuse rebases it
        again at insert."""
        self.kv_sums = jax.block_until_ready(self._kv_reset(
            self.kv_sums, self.caches, jnp.asarray(slot, jnp.int32),
            jnp.asarray(int(self.positions_h[slot]), jnp.int32)))

    def _decode_step(self) -> None:
        t0 = time.perf_counter()
        self._inject_faults()
        kv_ok = cur = pos = None
        if self._abft:
            # pre-decode integrity gate on the exact caches the donated
            # step is about to consume. block_until_ready serializes the
            # read against the donated in-place reuse: an async-pending
            # whole-cache read racing a donation is a runtime conflict,
            # not a dataflow edge
            pos = jnp.asarray(self.positions_h)
            kv_ok, cur = self._kv_check(self.caches, pos, self.kv_sums)
            jax.block_until_ready(cur)
        out = self._decode_with_recovery()
        if out is None:
            self._fail_inflight("decode failed on every ladder rung")
            return
        new_tok, mid, self.caches = out
        ok_h = np.asarray(mid) if (self._guard or self._abft) else None
        kv_ok_h = None
        if self._abft:
            # roll the conservation state over the one row the step just
            # wrote per slot (at the pre-step positions); blocked for the
            # same reason as the pre-step check -- the NEXT step donates
            # the cache buffers this read walks
            self.kv_sums = jax.block_until_ready(
                self._kv_roll(self.caches, pos, cur))
            kv_ok_h = np.asarray(kv_ok)
        new_tok_h = np.asarray(new_tok)           # blocks until ready
        dt_ms = (time.perf_counter() - t0) * 1e3
        self._decode_s += dt_ms * 1e-3
        self._step_latencies_ms.append(dt_ms)
        self._occupancy.append(self.sched.occupancy)
        self.step += 1
        self._watchdog(dt_ms)
        for slot in sorted(self.sched.active):
            st = self.sched.active[slot]
            if kv_ok_h is not None and not bool(kv_ok_h[slot]):
                # KV conservation broke with finite values: silent
                # corruption of already-written cache rows, attributed
                # directly (the NaN case routes to the logits guard)
                TRACE_COUNTS[("abft", "kv_trip")] += 1
                self._note_sdc()
                self.completions.append(self.sched.retire(
                    slot, "sdc_detected", float(self.step)))
                self._abft_rebase_slot(slot)
                continue
            if ok_h is not None and not bool(ok_h[slot]):
                # logits-level trip: NaN from a numeric event OR the
                # kernel checksum's NaN-poisoned rows. With ABFT on,
                # attribute by re-verifying the weight checksums.
                reason = "nan_guard"
                if self._abft and self._weights_corrupt():
                    reason = "sdc_detected"
                    self._note_sdc()
                else:
                    self.sched.counters["guard_trips"] += 1
                    TRACE_COUNTS[("serving", "guard_trip")] += 1
                self.completions.append(self.sched.retire(
                    slot, reason, float(self.step)))
                if self._abft:
                    self._abft_rebase_slot(slot)
                continue
            tok = int(new_tok_h[slot, 0])
            st.generated.append(tok)
            st.latencies_ms.append(dt_ms)
            st.pos += 1
            self.tokens_h[slot, 0] = tok
            self.positions_h[slot] = st.pos
            self._maybe_retire(slot, tok)

    def _watchdog(self, dt_ms: float) -> None:
        """Post-hoc step watchdog: a synchronous jit dispatch cannot be
        preempted, so the bound is checked after the fact (the slow
        step's result is still valid and used). Two CONSECUTIVE trips
        mean sustained sickness, not a scheduling blip -> degrade."""
        if self._watchdog_ms is None:
            return
        if self._watchdog_skip > 0:      # first step after a re-warm
            self._watchdog_skip -= 1     # compiles; not a hang
            return
        if dt_ms <= self._watchdog_ms:
            self._consec_slow = 0
            return
        self._consec_slow += 1
        self.sched.counters["watchdog_trips"] += 1
        TRACE_COUNTS[("serving", "watchdog_trip")] += 1
        if self._consec_slow >= 2:
            self._consec_slow = 0
            self._degrade(
                f"watchdog: 2 consecutive steps over "
                f"{self._watchdog_ms} ms")

    # ------------------------------------------------------ observability
    def decode_cache_size(self) -> int:
        """Total compiled decode executables across every rung bound so
        far -- 1 in steady state (fixed shapes, host-side scheduling),
        +1 per degradation re-warm and nothing else."""
        return sum(j._cache_size() for j in self._decode_jits)

    def quantize_weight_calls_during_serve(self) -> int:
        """quantize_weight invocations since warmup -- 0 on the prequant
        path (QTensor weights are consumed directly)."""
        return wquant.QUANTIZE_WEIGHT_CALLS - self._qw_calls_baseline

    def health(self) -> Dict[str, int]:
        """Structured robustness snapshot: the degradation / watchdog /
        numeric-guard / ABFT counters for THIS engine. TRACE_COUNTS keys
        are process-global, so they were snapshotted at construction and
        are reported here as deltas; scheduler counters are already
        per-engine."""
        delta = {k: int(TRACE_COUNTS[k] - self._trace_base[k])
                 for k in _HEALTH_TRACE_KEYS}
        return {
            "abft_enabled": int(self._abft),
            "guards_enabled": int(self._guard),
            "rung": int(self._rung),
            "degrades": int(self.sched.counters.get("degrades", 0)),
            "watchdog_trips": int(
                self.sched.counters.get("watchdog_trips", 0)),
            "step_retries": int(self.sched.counters.get("step_retries", 0)),
            "deadline_retired": int(
                self.sched.counters.get("deadline_retired", 0)),
            "nan_guard_trips": int(
                self.sched.counters.get("guard_trips", 0)),
            "sdc_retired": int(self.sched.counters.get("sdc_retired", 0)),
            "abft_kv_trips": delta[("abft", "kv_trip")],
            "abft_sdc_detections": delta[("abft", "sdc_detected")],
            "abft_params_checks": delta[("abft", "params_check")],
        }

    def summary(self) -> Dict[str, Any]:
        # per-token latencies: decode-produced tokens only (index 0 is the
        # prefill-produced first token, whose cost is the admission)
        lat = np.asarray([ms for c in self.completions
                          for ms in c.latencies_ms[1:]] or [0.0])
        gen = sum(len(c.tokens) for c in self.completions)
        gen_decode = sum(max(len(c.tokens) - 1, 0) for c in self.completions)
        by_status: Dict[str, int] = {}
        for c in self.completions:
            by_status[c.status] = by_status.get(c.status, 0) + 1
        return {
            "requests": len(self.completions),
            "generated_tokens": gen,
            "decode_steps": len(self._step_latencies_ms),
            "idle_steps": self._idle_steps,
            "tokens_per_s": (gen_decode / self._decode_s
                            if self._decode_s else 0.0),
            "occupancy": float(np.mean(self._occupancy)) if self._occupancy
            else 0.0,
            "p50_token_ms": float(np.percentile(lat, 50)),
            "p99_token_ms": float(np.percentile(lat, 99)),
            "compile_s": self._compile_s or 0.0,
            "decode_s": self._decode_s,
            "decode_executables": self.decode_cache_size(),
            "quantize_weight_calls": self.quantize_weight_calls_during_serve(),
            "kv_cache_bytes": cache_bytes(self.cfg, self.sched.num_slots,
                                          self.max_len),
            "rung": self._rung,
            "guards_enabled": int(self._guard),
            "abft_enabled": int(self._abft),
            "health": self.health(),
            **{f"status_{k}": v for k, v in sorted(by_status.items())},
            **{k: int(v) for k, v in self.sched.counters.items()},
        }
