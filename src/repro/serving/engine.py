"""Continuous-batching serving engine over pre-quantized QTensor weights.

The one-shot launcher (``launch/serve.py``) prefills a fixed batch, then
decodes every row in lockstep behind a single scalar ``pos`` until the
whole batch exits together. A production serving loop admits and retires
requests *mid-decode*. This engine does that with three jitted device
functions, each compiled exactly once per engine:

  prefill   (params, {tokens:(1,P)}, length) -> (first token, KV rows)
            -- prompts are right-padded to the fixed prefill bucket P, so
            every admission hits the same compiled executable; under the
            causal mask the padding rows never influence positions
            < length, and the logits are gathered at length-1.
  insert    (caches, kv, slot) -> caches    [donated caches]
            -- scatter the newcomer's KV block into its slot.
  decode    (params, caches, tokens, positions) -> tokens [donated caches]
            -- ``launch.steps.jit_serve_step(per_slot=True)``: one step
            over ALL slots with a (slots,) position vector; every slot
            writes and attends at its own depth.

The KV cache is allocated ONCE (``serving.cache``) in the serving quant
dtype; admissions, retirements, and slot reuse are host-side scheduler
bookkeeping (``serving.scheduler``) plus donated in-place updates -- the
steady-state decode step neither reallocates nor retraces (the decode
executable count stays 1 across the whole run unless the degradation
ladder re-warms; see ``decode_cache_size``). With ``cfg.weight_quant ==
'int8'`` the weights are pre-quantized QTensors, so the serving forward
performs zero ``quantize_weight`` calls after engine construction
(tracked via ``wquant.QUANTIZE_WEIGHT_CALLS``).

Robustness layer (PR 8, DESIGN.md section 12):

  * request lifecycle -- per-request deadlines (expired queued requests
    shed before admission; in-flight slots past deadline retired as
    ``timed_out``), bounded admission queue with immediate ``rejected``
    completions (``max_queue``);
  * decode watchdog -- ``watchdog_ms`` bounds per-step wall clock; the
    check is post-hoc (a synchronous jit dispatch cannot be preempted),
    so a slow step's result is still used, and two CONSECUTIVE trips
    trigger a degradation re-warm;
  * graceful degradation ladder -- a decode dispatch that raises is
    retried once on intact caches (faults fire at the host boundary,
    BEFORE the donated operands are consumed), then the engine re-warms
    one rung down: pallas/streamed -> pallas/rotate_once -> xla. Every
    rung is bitwise-identical by construction (asserted by the
    quant_dot parity tests), so mid-run degradation never changes
    emitted tokens. Rung switches tick
    ``TRACE_COUNTS[("serving", "degrade_<rung>")]`` and warn once;
  * numeric guardrails -- with ``REPRO_NUMERIC_GUARDS=1`` the jitted
    steps carry isfinite/positive-scale reductions
    (``core.guards``); a tripped slot is retired as ``degraded``
    (reason ``nan_guard``) at the step boundary instead of emitting
    poisoned tokens. Guard-off and guard-on runs are bitwise identical
    on healthy requests (guards observe, never perturb).

Fault injection (tests): ``repro.testing.faults`` installs a context-
scoped ``FaultPlan`` the engine polls at each decode dispatch --
synthetic kernel raises, artificial step latency, NaN pokes into live
KV rows. Zero-fault overhead is one attribute load + None check.

Timing discipline: ``warmup()`` pays all three compiles on dummy inputs
before any request is admitted, so reported per-token latencies are
steady-state (the same fix applied to ``serve.py``'s timed loop).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import guards, wquant
from repro.distributed import sharding as shd
from repro.kernels.registry import TRACE_COUNTS, warn_once
from repro.launch.steps import jit_serve_step
from repro.models.config import ModelConfig
from repro.models.lm import lm_forward
from repro.serving.cache import alloc_kv_caches, cache_bytes, make_insert_fn
from repro.serving.scheduler import Completion, Request, Scheduler
from repro.testing import faults

_SUPPORTED_KINDS = ("attn", "moe")


def _validate_config(cfg: ModelConfig) -> None:
    """Continuous batching needs position-addressable per-token caches;
    right-padded bucket prefill is only exact for causal attention (a
    padded row can never influence an earlier position). Scan-state
    architectures (mamba/rwkv) carry their whole prefix in one state
    tensor, so a padded prefill would fold padding into the state."""
    kinds = {k for pattern, _ in cfg.groups for k in pattern}
    bad = kinds - set(_SUPPORTED_KINDS)
    if bad or cfg.is_encdec or cfg.family == "vlm":
        raise ValueError(
            f"serving engine supports causal attention stacks only "
            f"(kinds {_SUPPORTED_KINDS}); config {cfg.name!r} has "
            f"kinds={sorted(kinds)} family={cfg.family!r} "
            f"encdec={cfg.is_encdec}")


def _make_prefill_fn(cfg: ModelConfig, guard: bool = False):
    def prefill(params, batch, length):
        logits, _, caches = lm_forward(cfg, params, batch, want_cache=True)
        # right-padded bucket: the request's last real token sits at
        # length-1; everything past it is padding the causal mask keeps
        # out of positions < length
        last = jax.lax.dynamic_slice_in_dim(logits, length - 1, 1, axis=1)
        tok = jnp.argmax(last[:, -1], axis=-1).astype(jnp.int32)
        return tok, caches

    if not guard:
        return prefill

    def guarded_prefill(params, batch, length):
        logits, _, caches = lm_forward(cfg, params, batch, want_cache=True)
        last = jax.lax.dynamic_slice_in_dim(logits, length - 1, 1, axis=1)
        ok = guards.rows_ok(last[:, -1], batch["tokens"].shape[0])
        tok = jnp.argmax(last[:, -1], axis=-1).astype(jnp.int32)
        return tok, ok, caches

    return guarded_prefill


def _degradation_ladder(cfg: ModelConfig) -> List[ModelConfig]:
    """The rungs below ``cfg``, most-capable first. Every rung computes
    bitwise-identical results (schedule/backend parity is asserted by the
    quant_dot tests); each is strictly simpler machinery:

        pallas + streamed  ->  pallas + rotate_once  ->  xla

    A config already on 'xla' has no lower rung: a failure there
    exhausts the ladder and fails the in-flight requests loudly."""
    ladder = [cfg]
    q = cfg.quant
    if q.backend in ("pallas", "auto"):
        if q.schedule != "rotate_once":
            ladder.append(cfg.with_quant(
                dataclasses.replace(q, schedule="rotate_once")))
        ladder.append(cfg.with_quant(
            dataclasses.replace(q, backend="xla", schedule=None)))
    elif q.backend == "ref":
        ladder.append(cfg.with_quant(
            dataclasses.replace(q, backend="xla", schedule=None)))
    return ladder


def _rung_name(cfg: ModelConfig) -> str:
    q = cfg.quant
    if q.backend == "xla":
        return "xla"
    return q.schedule or "default"


class ServeEngine:
    """Drives jitted prefill/insert/decode steps over a request stream.

    params must already be placed with ``launch.steps.param_shardings``
    (the launchers' init path); with ``cfg.weight_quant == 'int8'`` they
    are the pre-quantized QTensor tree."""

    def __init__(self, cfg: ModelConfig, params, mesh, *,
                 num_slots: int, max_len: int, prefill_len: int,
                 eos_id: Optional[int] = None, rules_overrides=None,
                 max_queue: Optional[int] = None,
                 watchdog_ms: Optional[float] = None):
        _validate_config(cfg)
        self.cfg = cfg
        self.params = params
        self.mesh = mesh
        self.eos_id = eos_id
        self.prefill_len = prefill_len
        self.max_len = max_len
        self.sched = Scheduler(num_slots, max_len, prefill_len,
                               max_queue=max_queue)
        self._rules_overrides = rules_overrides
        self._guard = guards.guards_enabled()
        self._watchdog_ms = watchdog_ms
        self._watchdog_skip = 0       # steps exempted after a re-warm
        self._consec_slow = 0

        self._ladder = _degradation_ladder(cfg)
        self._rung = 0
        self._decode_jits: list = []

        # insert is rung-independent (a pure cache scatter: its trace
        # never touches quant schedule or backend), so it is compiled
        # once and shared across every rung
        self._insert = jax.jit(self._in_rules(make_insert_fn(cfg)),
                               donate_argnums=(0,))
        self._bind_rung(0)

        # the ONE cache allocation of the engine's lifetime
        cs = self._decode_shardings[1]
        self.caches = jax.device_put(
            alloc_kv_caches(cfg, num_slots, max_len), cs)
        self.tokens_h = np.zeros((num_slots, 1), np.int32)
        self.positions_h = np.zeros((num_slots,), np.int32)

        self.step = 0
        self.completions: List[Completion] = []
        self._step_latencies_ms: List[float] = []
        self._occupancy: List[float] = []
        self._decode_s = 0.0
        self._compile_s: Optional[float] = None
        self._idle_steps = 0
        self._qw_calls_baseline = wquant.QUANTIZE_WEIGHT_CALLS

    def _in_rules(self, fn):
        mesh, overrides = self.mesh, self._rules_overrides

        def wrapped(*a):
            with shd.sharding_rules(mesh, overrides):
                return fn(*a)
        return wrapped

    def _bind_rung(self, i: int) -> None:
        """Compile-bind the jitted prefill/decode for ladder rung ``i``
        (lazily compiled on first call, as all jax.jit wrappers are)."""
        cfg = self._ladder[i]
        self._rung = i
        self._prefill = jax.jit(
            self._in_rules(_make_prefill_fn(cfg, guard=self._guard)))
        self._decode, self._decode_shardings = jit_serve_step(
            cfg, self.sched.num_slots, self.max_len, self.mesh,
            rules_overrides=self._rules_overrides,
            donate=True, per_slot=True, guard=self._guard)
        self._decode_jits.append(self._decode)

    # ---------------------------------------------------------- warm-up
    def warmup(self) -> float:
        """Compile prefill/insert/decode on dummy inputs before serving,
        so no request's latency includes a jit compile. Writes garbage
        into cache rows that are by-construction never attended before
        being overwritten (prefill-insert rewrites [0, P) on admission;
        decode rewrites row ``pos`` before attending it)."""
        if self._compile_s is not None:
            return self._compile_s
        t0 = time.perf_counter()
        batch = {"tokens": jnp.zeros((1, self.prefill_len), jnp.int32)}
        out = self._prefill(self.params, batch, jnp.asarray(1, jnp.int32))
        kv = out[-1]
        self.caches = self._insert(self.caches, kv,
                                   jnp.asarray(0, jnp.int32))
        new_tok, _, self.caches = self._decode(
            self.params, self.caches, jnp.asarray(self.tokens_h),
            jnp.asarray(self.positions_h))
        jax.block_until_ready(new_tok)
        self._compile_s = time.perf_counter() - t0
        # everything past this point is steady-state serving
        self._qw_calls_baseline = wquant.QUANTIZE_WEIGHT_CALLS
        return self._compile_s

    # ------------------------------------------------------- degradation
    def _degrade(self, why: str) -> bool:
        """Re-warm one rung down the ladder; False when exhausted. The
        new rung's prefill is compiled eagerly here (its dummy run
        touches no engine state); the decode executable compiles on its
        first real dispatch -- that step is exempted from the watchdog
        so a compile is not mistaken for a hang."""
        if self._rung + 1 >= len(self._ladder):
            warn_once(
                ("serving", "ladder_exhausted"),
                f"serving degradation ladder exhausted ({why}); failing "
                "in-flight requests (warned once per process; "
                "TRACE_COUNTS[('serving', 'ladder_exhausted')] keeps "
                "counting)")
            return False
        self._bind_rung(self._rung + 1)
        name = _rung_name(self._ladder[self._rung])
        self.sched.counters["degrades"] += 1
        warn_once(
            ("serving", f"degrade_{name}"),
            f"serving engine degraded to rung '{name}' "
            f"({self._rung + 1}/{len(self._ladder)}) after {why}; outputs "
            "are bitwise-unchanged (schedule/backend parity) -- warned "
            f"once per process; TRACE_COUNTS[('serving', 'degrade_{name}')]"
            " keeps counting")
        # eager prefill compile: the result is discarded, no engine
        # state is touched (prefill donates nothing)
        batch = {"tokens": jnp.zeros((1, self.prefill_len), jnp.int32)}
        out = self._prefill(self.params, batch, jnp.asarray(1, jnp.int32))
        jax.block_until_ready(out[0])
        self._watchdog_skip = 1
        self._consec_slow = 0
        return True

    def _fail_inflight(self, why: str) -> None:
        """Ladder exhausted: retire every active slot as degraded and
        drain the queue -- the engine never crashes the caller."""
        now = float(self.step)
        TRACE_COUNTS[("serving", "ladder_exhausted")] += 1
        for slot in sorted(self.sched.active):
            self.completions.append(
                self.sched.retire(slot, "engine_failed", now))
        queued = list(self.sched.queue)
        self.sched.queue.clear()
        self.sched.counters["shed"] += len(queued)
        for req in queued:
            self.completions.append(
                self.sched._unadmitted_completion(req, "shed_engine_failed"))

    # --------------------------------------------------------- lifecycle
    def submit(self, req: Request) -> Optional[Completion]:
        """Returns None on acceptance, or the ``rejected`` completion
        when the bounded queue pushed back (also appended to
        ``self.completions``)."""
        rejected = self.sched.submit(req)
        if rejected is not None:
            self.completions.append(rejected)
        return rejected

    def _admit(self, slot: int, req: Request) -> None:
        padded = np.zeros((1, self.prefill_len), np.int32)
        padded[0, :req.prompt_len] = req.tokens
        t0 = time.perf_counter()
        out = self._prefill(self.params, {"tokens": jnp.asarray(padded)},
                            jnp.asarray(req.prompt_len, jnp.int32))
        if self._guard:
            tok, ok, kv = out
            if not bool(np.asarray(ok)[0]):
                # poisoned prefill: never insert, never emit -- retire
                # the freshly admitted slot as degraded on the spot
                self.sched.counters["guard_trips"] += 1
                TRACE_COUNTS[("serving", "guard_trip")] += 1
                self.completions.append(self.sched.retire(
                    slot, "nan_guard", float(self.step)))
                return
        else:
            tok, kv = out
        self.caches = self._insert(self.caches, kv,
                                   jnp.asarray(slot, jnp.int32))
        tok_h = int(jax.block_until_ready(tok)[0])
        dt_ms = (time.perf_counter() - t0) * 1e3
        TRACE_COUNTS[("serving", "prefill_insert")] += 1
        self.sched.counters["prefill_inserts"] += 1

        st = self.sched.active[slot]
        st.generated.append(tok_h)
        st.latencies_ms.append(dt_ms)
        self.tokens_h[slot, 0] = tok_h
        self.positions_h[slot] = st.pos
        self._maybe_retire(slot, tok_h)

    def _maybe_retire(self, slot: int, last_tok: int) -> bool:
        st = self.sched.active[slot]
        reason = None
        if self.eos_id is not None and last_tok == self.eos_id:
            reason = "eos"
        elif len(st.generated) >= st.max_new_tokens:
            reason = "length"
        elif st.pos >= self.max_len:
            reason = "cache_full"
        if reason is None:
            return False
        self.completions.append(
            self.sched.retire(slot, reason, float(self.step)))
        return True

    def _retire_expired_inflight(self, now: float) -> None:
        for slot in sorted(self.sched.active):
            st = self.sched.active[slot]
            if st.deadline is not None and st.deadline <= now:
                self.sched.counters["deadline_retired"] += 1
                TRACE_COUNTS[("serving", "deadline_retire")] += 1
                self.completions.append(
                    self.sched.retire(slot, "deadline", now))

    # -------------------------------------------------------------- run
    def run(self, requests: Sequence[Request]) -> List[Completion]:
        """Serve a whole arrival stream to completion; returns the
        completion records (also accumulated on ``self.completions``)."""
        self.warmup()
        for req in requests:
            self.submit(req)
        while self.sched.has_work():
            now = float(self.step)
            # shed queued requests whose TTL expired before a slot freed
            self.completions.extend(self.sched.shed_expired(now))
            # retire in-flight slots past their deadline (distinct
            # status from a natural finish)
            self._retire_expired_inflight(now)
            # admissions: prefill-insert every arrived request a free
            # slot can take, straight into the running decode batch
            while True:
                adm = self.sched.next_admission(now)
                if adm is None:
                    break
                self._admit(*adm)
            if not self.sched.active:
                nxt = self.sched.next_arrival()
                if nxt is None:
                    break
                # idle: jump the step clock to the next arrival
                self.step = max(self.step + 1, int(np.ceil(nxt)))
                self._idle_steps += 1
                continue
            self._decode_step()
        return self.completions

    def _dispatch_decode(self):
        """One decode dispatch at the current rung, with fault hooks at
        the host boundary: an injected raise fires BEFORE the jitted
        call, so the donated caches were not consumed and a retry runs
        on intact state."""
        plan = faults.active()
        if plan is not None:
            if plan.should_poke(self.step):
                row = int(self.positions_h[plan.nan_poke_slot]) - 1
                if row >= 0:
                    self.caches = faults.poke_nan(
                        self.caches, plan.nan_poke_slot, row)
            d = plan.delay_s(self.step)
            if d > 0.0:
                time.sleep(d)
            plan.maybe_raise(self.step)
        return self._decode(
            self.params, self.caches, jnp.asarray(self.tokens_h),
            jnp.asarray(self.positions_h))

    def _decode_with_recovery(self):
        """Dispatch; on failure retry ONCE on the same rung (transient
        fault, caches intact), then walk the degradation ladder. None =
        ladder exhausted."""
        try:
            return self._dispatch_decode()
        except Exception as e:
            first = e
        self.sched.counters["step_retries"] += 1
        TRACE_COUNTS[("serving", "step_retry")] += 1
        try:
            return self._dispatch_decode()
        except Exception:
            pass
        while self._degrade(f"decode failure: {first!r}"):
            try:
                return self._dispatch_decode()
            except Exception:
                continue
        return None

    def _decode_step(self) -> None:
        t0 = time.perf_counter()
        out = self._decode_with_recovery()
        if out is None:
            self._fail_inflight("decode failed on every ladder rung")
            return
        new_tok, mid, self.caches = out
        new_tok_h = np.asarray(new_tok)           # blocks until ready
        ok_h = np.asarray(mid) if self._guard else None
        dt_ms = (time.perf_counter() - t0) * 1e3
        self._decode_s += dt_ms * 1e-3
        self._step_latencies_ms.append(dt_ms)
        self._occupancy.append(self.sched.occupancy)
        self.step += 1
        self._watchdog(dt_ms)
        for slot in sorted(self.sched.active):
            st = self.sched.active[slot]
            if ok_h is not None and not bool(ok_h[slot]):
                # numeric guard tripped this slot: retire as degraded
                # instead of emitting a poisoned token
                self.sched.counters["guard_trips"] += 1
                TRACE_COUNTS[("serving", "guard_trip")] += 1
                self.completions.append(self.sched.retire(
                    slot, "nan_guard", float(self.step)))
                continue
            tok = int(new_tok_h[slot, 0])
            st.generated.append(tok)
            st.latencies_ms.append(dt_ms)
            st.pos += 1
            self.tokens_h[slot, 0] = tok
            self.positions_h[slot] = st.pos
            self._maybe_retire(slot, tok)

    def _watchdog(self, dt_ms: float) -> None:
        """Post-hoc step watchdog: a synchronous jit dispatch cannot be
        preempted, so the bound is checked after the fact (the slow
        step's result is still valid and used). Two CONSECUTIVE trips
        mean sustained sickness, not a scheduling blip -> degrade."""
        if self._watchdog_ms is None:
            return
        if self._watchdog_skip > 0:      # first step after a re-warm
            self._watchdog_skip -= 1     # compiles; not a hang
            return
        if dt_ms <= self._watchdog_ms:
            self._consec_slow = 0
            return
        self._consec_slow += 1
        self.sched.counters["watchdog_trips"] += 1
        TRACE_COUNTS[("serving", "watchdog_trip")] += 1
        if self._consec_slow >= 2:
            self._consec_slow = 0
            self._degrade(
                f"watchdog: 2 consecutive steps over "
                f"{self._watchdog_ms} ms")

    # ------------------------------------------------------ observability
    def decode_cache_size(self) -> int:
        """Total compiled decode executables across every rung bound so
        far -- 1 in steady state (fixed shapes, host-side scheduling),
        +1 per degradation re-warm and nothing else."""
        return sum(j._cache_size() for j in self._decode_jits)

    def quantize_weight_calls_during_serve(self) -> int:
        """quantize_weight invocations since warmup -- 0 on the prequant
        path (QTensor weights are consumed directly)."""
        return wquant.QUANTIZE_WEIGHT_CALLS - self._qw_calls_baseline

    def summary(self) -> Dict[str, float]:
        # per-token latencies: decode-produced tokens only (index 0 is the
        # prefill-produced first token, whose cost is the admission)
        lat = np.asarray([ms for c in self.completions
                          for ms in c.latencies_ms[1:]] or [0.0])
        gen = sum(len(c.tokens) for c in self.completions)
        gen_decode = sum(max(len(c.tokens) - 1, 0) for c in self.completions)
        by_status: Dict[str, int] = {}
        for c in self.completions:
            by_status[c.status] = by_status.get(c.status, 0) + 1
        return {
            "requests": len(self.completions),
            "generated_tokens": gen,
            "decode_steps": len(self._step_latencies_ms),
            "idle_steps": self._idle_steps,
            "tokens_per_s": (gen_decode / self._decode_s
                            if self._decode_s else 0.0),
            "occupancy": float(np.mean(self._occupancy)) if self._occupancy
            else 0.0,
            "p50_token_ms": float(np.percentile(lat, 50)),
            "p99_token_ms": float(np.percentile(lat, 99)),
            "compile_s": self._compile_s or 0.0,
            "decode_s": self._decode_s,
            "decode_executables": self.decode_cache_size(),
            "quantize_weight_calls": self.quantize_weight_calls_during_serve(),
            "kv_cache_bytes": cache_bytes(self.cfg, self.sched.num_slots,
                                          self.max_len),
            "rung": self._rung,
            "guards_enabled": int(self._guard),
            **{f"status_{k}": v for k, v in sorted(by_status.items())},
            **{k: int(v) for k, v in self.sched.counters.items()},
        }
