"""Request-level scheduler for the continuous-batching engine.

Pure host-side bookkeeping -- no jax types -- so it is unit-testable
without a device and never causes a retrace: the device only ever sees
fixed-shape (slots,) position vectors and (slots, 1) token arrays.

Lifecycle of a request (DESIGN.md sections 10, 12):

    submit -> [bounded arrival queue | rejected]
           -> admit (free slot + arrived + deadline not already blown;
                     expired queued requests are SHED before admission)
           -> prefill-insert (engine) -> decode steps -> retire
           (EOS / max-new-tokens / cache-full / deadline / guard trip)
           -> slot back on free list

The free list gives retired slots back in LIFO order (immediate reuse --
the hot slot's cache rows are the ones most recently touched).
Admission is FCFS from the arrival queue; a step where the queue head
has arrived but no slot is free counts one ``queue_full_stall``.

Robustness invariants (PR 8):

  * bounded admission queue: ``max_queue`` caps queued-but-unadmitted
    requests; ``submit`` on a full queue returns a ``rejected``
    Completion immediately (backpressure) instead of growing unbounded;
  * per-request deadlines: ``Request.deadline`` (absolute, step units)
    -- expired requests still in the queue are shed by
    ``shed_expired`` without ever occupying a slot; in-flight slots
    past deadline are retired by the engine with reason ``deadline``;
  * monotonic clock: ``now`` values are clamped through an internal
    high-water mark, so a backwards wall-clock jump (NTP step, clock
    slew) can never stall admission forever -- the pre-fix failure was
    ``queue[0].arrival_time > now`` holding for every subsequent call.

Every Completion carries ``status``: 'ok' (eos/length/cache_full),
'timed_out' (deadline / deadline_shed), 'rejected' (queue_full), or
'degraded' (nan_guard / engine_failed / shed_engine_failed).

Observability: every transition bumps
``kernels.registry.TRACE_COUNTS[("serving", <event>)]`` (admit / retire /
prefill_insert / queue_full_stall / deadline_shed / queue_reject) plus
per-scheduler counters, so tests and the engine's stats report read one
shared ledger.
"""
from __future__ import annotations

import collections
import dataclasses
from typing import Deque, Dict, List, Optional, Tuple

import numpy as np

from repro.kernels.registry import TRACE_COUNTS


@dataclasses.dataclass(frozen=True)
class Request:
    """One generation request. ``arrival_time`` is in decode-step units
    (the synthetic streams are step-clocked, not wall-clocked)."""

    rid: int
    tokens: np.ndarray              # (prompt_len,) int32 prompt ids
    max_new_tokens: int
    arrival_time: float = 0.0
    deadline: Optional[float] = None  # absolute step-clock TTL; None = no TTL

    @property
    def prompt_len(self) -> int:
        return int(self.tokens.shape[0])


@dataclasses.dataclass
class SlotState:
    """Host mirror of one active slot."""

    rid: int
    prompt_len: int
    pos: int                        # rows already in the slot's KV cache
    max_new_tokens: int
    generated: List[int] = dataclasses.field(default_factory=list)
    admitted_step: int = 0
    latencies_ms: List[float] = dataclasses.field(default_factory=list)
    deadline: Optional[float] = None


# finish_reason -> Completion.status. Anything not listed is a bug.
STATUS_OF_REASON = {
    "eos": "ok",
    "length": "ok",
    "cache_full": "ok",
    "deadline": "timed_out",        # in-flight slot past its TTL
    "deadline_shed": "timed_out",   # shed from the queue, never admitted
    "queue_full": "rejected",       # bounded-queue backpressure
    "nan_guard": "degraded",        # numeric guard tripped the slot
    "sdc_detected": "degraded",     # ABFT checksum caught silent corruption
    "engine_failed": "degraded",    # step failed beyond the ladder
    "shed_engine_failed": "degraded",  # queued when the ladder ran out
}


@dataclasses.dataclass(frozen=True)
class Completion:
    rid: int
    prompt_len: int
    tokens: Tuple[int, ...]         # generated ids (first one from prefill)
    finish_reason: str              # a STATUS_OF_REASON key
    admitted_step: int
    retired_step: int
    latencies_ms: Tuple[float, ...]
    status: str = "ok"              # 'ok'|'timed_out'|'rejected'|'degraded'


class Scheduler:
    """Slot allocator + arrival queue. The engine owns the device arrays;
    this class owns which request lives in which slot."""

    def __init__(self, num_slots: int, max_len: int, prefill_len: int,
                 max_queue: Optional[int] = None):
        if prefill_len > max_len:
            raise ValueError(f"prefill_len {prefill_len} > max_len {max_len}")
        if max_queue is not None and max_queue < 1:
            raise ValueError(f"max_queue {max_queue} < 1")
        self.num_slots = num_slots
        self.max_len = max_len
        self.prefill_len = prefill_len
        self.max_queue = max_queue
        # LIFO free list, seeded so first admissions get slots 0,1,2,...
        self.free: List[int] = list(range(num_slots))[::-1]
        self.queue: Deque[Request] = collections.deque()
        self.active: Dict[int, SlotState] = {}
        self.counters: Dict[str, int] = collections.defaultdict(int)
        # monotonic high-water mark over every `now` this scheduler saw
        self._clock = float("-inf")

    def _mono(self, now: float) -> float:
        """Clamp ``now`` to the scheduler's monotonic high-water mark.
        Regression guard: a backwards wall-clock jump used to make
        ``queue[0].arrival_time > now`` true forever, stalling admission
        with slots free (see test_clock_jump_does_not_stall_admission)."""
        self._clock = max(self._clock, float(now))
        return self._clock

    # ------------------------------------------------------------ intake
    def submit(self, req: Request) -> Optional[Completion]:
        """Enqueue; returns None on acceptance, or a ``rejected``
        Completion when the bounded queue is full (backpressure -- the
        caller gets the verdict immediately instead of queueing work
        that cannot be served)."""
        if req.prompt_len < 1 or req.prompt_len > self.prefill_len:
            raise ValueError(
                f"request {req.rid}: prompt_len {req.prompt_len} outside "
                f"[1, prefill_len={self.prefill_len}]")
        if req.max_new_tokens < 1 or \
                req.prompt_len + req.max_new_tokens > self.max_len:
            raise ValueError(
                f"request {req.rid}: prompt_len + max_new_tokens "
                f"{req.prompt_len + req.max_new_tokens} > max_len "
                f"{self.max_len} (or max_new_tokens < 1)")
        if self.max_queue is not None and len(self.queue) >= self.max_queue:
            self.counters["rejected"] += 1
            TRACE_COUNTS[("serving", "queue_reject")] += 1
            return self._unadmitted_completion(req, "queue_full")
        self.queue.append(req)
        self.counters["submitted"] += 1
        return None

    def _unadmitted_completion(self, req: Request, reason: str) -> Completion:
        now = self._clock if self._clock > float("-inf") else 0.0
        return Completion(
            rid=req.rid, prompt_len=req.prompt_len, tokens=(),
            finish_reason=reason, admitted_step=-1, retired_step=int(now),
            latencies_ms=(), status=STATUS_OF_REASON[reason])

    # --------------------------------------------------------- admission
    def shed_expired(self, now: float,
                     reason: str = "deadline_shed") -> List[Completion]:
        """Drop every queued request whose deadline has already passed
        (whole-queue scan: FCFS order means expired work can sit behind
        live work). Shed requests never occupy a slot or pay a prefill."""
        now = self._mono(now)
        shed: List[Completion] = []
        if not self.queue:
            return shed
        keep: Deque[Request] = collections.deque()
        for req in self.queue:
            if req.deadline is not None and req.deadline <= now:
                self.counters["shed"] += 1
                TRACE_COUNTS[("serving", "deadline_shed")] += 1
                shed.append(self._unadmitted_completion(req, reason))
            else:
                keep.append(req)
        self.queue = keep
        return shed

    def next_admission(self, now: float) -> Optional[Tuple[int, Request]]:
        """Pop (slot, request) if the FCFS queue head has arrived and a
        slot is free; None otherwise. Counts a queue_full_stall when work
        has arrived but every slot is occupied. ``now`` is clamped
        monotonic, so a backwards clock jump cannot stall admission."""
        now = self._mono(now)
        if not self.queue or self.queue[0].arrival_time > now:
            return None
        if not self.free:
            self.counters["queue_full_stalls"] += 1
            TRACE_COUNTS[("serving", "queue_full_stall")] += 1
            return None
        req = self.queue.popleft()
        slot = self.free.pop()
        self.active[slot] = SlotState(
            rid=req.rid, prompt_len=req.prompt_len, pos=req.prompt_len,
            max_new_tokens=req.max_new_tokens, admitted_step=int(now),
            deadline=req.deadline)
        self.counters["admitted"] += 1
        TRACE_COUNTS[("serving", "admit")] += 1
        return slot, req

    # -------------------------------------------------------- retirement
    def retire(self, slot: int, finish_reason: str, now: float) -> Completion:
        st = self.active.pop(slot)
        self.free.append(slot)          # immediate LIFO reuse
        self.counters["retired"] += 1
        TRACE_COUNTS[("serving", "retire")] += 1
        return Completion(
            rid=st.rid, prompt_len=st.prompt_len,
            tokens=tuple(st.generated), finish_reason=finish_reason,
            admitted_step=st.admitted_step, retired_step=int(now),
            latencies_ms=tuple(st.latencies_ms),
            status=STATUS_OF_REASON.get(finish_reason, "degraded"))

    # ------------------------------------------------------------- state
    def has_work(self) -> bool:
        return bool(self.queue) or bool(self.active)

    def next_arrival(self) -> Optional[float]:
        return self.queue[0].arrival_time if self.queue else None

    @property
    def occupancy(self) -> float:
        return len(self.active) / max(self.num_slots, 1)
