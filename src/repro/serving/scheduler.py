"""Request-level scheduler for the continuous-batching engine.

Pure host-side bookkeeping -- no jax types -- so it is unit-testable
without a device and never causes a retrace: the device only ever sees
fixed-shape (slots,) position vectors and (slots, 1) token arrays.

Lifecycle of a request (DESIGN.md section 10):

    submit -> [arrival queue] -> admit (free slot + arrived)
           -> prefill-insert (engine) -> decode steps -> retire
           (EOS / max-new-tokens / cache-full) -> slot back on free list

The free list gives retired slots back in LIFO order (immediate reuse --
the hot slot's cache rows are the ones most recently touched).
Admission is FCFS from the arrival queue; a step where the queue head
has arrived but no slot is free counts one ``queue_full_stall``.

Observability: every transition bumps
``kernels.registry.TRACE_COUNTS[("serving", <event>)]`` (admit / retire /
prefill_insert / queue_full_stall) plus per-scheduler counters, so tests
and the engine's stats report read one shared ledger.
"""
from __future__ import annotations

import collections
import dataclasses
from typing import Deque, Dict, List, Optional, Tuple

import numpy as np

from repro.kernels.registry import TRACE_COUNTS


@dataclasses.dataclass(frozen=True)
class Request:
    """One generation request. ``arrival_time`` is in decode-step units
    (the synthetic streams are step-clocked, not wall-clocked)."""

    rid: int
    tokens: np.ndarray              # (prompt_len,) int32 prompt ids
    max_new_tokens: int
    arrival_time: float = 0.0

    @property
    def prompt_len(self) -> int:
        return int(self.tokens.shape[0])


@dataclasses.dataclass
class SlotState:
    """Host mirror of one active slot."""

    rid: int
    prompt_len: int
    pos: int                        # rows already in the slot's KV cache
    max_new_tokens: int
    generated: List[int] = dataclasses.field(default_factory=list)
    admitted_step: int = 0
    latencies_ms: List[float] = dataclasses.field(default_factory=list)


@dataclasses.dataclass(frozen=True)
class Completion:
    rid: int
    prompt_len: int
    tokens: Tuple[int, ...]         # generated ids (first one from prefill)
    finish_reason: str              # 'eos' | 'length' | 'cache_full'
    admitted_step: int
    retired_step: int
    latencies_ms: Tuple[float, ...]


class Scheduler:
    """Slot allocator + arrival queue. The engine owns the device arrays;
    this class owns which request lives in which slot."""

    def __init__(self, num_slots: int, max_len: int, prefill_len: int):
        if prefill_len > max_len:
            raise ValueError(f"prefill_len {prefill_len} > max_len {max_len}")
        self.num_slots = num_slots
        self.max_len = max_len
        self.prefill_len = prefill_len
        # LIFO free list, seeded so first admissions get slots 0,1,2,...
        self.free: List[int] = list(range(num_slots))[::-1]
        self.queue: Deque[Request] = collections.deque()
        self.active: Dict[int, SlotState] = {}
        self.counters: Dict[str, int] = collections.defaultdict(int)

    # ------------------------------------------------------------ intake
    def submit(self, req: Request) -> None:
        if req.prompt_len < 1 or req.prompt_len > self.prefill_len:
            raise ValueError(
                f"request {req.rid}: prompt_len {req.prompt_len} outside "
                f"[1, prefill_len={self.prefill_len}]")
        if req.max_new_tokens < 1 or \
                req.prompt_len + req.max_new_tokens > self.max_len:
            raise ValueError(
                f"request {req.rid}: prompt_len + max_new_tokens "
                f"{req.prompt_len + req.max_new_tokens} > max_len "
                f"{self.max_len} (or max_new_tokens < 1)")
        self.queue.append(req)
        self.counters["submitted"] += 1

    # --------------------------------------------------------- admission
    def next_admission(self, now: float) -> Optional[Tuple[int, Request]]:
        """Pop (slot, request) if the FCFS queue head has arrived and a
        slot is free; None otherwise. Counts a queue_full_stall when work
        has arrived but every slot is occupied."""
        if not self.queue or self.queue[0].arrival_time > now:
            return None
        if not self.free:
            self.counters["queue_full_stalls"] += 1
            TRACE_COUNTS[("serving", "queue_full_stall")] += 1
            return None
        req = self.queue.popleft()
        slot = self.free.pop()
        self.active[slot] = SlotState(
            rid=req.rid, prompt_len=req.prompt_len, pos=req.prompt_len,
            max_new_tokens=req.max_new_tokens, admitted_step=int(now))
        self.counters["admitted"] += 1
        TRACE_COUNTS[("serving", "admit")] += 1
        return slot, req

    # -------------------------------------------------------- retirement
    def retire(self, slot: int, finish_reason: str, now: float) -> Completion:
        st = self.active.pop(slot)
        self.free.append(slot)          # immediate LIFO reuse
        self.counters["retired"] += 1
        TRACE_COUNTS[("serving", "retire")] += 1
        return Completion(
            rid=st.rid, prompt_len=st.prompt_len,
            tokens=tuple(st.generated), finish_reason=finish_reason,
            admitted_step=st.admitted_step, retired_step=int(now),
            latencies_ms=tuple(st.latencies_ms))

    # ------------------------------------------------------------- state
    def has_work(self) -> bool:
        return bool(self.queue) or bool(self.active)

    def next_arrival(self) -> Optional[float]:
        return self.queue[0].arrival_time if self.queue else None

    @property
    def occupancy(self) -> float:
        return len(self.active) / max(self.num_slots, 1)
