"""Paper section 1 motivation: online rotation overhead inside a transformer
block must be small (the naive dense-matmul rotation pushes linear-layer
cost to ~110%). Measures a full block forward with rotation off / factored
Hadamard / dense-matmul rotation, across d_ff values from the assigned
archs (incl. non-power-of-2)."""
from __future__ import annotations

import time
from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.hadamard import grouped_hadamard, largest_pow2_divisor
from repro.kernels.ref import hadamard_matrix


def _block(x, w_up, w_down, rotate):
    h = jax.nn.silu(x @ w_up)
    h = rotate(h)
    return h @ w_down


def _time(fn, *args, iters=8):
    jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e3


def run(csv: List[str], smoke: bool = False, records=None):
    rng = np.random.default_rng(0)
    B, d = (64, 1024) if smoke else (512, 1024)
    for dff in (4096, 6912) if smoke else (4096, 6912, 14336):  # pow2, 27*256, 7*2048
        x = jnp.asarray(rng.standard_normal((B, d)), jnp.float32)
        w_up = jnp.asarray(rng.standard_normal((d, dff)) * 0.02, jnp.float32)
        w_down = jnp.asarray(rng.standard_normal((dff, d)) * 0.02, jnp.float32)

        none_fn = jax.jit(lambda a, u, dn: _block(a, u, dn, lambda h: h))
        had_fn = jax.jit(lambda a, u, dn: _block(a, u, dn,
                                                 lambda h: grouped_hadamard(h)))
        p = largest_pow2_divisor(dff)
        Hd = jnp.asarray(np.kron(np.eye(dff // p, dtype=np.float32),
                                 hadamard_matrix(p, 1.0 / np.sqrt(p))))
        dense_fn = jax.jit(lambda a, u, dn: _block(a, u, dn, lambda h: h @ Hd))

        t0 = _time(none_fn, x, w_up, w_down)
        t1 = _time(had_fn, x, w_up, w_down)
        t2 = _time(dense_fn, x, w_up, w_down)
        csv.append(f"e2e_rotation_overhead,dff={dff},block_ms={t0:.2f},"
                   f"with_fwht_ms={t1:.2f},with_dense_rot_ms={t2:.2f},"
                   f"fwht_overhead_pct={100*(t1-t0)/t0:.1f},"
                   f"dense_overhead_pct={100*(t2-t0)/t0:.1f}")
        if records is not None:
            byt = 4 * (B * d + d * dff + dff * d + B * dff + B * d)
            for backend, ms in (("none", t0), ("fwht", t1), ("dense", t2)):
                records.append({
                    "bench": "e2e_rotation_overhead", "shape": f"{B}x{d}x{dff}",
                    "dtype": "float32", "backend": backend,
                    "ms": round(ms, 4),
                    "gbps": round(byt / (ms * 1e-3) / 1e9, 3),
                })
    return csv
