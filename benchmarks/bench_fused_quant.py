"""Beyond-paper (the paper's stated future work): fused Hadamard+quantize
kernel vs. the two-step rotate-then-quantize, measured as HBM bytes moved
(the TPU-relevant metric; both are memory-bound) plus CPU-interpret
correctness cost."""
from __future__ import annotations

from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.quant import quantize
from repro.kernels.fused_quant import fused_hadamard_quantize
from repro.kernels.ops import hadamard


def run(csv: List[str]):
    rng = np.random.default_rng(0)
    for n in (2048, 4096):
        rows = 1 << 14
        dtype_bytes = 2  # bf16 activations on TPU
        # two-step: read x, write y (bf16); read y, write q(int8)+scales
        bytes_two = rows * n * dtype_bytes * 2 + rows * n * (dtype_bytes + 1) + rows * 4
        # fused: read x, write q + scales
        bytes_fused = rows * n * (dtype_bytes + 1) + rows * 4
        x = jnp.asarray(rng.standard_normal((256, n)), jnp.float32)
        q, s = fused_hadamard_quantize(x)          # correctness exercised
        y2 = quantize(hadamard(x), "int8", axis=-1)
        deq = np.asarray(q, np.float32) * np.asarray(s)
        err = np.abs(deq - np.asarray(y2)).max() / np.abs(np.asarray(y2)).max()
        csv.append(
            f"fused_quant,n={n},hbm_bytes_two_step={bytes_two},"
            f"hbm_bytes_fused={bytes_fused},"
            f"traffic_reduction={bytes_two/bytes_fused:.2f}x,"
            f"max_rel_err_vs_twostep={err:.2e}")
    return csv
