"""Beyond-paper (the paper's stated future work): fused Hadamard+quantize
kernel vs. the two-step rotate-then-quantize, measured as HBM bytes moved
(the TPU-relevant metric; both are memory-bound) plus CPU wall-clock of
the two algorithm shapes and interpret-mode correctness cost.

Sweeps every registered quantize epilogue (int8, fp8_e4m3, fp8_e5m2)
through the plan-based API: ``hadamard(x, plan)`` with a ``QuantEpilogue``
is one ``pallas_call``; the two-step baseline is the same plan without an
epilogue followed by ``core.quant.quantize``.
"""
from __future__ import annotations

import time
from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.api import QuantEpilogue, hadamard, plan_for
from repro.core.quant import quantize
from repro.kernels.registry import QSPECS

MODES = tuple(QSPECS)  # sweep every registered epilogue mode


def _time(fn, *args, iters: int = 5) -> float:
    jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e3  # ms


def _hbm_bytes(rows: int, n: int, dtype_bytes: int = 2):
    """Analytic HBM traffic (bf16 activations on TPU). Every registered
    quant mode stores 1 byte/element + 4 bytes/row of scales."""
    q_bytes = 1
    two_step = (
        rows * n * dtype_bytes * 2            # rotate: read x, write y
        + rows * n * (dtype_bytes + q_bytes)  # quantize: read y, write q
        + rows * 4                            # scales
    )
    fused = rows * n * (dtype_bytes + q_bytes) + rows * 4  # read x, write q+s
    return two_step, fused


def run(csv: List[str], smoke: bool = False, records=None):
    rng = np.random.default_rng(0)
    sizes = (2048,) if smoke else (2048, 4096)
    rows_model = 1 << (10 if smoke else 14)
    bench_rows = 64 if smoke else 256
    for n in sizes:
        x = jnp.asarray(rng.standard_normal((bench_rows, n)), jnp.float32)
        rot_plan = plan_for(n, backend="pallas")
        for mode in MODES:
            plan = plan_for(n, backend="pallas", epilogue=QuantEpilogue(mode))
            bytes_two, bytes_fused = _hbm_bytes(rows_model, n)

            fused_fn = jax.jit(lambda a, p=plan: hadamard(a, p))
            two_fn = jax.jit(
                lambda a, p=rot_plan, m=mode: quantize(hadamard(a, p), m, axis=-1)
            )
            t_fused = _time(fused_fn, x)
            t_two = _time(two_fn, x)  # same backend, rotate + separate quantize

            # correctness: dequantized fused output tracks the two-step path
            q, s = fused_fn(x)
            y2 = np.asarray(two_fn(x))
            deq = np.asarray(q, np.float32) * np.asarray(s)
            err = np.abs(deq - y2).max() / np.abs(y2).max()
            csv.append(
                f"fused_quant,n={n},mode={mode},"
                f"hbm_bytes_two_step={bytes_two},"
                f"hbm_bytes_fused={bytes_fused},"
                f"traffic_reduction={bytes_two/bytes_fused:.2f}x,"
                f"fused_ms={t_fused:.2f},two_step_ms={t_two:.2f},"
                f"max_rel_err_vs_twostep={err:.2e}")
            if records is not None:
                # gbps from the bytes of the shape actually timed, not
                # the rows_model analytic figures in the CSV
                mb_two, mb_fused = _hbm_bytes(bench_rows, n, dtype_bytes=4)
                shape = f"{bench_rows}x{n}"
                for backend, ms, byt in (
                        ("pallas_fused", t_fused, mb_fused),
                        ("two_step", t_two, mb_two)):
                    records.append({
                        "bench": f"fused_quant_{mode}", "shape": shape,
                        "dtype": "float32", "backend": backend,
                        "ms": round(ms, 4),
                        "gbps": round(byt / (ms * 1e-3) / 1e9, 3),
                    })
    return csv
