"""Benchmark harness: one module per paper table/figure.

  bench_hadamard        -- Figs 4-7 + Appendix B (in-place) + C (bf16)
  bench_quant_accuracy  -- section 4.2 MMLU table (container-scale proxy)
  bench_e2e_overhead    -- section 1 rotation-overhead motivation
  bench_fused_quant     -- conclusion's future-work fusion (beyond paper)
  bench_quant_dot       -- fused rotate+quantize+GEMM consumer (PR 3)
  bench_serve_prequant  -- pre-quantized QTensor weights vs per-forward
                           weight quantization on the serving path (PR 4)
  bench_serve_loop      -- continuous-batching engine under a synthetic
                           arrival stream: tok/s, occupancy, p50/p99
                           per-token latency (PR 6)

Prints ``name,key=value,...`` CSV lines; ``--only <name>`` runs a subset.
``--json PATH`` additionally writes machine-readable records
``{bench, shape, dtype, backend, ms, gbps}`` -- the perf-trajectory
format (``BENCH_<tag>.json`` files are committed per PR so regressions
are diffable across the stack's history; ``benchmarks/compare.py``
diffs two of them record-by-record and exits nonzero on ms regressions
-- the CI bench-smoke job runs it against the committed baseline).
"""
from __future__ import annotations

import argparse
import json
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny shapes / few steps: CI guard that the perf "
                         "scripts still run, not a measurement")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write machine-readable perf records "
                         "({bench, shape, dtype, backend, ms, gbps}) to PATH")
    args = ap.parse_args()

    from benchmarks import (  # noqa: PLC0415
        bench_e2e_overhead,
        bench_fused_quant,
        bench_hadamard,
        bench_quant_accuracy,
        bench_quant_dot,
        bench_serve_loop,
        bench_serve_prequant,
    )

    suites = {
        "hadamard": bench_hadamard.run,
        "quant_accuracy": bench_quant_accuracy.run,
        "e2e_overhead": bench_e2e_overhead.run,
        "fused_quant": bench_fused_quant.run,
        "quant_dot": bench_quant_dot.run,
        "serve_prequant": bench_serve_prequant.run,
        "serve_loop": bench_serve_loop.run,
    }
    csv, records = [], []
    for name, fn in suites.items():
        if args.only and args.only != name:
            continue
        t0 = time.time()
        print(f"# running {name} ...", file=sys.stderr)
        fn(csv, smoke=args.smoke, records=records)
        print(f"# {name} done in {time.time()-t0:.1f}s", file=sys.stderr)
    for line in csv:
        print(line)
    if args.json is not None:
        with open(args.json, "w") as f:
            json.dump(records, f, indent=1)
        print(f"# wrote {len(records)} perf records to {args.json}",
              file=sys.stderr)


if __name__ == "__main__":
    main()
