"""Paper Figures 4-7 (+ Appendix B/C): Hadamard-transform runtime/speedup
across transform sizes x element counts x dtypes x in-place.

Three implementations are timed on this host (CPU):
  * scalar  -- the original FWHT butterfly (kernels/ref.py), the role the
               Dao-AILab kernel plays in the paper;
  * factored -- HadaCore's matmul-structured algorithm on XLA (core/hadamard);
  * dense   -- explicit H matmul (the naive O(n^2) baseline rotations
               would otherwise pay).

Wall-clock on CPU compares the *algorithms*; for the TPU *kernel* the
analytic v5e roofline microseconds (one HBM read + one write at 819 GB/s
vs. matmul FLOPs at 197 TF) are derived per cell -- that is the number the
Pallas kernel is engineered against (EXPERIMENTS.md section Perf)."""
from __future__ import annotations

import math
import time
from typing import Callable, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.hadamard import hadamard_transform
from repro.kernels.ref import fwht, hadamard_matrix

PEAK_FLOPS = 197e12
HBM_BW = 819e9

SIZES = [128, 256, 512, 1024, 2048, 4096, 8192, 16384, 32768]
ELEM_COUNTS = [2**15, 2**18, 2**21, 2**24]


def _time(fn: Callable, *args, iters: int = 5) -> float:
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else \
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6  # us


def tpu_roofline_us(rows: int, n: int, dtype_bytes: int = 2) -> dict:
    """Analytic v5e time for the hadacore kernel: memory term (1 read + 1
    write) vs compute term (128-wide matmul passes)."""
    k = max(1, math.ceil(math.log(n, 128)))
    flops = 2.0 * rows * n * 128 * k
    mem = 2.0 * rows * n * dtype_bytes
    return {"t_mem_us": mem / HBM_BW * 1e6,
            "t_compute_us": flops / PEAK_FLOPS * 1e6,
            "bound": "memory" if mem / HBM_BW > flops / PEAK_FLOPS else "compute"}


def run(csv: List[str], smoke: bool = False, records=None):
    sizes = [128, 1024] if smoke else SIZES
    elem_counts = [2**15] if smoke else ELEM_COUNTS
    dense_cache = {}
    for n in sizes:
        for elems in elem_counts:
            rows = max(1, elems // n)
            x = jnp.asarray(np.random.default_rng(0).standard_normal((rows, n)),
                            dtype=jnp.float32)
            scale = 1.0 / math.sqrt(n)

            t_scalar = _time(jax.jit(lambda a: fwht(a, scale)), x)
            t_fact = _time(jax.jit(lambda a: hadamard_transform(a)), x)
            if n <= 4096:
                if n not in dense_cache:
                    dense_cache[n] = jnp.asarray(hadamard_matrix(n, scale))
                H = dense_cache[n]
                t_dense = _time(jax.jit(lambda a, h: a @ h), x, H)
            else:
                t_dense = float("nan")
            rf = tpu_roofline_us(rows, n)
            csv.append(
                f"hadamard_size_sweep,n={n},elems={rows*n},"
                f"scalar_us={t_scalar:.1f},factored_us={t_fact:.1f},"
                f"dense_us={t_dense:.1f},speedup_vs_scalar={t_scalar/t_fact:.2f},"
                f"tpu_roofline_us={max(rf['t_mem_us'], rf['t_compute_us']):.2f},"
                f"tpu_bound={rf['bound']}")
            if records is not None:
                byt = 2 * rows * n * 4  # one f32 read + one write
                for backend, us in (("ref", t_scalar), ("xla", t_fact)):
                    records.append({
                        "bench": "hadamard", "shape": f"{rows}x{n}",
                        "dtype": "float32", "backend": backend,
                        "ms": round(us / 1e3, 4),
                        "gbps": round(byt / (us * 1e-6) / 1e9, 3),
                    })

    # Appendix C: dtype sweep at a representative size
    drows = 256 if smoke else 4096
    for dt, name in ((jnp.float32, "f32"), (jnp.bfloat16, "bf16"),
                     (jnp.float16, "f16")):
        x = jnp.asarray(np.random.default_rng(1).standard_normal((drows, 2048)),
                        dtype=dt)
        t = _time(jax.jit(lambda a: hadamard_transform(a)), x)
        rf = tpu_roofline_us(drows, 2048, jnp.dtype(dt).itemsize)
        csv.append(f"hadamard_dtype,dtype={name},factored_us={t:.1f},"
                   f"tpu_roofline_us={max(rf['t_mem_us'], rf['t_compute_us']):.2f}")
        if records is not None:
            byt = 2 * drows * 2048 * jnp.dtype(dt).itemsize
            records.append({
                "bench": "hadamard_dtype", "shape": f"{drows}x2048",
                "dtype": name, "backend": "xla",
                "ms": round(t / 1e3, 4),
                "gbps": round(byt / (t * 1e-6) / 1e9, 3),
            })

    # Appendix B: in-place (buffer donation) vs out-of-place
    x = jnp.asarray(
        np.random.default_rng(2).standard_normal((512 if smoke else 8192, 2048)),
        dtype=jnp.float32)
    f_out = jax.jit(lambda a: hadamard_transform(a))
    f_in = jax.jit(lambda a: hadamard_transform(a), donate_argnums=0)
    t_out = _time(f_out, x)
    xs = [jnp.array(x) for _ in range(6)]
    jax.block_until_ready(f_in(xs.pop()))
    t0 = time.perf_counter()
    for _ in range(5):
        out = f_in(xs.pop())
    jax.block_until_ready(out)
    t_in = (time.perf_counter() - t0) / 5 * 1e6
    csv.append(f"hadamard_inplace,out_of_place_us={t_out:.1f},"
               f"in_place_us={t_in:.1f},speedup={t_out/max(t_in,1e-9):.2f}")
    return csv
