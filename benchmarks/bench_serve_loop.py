"""Continuous-batching serving engine under a synthetic arrival stream
(PR 6) -- the serving-trajectory numbers the ROADMAP asks to regression-
gate like the kernels.

Drives ``repro.serving.ServeEngine`` (slot KV cache in the serving quant
dtype, prefill-insert, per-slot decode over donated buffers) with a
seeded Poisson stream of mixed prompt/generation lengths, and reports:

  * tokens/s (decode-produced tokens over decode wall-clock),
  * mean slot occupancy,
  * p50/p99 per-token latency (steady-state: compiles are paid in the
    engine warm-up; the prefill-priced first token is excluded).

Records: ``ms`` is the p50 per-token latency; ``gbps`` is the per-step
KV-cache traffic (the whole slot cache is read every decode step --
decode's binding bandwidth) over that latency. Extra keys (tokens/s,
occupancy, p99) ride along for the committed BENCH_<tag>.json
trajectory; ``compare.py`` gates on ``ms``.

The ``serve_loop_overload`` case (PR 8) floods the engine far past
capacity with a bounded queue and per-request TTLs: its record carries
the shed / rejected / timed-out / degraded counts and the p99 under
overload -- the robustness-layer trajectory (graceful load-shedding
numbers should move deliberately, like the latency numbers).
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional

from repro.configs import get_config
from repro.core.quant import QuantConfig
from repro.launch.train import scaled_config


def _engine_case(mode: str, smoke: bool, seed: int = 0):
    import jax

    from repro.launch.mesh import make_local_mesh
    from repro.launch.steps import make_param_init, param_shardings
    from repro.serving import ServeEngine, synthetic_stream

    quant = QuantConfig(mode=mode, rotate="hadamard" if mode != "none"
                        else "none", backend="xla",
                        kv_quant=mode != "none")
    cfg = scaled_config(get_config("llama3-8b"),
                        0.004 if smoke else 0.01).with_quant(quant)
    if mode != "none":
        cfg = dataclasses.replace(cfg, weight_quant="int8")
    slots = 4 if smoke else 8
    max_len = 48 if smoke else 128
    prefill_len = 16 if smoke else 48
    n_req = 6 if smoke else 24
    mesh = make_local_mesh(1)
    with mesh:
        ps = param_shardings(cfg, mesh)
        params = jax.jit(make_param_init(cfg), out_shardings=ps)(
            jax.random.PRNGKey(seed))
    engine = ServeEngine(cfg, params, mesh, num_slots=slots,
                         max_len=max_len, prefill_len=prefill_len)
    stream = synthetic_stream(
        n_req, vocab_size=cfg.vocab_size, prompt_len=(4, prefill_len),
        max_new_tokens=(4, 8) if smoke else (8, 24),
        rate=0.75, seed=seed)
    engine.run(stream)
    return engine, slots, max_len


def _overload_case(smoke: bool, seed: int = 0):
    """Arrival flood: ~4x the sustainable rate, a bounded queue, and
    TTLs tight enough that queued work expires -- exercising rejection
    (backpressure), deadline shedding, and in-flight timeouts at once."""
    import jax

    from repro.launch.mesh import make_local_mesh
    from repro.launch.steps import make_param_init, param_shardings
    from repro.serving import ServeEngine, synthetic_stream

    quant = QuantConfig(mode="int8", rotate="hadamard", backend="xla",
                        kv_quant=True)
    cfg = scaled_config(get_config("llama3-8b"),
                        0.004 if smoke else 0.01).with_quant(quant)
    cfg = dataclasses.replace(cfg, weight_quant="int8")
    slots = 2 if smoke else 4
    max_len = 48 if smoke else 128
    prefill_len = 16 if smoke else 48
    n_req = 10 if smoke else 48
    mesh = make_local_mesh(1)
    with mesh:
        ps = param_shardings(cfg, mesh)
        params = jax.jit(make_param_init(cfg), out_shardings=ps)(
            jax.random.PRNGKey(seed))
    # queue bounded below the flood size (every submit happens before the
    # first admission, so n_req - max_queue are rejected outright) and
    # TTLs tight enough that late-wave queued work expires
    engine = ServeEngine(cfg, params, mesh, num_slots=slots,
                         max_len=max_len, prefill_len=prefill_len,
                         max_queue=max(2, n_req * 3 // 5))
    stream = synthetic_stream(
        n_req, vocab_size=cfg.vocab_size, prompt_len=(4, prefill_len),
        max_new_tokens=(4, 8) if smoke else (8, 24),
        rate=4.0, seed=seed, deadline_slack=2.0)
    engine.run(stream)
    return engine, slots, max_len


def run(csv: List[str], smoke: bool = False, records: Optional[List] = None):
    modes = ("none", "int8") if smoke else ("none", "int8", "fp8_e4m3")
    for mode in modes:
        engine, slots, max_len = _engine_case(mode, smoke)
        s = engine.summary()
        csv.append(
            f"serve_loop,mode={mode},slots={slots},max_len={max_len},"
            f"requests={s['requests']:.0f},tok_s={s['tokens_per_s']:.1f},"
            f"occupancy={s['occupancy']:.2f},"
            f"p50_token_ms={s['p50_token_ms']:.2f},"
            f"p99_token_ms={s['p99_token_ms']:.2f},"
            f"stalls={s.get('queue_full_stalls', 0):.0f},"
            f"decode_executables={s['decode_executables']:.0f},"
            f"quantize_weight_calls={s['quantize_weight_calls']:.0f}")
        if records is not None:
            ms = s["p50_token_ms"]
            records.append({
                "bench": f"serve_loop_{mode}",
                "shape": f"slots{slots}x{max_len}",
                "dtype": mode if mode != "none" else "bfloat16",
                "backend": "engine",
                "ms": round(ms, 4),
                # decode reads the whole slot cache every step
                "gbps": round(s["kv_cache_bytes"] / (ms * 1e-3) / 1e9, 3),
                "tokens_per_s": round(s["tokens_per_s"], 2),
                "occupancy": round(s["occupancy"], 3),
                "p99_ms": round(s["p99_token_ms"], 4),
            })

    engine, slots, max_len = _overload_case(smoke)
    s = engine.summary()
    csv.append(
        f"serve_loop_overload,slots={slots},max_len={max_len},"
        f"requests={s['requests']:.0f},ok={s.get('status_ok', 0):.0f},"
        f"timed_out={s.get('status_timed_out', 0):.0f},"
        f"rejected={s.get('status_rejected', 0):.0f},"
        f"degraded={s.get('status_degraded', 0):.0f},"
        f"shed={s.get('shed', 0):.0f},"
        f"tok_s={s['tokens_per_s']:.1f},"
        f"p50_token_ms={s['p50_token_ms']:.2f},"
        f"p99_token_ms={s['p99_token_ms']:.2f}")
    if records is not None:
        ms = s["p50_token_ms"]
        records.append({
            "bench": "serve_loop_overload",
            "shape": f"slots{slots}x{max_len}",
            "dtype": "int8",
            "backend": "engine",
            "ms": round(ms, 4),
            "gbps": round(s["kv_cache_bytes"] / (ms * 1e-3) / 1e9, 3),
            "p99_ms": round(s["p99_token_ms"], 4),
            "ok": int(s.get("status_ok", 0)),
            "timed_out": int(s.get("status_timed_out", 0)),
            "rejected": int(s.get("status_rejected", 0)),
            "degraded": int(s.get("status_degraded", 0)),
            "shed": int(s.get("shed", 0)),
        })
    return csv


def main():
    import argparse
    import json

    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--json", default=None, metavar="PATH")
    args = ap.parse_args()
    csv: List[str] = []
    records: List[dict] = []
    run(csv, smoke=args.smoke, records=records)
    for line in csv:
        print(line)
    if args.json:
        with open(args.json, "w") as f:
            json.dump(records, f, indent=1)


if __name__ == "__main__":
    main()
