"""Paper section 4.2 (MMLU table) proxy: end-to-end accuracy of FP8 attention
with and without Hadamard rotation on a small trained Llama-family model.

The paper's table:   FP16 65.38 | FP8-no-rot 64.40 | FP8+DaoKernel 65.45 |
FP8+HadaCore 65.09 (5-shot MMLU, Llama-3.1-8B).

Container-scale translation: train a ~5M llama3-family model for a few
hundred steps, then measure (i) eval cross-entropy and (ii) top-1 token
agreement with the full-precision model, for: fp16 baseline, fp8 attention
without rotation, fp8 attention + rotation via the factored XLA path (the
"reference kernel" column) and via hadacore-pallas interpret (the
"HadaCore" column). The claim being reproduced: rotation recovers the
quantization loss and the faster kernel is numerically equivalent."""
from __future__ import annotations

from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core.quant import QuantConfig
from repro.data import SyntheticDataset
from repro.launch.shapes import ShapeSpec, make_batch
from repro.launch.steps import make_train_step
from repro.models import init_lm, lm_forward, lm_loss
from repro.optim import OptConfig, init_opt_state


def _train_small(cfg, shape, steps=120, seed=0):
    params = init_lm(jax.random.PRNGKey(seed), cfg)
    opt_cfg = OptConfig(lr=1e-3, warmup_steps=10, total_steps=steps)
    state = init_opt_state(params, opt_cfg)
    step_fn = jax.jit(make_train_step(cfg, opt_cfg))
    ds = SyntheticDataset(cfg, shape, seed=seed)
    # structured synthetic language: tokens follow a fixed bigram chain so
    # there is real signal to learn (pure-noise data says nothing about
    # quantization error visibility)
    rng = np.random.default_rng(7)
    table = rng.integers(0, cfg.vocab_size, cfg.vocab_size, dtype=np.int32)

    def structured(step):
        b = ds.batch(step)
        t = b["tokens"]
        for j in range(1, t.shape[1]):
            mask = rng.random(t.shape[0]) < 0.8
            t[mask, j] = table[t[mask, j - 1]]
        b["tokens"] = t
        b["labels"] = np.concatenate([t[:, 1:], t[:, :1]], axis=1)
        return b

    for s in range(steps):
        batch = structured(s)
        params, state, metrics = step_fn(params, state, batch)
    return params, structured


def run(csv: List[str], smoke: bool = False, records=None):
    # accuracy suite: no ms/gbps records (records kept for signature parity)
    from repro.core.rotations import fuse_down_proj_rotations

    base = get_config("llama3_8b").scaled_down()
    shape = ShapeSpec("bench", "train", 64, 8)
    params, data_fn = _train_small(base, shape, steps=10 if smoke else 120)
    # post-training deployment: the offline half of the rotation is fused
    # into the trained weights once (exact rewrite)
    params_rotated = fuse_down_proj_rotations(params)

    eval_batches = [data_fn(10_000 + i) for i in range(4)]

    def evaluate(cfg):
        p = params_rotated if cfg.quant.rotating else params
        ces, agrees = [], []
        for b in eval_batches:
            logits, _, _ = lm_forward(cfg, p, b)
            lf = logits.astype(jnp.float32)
            lse = jax.nn.logsumexp(lf, -1)
            ll = jnp.take_along_axis(lf, b["labels"][..., None], -1)[..., 0]
            ces.append(float(jnp.mean(lse - ll)))
            agrees.append(np.asarray(jnp.argmax(lf, -1)))
        return float(np.mean(ces)), agrees

    variants = {
        "fp16_baseline": base,
        "fp8_attn_no_rotation": base.with_quant(
            QuantConfig(mode="fp8_e4m3", kv_quant=True, backend="xla")),
        "fp8_attn_rotation_xla": base.with_quant(
            QuantConfig(mode="fp8_e4m3", rotate="hadamard", kv_quant=True,
                        backend="xla")),
        "fp8_attn_rotation_hadacore": base.with_quant(
            QuantConfig(mode="fp8_e4m3", rotate="hadamard", kv_quant=True,
                        backend="pallas")),
    }
    results = {}
    for name, cfg in variants.items():
        ce, preds = evaluate(cfg)
        results[name] = (ce, preds)

    base_preds = results["fp16_baseline"][1]
    for name, (ce, preds) in results.items():
        agree = float(np.mean([np.mean(p == bp) for p, bp in zip(preds, base_preds)]))
        csv.append(f"quant_accuracy,variant={name},eval_ce={ce:.4f},"
                   f"top1_agreement_vs_fp16={agree:.4f}")
    # the paper's qualitative claims, as recorded assertions:
    ce16 = results["fp16_baseline"][0]
    ce_no = results["fp8_attn_no_rotation"][0]
    ce_rx = results["fp8_attn_rotation_xla"][0]
    ce_hc = results["fp8_attn_rotation_hadacore"][0]
    csv.append(
        "quant_accuracy_claims,"
        # comparable accuracy: rotated-fp8 CE within 1% of the fp16 CE
        # (synthetic activations lack real-LLM outlier structure, so the
        # rotation is accuracy-NEUTRAL here rather than positive -- the
        # int8 benches show the positive case; see EXPERIMENTS.md)
        f"rotation_comparable_to_fp16={abs(ce_rx-ce16) < 0.01 * ce16},"
        f"hadacore_matches_reference={abs(ce_hc-ce_rx) < 5e-3}")
    return csv
