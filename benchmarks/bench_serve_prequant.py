"""Pre-quantized serving weights (QTensor) vs per-forward weight
quantization -- the PR 4 serving-path claim, measured.

Both paths run the SAME rotate -> per-token-quantize -> low-precision
contraction (``core.api.quant_dot``); the delta is what happens to the
weight every step:

  * ``per_forward``: the raw f32 weight is absmax-reduced, scaled,
    rounded, and cast per out-channel INSIDE the jitted forward -- the
    pre-PR-4 serving behavior (plus 4x the weight HBM read: f32 vs the
    1-byte storage grid).
  * ``prequant``: the weight was quantized ONCE at load into a
    :class:`repro.core.wquant.QTensor`; the forward contracts against
    ``q``/``scale`` directly (zero quantize_weight work per step).

The analytic HBM delta alone is 4x on the weight bytes (f32 in vs int8
in); the measured delta adds the absmax reduction + round/cast removal.
"""
from __future__ import annotations

import time
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.api import QuantDotSpec
from repro.core.quant import QuantConfig
from repro.core.wquant import quantize_weight


def _time(fn, *args, iters: int = 5) -> float:
    jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e3  # ms


def run(csv: List[str], smoke: bool = False, records: Optional[List] = None):
    rng = np.random.default_rng(0)
    sizes = ((1024, 512),) if smoke else ((1024, 512), (4096, 1024))
    rows = 64 if smoke else 256
    modes = ("int8",) if smoke else ("int8", "fp8_e4m3")
    cfg = dict(rotate="hadamard", backend="pallas")
    for n, d in sizes:
        x = jnp.asarray(rng.standard_normal((rows, n)), jnp.float32)
        w = jnp.asarray(rng.standard_normal((n, d)) * 0.05, jnp.float32)
        for mode in modes:
            spec = QuantDotSpec.for_config(
                n, QuantConfig(mode=mode, **cfg))
            qt = quantize_weight(w, mode)          # once, at "load"

            per_forward = jax.jit(lambda a, ww, s=spec: s.bind(ww)(a))
            prequant = jax.jit(
                lambda a, q, sc, s=spec, m=mode:
                s.bind(type(qt)(q=q, scale=sc, mode=m))(a))

            t_raw = _time(per_forward, x, w)
            t_pre = _time(prequant, x, qt.q, qt.scale)
            err = float(jnp.abs(per_forward(x, w)
                                - prequant(x, qt.q, qt.scale)).max())
            qb = jnp.dtype(qt.q.dtype).itemsize
            # weight bytes entering the step: raw f32 vs storage grid
            b_raw = n * d * 4
            b_pre = n * d * qb + d * 4
            csv.append(
                f"serve_prequant,n={n},d={d},mode={mode},"
                f"per_forward_ms={t_raw:.2f},prequant_ms={t_pre:.2f},"
                f"speedup={t_raw / max(t_pre, 1e-9):.2f}x,"
                f"weight_bytes_per_step={b_raw}->{b_pre},"
                f"max_abs_err={err:.2e}")
            if records is not None:
                shape = f"{rows}x{n}x{d}"
                act = rows * n * 4 + rows * d * 4
                for backend, ms, byt in (
                        ("per_forward_wquant", t_raw, b_raw + act),
                        ("prequant_qtensor", t_pre, b_pre + act)):
                    records.append({
                        "bench": f"serve_prequant_{mode}", "shape": shape,
                        "dtype": "float32", "backend": backend,
                        "ms": round(ms, 4),
                        "gbps": round(byt / (ms * 1e-3) / 1e9, 3),
                    })
    return csv
