"""Diff two ``BENCH_*.json`` perf-record files and gate on regressions.

The perf-trajectory files committed per PR (``benchmarks/run.py --json``)
hold records ``{bench, shape, dtype, backend, ms, gbps}``. This tool
matches records between a baseline and a candidate file on the identity
key ``(bench, shape, dtype, backend)``, prints a per-record delta table,
and exits nonzero when any matched record's ``ms`` regressed by more
than ``--max-regress`` percent -- so a perf regression in a committed
baseline (or in CI's bench-smoke run against it) fails loudly instead of
drifting silently.

Records present in only one file are listed informationally (bench
suites grow across PRs; new records are not regressions). Pass
``--require-overlap`` to also fail when NO record matches -- this keeps
a CI gate honest: if a shape/bench rename silently empties the
comparison, the gate errors instead of vacuously passing.

Usage:
  python benchmarks/compare.py BASELINE.json NEW.json \
      [--max-regress PCT] [--require-overlap]

Exit codes: 0 ok, 1 regression above threshold, 2 no overlapping
records with --require-overlap.
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import Dict, List, Tuple

Key = Tuple[str, str, str, str]


def _load(path: str) -> Dict[Key, dict]:
    with open(path) as f:
        records = json.load(f)
    out: Dict[Key, dict] = {}
    for r in records:
        key = (r["bench"], r["shape"], r["dtype"], r["backend"])
        # duplicate keys (repeated suites in one run): keep the fastest,
        # matching how perf is read everywhere else (min over repeats)
        if key not in out or r["ms"] < out[key]["ms"]:
            out[key] = r
    return out


def compare(base: Dict[Key, dict], new: Dict[Key, dict],
            max_regress: float,
            min_ms: float = 0.0) -> Tuple[List[str], List[str], int]:
    """Returns (report lines, regression lines, overlap count).

    Pairs where either side is below ``min_ms`` are reported but never
    flagged: sub-millisecond interpret/XLA records jitter by multiples
    run-to-run, so a percent bound on them is pure noise (CI floors them
    at 1 ms)."""
    lines: List[str] = []
    regressions: List[str] = []
    common = sorted(set(base) & set(new))
    for key in common:
        b, n = base[key]["ms"], new[key]["ms"]
        delta = (n - b) / b * 100 if b > 0 else 0.0
        if min(b, n) < min_ms:
            lines.append(f"{'/'.join(key)}: {b:.4f} -> {n:.4f} ms "
                         f"({delta:+.1f}%)  [below {min_ms:g} ms floor, "
                         "not gated]")
            continue
        tag = ""
        if delta > max_regress:
            tag = f"  <-- REGRESSION (> {max_regress:.0f}%)"
            regressions.append(f"{'/'.join(key)}: {b:.4f} -> {n:.4f} ms "
                               f"(+{delta:.1f}%)")
        lines.append(f"{'/'.join(key)}: {b:.4f} -> {n:.4f} ms "
                     f"({delta:+.1f}%){tag}")
    for key in sorted(set(base) - set(new)):
        lines.append(f"{'/'.join(key)}: only in baseline")
    for key in sorted(set(new) - set(base)):
        lines.append(f"{'/'.join(key)}: only in candidate (new record)")
    return lines, regressions, len(common)


def main() -> int:
    ap = argparse.ArgumentParser(
        description="diff two BENCH_*.json files; exit nonzero on "
                    "ms regressions above the threshold")
    ap.add_argument("baseline", help="baseline BENCH_*.json")
    ap.add_argument("candidate", help="candidate BENCH_*.json")
    ap.add_argument("--max-regress", type=float, default=25.0,
                    metavar="PCT",
                    help="max tolerated ms increase per record, percent "
                         "(default 25; CI uses a loose bound because "
                         "wall-clock compares across machines)")
    ap.add_argument("--min-ms", type=float, default=0.0, metavar="MS",
                    help="ignore (report but never flag) record pairs "
                         "where either side is faster than this -- "
                         "sub-ms interpret records jitter by multiples "
                         "(default 0 = gate everything)")
    ap.add_argument("--require-overlap", action="store_true",
                    help="also fail (exit 2) when no record key matches "
                         "between the files")
    args = ap.parse_args()

    base, new = _load(args.baseline), _load(args.candidate)
    lines, regressions, overlap = compare(base, new, args.max_regress,
                                          args.min_ms)
    for line in lines:
        print(line)
    print(f"# {overlap} matched record(s), {len(regressions)} "
          f"regression(s) above {args.max_regress:.0f}%")
    if args.require_overlap and overlap == 0:
        print("# ERROR: no overlapping records -- the comparison is "
              "vacuous", file=sys.stderr)
        return 2
    if regressions:
        print("# ms regressions:", file=sys.stderr)
        for r in regressions:
            print(f"#   {r}", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
