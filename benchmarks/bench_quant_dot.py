"""Fused rotate->quantize->GEMM consumer (quant_dot) vs. the unfused
two-kernel path: rotate+quantize epilogue, HBM round trip of (q, scales),
then the low-precision contraction.

Both paths run the SAME low-precision arithmetic (int8 operands with
int32 accumulation; fp8 embedded in bf16 with f32 accumulation) -- the
delta is purely the HBM round trip of the quantized activations plus the
extra kernel launch, which is exactly what the fused kernel exists to
remove. Analytic HBM traffic is reported alongside CPU/interpret
wall-clock (the TPU-relevant metric; both paths are memory-bound).
"""
from __future__ import annotations

import os
import time
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import verify
from repro.core.api import QuantEpilogue, hadamard, plan_for, quant_dot
from repro.core.wquant import quantize_weight, weight_checksum
from repro.kernels.quant_dot import (STREAM_INTERPRET_ENV, epilogue_dot,
                                     pallas_quant_dot, quant_dot_blocks)
from repro.kernels.registry import QSPECS


def _time(fn, *args, iters: int = 5) -> float:
    jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e3  # ms


def _hbm_bytes(rows: int, n: int, d: int, dtype_bytes: int, q_bytes: int):
    """Analytic HBM traffic. Weight reads are identical on both paths
    (n*d quantized bytes); the unfused path additionally writes and
    re-reads the quantized activations + scales."""
    w = n * d * q_bytes + d * 4
    fused = rows * n * dtype_bytes + w + rows * d * dtype_bytes
    unfused = fused + 2 * (rows * n * q_bytes + rows * 4)
    return unfused, fused


def _time_min(fn, *args, iters: int = 7) -> float:
    """min-of-iters wall clock (ms): the robust estimator for the noisy
    CPU/interpret timings the d-sweep compares."""
    jax.block_until_ready(fn(*args))
    best = float("inf")
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        best = min(best, time.perf_counter() - t0)
    return best * 1e3


def _run_d_sweep(csv: List[str], smoke: bool, records: Optional[List]):
    """Transform-amortization curve (PR 5): sweep the out-channel width d
    at fixed n / rows / block_n and compare the rotate-once schedule
    against the PR-3 revisit schedule. With block_n pinned, the grid
    revisits each row block d/block_n times; the revisit schedule
    recomputes the rotate+quantize every visit -- a transform term LINEAR
    in d/block_n on top of the GEMM -- while the rotate-once schedule
    transforms once per row block and serves every visit from VMEM
    scratch, so its transform work is FLAT in d (the
    ``transforms_per_row_block`` columns; the structural guarantee is
    asserted in tests/test_quant_dot.py). Outputs are bitwise identical
    (asserted here).

    Wall-clock caveat: on the TPU-relevant path the scratch lives in VMEM
    and the win is the eliminated transform flops. CPU *interpret* mode,
    however, functionalizes scratch state -- the q/s buffers are threaded
    (copied) through every grid step and the j==0 cond -- adding a
    per-step overhead of the same order as the transform it saves, so the
    interpret ms of the two schedules track each other within noise. The
    ms records are still the trajectory gate (regressions in either
    schedule fail benchmarks/compare.py); the amortization claim rides on
    the transform-work columns.

    PR 7 adds the ``streamed`` A/B column at the same pinned block_n: the
    rotate-once structure with the implicit weight fetch replaced by the
    two-slot DMA ring (prefetch tile j+1 during the tile-j contraction).
    On the interpreter the DMA simulation is synchronous, so the streamed
    ms carries ring bookkeeping overhead with no overlap win -- the
    overlap claim is the structural jaxpr assertion in tests; the ms
    records gate the trajectory. The CSV also logs the streamed
    BlockDecision (schedule + charged VMEM including the ring) at the
    sweep's pinned tile.

    PR 10 adds the ABFT A/B column: the checksum-VERIFIED rotate-once
    twin (same grid, same specs, plus the (1, n) checksum input and the
    per-row f32 residual output) timed against the unverified kernel at
    the same pinned tile -- the measured cost of runtime verification.
    The real output is asserted bitwise identical and the healthy
    residual is asserted under the calibrated tolerance on every sweep
    point, so the record proves overhead AND zero false positives on the
    exact shapes benchmarked."""
    rng = np.random.default_rng(1)
    n, rows, bn, mode = 1024, 64, 256, "int8"
    ds = (256, 512) if smoke else (256, 512, 1024, 2048)
    x = jnp.asarray(rng.standard_normal((rows, n)), jnp.float32)
    plan = plan_for(n, backend="pallas", epilogue=QuantEpilogue(mode))
    # run the real streamed kernel body on the interpreter's synchronous
    # DMA simulation rather than the rotate_once fallback
    prev = os.environ.get(STREAM_INTERPRET_ENV)
    os.environ[STREAM_INTERPRET_ENV] = "1"
    try:
        for d in ds:
            w = jnp.asarray(rng.standard_normal((n, d)) * 0.05, jnp.float32)
            wq, sw = quantize_weight(w, mode)
            once = jax.jit(lambda a, q, s: pallas_quant_dot(
                a, q, s, plan, True, "rotate_once", bn))
            revisit = jax.jit(lambda a, q, s: pallas_quant_dot(
                a, q, s, plan, True, "revisit", bn))
            streamed = jax.jit(lambda a, q, s: pallas_quant_dot(
                a, q, s, plan, True, "streamed", bn))
            cw = weight_checksum(wq, sw)
            abft = jax.jit(lambda a, q, s, c: pallas_quant_dot(
                a, q, s, plan, True, "rotate_once", bn, check=c))
            t_once = _time_min(once, x, wq, sw)
            t_revisit = _time_min(revisit, x, wq, sw)
            t_streamed = _time_min(streamed, x, wq, sw)
            t_abft = _time_min(abft, x, wq, sw, cw)
            ref = np.asarray(once(x, wq, sw))
            assert (ref == np.asarray(revisit(x, wq, sw))).all()
            assert (ref == np.asarray(streamed(x, wq, sw))).all()
            ya, resid = abft(x, wq, sw, cw)
            assert (ref == np.asarray(ya)).all()
            assert bool(verify.residual_ok(ya, resid, n=n, d=d).all())
            tiles = -(-d // bn)
            blocks = quant_dot_blocks(n, d, rows, jnp.float32, jnp.float32,
                                      mode, block_n=bn, schedule="streamed")
            csv.append(
                f"quant_dot_dsweep,n={n},d={d},mode={mode},block_n={bn},"
                f"tiles_per_row_block={tiles},"
                f"transforms_per_row_block_rotate_once=1,"
                f"transforms_per_row_block_revisit={tiles},"
                f"rotate_once_ms={t_once:.2f},revisit_ms={t_revisit:.2f},"
                f"streamed_ms={t_streamed:.2f},abft_ms={t_abft:.2f},"
                f"abft_overhead={t_abft / t_once:.2f}x,"
                f"streamed_schedule={blocks.schedule},"
                f"streamed_vmem_bytes={blocks.vmem_bytes},"
                f"speedup={t_revisit / t_once:.2f}x")
            if records is not None:
                shape = f"{rows}x{n}x{d}"
                # bytes of the shape actually timed (same convention as
                # the fused-vs-unfused records below): activation in +
                # int8 weight + f32 out-channel scales + f32 output
                byt = rows * n * 4 + n * d * 1 + d * 4 + rows * d * 4
                for backend, ms, tr in (
                        ("pallas_rotate_once", t_once, 1),
                        ("pallas_revisit", t_revisit, tiles),
                        ("pallas_streamed", t_streamed, 1),
                        ("pallas_rotate_once_abft", t_abft, 1)):
                    rec = {
                        "bench": f"quant_dot_dsweep_{mode}", "shape": shape,
                        "dtype": "float32", "backend": backend,
                        "ms": round(ms, 4),
                        "gbps": round(byt / (ms * 1e-3) / 1e9, 3),
                        # extra trajectory field (compare.py matches on
                        # the 4-key identity and ignores it): the
                        # per-row-block transform count -- flat at 1 for
                        # rotate-once/streamed, linear in d/block_n for
                        # the PR-3 schedule
                        "transforms_per_row_block": tr,
                    }
                    if backend == "pallas_streamed":
                        # the ring's VMEM charge at the pinned tile --
                        # the block planner's streamed accounting
                        rec["schedule"] = blocks.schedule
                        rec["vmem_bytes"] = blocks.vmem_bytes
                    if backend == "pallas_rotate_once_abft":
                        # checksum-verification cost relative to the
                        # unverified kernel at the same pinned tile
                        rec["abft_overhead"] = round(t_abft / t_once, 3)
                    records.append(rec)
    finally:
        if prev is None:
            os.environ.pop(STREAM_INTERPRET_ENV, None)
        else:
            os.environ[STREAM_INTERPRET_ENV] = prev


def run(csv: List[str], smoke: bool = False, records: Optional[List] = None):
    _run_d_sweep(csv, smoke, records)
    rng = np.random.default_rng(0)
    sizes = ((2048, 512),) if smoke else ((2048, 512), (4096, 1024))
    rows = 64 if smoke else 256
    rows_model = 1 << 14   # the deployment-scale row count for the analytic model
    modes = ("int8",) if smoke else ("int8", "fp8_e4m3")
    for n, d in sizes:
        x = jnp.asarray(rng.standard_normal((rows, n)), jnp.float32)
        w = jnp.asarray(rng.standard_normal((n, d)) * 0.05, jnp.float32)
        for mode in modes:
            plan = plan_for(n, backend="pallas", epilogue=QuantEpilogue(mode))
            qt = quantize_weight(w, mode)          # QTensor (pytree: jits)
            wq, sw = qt.q, qt.scale
            fused_fn = jax.jit(lambda a, q, s, p=plan: quant_dot(a, (q, s), p))

            def unfused(a, q, s, p=plan, m=mode):
                # two kernels: fused rotate+quantize, then the contraction
                # reads (q, scales) back from HBM
                aq, ascale = hadamard(a, p)
                return epilogue_dot(
                    aq.astype(jnp.float32), ascale, q, s, m, a.dtype)

            unfused_fn = jax.jit(unfused)
            t_fused = _time(fused_fn, x, wq, sw)
            t_unfused = _time(unfused_fn, x, wq, sw)

            err = float(jnp.abs(fused_fn(x, wq, sw)
                                - unfused_fn(x, wq, sw)).max())
            qb = jnp.dtype(QSPECS[mode][1]).itemsize
            b_un, b_f = _hbm_bytes(rows_model, n, d, 4, qb)
            csv.append(
                f"quant_dot,n={n},d={d},mode={mode},"
                f"hbm_bytes_unfused={b_un},hbm_bytes_fused={b_f},"
                f"traffic_reduction={b_un/b_f:.2f}x,"
                f"fused_ms={t_fused:.2f},unfused_ms={t_unfused:.2f},"
                f"max_abs_err_fused_vs_unfused={err:.2e}")
            if records is not None:
                # gbps from the bytes of the shape actually timed (the
                # CSV's rows_model figures are the deployment-scale
                # analytic model, not this measurement)
                mb_un, mb_f = _hbm_bytes(rows, n, d, 4, qb)
                shape = f"{rows}x{n}x{d}"
                for backend, ms, byt in (("pallas_fused", t_fused, mb_f),
                                         ("unfused_2kernel", t_unfused, mb_un)):
                    records.append({
                        "bench": f"quant_dot_{mode}", "shape": shape,
                        "dtype": "float32", "backend": backend,
                        "ms": round(ms, 4),
                        "gbps": round(byt / (ms * 1e-3) / 1e9, 3),
                    })
    return csv
