"""Fused rotate->quantize->GEMM consumer (quant_dot) vs. the unfused
two-kernel path: rotate+quantize epilogue, HBM round trip of (q, scales),
then the low-precision contraction.

Both paths run the SAME low-precision arithmetic (int8 operands with
int32 accumulation; fp8 embedded in bf16 with f32 accumulation) -- the
delta is purely the HBM round trip of the quantized activations plus the
extra kernel launch, which is exactly what the fused kernel exists to
remove. Analytic HBM traffic is reported alongside CPU/interpret
wall-clock (the TPU-relevant metric; both paths are memory-bound).
"""
from __future__ import annotations

import time
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.api import QuantEpilogue, hadamard, plan_for, quant_dot
from repro.core.wquant import quantize_weight
from repro.kernels.quant_dot import epilogue_dot
from repro.kernels.registry import QSPECS


def _time(fn, *args, iters: int = 5) -> float:
    jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e3  # ms


def _hbm_bytes(rows: int, n: int, d: int, dtype_bytes: int, q_bytes: int):
    """Analytic HBM traffic. Weight reads are identical on both paths
    (n*d quantized bytes); the unfused path additionally writes and
    re-reads the quantized activations + scales."""
    w = n * d * q_bytes + d * 4
    fused = rows * n * dtype_bytes + w + rows * d * dtype_bytes
    unfused = fused + 2 * (rows * n * q_bytes + rows * 4)
    return unfused, fused


def run(csv: List[str], smoke: bool = False, records: Optional[List] = None):
    rng = np.random.default_rng(0)
    sizes = ((2048, 512),) if smoke else ((2048, 512), (4096, 1024))
    rows = 64 if smoke else 256
    rows_model = 1 << 14   # the deployment-scale row count for the analytic model
    modes = ("int8",) if smoke else ("int8", "fp8_e4m3")
    for n, d in sizes:
        x = jnp.asarray(rng.standard_normal((rows, n)), jnp.float32)
        w = jnp.asarray(rng.standard_normal((n, d)) * 0.05, jnp.float32)
        for mode in modes:
            plan = plan_for(n, backend="pallas", epilogue=QuantEpilogue(mode))
            qt = quantize_weight(w, mode)          # QTensor (pytree: jits)
            wq, sw = qt.q, qt.scale
            fused_fn = jax.jit(lambda a, q, s, p=plan: quant_dot(a, (q, s), p))

            def unfused(a, q, s, p=plan, m=mode):
                # two kernels: fused rotate+quantize, then the contraction
                # reads (q, scales) back from HBM
                aq, ascale = hadamard(a, p)
                return epilogue_dot(
                    aq.astype(jnp.float32), ascale, q, s, m, a.dtype)

            unfused_fn = jax.jit(unfused)
            t_fused = _time(fused_fn, x, wq, sw)
            t_unfused = _time(unfused_fn, x, wq, sw)

            err = float(jnp.abs(fused_fn(x, wq, sw)
                                - unfused_fn(x, wq, sw)).max())
            qb = jnp.dtype(QSPECS[mode][1]).itemsize
            b_un, b_f = _hbm_bytes(rows_model, n, d, 4, qb)
            csv.append(
                f"quant_dot,n={n},d={d},mode={mode},"
                f"hbm_bytes_unfused={b_un},hbm_bytes_fused={b_f},"
                f"traffic_reduction={b_un/b_f:.2f}x,"
                f"fused_ms={t_fused:.2f},unfused_ms={t_unfused:.2f},"
                f"max_abs_err_fused_vs_unfused={err:.2e}")
            if records is not None:
                # gbps from the bytes of the shape actually timed (the
                # CSV's rows_model figures are the deployment-scale
                # analytic model, not this measurement)
                mb_un, mb_f = _hbm_bytes(rows, n, d, 4, qb)
                shape = f"{rows}x{n}x{d}"
                for backend, ms, byt in (("pallas_fused", t_fused, mb_f),
                                         ("unfused_2kernel", t_unfused, mb_un)):
                    records.append({
                        "bench": f"quant_dot_{mode}", "shape": shape,
                        "dtype": "float32", "backend": backend,
                        "ms": round(ms, 4),
                        "gbps": round(byt / (ms * 1e-3) / 1e9, 3),
                    })
    return csv
