import os
import subprocess
import sys

import pytest

try:  # real hypothesis when available ...
    import hypothesis  # noqa: F401
except ImportError:  # ... deterministic fallback otherwise (see module doc)
    sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
    from _hypothesis_stub import build_module

    _mod = build_module()
    sys.modules["hypothesis"] = _mod
    sys.modules["hypothesis.strategies"] = _mod.strategies


def run_py_subprocess(code: str, devices: int = 8, timeout: int = 600):
    """Run python code in a subprocess with N fake XLA host devices.

    Multi-device tests need this because jax locks the device count at
    first init; the main pytest process keeps the default single device
    (per the dry-run isolation requirement)."""
    repo_root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = {
        "XLA_FLAGS": f"--xla_force_host_platform_device_count={devices}",
        "PYTHONPATH": os.path.join(repo_root, "src"),
        "PATH": os.environ.get("PATH", "/usr/bin:/bin:/usr/local/bin"),
        "HOME": os.environ.get("HOME", "/root"),
    }
    # propagate the parent's platform pin: in sandboxes where jax's
    # platform auto-discovery hangs (plugin probes), the runner exports
    # JAX_PLATFORMS=cpu -- dropping it here would stall EVERY subprocess
    # for minutes at first backend init
    if "JAX_PLATFORMS" in os.environ:
        env["JAX_PLATFORMS"] = os.environ["JAX_PLATFORMS"]
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, timeout=timeout, env=env, cwd=repo_root)
    if r.returncode != 0:
        raise AssertionError(f"subprocess failed:\nSTDOUT:{r.stdout}\nSTDERR:{r.stderr}")
    return r.stdout


@pytest.fixture
def subproc():
    return run_py_subprocess
