"""The fused rotate->quantize->GEMM consumer path (DESIGN.md section 6):
quant_dot against the unfused ``quantize(hadamard(x)) @ quantize(w)``
oracle across modes x dtypes x pow2/non-pow2 sizes, single-kernel
lowering of the model hot path, compute-dtype-aware plans (native bf16
passes + honest VMEM accounting), STE gradients, no-retrace plan
caching, and the deprecation shims."""
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.api import (
    QuantEpilogue,
    hadamard,
    plan_for,
    quant_dot,
)
from repro.core.hadamard import resolve_compute_dtype
from repro.core.quant import QuantConfig, quantize
from repro.core.rotations import rotated_quant_dot, rotated_quant_dot_experts
from repro.core.wquant import quantize_weight
from repro.kernels import registry
from repro.kernels.registry import default_block_m

MODES = ("int8", "fp8_e4m3", "fp8_e5m2")
# contraction-rounding tolerance vs. the fake-quant oracle (the oracle
# rounds dequantized operands to the io dtype before its matmul; the real
# path contracts exactly on the int8/fp8 grid and scales afterwards)
TOL = {jnp.float32: 1e-4, jnp.bfloat16: 5e-2, jnp.float16: 1e-2}


def _x(shape, seed=0, dtype=jnp.float32):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.standard_normal(shape), dtype=dtype)


def _oracle(x, w, mode, backend):
    """The unfused reference the issue names: fake-quantize the rotated
    activation per token and the weight per out-channel, then matmul."""
    xq = quantize(hadamard(x, backend=backend), mode, axis=-1)
    wq = quantize(w, mode, axis=0)
    return xq @ wq


def _rel_err(got, want):
    want = np.asarray(want, np.float32)
    got = np.asarray(got, np.float32)
    return np.abs(got - want).max() / max(np.abs(want).max(), 1e-6)


# --------------------------------------------------------------- oracle
@pytest.mark.parametrize("mode", MODES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16, jnp.float16])
@pytest.mark.parametrize("n", [256, 384])  # pow2 (fused) and 3*128 (grouped)
def test_quant_dot_matches_unfused_oracle(mode, dtype, n):
    x = _x((9, n), seed=n, dtype=dtype)
    w = _x((n, 160), seed=n + 1, dtype=dtype) * 0.05
    out = quant_dot(x, w, mode=mode, backend="pallas")
    want = _oracle(x, w, mode, backend="pallas")
    assert out.shape == (9, 160) and out.dtype == x.dtype
    assert _rel_err(out, want) < TOL[dtype]


@settings(deadline=None, max_examples=8)
@given(logn=st.integers(5, 10), seed=st.integers(0, 2**31 - 1),
       mode=st.sampled_from(MODES))
def test_property_quant_dot_pow2(logn, seed, mode):
    n = 2 ** logn
    x = _x((5, n), seed=seed)
    w = _x((n, 96), seed=seed + 1) * 0.1
    out = quant_dot(x, w, mode=mode, backend="pallas")
    assert _rel_err(out, _oracle(x, w, mode, "pallas")) < 1e-3


@settings(deadline=None, max_examples=6)
@given(g=st.integers(3, 7), logp=st.integers(4, 7),
       seed=st.integers(0, 2**31 - 1))
def test_property_quant_dot_grouped(g, logp, seed):
    n = g * 2 ** logp  # non-power-of-2: unfused fallback, grouped rotate
    if n & (n - 1) == 0:
        n += 2 ** logp  # g even could make a pow2; keep it grouped
    x = _x((4, n), seed=seed)
    w = _x((n, 64), seed=seed + 1) * 0.1
    out = quant_dot(x, w, mode="int8")
    xq = quantize(hadamard(x), "int8", axis=-1)
    want = xq @ quantize(w, "int8", axis=0)
    assert _rel_err(out, want) < 1e-3


def test_prequantized_weights_match_on_the_fly():
    x = _x((7, 512), seed=3)
    w = _x((512, 128), seed=4) * 0.05
    for mode in MODES:
        a = quant_dot(x, w, mode=mode, backend="pallas")
        b = quant_dot(x, quantize_weight(w, mode), mode=mode,
                      backend="pallas")
        assert (np.asarray(a) == np.asarray(b)).all()


def test_pallas_and_xla_backends_agree_bitwise():
    x = _x((11, 1024), seed=5)
    w = _x((1024, 192), seed=6) * 0.05
    for mode in MODES:
        a = quant_dot(x, w, mode=mode, backend="pallas")
        b = quant_dot(x, w, mode=mode, backend="xla")
        # same epilogue math, same exact low-precision contraction
        assert (np.asarray(a, np.float32) == np.asarray(b, np.float32)).all()


# ----------------------------------------------------------- validation
def test_quant_dot_plan_validation():
    x = _x((4, 256))
    w = _x((256, 64))
    with pytest.raises(ValueError, match="non-dequant"):
        quant_dot(x, w, plan_for(256))  # no epilogue
    with pytest.raises(ValueError, match="non-dequant"):
        quant_dot(x, w, plan_for(
            256, epilogue=QuantEpilogue("int8", dequant=True)))
    with pytest.raises(ValueError, match="explicit plan"):
        quant_dot(x, w, plan_for(256, epilogue=QuantEpilogue("int8")),
                  mode="int8")
    with pytest.raises(ValueError, match="contraction dim"):
        quant_dot(x, _x((128, 64)), mode="int8")
    with pytest.raises(ValueError, match="dtype"):
        quant_dot(_x((4, 256), dtype=jnp.bfloat16), w,
                  plan_for(256, epilogue=QuantEpilogue("int8")))
    with pytest.raises(ValueError, match="storage dtype"):
        quant_dot(x, quantize_weight(w, "fp8_e4m3"), mode="int8")


def test_qd_fusability_vmem_budget_guard():
    """fp8 weight tiles cost 3 bytes/element in VMEM (storage + bf16
    embedding): at the n=2^15 kernel cap even the minimal (n, 128) tile
    busts the budget, so the plan must take the unfused fallback; int8
    still fuses."""
    from repro.core.api import _qd_fusable

    assert _qd_fusable(
        plan_for(32768, backend="pallas", epilogue=QuantEpilogue("int8")))
    assert not _qd_fusable(
        plan_for(32768, backend="pallas",
                 epilogue=QuantEpilogue("fp8_e4m3")))
    assert _qd_fusable(
        plan_for(4096, backend="pallas",
                 epilogue=QuantEpilogue("fp8_e4m3")))


# ---------------------------------------------------- single-kernel HLO
def _count_pallas_calls(jaxpr) -> int:
    from jax.core import ClosedJaxpr, Jaxpr

    def walk(v):
        if isinstance(v, ClosedJaxpr):
            return count(v.jaxpr)
        if isinstance(v, Jaxpr):
            return count(v)
        if isinstance(v, (list, tuple)):
            return sum(walk(u) for u in v)
        return 0

    def count(j):
        total = 0
        for eqn in j.eqns:
            if eqn.primitive.name == "pallas_call":
                total += 1
            for param in eqn.params.values():
                total += walk(param)
        return total

    return count(jaxpr)


def test_rotated_quant_dot_lowers_to_single_pallas_call():
    """Acceptance: pallas + int8 + pow2 n is ONE pallas_call -- rotate,
    quantize AND the GEMM; no HBM round trip of the rotated tensor."""
    cfg = QuantConfig(mode="int8", rotate="hadamard", backend="pallas")
    x = _x((2, 4, 2048), seed=7)
    w = _x((2048, 256), seed=8) * 0.05
    jaxpr = jax.make_jaxpr(lambda a, b: rotated_quant_dot(a, b, cfg))(x, w)
    assert _count_pallas_calls(jaxpr.jaxpr) == 1
    # ... and the dot really happened inside it: no dot_general outside
    outer_dots = [e for e in jaxpr.jaxpr.eqns
                  if e.primitive.name == "dot_general"]
    assert not outer_dots


def test_trace_counts_stable_for_quant_dot():
    cfg = QuantConfig(mode="int8", rotate="hadamard", backend="pallas")
    w = _x((512, 64), seed=9) * 0.1
    rotated_quant_dot(_x((8, 512)), w, cfg)  # warm
    key = ("pallas", "quant_dot")
    before = registry.TRACE_COUNTS[key]
    for seed in range(3):
        rotated_quant_dot(_x((8, 512), seed=seed), w, cfg)
    assert registry.TRACE_COUNTS[key] == before
    rotated_quant_dot(_x((4, 1024)), _x((1024, 64)) * 0.1, cfg)
    assert registry.TRACE_COUNTS[key] == before + 1


# ------------------------------------------------------------ autodiff
def test_quant_dot_ste_gradients():
    x = _x((6, 256), seed=11)
    w = _x((256, 96), seed=12) * 0.1
    g = _x((6, 96), seed=13)
    gx, gw = jax.grad(
        lambda a, b: jnp.sum(quant_dot(a, b, mode="int8",
                                       backend="pallas") * g),
        argnums=(0, 1))(x, w)
    # STE: out ~= had(x) @ w, so gx = had(g w^T), gw = had(x)^T g
    want_gx = hadamard(g @ w.T, backend="pallas")
    want_gw = hadamard(x, backend="pallas").T @ g
    np.testing.assert_allclose(np.asarray(gx), np.asarray(want_gx),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(gw), np.asarray(want_gw),
                               rtol=1e-4, atol=1e-4)


def test_quant_dot_prequantized_weight_gets_no_gradient():
    x = _x((4, 256), seed=14)
    wq, sw = quantize_weight(_x((256, 32), seed=15) * 0.1, "fp8_e4m3")
    gx, gsw = jax.grad(
        lambda a, s: jnp.sum(quant_dot(a, (wq, s), mode="fp8_e4m3",
                                       backend="pallas") ** 2),
        argnums=(0, 1))(x, sw)
    assert bool(jnp.isfinite(gx).all()) and float(jnp.abs(gx).max()) > 0
    assert float(jnp.abs(gsw).max()) == 0.0  # scale is a statistic


# ------------------------------------------------- compute-dtype plans
def test_compute_dtype_resolution():
    assert resolve_compute_dtype(jnp.float32) == "float32"
    assert resolve_compute_dtype(jnp.bfloat16) == "bfloat16"
    assert resolve_compute_dtype(jnp.float16) == "float16"
    assert resolve_compute_dtype(jnp.bfloat16, jnp.float32) == "float32"
    with pytest.raises(ValueError):
        resolve_compute_dtype(jnp.float32, jnp.int8)
    assert plan_for(4096, dtype=jnp.bfloat16).compute_dtype == "bfloat16"
    assert plan_for(4096, dtype=jnp.float32).compute_dtype == "float32"
    # the override is part of the cache key
    p32 = plan_for(4096, dtype=jnp.bfloat16, compute_dtype=jnp.float32)
    assert p32.compute_dtype == "float32"
    assert p32 is not plan_for(4096, dtype=jnp.bfloat16)


def test_default_block_m_16bit_rows_at_least_1p5x_f32():
    """Acceptance: dropping the unconditional f32 VMEM copy buys 16-bit
    dtypes >= 1.5x larger row tiles at n=4096."""
    m = 1 << 16
    bm_f32 = default_block_m(4096, m, jnp.float32,
                             compute_dtype=jnp.float32)
    for dt in (jnp.bfloat16, jnp.float16):
        bm16 = default_block_m(4096, m, dt, compute_dtype=dt)
        assert bm16 >= 1.5 * bm_f32, (bm16, bm_f32)


def test_default_block_m_charges_epilogue_outputs():
    """The fused kernels' q tile + per-row scales are charged: the tile
    fits the documented 8 MiB budget for every epilogue form."""
    budget = 8 * 1024 * 1024
    m = 1 << 16
    for n in (4096, 16384, 32768):
        for epi in (None, QuantEpilogue("int8"),
                    QuantEpilogue("fp8_e4m3", dequant=True)):
            bm = default_block_m(n, m, jnp.float32,
                                 compute_dtype=jnp.float32, epilogue=epi)
            out_b = 4 if (epi is None or epi.dequant) else 1
            resident = bm * n * (4 + 4 + out_b) + (0 if epi is None else bm * 4)
            assert resident <= budget + n * 16  # one-row rounding slack


def test_bf16_compute_error_bound_vs_f32():
    """Appendix C mirror: native bf16 passes track the f32-compute
    transform within a small relative bound -- and differ from it
    (proving the low-precision path is actually taken)."""
    x = _x((32, 4096), seed=16, dtype=jnp.bfloat16)
    y16 = hadamard(x, plan_for(4096, dtype=jnp.bfloat16, backend="pallas"))
    y32 = hadamard(x, plan_for(4096, dtype=jnp.bfloat16, backend="pallas",
                               compute_dtype=jnp.float32))
    a16 = np.asarray(y16, np.float32)
    a32 = np.asarray(y32, np.float32)
    rel = np.abs(a16 - a32).max() / np.abs(a32).max()
    assert 0 < rel < 2e-2, rel
    # and the bf16 result still matches the exact rotation to bf16 accuracy
    want = np.asarray(hadamard(x.astype(jnp.float32)), np.float32)
    assert np.abs(a16 - want).max() / np.abs(want).max() < 2e-2


def test_quant_dot_bf16_no_retrace_and_correct():
    cfg = QuantConfig(mode="int8", rotate="hadamard", backend="pallas")
    x = _x((8, 512), seed=17, dtype=jnp.bfloat16)
    w = _x((512, 64), seed=18, dtype=jnp.bfloat16) * 0.1
    out = rotated_quant_dot(x, w, cfg)
    assert out.dtype == jnp.bfloat16
    key = ("pallas", "quant_dot")
    before = registry.TRACE_COUNTS[key]
    rotated_quant_dot(_x((8, 512), seed=19, dtype=jnp.bfloat16), w, cfg)
    assert registry.TRACE_COUNTS[key] == before


# ------------------------------------------------------------ MoE path
def test_rotated_quant_dot_experts_matches_per_expert_quant_dot():
    cfg = QuantConfig(mode="int8", rotate="hadamard", backend="pallas")
    x = _x((2, 3, 4, 256), seed=20)          # (B, E, cap, f)
    w = _x((3, 256, 64), seed=21) * 0.1      # (E, f, d)
    out = rotated_quant_dot_experts(x, w, cfg)
    assert out.shape == (2, 3, 4, 64)
    for e in range(3):
        want = quant_dot(x[:, e], w[e], mode="int8", backend="pallas")
        np.testing.assert_allclose(np.asarray(out[:, e]), np.asarray(want),
                                   rtol=1e-5, atol=1e-5)
    ge = jax.grad(lambda ww: jnp.sum(
        rotated_quant_dot_experts(x, ww, cfg) ** 2))(w)
    assert bool(jnp.isfinite(ge).all()) and float(jnp.abs(ge).max()) > 0


# ---------------------------------------------------------------- shims
def test_deprecation_shims_warn_once():
    from repro.kernels import fused_quant, ops

    for mod, call in (
        (ops, lambda: ops.hadamard(_x((2, 128)))),
        (fused_quant,
         lambda: fused_quant.fused_hadamard_quantize(_x((2, 128)))),
    ):
        mod._warned = False
        with pytest.warns(DeprecationWarning):
            call()
        with warnings.catch_warnings():
            warnings.simplefilter("error")  # second call must stay silent
            call()
