"""The fused rotate->quantize->GEMM consumer path (DESIGN.md section 6):
quant_dot against the unfused ``quantize(hadamard(x)) @ quantize(w)``
oracle across modes x dtypes x pow2/non-pow2 sizes, single-kernel
lowering of the model hot path, compute-dtype-aware plans (native bf16
passes + honest VMEM accounting), STE gradients, no-retrace plan
caching, and the deprecation shims."""
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.api import (
    QuantEpilogue,
    hadamard,
    plan_for,
    quant_dot,
)
from repro.core.hadamard import resolve_compute_dtype
from repro.core.quant import QuantConfig, quantize
from repro.core.rotations import rotated_quant_dot, rotated_quant_dot_experts
from repro.core.wquant import quantize_weight
from repro.kernels import registry
from repro.kernels.registry import default_block_m

MODES = ("int8", "fp8_e4m3", "fp8_e5m2")
# contraction-rounding tolerance vs. the fake-quant oracle (the oracle
# rounds dequantized operands to the io dtype before its matmul; the real
# path contracts exactly on the int8/fp8 grid and scales afterwards)
TOL = {jnp.float32: 1e-4, jnp.bfloat16: 5e-2, jnp.float16: 1e-2}


def _x(shape, seed=0, dtype=jnp.float32):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.standard_normal(shape), dtype=dtype)


def _oracle(x, w, mode, backend):
    """The unfused reference the issue names: fake-quantize the rotated
    activation per token and the weight per out-channel, then matmul."""
    xq = quantize(hadamard(x, backend=backend), mode, axis=-1)
    wq = quantize(w, mode, axis=0)
    return xq @ wq


def _rel_err(got, want):
    want = np.asarray(want, np.float32)
    got = np.asarray(got, np.float32)
    return np.abs(got - want).max() / max(np.abs(want).max(), 1e-6)


# --------------------------------------------------------------- oracle
@pytest.mark.parametrize("mode", MODES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16, jnp.float16])
@pytest.mark.parametrize("n", [256, 384])  # pow2 (fused) and 3*128 (grouped)
def test_quant_dot_matches_unfused_oracle(mode, dtype, n):
    x = _x((9, n), seed=n, dtype=dtype)
    w = _x((n, 160), seed=n + 1, dtype=dtype) * 0.05
    out = quant_dot(x, w, mode=mode, backend="pallas")
    want = _oracle(x, w, mode, backend="pallas")
    assert out.shape == (9, 160) and out.dtype == x.dtype
    assert _rel_err(out, want) < TOL[dtype]


@settings(deadline=None, max_examples=8)
@given(logn=st.integers(5, 10), seed=st.integers(0, 2**31 - 1),
       mode=st.sampled_from(MODES))
def test_property_quant_dot_pow2(logn, seed, mode):
    n = 2 ** logn
    x = _x((5, n), seed=seed)
    w = _x((n, 96), seed=seed + 1) * 0.1
    out = quant_dot(x, w, mode=mode, backend="pallas")
    assert _rel_err(out, _oracle(x, w, mode, "pallas")) < 1e-3


@settings(deadline=None, max_examples=6)
@given(g=st.integers(3, 7), logp=st.integers(4, 7),
       seed=st.integers(0, 2**31 - 1))
def test_property_quant_dot_grouped(g, logp, seed):
    n = g * 2 ** logp  # non-power-of-2: unfused fallback, grouped rotate
    if n & (n - 1) == 0:
        n += 2 ** logp  # g even could make a pow2; keep it grouped
    x = _x((4, n), seed=seed)
    w = _x((n, 64), seed=seed + 1) * 0.1
    out = quant_dot(x, w, mode="int8")
    xq = quantize(hadamard(x), "int8", axis=-1)
    want = xq @ quantize(w, "int8", axis=0)
    assert _rel_err(out, want) < 1e-3


def test_prequantized_weights_match_on_the_fly():
    x = _x((7, 512), seed=3)
    w = _x((512, 128), seed=4) * 0.05
    for mode in MODES:
        a = quant_dot(x, w, mode=mode, backend="pallas")
        b = quant_dot(x, quantize_weight(w, mode), mode=mode,
                      backend="pallas")
        assert (np.asarray(a) == np.asarray(b)).all()


def test_pallas_and_xla_backends_agree_bitwise():
    x = _x((11, 1024), seed=5)
    w = _x((1024, 192), seed=6) * 0.05
    for mode in MODES:
        a = quant_dot(x, w, mode=mode, backend="pallas")
        b = quant_dot(x, w, mode=mode, backend="xla")
        # same epilogue math, same exact low-precision contraction
        assert (np.asarray(a, np.float32) == np.asarray(b, np.float32)).all()


# ----------------------------------------------------------- validation
def test_quant_dot_plan_validation():
    x = _x((4, 256))
    w = _x((256, 64))
    with pytest.raises(ValueError, match="non-dequant"):
        quant_dot(x, w, plan_for(256))  # no epilogue
    with pytest.raises(ValueError, match="non-dequant"):
        quant_dot(x, w, plan_for(
            256, epilogue=QuantEpilogue("int8", dequant=True)))
    with pytest.raises(ValueError, match="explicit plan"):
        quant_dot(x, w, plan_for(256, epilogue=QuantEpilogue("int8")),
                  mode="int8")
    with pytest.raises(ValueError, match="contraction dim"):
        quant_dot(x, _x((128, 64)), mode="int8")
    with pytest.raises(ValueError, match="dtype"):
        quant_dot(_x((4, 256), dtype=jnp.bfloat16), w,
                  plan_for(256, epilogue=QuantEpilogue("int8")))
    with pytest.raises(ValueError, match="storage dtype"):
        quant_dot(x, quantize_weight(w, "fp8_e4m3"), mode="int8")


def test_qd_fusability_vmem_budget_guard():
    """fp8 weight tiles cost 3 bytes/element in VMEM (storage + bf16
    embedding): at the n=2^15 kernel cap even the minimal (n, 128) tile
    busts the budget, so the plan must take the unfused fallback; int8
    still fuses."""
    from repro.core.api import _qd_fusable

    assert _qd_fusable(
        plan_for(32768, backend="pallas", epilogue=QuantEpilogue("int8")))
    assert not _qd_fusable(
        plan_for(32768, backend="pallas",
                 epilogue=QuantEpilogue("fp8_e4m3")))
    assert _qd_fusable(
        plan_for(4096, backend="pallas",
                 epilogue=QuantEpilogue("fp8_e4m3")))


# ---------------------------------------------------- single-kernel HLO
# the structural walkers live in repro.analysis (shared with the lint
# rules); the tests assert through the same implementation CI lints with
from repro.analysis import count_pallas_calls as _count_pallas_calls


def test_rotated_quant_dot_lowers_to_single_pallas_call():
    """Acceptance: pallas + int8 + pow2 n is ONE pallas_call -- rotate,
    quantize AND the GEMM; no HBM round trip of the rotated tensor."""
    cfg = QuantConfig(mode="int8", rotate="hadamard", backend="pallas")
    x = _x((2, 4, 2048), seed=7)
    w = _x((2048, 256), seed=8) * 0.05
    jaxpr = jax.make_jaxpr(lambda a, b: rotated_quant_dot(a, b, cfg))(x, w)
    assert _count_pallas_calls(jaxpr.jaxpr) == 1
    # ... and the dot really happened inside it: no dot_general outside
    outer_dots = [e for e in jaxpr.jaxpr.eqns
                  if e.primitive.name == "dot_general"]
    assert not outer_dots


def test_trace_counts_stable_for_quant_dot():
    cfg = QuantConfig(mode="int8", rotate="hadamard", backend="pallas")
    w = _x((512, 64), seed=9) * 0.1
    rotated_quant_dot(_x((8, 512)), w, cfg)  # warm
    key = ("pallas", "quant_dot")
    before = registry.TRACE_COUNTS[key]
    for seed in range(3):
        rotated_quant_dot(_x((8, 512), seed=seed), w, cfg)
    assert registry.TRACE_COUNTS[key] == before
    rotated_quant_dot(_x((4, 1024)), _x((1024, 64)) * 0.1, cfg)
    assert registry.TRACE_COUNTS[key] == before + 1


# ------------------------------------------------------------ autodiff
def test_quant_dot_ste_gradients():
    x = _x((6, 256), seed=11)
    w = _x((256, 96), seed=12) * 0.1
    g = _x((6, 96), seed=13)
    gx, gw = jax.grad(
        lambda a, b: jnp.sum(quant_dot(a, b, mode="int8",
                                       backend="pallas") * g),
        argnums=(0, 1))(x, w)
    # STE: out ~= had(x) @ w, so gx = had(g w^T), gw = had(x)^T g
    want_gx = hadamard(g @ w.T, backend="pallas")
    want_gw = hadamard(x, backend="pallas").T @ g
    np.testing.assert_allclose(np.asarray(gx), np.asarray(want_gx),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(gw), np.asarray(want_gw),
                               rtol=1e-4, atol=1e-4)


def test_quant_dot_prequantized_weight_gets_no_gradient():
    x = _x((4, 256), seed=14)
    wq, sw = quantize_weight(_x((256, 32), seed=15) * 0.1, "fp8_e4m3")
    gx, gsw = jax.grad(
        lambda a, s: jnp.sum(quant_dot(a, (wq, s), mode="fp8_e4m3",
                                       backend="pallas") ** 2),
        argnums=(0, 1))(x, sw)
    assert bool(jnp.isfinite(gx).all()) and float(jnp.abs(gx).max()) > 0
    assert float(jnp.abs(gsw).max()) == 0.0  # scale is a statistic


# ------------------------------------------------- compute-dtype plans
def test_compute_dtype_resolution():
    assert resolve_compute_dtype(jnp.float32) == "float32"
    assert resolve_compute_dtype(jnp.bfloat16) == "bfloat16"
    assert resolve_compute_dtype(jnp.float16) == "float16"
    assert resolve_compute_dtype(jnp.bfloat16, jnp.float32) == "float32"
    with pytest.raises(ValueError):
        resolve_compute_dtype(jnp.float32, jnp.int8)
    assert plan_for(4096, dtype=jnp.bfloat16).compute_dtype == "bfloat16"
    assert plan_for(4096, dtype=jnp.float32).compute_dtype == "float32"
    # the override is part of the cache key
    p32 = plan_for(4096, dtype=jnp.bfloat16, compute_dtype=jnp.float32)
    assert p32.compute_dtype == "float32"
    assert p32 is not plan_for(4096, dtype=jnp.bfloat16)


def test_default_block_m_16bit_rows_at_least_1p5x_f32():
    """Acceptance: dropping the unconditional f32 VMEM copy buys 16-bit
    dtypes >= 1.5x larger row tiles at n=4096."""
    m = 1 << 16
    bm_f32 = default_block_m(4096, m, jnp.float32,
                             compute_dtype=jnp.float32)
    for dt in (jnp.bfloat16, jnp.float16):
        bm16 = default_block_m(4096, m, dt, compute_dtype=dt)
        assert bm16 >= 1.5 * bm_f32, (bm16, bm_f32)


def test_default_block_m_charges_epilogue_outputs():
    """The fused kernels' q tile + per-row scales are charged: the tile
    fits the documented 8 MiB budget for every epilogue form."""
    budget = 8 * 1024 * 1024
    m = 1 << 16
    for n in (4096, 16384, 32768):
        for epi in (None, QuantEpilogue("int8"),
                    QuantEpilogue("fp8_e4m3", dequant=True)):
            bm = default_block_m(n, m, jnp.float32,
                                 compute_dtype=jnp.float32, epilogue=epi)
            out_b = 4 if (epi is None or epi.dequant) else 1
            resident = bm * n * (4 + 4 + out_b) + (0 if epi is None else bm * 4)
            assert resident <= budget + n * 16  # one-row rounding slack


def test_bf16_compute_error_bound_vs_f32():
    """Appendix C mirror: native bf16 passes track the f32-compute
    transform within a small relative bound -- and differ from it
    (proving the low-precision path is actually taken)."""
    x = _x((32, 4096), seed=16, dtype=jnp.bfloat16)
    y16 = hadamard(x, plan_for(4096, dtype=jnp.bfloat16, backend="pallas"))
    y32 = hadamard(x, plan_for(4096, dtype=jnp.bfloat16, backend="pallas",
                               compute_dtype=jnp.float32))
    a16 = np.asarray(y16, np.float32)
    a32 = np.asarray(y32, np.float32)
    rel = np.abs(a16 - a32).max() / np.abs(a32).max()
    assert 0 < rel < 2e-2, rel
    # and the bf16 result still matches the exact rotation to bf16 accuracy
    want = np.asarray(hadamard(x.astype(jnp.float32)), np.float32)
    assert np.abs(a16 - want).max() / np.abs(want).max() < 2e-2


def test_quant_dot_bf16_no_retrace_and_correct():
    cfg = QuantConfig(mode="int8", rotate="hadamard", backend="pallas")
    x = _x((8, 512), seed=17, dtype=jnp.bfloat16)
    w = _x((512, 64), seed=18, dtype=jnp.bfloat16) * 0.1
    out = rotated_quant_dot(x, w, cfg)
    assert out.dtype == jnp.bfloat16
    key = ("pallas", "quant_dot")
    before = registry.TRACE_COUNTS[key]
    rotated_quant_dot(_x((8, 512), seed=19, dtype=jnp.bfloat16), w, cfg)
    assert registry.TRACE_COUNTS[key] == before


# ------------------------------------------------------------ MoE path
def test_rotated_quant_dot_experts_matches_per_expert_quant_dot():
    cfg = QuantConfig(mode="int8", rotate="hadamard", backend="pallas")
    x = _x((2, 3, 4, 256), seed=20)          # (B, E, cap, f)
    w = _x((3, 256, 64), seed=21) * 0.1      # (E, f, d)
    out = rotated_quant_dot_experts(x, w, cfg)
    assert out.shape == (2, 3, 4, 64)
    for e in range(3):
        want = quant_dot(x[:, e], w[e], mode="int8", backend="pallas")
        np.testing.assert_allclose(np.asarray(out[:, e]), np.asarray(want),
                                   rtol=1e-5, atol=1e-5)
    ge = jax.grad(lambda ww: jnp.sum(
        rotated_quant_dot_experts(x, ww, cfg) ** 2))(w)
    assert bool(jnp.isfinite(ge).all()) and float(jnp.abs(ge).max()) > 0


# -------------------------------------------- rotate-once grid schedule
from repro.analysis import dots_by_region as _dots_by_region
from repro.analysis import kernel_jaxpr as _kernel_jaxpr


@pytest.mark.parametrize("d", [256, 1024])
def test_rotate_once_transform_guarded_per_row_block(d):
    """Acceptance (structural): in the rotate-once kernel the transform
    matmuls are guarded by the j == 0 cond -- executed once per ROW BLOCK
    -- while exactly ONE top-level dot_general (the contraction) runs per
    out-channel tile; and the counts are independent of d (the revisit
    count d/block_n only changes the grid, never the per-block transform
    work)."""
    from repro.core.api import QuantEpilogue, plan_for
    from repro.kernels.quant_dot import pallas_quant_dot

    plan = plan_for(512, backend="pallas", epilogue=QuantEpilogue("int8"))
    x = _x((8, 512))
    wq = jnp.zeros((512, d), jnp.int8)
    sw = jnp.ones((1, d), jnp.float32)
    closed = jax.make_jaxpr(
        lambda a, q, s: pallas_quant_dot(a, q, s, plan, True,
                                         "rotate_once", 128))(x, wq, sw)
    top, in_cond = _dots_by_region(_kernel_jaxpr(closed))
    assert top == 1, top                       # the contraction only
    assert in_cond == plan.num_passes, (in_cond, plan.num_passes)

    # the PR-3 revisit schedule as contrast: every grid step recomputes
    # the passes unguarded -- passes + contraction all at top level
    closed_rv = jax.make_jaxpr(
        lambda a, q, s: pallas_quant_dot(a, q, s, plan, True,
                                         "revisit", 128))(x, wq, sw)
    top_rv, in_cond_rv = _dots_by_region(_kernel_jaxpr(closed_rv))
    assert top_rv == plan.num_passes + 1 and in_cond_rv == 0


@pytest.mark.parametrize("mode", MODES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16, jnp.float16])
def test_rotate_once_bitwise_vs_revisit_schedule(mode, dtype):
    """Acceptance: the new schedule is bitwise the PR-3 kernel across all
    three quant modes x f32/bf16/fp16 -- with block_n pinned small so the
    out-channel loop really revisits (d / block_n = 5 tiles)."""
    from repro.core.api import QuantEpilogue, plan_for
    from repro.kernels.quant_dot import pallas_quant_dot

    x = _x((23, 512), seed=30, dtype=dtype)
    wq, sw = quantize_weight(_x((512, 640), seed=31, dtype=dtype) * 0.05,
                             mode)
    plan = plan_for(512, dtype=dtype, backend="pallas",
                    epilogue=QuantEpilogue(mode))
    a = pallas_quant_dot(x, wq, sw, plan, True, "rotate_once", 128)
    b = pallas_quant_dot(x, wq, sw, plan, True, "revisit", 128)
    assert (np.asarray(a, np.float32) == np.asarray(b, np.float32)).all()


def test_quant_dot_schedule_validation_and_env(monkeypatch):
    from repro.core.api import QuantEpilogue, plan_for
    from repro.kernels.quant_dot import SCHEDULE_ENV_VAR, pallas_quant_dot

    x = _x((4, 256))
    wq, sw = quantize_weight(_x((256, 64), seed=1) * 0.1, "int8")
    plan = plan_for(256, backend="pallas", epilogue=QuantEpilogue("int8"))
    with pytest.raises(ValueError, match="schedule"):
        pallas_quant_dot(x, wq, sw, plan, True, "typo")
    want = pallas_quant_dot(x, wq, sw, plan, True)
    monkeypatch.setenv(SCHEDULE_ENV_VAR, "revisit")
    got = pallas_quant_dot(x, wq, sw, plan, True)
    assert (np.asarray(got) == np.asarray(want)).all()
    monkeypatch.setenv(SCHEDULE_ENV_VAR, "bogus")
    with pytest.raises(ValueError, match="schedule"):
        pallas_quant_dot(x, wq, sw, plan, True)


def test_quant_dot_blocks_pinned_block_m_drives_bn():
    """Satellite fix: a user-pinned block_m participates in the
    weight-tile/block_n tradeoff INSTEAD of being applied after the
    heuristic bm sizing -- a tiny pinned row tile frees VMEM, so the
    out-channel tile widens beyond what the default-bm sizing picks."""
    from repro.kernels.quant_dot import quant_dot_blocks

    args = (4096, 8192, 1 << 14, jnp.float32, jnp.float32, "fp8_e4m3")
    bm_def, bn_def = quant_dot_blocks(*args)
    bm_pin, bn_pin = quant_dot_blocks(*args, block_m=8)
    assert bm_pin == 8                      # the pin is honored verbatim
    assert bn_pin > bn_def, (bn_pin, bn_def)
    assert bn_pin % 128 == 0
    # and a pinned block_n is honored verbatim on both paths
    assert quant_dot_blocks(*args, block_n=256)[1] == 256
    assert quant_dot_blocks(*args, block_m=8, block_n=256) == (8, 256)


def test_quant_dot_pinned_block_m_end_to_end():
    """plan.block_m flows through the rotate-once kernel (scratch sized
    to the pin) and stays bitwise with the default tiling."""
    from repro.core.api import QuantEpilogue, plan_for, quant_dot

    x = _x((24, 512), seed=33)
    w = _x((512, 320), seed=34) * 0.05
    qt = quantize_weight(w, "int8")
    want = quant_dot(x, qt, mode="int8", backend="pallas")
    got = quant_dot(x, qt, plan_for(
        512, backend="pallas", epilogue=QuantEpilogue("int8"), block_m=8))
    assert (np.asarray(got) == np.asarray(want)).all()


# ------------------------------------------- fused 3-D expert kernel
from repro.analysis import dots_outside_pallas as _dots_outside_pallas


def test_quant_dot_experts_fused_single_kernel():
    """Off-mesh fusable expert plans run ONE pallas_call carrying every
    expert's rotation, quantization AND contraction -- no per-expert
    einsum outside the kernel (PR 4 split into a rotate+quantize kernel
    plus an XLA einsum that re-read (q, scales) from HBM)."""
    from repro.core.api import QuantEpilogue, plan_for, quant_dot_experts

    x = _x((2, 3, 8, 256), seed=40)
    qt = quantize_weight(_x((3, 256, 192), seed=41) * 0.1, "int8")
    plan = plan_for(256, backend="pallas", epilogue=QuantEpilogue("int8"))
    closed = jax.make_jaxpr(
        lambda a: quant_dot_experts(a, qt, plan, interpret=True))(x)
    assert _count_pallas_calls(closed.jaxpr) == 1
    assert _dots_outside_pallas(closed) == 0


@pytest.mark.parametrize("mode", MODES)
def test_quant_dot_experts_fused_matches_einsum_oracle(mode):
    """The 3-D rotate-once expert kernel is bitwise the einsum form for
    int8 (exact int32 accumulation) and allclose for fp8 (f32
    accumulation order differs between dot shapes)."""
    from repro.core.api import (QuantEpilogue, _experts_einsum_qw, plan_for,
                                quant_dot_experts)

    x = _x((2, 4, 6, 256), seed=42)
    qt = quantize_weight(_x((4, 256, 200), seed=43) * 0.1, mode)
    plan = plan_for(256, backend="pallas", epilogue=QuantEpilogue(mode))
    got = np.asarray(quant_dot_experts(x, qt, plan), np.float32)
    want = np.asarray(_experts_einsum_qw(x, qt.q, qt.scale, plan, True),
                      np.float32)
    assert got.shape == (2, 4, 6, 200)
    if mode == "int8":
        assert (got == want).all()
    else:
        np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-6)


def test_quant_dot_experts_einsum_under_mesh():
    """Under an active mesh the expert site must stay on the
    GSPMD-shardable einsum form (a pallas_call would not partition)."""
    from repro.core.api import QuantEpilogue, plan_for, quant_dot_experts
    from repro.distributed import sharding as shd

    x = _x((1, 2, 4, 256), seed=44)
    qt = quantize_weight(_x((2, 256, 64), seed=45) * 0.1, "int8")
    plan = plan_for(256, backend="pallas", epilogue=QuantEpilogue("int8"))
    off_mesh = quant_dot_experts(x, qt, plan)
    mesh = jax.make_mesh((1,), ("model",))
    key = ("pallas", "quant_dot_experts")
    obs = ("sharded_quant_dot", "experts_einsum_on_mesh")
    with shd.sharding_rules(mesh):
        before = registry.TRACE_COUNTS[key]
        obs_before = registry.TRACE_COUNTS[obs]
        on_mesh = quant_dot_experts(x, qt, plan)
        assert registry.TRACE_COUNTS[key] == before  # einsum path, no kernel
        # ... and the kernel-form bypass is observable
        assert registry.TRACE_COUNTS[obs] == obs_before + 1
    assert (np.asarray(on_mesh) == np.asarray(off_mesh)).all()


# ------------------------------------- streamed DMA-ring grid schedule
from repro.analysis import stream_events as _stream_events


def _streamed_jaxpr(d=640, bn=128, experts=False):
    from repro.core.api import QuantEpilogue, plan_for
    from repro.kernels.quant_dot import (pallas_quant_dot,
                                         pallas_quant_dot_experts)

    plan = plan_for(512, backend="pallas", epilogue=QuantEpilogue("int8"))
    sw = jnp.ones((1, d), jnp.float32)
    if experts:
        x = _x((1, 2, 8, 512))
        wq = jnp.zeros((2, 512, d), jnp.int8)
        swe = jnp.ones((2, 1, d), jnp.float32)
        return jax.make_jaxpr(
            lambda a, q, s: pallas_quant_dot_experts(
                a, q, s, plan, True, "streamed", bn))(x, wq, swe)
    x = _x((8, 512))
    wq = jnp.zeros((512, d), jnp.int8)
    return jax.make_jaxpr(
        lambda a, q, s: pallas_quant_dot(a, q, s, plan, True,
                                         "streamed", bn))(x, wq, sw)


@pytest.mark.parametrize("experts", [False, True], ids=["2d", "experts"])
def test_streamed_prefetch_starts_before_contraction(experts, monkeypatch):
    """Acceptance (structural): the streamed body kicks off the j+1
    copy-start BEFORE waiting on the j slot, and every DMA wait precedes
    the (single) top-level contraction -- the overlap window really
    exists in the kernel jaxpr rather than degenerate start->wait->dot
    per tile."""
    from repro.kernels.quant_dot import STREAM_INTERPRET_ENV

    monkeypatch.setenv(STREAM_INTERPRET_ENV, "1")
    events = _stream_events(_kernel_jaxpr(_streamed_jaxpr(experts=experts)))
    assert events.count("dot") == 1, events     # the contraction only
    first_wait = events.index("wait")
    dot_at = events.index("dot")
    # warm-up (j==0) and prefetch (j+1) starts both precede the blocking
    # wait; the wait pair (weight + scale slots) precedes the dot
    assert events[:first_wait].count("start_cond") >= 2, events
    assert first_wait < dot_at and events[first_wait:dot_at].count(
        "wait") >= 2, events
    assert "start_cond" not in events[dot_at:], events


def test_streamed_keeps_rotate_once_transform_guard(monkeypatch):
    """Streaming replaces the weight fetch, not the schedule: the
    transform matmuls stay under the j == 0 cond (once per row block)
    and exactly one top-level dot_general contracts per tile."""
    from repro.core.api import QuantEpilogue, plan_for
    from repro.kernels.quant_dot import STREAM_INTERPRET_ENV

    monkeypatch.setenv(STREAM_INTERPRET_ENV, "1")
    plan = plan_for(512, backend="pallas", epilogue=QuantEpilogue("int8"))
    top, in_cond = _dots_by_region(_kernel_jaxpr(_streamed_jaxpr()))
    assert top == 1, top
    assert in_cond == plan.num_passes, (in_cond, plan.num_passes)


@pytest.mark.parametrize("mode", MODES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16, jnp.float16])
def test_streamed_bitwise_vs_rotate_once(mode, dtype, monkeypatch):
    """Acceptance: streamed is bitwise rotate_once across all three quant
    modes x f32/bf16/fp16 -- d = 600 with block_n = 128 so the last tile
    is a padded tail (600 = 4*128 + 88) and the ring drains mid-tile."""
    from repro.core.api import QuantEpilogue, plan_for
    from repro.kernels.quant_dot import STREAM_INTERPRET_ENV, pallas_quant_dot

    monkeypatch.setenv(STREAM_INTERPRET_ENV, "1")
    x = _x((23, 512), seed=50, dtype=dtype)
    wq, sw = quantize_weight(_x((512, 600), seed=51, dtype=dtype) * 0.05,
                             mode)
    plan = plan_for(512, dtype=dtype, backend="pallas",
                    epilogue=QuantEpilogue(mode))
    a = pallas_quant_dot(x, wq, sw, plan, True, "rotate_once", 128)
    b = pallas_quant_dot(x, wq, sw, plan, True, "streamed", 128)
    assert a.dtype == b.dtype == x.dtype
    assert (np.asarray(a, np.float32) == np.asarray(b, np.float32)).all()


@pytest.mark.parametrize("mode", MODES)
def test_streamed_experts_bitwise_vs_rotate_once(mode, monkeypatch):
    """The 3-D (expert, rows, out-channels) ring resets slot parity at
    every new (expert, row-block) pair: multiple experts x multiple row
    blocks x a padded tail tile stay bitwise with the implicit fetch."""
    from repro.core.api import QuantEpilogue, plan_for
    from repro.kernels.quant_dot import (STREAM_INTERPRET_ENV,
                                         pallas_quant_dot_experts)

    monkeypatch.setenv(STREAM_INTERPRET_ENV, "1")
    x = _x((2, 3, 6, 256), seed=52)
    qt = quantize_weight(_x((3, 256, 200), seed=53) * 0.1, mode)
    plan = plan_for(256, backend="pallas", epilogue=QuantEpilogue(mode))
    a = pallas_quant_dot_experts(x, qt.q, qt.scale, plan, True,
                                 "rotate_once", 128)
    b = pallas_quant_dot_experts(x, qt.q, qt.scale, plan, True,
                                 "streamed", 128)
    assert (np.asarray(a, np.float32) == np.asarray(b, np.float32)).all()


def test_streamed_interpret_fallback_warns_once_and_counts(monkeypatch):
    """Without the force flag, interpret mode degrades streamed ->
    rotate_once: warn ONCE per process, tick
    TRACE_COUNTS[('quant_dot', 'stream_fallback')] every time, stay
    bitwise (mirrors the PR 5 _sharded_fallback pattern)."""
    import repro.kernels.quant_dot as qd

    monkeypatch.delenv(qd.STREAM_INTERPRET_ENV, raising=False)
    registry.WARN_ONCE_SEEN.discard(("quant_dot", "stream_fallback"))
    x = _x((4, 256), seed=54)
    wq, sw = quantize_weight(_x((256, 64), seed=55) * 0.1, "int8")
    plan = plan_for(256, backend="pallas", epilogue=QuantEpilogue("int8"))
    key = ("quant_dot", "stream_fallback")
    before = registry.TRACE_COUNTS[key]
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        a = qd.pallas_quant_dot(x, wq, sw, plan, True, "streamed")
        b = qd.pallas_quant_dot(x, wq, sw, plan, True, "streamed")
    msgs = [r for r in rec if issubclass(r.category, RuntimeWarning)
            and "streamed" in str(r.message)]
    assert len(msgs) == 1, [str(r.message) for r in rec]
    assert registry.TRACE_COUNTS[key] == before + 2
    want = qd.pallas_quant_dot(x, wq, sw, plan, True, "rotate_once")
    assert (np.asarray(a) == np.asarray(want)).all()
    assert (np.asarray(b) == np.asarray(want)).all()
    # the force flag suppresses the fallback: streamed really runs
    monkeypatch.setenv(qd.STREAM_INTERPRET_ENV, "1")
    after = registry.TRACE_COUNTS[key]
    forced = qd.pallas_quant_dot(x, wq, sw, plan, True, "streamed")
    assert registry.TRACE_COUNTS[key] == after
    assert (np.asarray(forced) == np.asarray(want)).all()


def test_quant_dot_blocks_charges_streamed_ring():
    """Satellite: the block planner charges the second weight-tile slot +
    double scale slot + ring residency when sizing streamed blocks, and
    the returned BlockDecision exposes the schedule and the charged VMEM
    so benches can record them -- while staying a 2-tuple for legacy
    unpacking."""
    from repro.kernels.quant_dot import (_VMEM_BUDGET_BYTES, BlockDecision,
                                         quant_dot_blocks)

    args = (4096, 8192, 1 << 14, jnp.float32, jnp.float32, "int8")
    base = quant_dot_blocks(*args)
    streamed = quant_dot_blocks(*args, schedule="streamed")
    assert isinstance(base, BlockDecision) and isinstance(streamed,
                                                          BlockDecision)
    assert base.schedule == "rotate_once" and streamed.schedule == "streamed"
    # legacy consumers: tuple unpack and equality still work
    bm, bn = streamed
    assert (bm, bn) == (streamed.block_m, streamed.block_n)
    assert quant_dot_blocks(*args, block_m=8, block_n=256,
                            schedule="streamed") == (8, 256)
    # both decisions honor the budget; the ring narrows (or holds) bn
    # and, at equal tiles, charges strictly more VMEM
    assert base.vmem_bytes <= _VMEM_BUDGET_BYTES
    assert streamed.vmem_bytes <= _VMEM_BUDGET_BYTES
    assert streamed.block_n <= base.block_n
    pinned = dict(block_m=base.block_m, block_n=base.block_n)
    assert (quant_dot_blocks(*args, schedule="streamed",
                             **pinned).vmem_bytes >
            quant_dot_blocks(*args, **pinned).vmem_bytes)


def test_quant_dot_schedule_through_public_api(monkeypatch):
    """The schedule kwarg rides quant_dot / quant_dot_experts /
    QuantDotSpec end to end (custom_vjp nondiff plumbing) and composes
    with an explicit plan -- it is dispatch-level, not plan config."""
    from repro.core.api import QuantDotSpec, quant_dot_experts
    from repro.kernels.quant_dot import STREAM_INTERPRET_ENV

    monkeypatch.setenv(STREAM_INTERPRET_ENV, "1")
    x = _x((9, 256), seed=56)
    w = _x((256, 320), seed=57) * 0.05
    qt = quantize_weight(w, "int8")
    want = quant_dot(x, qt, mode="int8", backend="pallas")
    got = quant_dot(x, qt, mode="int8", backend="pallas",
                    schedule="streamed")
    assert (np.asarray(got) == np.asarray(want)).all()
    # explicit plan + schedule does NOT trip the plan/kwargs guard
    plan = plan_for(256, backend="pallas", epilogue=QuantEpilogue("int8"))
    assert (np.asarray(quant_dot(x, qt, plan, schedule="streamed"))
            == np.asarray(want)).all()
    # spec-bound site + validation
    spec = QuantDotSpec(n=256, mode="int8", backend="pallas",
                        schedule="streamed")
    assert (np.asarray(spec(x, qt)) == np.asarray(want)).all()
    with pytest.raises(ValueError, match="schedule"):
        QuantDotSpec(n=256, schedule="bogus")
    # STE gradients are schedule-invariant (nondiff argnum plumbing)
    gx = jax.grad(lambda a: jnp.sum(
        quant_dot(a, w, mode="int8", backend="pallas",
                  schedule="streamed") ** 2))(x)
    gx0 = jax.grad(lambda a: jnp.sum(
        quant_dot(a, w, mode="int8", backend="pallas") ** 2))(x)
    assert (np.asarray(gx) == np.asarray(gx0)).all()
    # experts: spec + function form
    xe = _x((1, 2, 4, 256), seed=58)
    qte = quantize_weight(_x((2, 256, 128), seed=59) * 0.1, "int8")
    eplan = plan_for(256, backend="pallas", epilogue=QuantEpilogue("int8"))
    ewant = quant_dot_experts(xe, qte, eplan)
    egot = quant_dot_experts(xe, qte, eplan, schedule="streamed")
    assert (np.asarray(egot) == np.asarray(ewant)).all()


def test_streamed_env_var_resolution(monkeypatch):
    """REPRO_QUANT_DOT_SCHEDULE=streamed flips the default (the tier-1 CI
    streamed leg); an explicit schedule argument beats the env."""
    from repro.kernels.quant_dot import (SCHEDULE_ENV_VAR,
                                         STREAM_INTERPRET_ENV,
                                         pallas_quant_dot)

    monkeypatch.setenv(STREAM_INTERPRET_ENV, "1")
    x = _x((4, 256), seed=60)
    wq, sw = quantize_weight(_x((256, 64), seed=61) * 0.1, "int8")
    plan = plan_for(256, backend="pallas", epilogue=QuantEpilogue("int8"))
    want = pallas_quant_dot(x, wq, sw, plan, True, "rotate_once")
    monkeypatch.setenv(SCHEDULE_ENV_VAR, "streamed")
    got = pallas_quant_dot(x, wq, sw, plan, True)       # env default
    assert (np.asarray(got) == np.asarray(want)).all()
    got2 = pallas_quant_dot(x, wq, sw, plan, True, "revisit")  # arg wins
    assert (np.asarray(got2) == np.asarray(want)).all()


# ---------------------------------------------------------------- shims
def test_deprecation_shims_warn_once():
    from repro.kernels import fused_quant, ops

    for mod, call in (
        (ops, lambda: ops.hadamard(_x((2, 128)))),
        (fused_quant,
         lambda: fused_quant.fused_hadamard_quantize(_x((2, 128)))),
    ):
        registry.WARN_ONCE_SEEN.discard(mod.WARN_KEY)
        before = registry.TRACE_COUNTS[mod.WARN_KEY]
        with pytest.warns(DeprecationWarning):
            call()
        with warnings.catch_warnings():
            warnings.simplefilter("error")  # second call must stay silent
            call()
        # the shared warn_once util keeps counting after going quiet
        assert registry.TRACE_COUNTS[mod.WARN_KEY] == before + 2
