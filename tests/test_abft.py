"""ABFT runtime verification (PR 10, DESIGN.md section 14).

The checksum-verified kernels must be invisible when healthy -- bitwise
identical outputs, zero trips across modes x dtypes x schedules (the
false-positive property the calibrated tolerances buy) -- and loud when
corrupted: a flipped weight element, a clobbered column, or a poisoned
expert shifts the per-row residual by orders of magnitude over the
threshold, and ONLY the affected rows trip. The KV conservation law,
the pure-rotation linearity check, the stored-checksum weight audit,
and the checkpoint CRC seam get the same healthy/corrupt treatment.
"""
import dataclasses
import json
import os

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax
import jax.numpy as jnp

from repro import verify
from repro.core.api import (
    QuantDotSpec,
    QuantEpilogue,
    RotationSpec,
    plan_for,
)
from repro.core.hadamard import hadamard_check, hadamard_transform
from repro.core.wquant import QTensor, quantize_weight, weight_checksum
from repro.kernels.quant_dot import (
    pallas_quant_dot,
    pallas_quant_dot_experts,
    xla_quant_dot_resid,
)
from repro.kernels.registry import TRACE_COUNTS

MODES = ("int8", "fp8_e4m3", "fp8_e5m2")
DTYPES = (jnp.float32, jnp.bfloat16, jnp.float16)
SCHEDULES = ("rotate_once", "streamed")


def _x(shape, seed=0, dtype=jnp.float32):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.standard_normal(shape), dtype=dtype)


def _qw(n, d, mode, seed=1, scale=0.05):
    w = _x((n, d), seed=seed) * scale
    return quantize_weight(w, mode, with_check=True)


def _stream_env(monkeypatch, schedule):
    if schedule == "streamed":
        # run the real streamed kernel body on the interpreter's
        # synchronous DMA simulation instead of falling back
        monkeypatch.setenv("REPRO_QUANT_DOT_STREAM_INTERPRET", "1")


# ------------------------------------------------------------- checksum math
def test_weight_checksum_shape_and_identity():
    qt = _qw(256, 96, "int8")
    assert qt.check is not None and qt.check.shape == (1, 256)
    assert qt.check.dtype == jnp.float32
    # sum_d (a . W_dq)[d] == a . check for any activation row a
    a = _x((3, 256), seed=7)
    lhs = (a @ qt.dequant(jnp.float32)).sum(axis=-1)
    rhs = a @ qt.check.reshape(256)
    np.testing.assert_allclose(np.asarray(lhs), np.asarray(rhs),
                               rtol=1e-4, atol=1e-4)
    # default quantization carries no checksum (empty pytree subtree)
    assert quantize_weight(_x((256, 96)) * 0.05, "int8").check is None


def test_with_checks_attaches_and_params_ok_audits():
    tree = {"w_down": quantize_weight(_x((128, 64), seed=2) * 0.1, "int8"),
            "bias": jnp.zeros((4,))}
    tree = verify.with_checks(tree)
    assert tree["w_down"].check is not None
    assert verify.params_ok(tree)
    # silent corruption of the live weight breaks the stored checksum
    bad = dataclasses.replace(
        tree["w_down"], q=tree["w_down"].q.at[3, 5].set(127))
    assert not verify.params_ok({"w_down": bad, "bias": tree["bias"]})


# ----------------------------------------- healthy runs: bitwise, zero trips
@pytest.mark.parametrize("schedule", SCHEDULES)
@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("mode", MODES)
def test_healthy_verified_kernel_bitwise_and_all_ok(mode, dtype, schedule,
                                                    monkeypatch):
    _stream_env(monkeypatch, schedule)
    n, d, m = 256, 256, 9
    x = _x((m, n), seed=n, dtype=dtype)
    qt = _qw(n, d, mode, seed=n + 1)
    plan = plan_for(n, dtype=dtype, backend="pallas",
                    epilogue=QuantEpilogue(mode))
    y = pallas_quant_dot(x, qt.q, qt.scale, plan, True, schedule)
    yv, resid = pallas_quant_dot(x, qt.q, qt.scale, plan, True, schedule,
                                 check=qt.check)
    # the verified kernel's real output is graph-identical -> bitwise
    assert (np.asarray(y, np.float32) == np.asarray(yv, np.float32)).all()
    assert resid.shape == (m, 1) and resid.dtype == jnp.float32
    ok = verify.residual_ok(yv, resid, n=n, d=d)
    assert bool(ok.all()), np.asarray(resid)[~np.asarray(ok)[:, 0]]


def test_healthy_padded_tail_all_ok(monkeypatch):
    # d = 600 with block_n=128 pads 40 out-channels; the zero pad columns
    # must contribute nothing to either residual side
    n, d, m = 256, 600, 5
    x = _x((m, n), seed=3)
    qt = _qw(n, d, "int8", seed=4)
    plan = plan_for(n, dtype=jnp.float32, backend="pallas",
                    epilogue=QuantEpilogue("int8"))
    y = pallas_quant_dot(x, qt.q, qt.scale, plan, True, "rotate_once", 128)
    yv, resid = pallas_quant_dot(x, qt.q, qt.scale, plan, True,
                                 "rotate_once", 128, check=qt.check)
    assert (np.asarray(y) == np.asarray(yv)).all()
    assert bool(verify.residual_ok(yv, resid, n=n, d=d).all())


@settings(deadline=None, max_examples=6)
@given(logn=st.integers(5, 8), seed=st.integers(0, 2**31 - 1),
       mode=st.sampled_from(MODES))
def test_property_healthy_never_trips(logn, seed, mode):
    """False-positive property: no healthy (shape, seed, mode) trips the
    calibrated tolerance -- the ~500x headroom in abft_tolerance."""
    n = 2 ** logn
    x = _x((6, n), seed=seed)
    qt = _qw(n, 96, mode, seed=seed + 1)
    plan = plan_for(n, dtype=jnp.float32, backend="pallas",
                    epilogue=QuantEpilogue(mode))
    yv, resid = pallas_quant_dot(x, qt.q, qt.scale, plan, True,
                                 "rotate_once", check=qt.check)
    assert bool(verify.residual_ok(yv, resid, n=n, d=96).all())


# --------------------------------------------------- detection: iff affected
def test_corrupt_weight_column_trips_only_affected_rows():
    n, d, m = 256, 128, 6
    x = _x((m, n), seed=11)
    x = x.at[2].set(0.0)            # a zero activation row is unaffected
    qt = _qw(n, d, "int8", seed=12)
    bad_q = qt.q.at[:, 0].set(127)  # clobber one out-channel column
    plan = plan_for(n, dtype=jnp.float32, backend="pallas",
                    epilogue=QuantEpilogue("int8"))
    yv, resid = pallas_quant_dot(x, bad_q, qt.scale, plan, True,
                                 "rotate_once", check=qt.check)
    ok = np.asarray(verify.residual_ok(yv, resid, n=n, d=d))[:, 0]
    assert not ok[[0, 1, 3, 4, 5]].any(), np.asarray(resid)
    assert ok[2]                    # zero row: residual exactly zero


def test_single_lsb_flip_is_detected():
    """Detection sensitivity: ONE least-significant-bit flip of one int8
    weight element shifts affected rows' residuals past the threshold."""
    n, d = 256, 128
    x = _x((8, n), seed=21)
    qt = _qw(n, d, "int8", seed=22)
    bad_q = qt.q.at[17, 40].add(1)
    plan = plan_for(n, dtype=jnp.float32, backend="pallas",
                    epilogue=QuantEpilogue("int8"))
    yv, resid = pallas_quant_dot(x, bad_q, qt.scale, plan, True,
                                 "rotate_once", check=qt.check)
    ok = np.asarray(verify.residual_ok(yv, resid, n=n, d=d))
    assert not ok.all(), "LSB flip went undetected"


def test_experts_healthy_bitwise_and_surgical_detection():
    n, d, m = 256, 128, 4
    xe = _x((1, 2, m, n), seed=31)
    we = _x((2, n, d), seed=32) * 0.05
    qt = quantize_weight(we, "int8", with_check=True)
    assert qt.check.shape == (2, 1, n)
    plan = plan_for(n, dtype=jnp.float32, backend="pallas",
                    epilogue=QuantEpilogue("int8"))
    y = pallas_quant_dot_experts(xe, qt.q, qt.scale, plan, True)
    yv, resid = pallas_quant_dot_experts(xe, qt.q, qt.scale, plan, True,
                                         check=qt.check)
    assert (np.asarray(y) == np.asarray(yv)).all()
    ok = verify.residual_ok(yv, resid, n=n, d=d)
    assert bool(ok.all())
    # poison expert 0's weights: ONLY expert 0's rows trip
    bad_q = qt.q.at[0, :, 0].set(127)
    yb, rb = pallas_quant_dot_experts(xe, bad_q, qt.scale, plan, True,
                                      check=qt.check)
    okb = np.asarray(verify.residual_ok(yb, rb, n=n, d=d))[0, :, :, 0]
    assert not okb[0].any() and okb[1].all(), okb


# ------------------------------------------------------------ XLA residual
def test_xla_resid_exactly_zero_when_healthy():
    """The unfused oracle recomputes the checksum from the live weight
    with the identical op order -> the healthy residual is EXACTLY zero
    (not merely small), including on grouped (non-pow2) plans."""
    for n in (256, 384):            # pow2 and 3*128 grouped
        x = _x((5, n), seed=n)
        qt = _qw(n, 96, "fp8_e4m3", seed=n + 1)
        plan = plan_for(n, dtype=jnp.float32, backend="xla",
                        epilogue=QuantEpilogue("fp8_e4m3"))
        resid = xla_quant_dot_resid(x, qt.q, qt.scale, qt.check, plan, True)
        assert resid.shape == (5, 1)
        assert (np.asarray(resid) == 0.0).all(), (n, np.asarray(resid))
        # a corrupted STORED checksum (stale metadata) is caught too
        bad = xla_quant_dot_resid(x, qt.q, qt.scale, qt.check + 1.0,
                                  plan, True)
        assert (np.asarray(bad) != 0.0).all()


# -------------------------------------------------------- rotation linearity
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_hadamard_check_healthy_and_corrupt(dtype):
    x = _x((16, 128), seed=41, dtype=dtype)
    y = hadamard_transform(x)
    assert bool(hadamard_check(x, y))
    # one corrupted output element shifts one column sum
    bad = y.at[3, 7].add(jnp.asarray(1.0, dtype))
    assert not bool(hadamard_check(x, bad))
    # non-finite outputs fail (NaN compares unordered)
    assert not bool(hadamard_check(x, y.at[0, 0].set(jnp.nan)))


def test_rotation_spec_abft_bitwise_and_traced():
    x = _x((8, 128), seed=51)
    plain = RotationSpec(n=128, mode="none")(x)
    before = TRACE_COUNTS[("abft", "rotation_site")]
    checked = RotationSpec(n=128, mode="none", abft=True)(x)
    assert TRACE_COUNTS[("abft", "rotation_site")] > before
    assert (np.asarray(plain) == np.asarray(checked)).all()
    assert np.isfinite(np.asarray(checked)).all()


# ------------------------------------------------------- spec-level poisoning
def test_quant_dot_spec_abft_healthy_bitwise_corrupt_nan(monkeypatch):
    # pin the runtime switch OFF: this test exercises the spec-level
    # abft field in isolation (the CI ABFT chaos leg exports
    # REPRO_ABFT=1 globally, which would legitimately verify the
    # "inert" binding below)
    monkeypatch.delenv(verify.ABFT_ENV, raising=False)
    n, d = 256, 128
    x = _x((7, n), seed=61)
    qt = _qw(n, d, "int8", seed=62)
    spec = QuantDotSpec(n=n, mode="int8")
    y = spec.bind(qt)(x)
    yv = dataclasses.replace(spec, abft=True).bind(qt)(x)
    # healthy: the NaN-poison select is exact -> bitwise identical
    assert (np.asarray(y) == np.asarray(yv)).all()
    # corrupt: every affected row surfaces as NaN, nothing else changes
    bad = dataclasses.replace(qt, q=qt.q.at[:, 0].set(127))
    yb = np.asarray(dataclasses.replace(spec, abft=True).bind(bad)(x),
                    np.float32)
    assert np.isnan(yb).any()
    # checksums alone are inert: abft=False ignores the stored check
    yoff = spec.bind(bad)(x)
    assert np.isfinite(np.asarray(yoff, np.float32)).all()


# --------------------------------------------------------- KV conservation
def _toy_caches(seed=71, slots=3, t=8):
    rng = np.random.default_rng(seed)
    mk = lambda s: jnp.asarray(
        rng.standard_normal((1, slots, t, 2, 4)), jnp.float32)
    return [mk(0), mk(1)]


def test_kv_check_roundtrip_and_roll():
    caches = _toy_caches()
    pos = jnp.asarray([3, 5, 0], jnp.int32)
    sums = verify.kv_tree_sums(caches, pos)
    ok, cur = verify.kv_check(caches, pos, sums)
    assert bool(ok.all()) and (np.asarray(cur) == np.asarray(sums)).all()
    # a decode step writes row pos[slot]; the rollforward must equal a
    # full recompute at pos+1
    new = [c.at[:, :, 4].add(1.0) for c in caches]  # row 4 rewritten
    pos2 = jnp.asarray([4, 4, 4], jnp.int32)
    rolled = verify.kv_roll(new, pos2, verify.kv_tree_sums(new, pos2))
    full = verify.kv_tree_sums(new, pos2 + 1)
    np.testing.assert_allclose(np.asarray(rolled), np.asarray(full),
                               rtol=1e-5, atol=1e-5)


def test_kv_finite_corruption_trips_only_that_slot():
    caches = _toy_caches()
    pos = jnp.asarray([3, 5, 2], jnp.int32)
    sums = verify.kv_tree_sums(caches, pos)
    bad = [caches[0].at[0, 1, 2, 0, 0].add(448.0), caches[1]]
    ok, _ = verify.kv_check(bad, pos, sums)
    assert not bool(ok[1]) and bool(ok[0]) and bool(ok[2])


def test_kv_nan_routes_to_guard_channel_not_abft():
    """NaN in a valid row announces itself at the logits guard; the KV
    conservation verdict deliberately stays True so the engine can
    attribute the trip (silent corruption vs numeric blow-up)."""
    caches = _toy_caches()
    pos = jnp.asarray([3, 5, 2], jnp.int32)
    sums = verify.kv_tree_sums(caches, pos)
    bad = [caches[0].at[0, 0, 1, 0, 0].set(jnp.nan), caches[1]]
    ok, _ = verify.kv_check(bad, pos, sums)
    assert bool(ok[0])


def test_kv_stale_rows_are_masked():
    # garbage (even NaN) at/after pos is invisible: warmup scribbles and
    # retired-slot leftovers cannot trip the law
    caches = _toy_caches()
    pos = jnp.asarray([3, 5, 2], jnp.int32)
    sums = verify.kv_tree_sums(caches, pos)
    bad = [caches[0].at[0, 0, 6].set(jnp.nan), caches[1].at[0, 2, 7].set(1e9)]
    ok, _ = verify.kv_check(bad, pos, sums)
    assert bool(ok.all())


def test_kv_slot_reset_rebases_one_slot():
    caches = _toy_caches()
    pos = jnp.asarray([3, 5, 2], jnp.int32)
    sums = verify.kv_tree_sums(caches, pos)
    stale = sums.at[1].add(99.0)    # slot 1 drifted (e.g. retired mid-trip)
    fixed = verify.kv_slot_reset(stale, caches, jnp.asarray(1, jnp.int32),
                                 jnp.asarray(5, jnp.int32))
    np.testing.assert_allclose(np.asarray(fixed), np.asarray(sums),
                               rtol=1e-6)
    ok, _ = verify.kv_check(caches, pos, fixed)
    assert bool(ok.all())


# ------------------------------------------------------------ checkpoint CRC
def test_checkpoint_crc_roundtrip_and_corruption(tmp_path):
    from repro.checkpoint.store import restore_checkpoint, save_checkpoint

    tree = {"a": jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
            "b": {"c": jnp.ones((5,), jnp.bfloat16)}}
    save_checkpoint(str(tmp_path), 3, tree, async_write=False)
    back = restore_checkpoint(str(tmp_path), 3, tree)
    assert (np.asarray(back["a"]) == np.asarray(tree["a"])).all()

    # flip one payload byte on disk: restore must refuse, naming the leaf
    arr0 = os.path.join(str(tmp_path), "step_000000003", "arr_0.npy")
    raw = bytearray(open(arr0, "rb").read())
    raw[-1] ^= 0xFF
    open(arr0, "wb").write(bytes(raw))
    with pytest.raises(ValueError, match="CORRUPT.*CRC-32"):
        restore_checkpoint(str(tmp_path), 3, tree)

    # pre-PR 10 manifests (no crc entries) still restore unchecked
    man = os.path.join(str(tmp_path), "step_000000003", "tree.json")
    m = json.load(open(man))
    for leaf in m["leaves"]:
        leaf.pop("crc", None)
    json.dump(m, open(man, "w"))
    restore_checkpoint(str(tmp_path), 3, tree)


# ------------------------------------------------------------------- linting
def test_abft_kernel_sites_lint_green():
    """The verification column must not break the fusion / rotate-once /
    DMA contracts -- the lint runs the same rules over the verified
    twins that gate the unverified kernels."""
    from repro.analysis.rules import run_rules
    from repro.analysis.sites import kernel_sites

    report = run_rules(kernel_sites("llama3_8b", "rotate_once", abft=True))
    assert report.ok, report.format_text()


def test_abft_tolerance_scaling():
    r1, a1 = verify.abft_tolerance(256, 128)
    r2, a2 = verify.abft_tolerance(1024, 512)
    assert 0 < r1 < r2 < 1e-4 and a1 == a2 > 0
