"""The declarative rotation-site API (DESIGN.md section 7): QuantDotSpec /
RotationSpec binding, QTensor serving equivalence, the zero-per-forward-
weight-quantization acceptance, checkpoint round-trips, and the
deprecation shims over the old QuantConfig-threading free functions."""
import dataclasses
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import rotations, wquant
from repro.core.api import (
    QuantDotSpec,
    QuantEpilogue,
    RotationSpec,
    hadamard,
    plan_for,
    quant_dot,
)
from repro.core.quant import QuantConfig, quantize
from repro.core.wquant import QTensor, quantize_lm_weights, quantize_weight
from repro.launch.shapes import ShapeSpec, make_batch
from repro.models import init_lm, lm_param_specs
from repro.models.lm import lm_forward, lm_prefill

MODES = ("int8", "fp8_e4m3", "fp8_e5m2")


def _x(shape, seed=0, dtype=jnp.float32):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.standard_normal(shape), dtype=dtype)


# ------------------------------------------------------------ spec: dense
@pytest.mark.parametrize("mode", MODES)
def test_bind_qtensor_matches_raw_bitwise(mode):
    """Serving form (pre-quantized QTensor) == training form (on-the-fly
    weight quantization) bit for bit: same epilogue math, same grids."""
    x = _x((7, 512), seed=1)
    w = _x((512, 96), seed=2) * 0.05
    cfg = QuantConfig(mode=mode, rotate="hadamard", backend="pallas")
    spec = QuantDotSpec.for_config(512, cfg)
    a = spec.bind(w)(x)
    b = spec.bind(quantize_weight(w, mode))(x)
    assert (np.asarray(a) == np.asarray(b)).all()


def test_spec_matches_plain_quant_dot():
    x = _x((5, 256), seed=3)
    w = _x((256, 64), seed=4) * 0.1
    cfg = QuantConfig(mode="int8", rotate="hadamard", backend="pallas")
    a = QuantDotSpec.for_config(256, cfg).bind(w)(x)
    b = quant_dot(x, w, mode="int8", backend="pallas")
    assert (np.asarray(a) == np.asarray(b)).all()


def test_spec_disabled_and_unrotated_paths():
    x = _x((5, 256), seed=5)
    w = _x((256, 64), seed=6) * 0.1
    # mode 'none': plain (rotated) matmul
    off = QuantDotSpec.for_config(256, QuantConfig())
    np.testing.assert_allclose(np.asarray(off.bind(w)(x)),
                               np.asarray(x @ w), rtol=1e-6)
    rot = QuantDotSpec.for_config(
        256, QuantConfig(rotate="hadamard", backend="xla"))
    np.testing.assert_allclose(np.asarray(rot.bind(w)(x)),
                               np.asarray(hadamard(x, backend="xla") @ w),
                               rtol=1e-6)
    # quantize without rotation: the fake-quant matmul
    fq = QuantDotSpec.for_config(256, QuantConfig(mode="int8"))
    from repro.core.quant import quant_dot as fake_quant_dot
    np.testing.assert_allclose(
        np.asarray(fq.bind(w)(x)),
        np.asarray(fake_quant_dot(x, w, QuantConfig(mode="int8"))),
        rtol=1e-6)


def test_bind_qtensor_mode_mismatch_dequantizes_not_requantizes():
    """A storage-only QTensor at a site with a different mode falls back
    to the dequantized raw path -- never a silent re-quantization."""
    x = _x((4, 256), seed=7)
    qt = quantize_weight(_x((256, 32), seed=8) * 0.1, "int8")
    spec = QuantDotSpec.for_config(
        256, QuantConfig(mode="fp8_e4m3", rotate="hadamard", backend="xla"))
    out = spec.bind(qt)(x)
    want = spec.bind(qt.dequant(jnp.float32))(x)
    assert (np.asarray(out) == np.asarray(want)).all()


def test_spec_ste_gradients_flow():
    x = _x((6, 256), seed=9)
    w = _x((256, 64), seed=10) * 0.1
    cfg = QuantConfig(mode="int8", rotate="hadamard", backend="pallas")
    spec = QuantDotSpec.for_config(256, cfg)
    gx, gw = jax.grad(lambda a, b: jnp.sum(spec.bind(b)(a) ** 2),
                      argnums=(0, 1))(x, w)
    assert bool(jnp.isfinite(gx).all()) and float(jnp.abs(gx).max()) > 0
    assert bool(jnp.isfinite(gw).all()) and float(jnp.abs(gw).max()) > 0
    # serving form: x-only gradients, quantized weight is a statistic
    qt = quantize_weight(w, "int8")
    gx2 = jax.grad(lambda a: jnp.sum(spec.bind(qt)(a) ** 2))(x)
    assert bool(jnp.isfinite(gx2).all()) and float(jnp.abs(gx2).max()) > 0


# ---------------------------------------------------------- spec: experts
def test_bind_experts_qtensor_matches_raw():
    x = _x((2, 3, 4, 256), seed=11)
    w = _x((3, 256, 64), seed=12) * 0.1
    cfg = QuantConfig(mode="int8", rotate="hadamard", backend="pallas")
    spec = QuantDotSpec.for_config(256, cfg)
    a = spec.bind_experts(w)(x)
    b = spec.bind_experts(quantize_weight(w, "int8"))(x)
    assert (np.asarray(a) == np.asarray(b)).all()
    # per-expert agreement with the dense spec
    for e in range(3):
        want = spec.bind(w[e])(x[:, e])
        np.testing.assert_allclose(np.asarray(a[:, e]), np.asarray(want),
                                   rtol=1e-5, atol=1e-5)
    # x-gradient flows through the serving form
    g = jax.grad(lambda a_: jnp.sum(
        spec.bind_experts(quantize_weight(w, "int8"))(a_) ** 2))(x)
    assert bool(jnp.isfinite(g).all()) and float(jnp.abs(g).max()) > 0


# -------------------------------------------------------- RotationSpec
def test_rotation_spec_covers_all_site_shapes():
    x = _x((4, 8, 128), seed=13)
    # rotate + fake-quant (the fused KV site)
    cfgq = QuantConfig(mode="fp8_e4m3", rotate="hadamard", backend="pallas",
                       kv_quant=True)
    spec = RotationSpec.for_config(128, cfgq)
    want = quantize(hadamard(x, backend="pallas"), "fp8_e4m3", axis=-1)
    np.testing.assert_allclose(np.asarray(spec(x)), np.asarray(want),
                               rtol=1e-5, atol=1e-6)
    # rotate only
    s2 = RotationSpec.for_config(128, QuantConfig(rotate="hadamard",
                                                  backend="xla"))
    np.testing.assert_allclose(np.asarray(s2(x)),
                               np.asarray(hadamard(x, backend="xla")),
                               rtol=1e-6)
    # quantize only (the V site: rotate=False)
    s3 = RotationSpec.for_config(128, cfgq, rotate=False)
    np.testing.assert_allclose(np.asarray(s3(x)),
                               np.asarray(quantize(x, "fp8_e4m3", axis=-1)),
                               rtol=1e-6)
    # identity
    s4 = RotationSpec.for_config(128, QuantConfig())
    assert s4(x) is x
    with pytest.raises(ValueError, match="last"):
        spec(_x((4, 64)))


# ------------------------------------- acceptance: zero per-forward quant
def _serving_cfg(mode="fp8_e4m3"):
    quant = QuantConfig(mode=mode, rotate="hadamard", backend="xla",
                        kv_quant=True)
    return dataclasses.replace(
        get_config("llama3_8b").scaled_down().with_quant(quant),
        weight_quant="int8")


def test_serving_forward_zero_weight_quantization():
    """THE acceptance criterion: with a pre-quantized QTensor param tree
    the serving forward (prefill and decode) contains no per-forward
    weight quantization -- asserted via the quantize_weight trace
    counter, which the raw-weight path demonstrably trips."""
    cfg = _serving_cfg()
    cfg_raw = dataclasses.replace(cfg, weight_quant="none")
    params = init_lm(jax.random.PRNGKey(0), cfg_raw)
    qparams = quantize_lm_weights(params, cfg, lm_param_specs(cfg))
    batch = make_batch(cfg, ShapeSpec("t", "train", 32, 2))

    wquant.reset_quantize_weight_calls()
    jax.make_jaxpr(lambda p, b: lm_prefill(cfg, p, b)[0])(qparams, batch)
    assert wquant.QUANTIZE_WEIGHT_CALLS == 0

    # the counter is live: the raw-weight quantized forward trips it
    wquant.reset_quantize_weight_calls()
    jax.make_jaxpr(lambda p, b: lm_forward(cfg_raw, p, b)[0])(params, batch)
    assert wquant.QUANTIZE_WEIGHT_CALLS > 0


def test_serving_forward_numerics_close_to_raw():
    cfg = _serving_cfg()
    cfg_raw = dataclasses.replace(cfg, weight_quant="none")
    params = init_lm(jax.random.PRNGKey(0), cfg_raw)
    qparams = quantize_lm_weights(params, cfg, lm_param_specs(cfg))
    batch = make_batch(cfg, ShapeSpec("t", "train", 32, 2))
    lq, _, _ = lm_forward(cfg, qparams, batch)
    lr, _, _ = lm_forward(cfg_raw, params, batch)
    a = np.asarray(lq[..., :cfg.vocab_size], np.float32)
    b = np.asarray(lr[..., :cfg.vocab_size], np.float32)
    assert np.isfinite(a).all()
    # weight storage quantization is the only delta; logits stay close
    assert np.abs(a - b).max() / max(np.abs(b).max(), 1e-6) < 0.15


# ------------------------------------------------------------- checkpoint
def test_checkpoint_roundtrips_qtensor_tree(tmp_path):
    from repro.checkpoint import restore_checkpoint, save_checkpoint

    tree = {"mlp": {"w_down": quantize_weight(
        _x((128, 64), seed=14) * 0.1, "fp8_e4m3", axes=("dff", "fsdp"))},
        "norm": jnp.ones((8,))}
    save_checkpoint(str(tmp_path), 3, tree, async_write=False)
    back = restore_checkpoint(str(tmp_path), 3,
                              jax.eval_shape(lambda: tree))
    qt, bt = tree["mlp"]["w_down"], back["mlp"]["w_down"]
    assert isinstance(bt, QTensor) and bt.mode == "fp8_e4m3"
    assert bt.axes == ("dff", "fsdp")
    assert (np.asarray(qt.q, np.float32) == np.asarray(bt.q, np.float32)).all()
    assert (np.asarray(qt.scale) == np.asarray(bt.scale)).all()


def test_checkpoint_leaf_mismatch_is_loud(tmp_path):
    from repro.checkpoint import restore_checkpoint, save_checkpoint

    save_checkpoint(str(tmp_path), 1, {"w": jnp.ones((64, 32))},
                    async_write=False)
    template = jax.eval_shape(
        lambda: {"w": quantize_weight(jnp.ones((64, 32)), "int8")})
    with pytest.raises(ValueError, match="leaves"):
        restore_checkpoint(str(tmp_path), 1, template)


# ------------------------------------------------------------------ shims
def test_rotation_shims_warn_once_and_delegate():
    x = _x((4, 256), seed=15)
    w = _x((256, 64), seed=16) * 0.1
    xe = _x((2, 2, 3, 256), seed=18)          # (B, E, cap, f)
    we = _x((2, 256, 64), seed=17) * 0.1      # (E, f, d)
    cfg = QuantConfig(mode="int8", rotate="hadamard", backend="pallas")
    calls = {
        "rotated_quant_dot":
            lambda: rotations.rotated_quant_dot(x, w, cfg),
        "rotated_quant_dot_experts":
            lambda: rotations.rotated_quant_dot_experts(xe, we, cfg),
        "online_hadamard_quantize":
            lambda: rotations.online_hadamard_quantize(x, cfg),
    }
    from repro.kernels.registry import WARN_ONCE_SEEN

    for name, call in calls.items():
        WARN_ONCE_SEEN.discard(("deprecated", name))
        with pytest.warns(DeprecationWarning, match=name):
            call()
        with warnings.catch_warnings():
            warnings.simplefilter("error")  # second call must stay silent
            call()
    # ... and the shim output is the spec API's output
    WARN_ONCE_SEEN.add(("deprecated", "rotated_quant_dot"))
    a = rotations.rotated_quant_dot(x, w, cfg)
    b = QuantDotSpec.for_config(256, cfg).bind(w)(x)
    assert (np.asarray(a) == np.asarray(b)).all()


def test_bind_accepts_legacy_weight_tuple():
    """The legacy pre-quantized ``(wq, sw)`` tuple (DESIGN.md migration
    table) binds like a QTensor -- both through the spec and through the
    deprecated rotated_quant_dot shim -- with storage-dtype validation."""
    x = _x((4, 256), seed=19)
    w = _x((256, 64), seed=20) * 0.1
    cfg = QuantConfig(mode="int8", rotate="hadamard", backend="pallas")
    spec = QuantDotSpec.for_config(256, cfg)
    qt = quantize_weight(w, "int8")
    want = spec.bind(qt)(x)
    assert (np.asarray(spec.bind((qt.q, qt.scale))(x))
            == np.asarray(want)).all()
    from repro.kernels.registry import WARN_ONCE_SEEN

    WARN_ONCE_SEEN.add(("deprecated", "rotated_quant_dot"))
    assert (np.asarray(rotations.rotated_quant_dot(x, (qt.q, qt.scale), cfg))
            == np.asarray(want)).all()
    with pytest.raises(ValueError, match="storage dtype"):
        bad = quantize_weight(w, "fp8_e4m3")
        spec.bind((bad.q, bad.scale))


# -------------------------------------------------------- mesh plan keys
def test_meshless_spec_plan_has_no_mesh_axes():
    """Without an active mesh the spec's plan key carries mesh_axes=None
    and is the SAME cached object as a plain plan_for plan (no retrace on
    migration). The >1-device mesh-key case lives in test_distributed."""
    cfg = QuantConfig(mode="int8", rotate="hadamard", backend="xla")
    spec = QuantDotSpec.for_config(256, cfg, weight_axes=("dff", "fsdp"))
    p = spec.plan(jnp.float32, d=64)
    assert p.mesh_axes is None
    assert p is plan_for(256, backend="xla",
                         epilogue=QuantEpilogue("int8"))
