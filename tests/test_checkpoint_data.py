"""Checkpoint store (fault tolerance) + deterministic data pipeline."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import latest_step, restore_checkpoint, save_checkpoint
from repro.checkpoint.store import wait_for_writes
from repro.configs import get_config
from repro.data import MemmapDataset, SyntheticDataset
from repro.data.pipeline import write_synthetic_corpus
from repro.launch.shapes import ShapeSpec


def _tree():
    return {"a": jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
            "b": {"c": jnp.asarray([1, 2, 3], jnp.int32),
                  "d": jnp.asarray(2.5, jnp.bfloat16)}}


def test_save_restore_roundtrip(tmp_path):
    t = _tree()
    save_checkpoint(str(tmp_path), 7, t, async_write=False)
    assert latest_step(str(tmp_path)) == 7
    back = restore_checkpoint(str(tmp_path), 7, jax.eval_shape(lambda: t))
    for x, y in zip(jax.tree.leaves(t), jax.tree.leaves(back)):
        np.testing.assert_array_equal(np.asarray(x, np.float32),
                                      np.asarray(y, np.float32))


def test_async_write_and_latest(tmp_path):
    t = _tree()
    for step in (10, 20, 30):
        save_checkpoint(str(tmp_path), step, t, async_write=True)
    wait_for_writes()
    assert latest_step(str(tmp_path)) == 30


def test_incomplete_checkpoint_ignored(tmp_path):
    t = _tree()
    save_checkpoint(str(tmp_path), 5, t, async_write=False)
    # simulate a crash mid-write of step 6: directory without .done marker
    os.makedirs(tmp_path / "step_000000006")
    assert latest_step(str(tmp_path)) == 5


def test_restore_with_shardings(tmp_path):
    """Elastic path: restore re-shards onto the current (1-device) mesh."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    mesh = jax.make_mesh((1,), ("data",))
    t = {"w": jnp.ones((8, 4), jnp.float32)}
    save_checkpoint(str(tmp_path), 1, t, async_write=False)
    sh = {"w": NamedSharding(mesh, P("data", None))}
    back = restore_checkpoint(str(tmp_path), 1, jax.eval_shape(lambda: t), sh)
    assert back["w"].sharding == sh["w"]


def test_synthetic_data_deterministic():
    cfg = get_config("llama3_8b").scaled_down()
    shape = ShapeSpec("t", "train", 64, 4)
    ds1 = SyntheticDataset(cfg, shape, seed=3)
    ds2 = SyntheticDataset(cfg, shape, seed=3)
    for step in (0, 5, 1000):
        b1, b2 = ds1.batch(step), ds2.batch(step)
        np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    assert not np.array_equal(ds1.batch(0)["tokens"], ds1.batch(1)["tokens"])
    # restart-resume: a "new process" at step k sees the same batch
    assert np.array_equal(SyntheticDataset(cfg, shape, seed=3).batch(7)["tokens"],
                          ds1.batch(7)["tokens"])


def test_memmap_dataset(tmp_path):
    cfg = get_config("llama3_8b").scaled_down()
    path = str(tmp_path / "corpus.bin")
    write_synthetic_corpus(path, 100000, cfg.vocab_size, seed=1)
    shape = ShapeSpec("t", "train", 64, 4)
    ds = MemmapDataset(cfg, shape, path)
    b = ds.batch(0)
    assert b["tokens"].shape == (4, 64)
    assert (b["tokens"] >= 0).all() and (b["tokens"] < cfg.vocab_size).all()
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])
    np.testing.assert_array_equal(ds.batch(3)["tokens"],
                                  MemmapDataset(cfg, shape, path).batch(3)["tokens"])
