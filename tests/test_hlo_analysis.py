"""The while-aware HLO analyzer that feeds the roofline table."""
import numpy as np
import pytest

from repro.launch.hlo_analysis import (
    Instr,
    _shape_bytes,
    _split_operands,
    _trip_count_from_config,
    analyze_hlo,
    parse_hlo,
    parse_input_output_aliases,
)


def test_shape_bytes():
    assert _shape_bytes("f32[128,512]{1,0}") == 128 * 512 * 4
    assert _shape_bytes("bf16[7,512,128]") == 7 * 512 * 128 * 2
    assert _shape_bytes("(s32[], bf16[4,4])") == 4 + 32
    assert _shape_bytes("pred[10]") == 10
    assert _shape_bytes("f8e4m3fn[100]") == 100
    # tuple with /*index=N*/ comments (real XLA print format)
    assert _shape_bytes("(s32[], f32[2,2], /*index=2*/bf16[4])") == 4 + 16 + 8


def test_shape_bytes_sub_byte_and_fp8_dtypes():
    # every fp8 spelling XLA prints is 1 byte/element
    for dt in ("f8e4m3fn", "f8e5m2", "f8e4m3", "f8e5m2fnuz", "f8e4m3fnuz"):
        assert _shape_bytes(f"{dt}[16,32]") == 16 * 32
    # int4 weights pack two to a byte
    assert _shape_bytes("s4[128,256]{1,0}") == 128 * 256 / 2
    assert _shape_bytes("u4[64]") == 32
    assert _shape_bytes("(s4[8], f8e4m3fn[8], f32[8])") == 4 + 8 + 32
    # unknown dtypes are skipped, not mis-billed
    assert _shape_bytes("token[]") == 0


def test_trip_count_from_backend_config():
    """XLA records statically-known trip counts on the while instruction
    itself; the analyzer must prefer that over cond-constant recovery."""
    line = ('  %w = (s32[], f32[4]) while(%t0), condition=%c, body=%b, '
            'backend_config={"known_trip_count":{"n":"6"}}')
    ins = Instr("w", "(s32[], f32[4])", "while", ["t0"], "", line)
    assert _trip_count_from_config(ins) == 6
    plain = Instr("w", "(s32[], f32[4])", "while", ["t0"], "",
                  "  %w = (s32[], f32[4]) while(%t0), condition=%c")
    assert _trip_count_from_config(plain) is None


def _coll_hlo(op_line: str) -> str:
    return f"""HloModule coll, entry_computation_layout={{()->f32[]}}

%sum (a: f32[], b: f32[]) -> f32[] {{
  %a = f32[] parameter(0)
  %b = f32[] parameter(1)
  ROOT %r = f32[] add(%a, %b)
}}

ENTRY %main (p: f32[64,64]) -> f32[] {{
  %p = f32[64,64] parameter(0)
{op_line}
  %z = f32[] constant(0)
  ROOT %s = f32[] reduce(%o, %z), dimensions={{0,1}}, to_apply=%sum
}}
"""


@pytest.mark.parametrize("op,out_shape,wire", [
    # ring models over a 4-member group, f32[64,64] = 16384 B
    ("all-reduce", "f32[64,64]", 2 * 16384 * 3 / 4),
    ("all-gather", "f32[64,64]", 16384 * 3 / 4),
    ("reduce-scatter", "f32[16,64]", 16384 * 3 / 4),   # in_size-based
    ("all-to-all", "f32[64,64]", 16384 * 3 / 4),
    ("collective-permute", "f32[64,64]", 16384.0),     # no ring factor
])
def test_collective_ring_cost_models(op, out_shape, wire):
    attrs = "replica_groups={{0,1,2,3}}"
    if op == "all-reduce":
        attrs += ", to_apply=%sum"
    line = f"  %o = {out_shape} {op}(%p), {attrs}"
    res = analyze_hlo(_coll_hlo(line))
    assert res["collective_counts"] == {op: 1}
    assert res["collective_wire_bytes_per_device"][op] == pytest.approx(wire)


def test_replica_group_size_bare_and_iota_forms():
    # replica_groups=[2,4] (iota shorthand: 2 groups of 4)
    line = ("  %o = f32[64,64] all-gather(%p), replica_groups=[2,4]<=[8], "
            "dimensions={0}")
    res = analyze_hlo(_coll_hlo(line))
    assert res["collective_wire_bytes_per_device"]["all-gather"] == \
        pytest.approx(16384 * 3 / 4)


def test_parse_input_output_aliases():
    hdr = ("HloModule jit_step, input_output_alias={ {0}: (1, {0}, "
           "may-alias), {1}: (1, {1}, may-alias), {2,0}: (3, {}, "
           "must-alias) }, entry_computation_layout={(f32[2])->f32[2]}")
    assert parse_input_output_aliases(hdr) == [
        ((0,), 1, (0,)), ((1,), 1, (1,)), ((2, 0), 3, ())]
    assert parse_input_output_aliases("HloModule nodonation") == []


def test_donated_jit_shows_aliases_in_compiled_hlo():
    """End-to-end: a donate_argnums jit on CPU really carries
    input_output_alias pairs the donation rule can count."""
    import jax
    import jax.numpy as jnp

    f = jax.jit(lambda c, x: (c + x, x.sum()), donate_argnums=(0,))
    text = f.lower(jnp.zeros((8, 8)), jnp.ones((8, 8))).compile().as_text()
    aliases = parse_input_output_aliases(text)
    assert len(aliases) == 1


def test_split_operands():
    ops = _split_operands("%a, %b.2), kind=kLoop, calls=%c")
    assert ops == ["a", "b.2"]


def test_scan_flops_trip_corrected(subproc):
    """A scan of L matmuls must report L x the single-matmul FLOPs."""
    out = subproc("""
import jax, jax.numpy as jnp
from repro.launch.hlo_analysis import analyze_hlo
L, M, K, N = 7, 64, 128, 96
def f(x, w):
    def body(c, wi):
        return c @ wi, ()
    y, _ = jax.lax.scan(body, x, w)
    return y.sum()
comp = jax.jit(f).lower(jax.ShapeDtypeStruct((M, K), jnp.float32),
                        jax.ShapeDtypeStruct((L, K, K), jnp.float32)).compile()
res = analyze_hlo(comp.as_text())
true = 2 * M * K * K * L
ratio = res["flops_per_device"] / true
assert 0.9 < ratio < 1.2, (res["flops_per_device"], true)
print("FLOPS_OK", ratio)
""", devices=1)
    assert "FLOPS_OK" in out


def test_collectives_detected_inside_scan(subproc):
    """FSDP-style: all-gather inside a scanned layer body is multiplied by
    the trip count."""
    out = subproc("""
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.launch.hlo_analysis import analyze_hlo
mesh = jax.make_mesh((4,), ("data",))
L, D = 5, 256
def f(x, w):
    def body(c, wi):
        return jnp.tanh(c @ wi), ()
    y, _ = jax.lax.scan(body, x, w)
    return y.sum()
comp = jax.jit(f, in_shardings=(NamedSharding(mesh, P(None, None)),
                                NamedSharding(mesh, P(None, "data", None)))) \
    .lower(jax.ShapeDtypeStruct((8, D), jnp.float32),
           jax.ShapeDtypeStruct((L, D, D), jnp.float32)).compile()
res = analyze_hlo(comp.as_text())
total = res["collective_total_bytes_per_device"]
counts = res["collective_counts"]
# XLA partial-dots the sharded contraction and all-reduces the (8,D)
# activation once per layer iteration: ring wire = 2*8*D*4*(3/4) per trip
per_iter = 2 * 8 * D * 4 * 3 / 4
assert sum(counts.values()) >= L, counts
assert total >= per_iter * L * 0.9, (total, counts)
print("COLL_OK", total, counts)
""", devices=4)
    assert "COLL_OK" in out


def test_parse_hlo_handles_tuple_whiles():
    text = """HloModule test, entry_computation_layout={()->f32[]}

%body (p: (s32[], f32[4,4])) -> (s32[], f32[4,4]) {
  %p = (s32[], f32[4,4]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %x = f32[4,4] get-tuple-element(%p), index=1
  %d = f32[4,4] dot(%x, %x), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %one = s32[] constant(1)
  %ip = s32[] add(%i, %one)
  ROOT %t = (s32[], f32[4,4]) tuple(%ip, %d)
}

%cond (p: (s32[], f32[4,4])) -> pred[] {
  %p = (s32[], f32[4,4]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %n = s32[] constant(11)
  ROOT %lt = pred[] compare(%i, %n), direction=LT
}

ENTRY %main () -> f32[] {
  %c = f32[4,4] constant(0)
  %z = s32[] constant(0)
  %t0 = (s32[], f32[4,4]) tuple(%z, %c)
  %w = (s32[], f32[4,4]) while(%t0), condition=%cond, body=%body
  %r = f32[4,4] get-tuple-element(%w), index=1
  ROOT %s = f32[] reduce(%r, %z), dimensions={0,1}, to_apply=%body
}
"""
    res = analyze_hlo(text)
    # 11 iterations x (2*4*4*4) dot flops
    assert res["flops_per_device"] == 11 * 2 * 4 * 4 * 4
