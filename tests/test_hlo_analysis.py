"""The while-aware HLO analyzer that feeds the roofline table."""
import numpy as np
import pytest

from repro.launch.hlo_analysis import (
    _shape_bytes,
    _split_operands,
    analyze_hlo,
    parse_hlo,
)


def test_shape_bytes():
    assert _shape_bytes("f32[128,512]{1,0}") == 128 * 512 * 4
    assert _shape_bytes("bf16[7,512,128]") == 7 * 512 * 128 * 2
    assert _shape_bytes("(s32[], bf16[4,4])") == 4 + 32
    assert _shape_bytes("pred[10]") == 10
    assert _shape_bytes("f8e4m3fn[100]") == 100
    # tuple with /*index=N*/ comments (real XLA print format)
    assert _shape_bytes("(s32[], f32[2,2], /*index=2*/bf16[4])") == 4 + 16 + 8


def test_split_operands():
    ops = _split_operands("%a, %b.2), kind=kLoop, calls=%c")
    assert ops == ["a", "b.2"]


def test_scan_flops_trip_corrected(subproc):
    """A scan of L matmuls must report L x the single-matmul FLOPs."""
    out = subproc("""
import jax, jax.numpy as jnp
from repro.launch.hlo_analysis import analyze_hlo
L, M, K, N = 7, 64, 128, 96
def f(x, w):
    def body(c, wi):
        return c @ wi, ()
    y, _ = jax.lax.scan(body, x, w)
    return y.sum()
comp = jax.jit(f).lower(jax.ShapeDtypeStruct((M, K), jnp.float32),
                        jax.ShapeDtypeStruct((L, K, K), jnp.float32)).compile()
res = analyze_hlo(comp.as_text())
true = 2 * M * K * K * L
ratio = res["flops_per_device"] / true
assert 0.9 < ratio < 1.2, (res["flops_per_device"], true)
print("FLOPS_OK", ratio)
""", devices=1)
    assert "FLOPS_OK" in out


def test_collectives_detected_inside_scan(subproc):
    """FSDP-style: all-gather inside a scanned layer body is multiplied by
    the trip count."""
    out = subproc("""
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.launch.hlo_analysis import analyze_hlo
mesh = jax.make_mesh((4,), ("data",))
L, D = 5, 256
def f(x, w):
    def body(c, wi):
        return jnp.tanh(c @ wi), ()
    y, _ = jax.lax.scan(body, x, w)
    return y.sum()
comp = jax.jit(f, in_shardings=(NamedSharding(mesh, P(None, None)),
                                NamedSharding(mesh, P(None, "data", None)))) \
    .lower(jax.ShapeDtypeStruct((8, D), jnp.float32),
           jax.ShapeDtypeStruct((L, D, D), jnp.float32)).compile()
res = analyze_hlo(comp.as_text())
total = res["collective_total_bytes_per_device"]
counts = res["collective_counts"]
# XLA partial-dots the sharded contraction and all-reduces the (8,D)
# activation once per layer iteration: ring wire = 2*8*D*4*(3/4) per trip
per_iter = 2 * 8 * D * 4 * 3 / 4
assert sum(counts.values()) >= L, counts
assert total >= per_iter * L * 0.9, (total, counts)
print("COLL_OK", total, counts)
""", devices=4)
    assert "COLL_OK" in out


def test_parse_hlo_handles_tuple_whiles():
    text = """HloModule test, entry_computation_layout={()->f32[]}

%body (p: (s32[], f32[4,4])) -> (s32[], f32[4,4]) {
  %p = (s32[], f32[4,4]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %x = f32[4,4] get-tuple-element(%p), index=1
  %d = f32[4,4] dot(%x, %x), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %one = s32[] constant(1)
  %ip = s32[] add(%i, %one)
  ROOT %t = (s32[], f32[4,4]) tuple(%ip, %d)
}

%cond (p: (s32[], f32[4,4])) -> pred[] {
  %p = (s32[], f32[4,4]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %n = s32[] constant(11)
  ROOT %lt = pred[] compare(%i, %n), direction=LT
}

ENTRY %main () -> f32[] {
  %c = f32[4,4] constant(0)
  %z = s32[] constant(0)
  %t0 = (s32[], f32[4,4]) tuple(%z, %c)
  %w = (s32[], f32[4,4]) while(%t0), condition=%cond, body=%body
  %r = f32[4,4] get-tuple-element(%w), index=1
  ROOT %s = f32[] reduce(%r, %z), dimensions={0,1}, to_apply=%body
}
"""
    res = analyze_hlo(text)
    # 11 iterations x (2*4*4*4) dot flops
    assert res["flops_per_device"] == 11 * 2 * 4 * 4 * 4
