"""Continuous-batching serving engine (PR 6): scheduler correctness,
bitwise parity with the one-shot serve path, slot-reuse hygiene, retrace
and prequant invariants, env hardening, CLI + bench smoke."""
import dataclasses

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core.quant import QuantConfig
from repro.launch.train import scaled_config


# --------------------------------------------------------------- fixtures
@pytest.fixture(scope="module")
def quant_setup():
    """One small pre-quantized llama3 config + params, shared across the
    engine tests (param init + quantize once; engines are cheap-ish)."""
    from repro.launch.mesh import make_local_mesh
    from repro.launch.steps import make_param_init, param_shardings

    quant = QuantConfig(mode="fp8_e4m3", rotate="hadamard", backend="xla",
                        kv_quant=True)
    cfg = scaled_config(get_config("llama3-8b"), 0.005).with_quant(quant)
    cfg = dataclasses.replace(cfg, weight_quant="int8")
    mesh = make_local_mesh(1)
    with mesh:
        ps = param_shardings(cfg, mesh)
        params = jax.jit(make_param_init(cfg), out_shardings=ps)(
            jax.random.PRNGKey(0))
    return cfg, params, mesh


def _prompts(cfg, n, length, seed=1):
    rng = np.random.default_rng(seed)
    return rng.integers(0, cfg.vocab_size, (n, length), dtype=np.int32)


def _one_shot_streams(cfg, params, mesh, prompts, gen, max_len):
    """Reference token streams via the serve.py path (batch prefill +
    scalar-pos lockstep decode), with the cache padded to the SAME
    max_len the engine uses."""
    from repro.launch import shapes as shp
    from repro.launch.steps import jit_prefill_step, jit_serve_step
    from repro.models.lm import pad_kv_caches

    B, P = prompts.shape
    shape = shp.ShapeSpec("serve", "prefill", P, B)
    prefill, _ = jit_prefill_step(cfg, shape, mesh)
    serve, _ = jit_serve_step(cfg, B, max_len, mesh, donate=True)
    batch = {"tokens": jnp.asarray(prompts), "labels": jnp.asarray(prompts)}
    logits, caches = prefill(params, batch)
    caches = pad_kv_caches(cfg, caches, max_len)
    tok = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)[:, None]
    out = [np.asarray(tok)]
    for i in range(gen - 1):
        tok, _, caches = serve(params, caches, tok, jnp.asarray(P + i, jnp.int32))
        out.append(np.asarray(tok))
    return np.concatenate(out, axis=1)  # (B, gen)


# ------------------------------------------------- per-slot decode (model)
def test_vector_cache_pos_matches_scalar(quant_setup):
    """lm_decode_step with a (B,) position vector of identical entries is
    bitwise the scalar-pos step: logits AND every cache leaf."""
    from repro.launch import shapes as shp
    from repro.launch.steps import jit_prefill_step
    from repro.models.lm import lm_decode_step, pad_kv_caches

    cfg, params, mesh = quant_setup
    B, P, T = 2, 8, 16
    prompts = _prompts(cfg, B, P)
    prefill, _ = jit_prefill_step(cfg, mesh=mesh,
                                  shape=shp.ShapeSpec("s", "prefill", P, B))
    logits, caches = prefill(params, {"tokens": jnp.asarray(prompts),
                                      "labels": jnp.asarray(prompts)})
    caches = pad_kv_caches(cfg, caches, T)
    tok = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)[:, None]

    with mesh:
        l_s, c_s = jax.jit(lambda *a: lm_decode_step(cfg, *a))(
            params, caches, tok, jnp.asarray(P, jnp.int32))
        l_v, c_v = jax.jit(lambda *a: lm_decode_step(cfg, *a))(
            params, caches, tok, jnp.full((B,), P, jnp.int32))
    assert np.array_equal(np.asarray(l_s), np.asarray(l_v))
    for a, b in zip(jax.tree.leaves(c_s), jax.tree.leaves(c_v)):
        assert np.array_equal(np.asarray(a), np.asarray(b))


# ----------------------------------------------------------------- parity
def test_staggered_parity_bitwise(quant_setup):
    """The tentpole acceptance: staggered-arrival continuous batching
    (fewer slots than requests, so admission waits on a retirement and a
    slot is REUSED) emits per-request greedy token streams bitwise equal
    to the one-shot serve.py path -- on the fp8-KV + hadamard + prequant
    QTensor config."""
    from repro.serving import ServeEngine
    from repro.serving.scheduler import Request

    cfg, params, mesh = quant_setup
    P, GEN, MAXLEN, B = 16, 6, 48, 3
    prompts = _prompts(cfg, B, P)
    base = _one_shot_streams(cfg, params, mesh, prompts, GEN, MAXLEN)

    eng = ServeEngine(cfg, params, mesh, num_slots=2, max_len=MAXLEN,
                      prefill_len=P)
    reqs = [Request(rid=i, tokens=prompts[i], max_new_tokens=GEN,
                    arrival_time=[0.0, 2.0, 4.0][i]) for i in range(B)]
    comps = eng.run(reqs)
    assert len(comps) == B
    for c in comps:
        assert c.finish_reason == "length"
        assert np.array_equal(np.array(c.tokens), base[c.rid]), c.rid
    s = eng.summary()
    # request 2 queued behind fully-occupied slots at least once
    assert s["queue_full_stalls"] >= 1
    # the decode step compiled exactly once across admissions/retirements
    assert s["decode_executables"] == 1
    # prequant QTensor weights: zero per-forward quantize_weight calls
    assert s["quantize_weight_calls"] == 0
    assert s["prefill_inserts"] == B and s["admitted"] == B \
        and s["retired"] == B


def test_slot_reuse_no_stale_kv(quant_setup):
    """A retired-then-reused slot leaks no stale KV: the follow-up
    request's stream is bitwise what it gets in a FRESH engine, even
    though the reused slot's cache rows still hold the predecessor's
    data beyond the new request's range (stale-mask assertion)."""
    from repro.serving import ServeEngine
    from repro.serving.scheduler import Request

    cfg, params, mesh = quant_setup
    P, MAXLEN = 16, 64
    prompts = _prompts(cfg, 2, P, seed=7)
    # r1 generates LONG (fills deep cache rows), r2 short, same slot
    r1 = Request(rid=0, tokens=prompts[0], max_new_tokens=24)
    r2 = Request(rid=1, tokens=prompts[1], max_new_tokens=6,
                 arrival_time=1.0)

    eng_reuse = ServeEngine(cfg, params, mesh, num_slots=1, max_len=MAXLEN,
                            prefill_len=P)
    comps = eng_reuse.run([r1, r2])
    reused = {c.rid: c for c in comps}

    eng_fresh = ServeEngine(cfg, params, mesh, num_slots=1, max_len=MAXLEN,
                            prefill_len=P)
    fresh = {c.rid: c for c in eng_fresh.run([dataclasses.replace(
        r2, arrival_time=0.0)])}

    assert np.array_equal(np.array(reused[1].tokens),
                          np.array(fresh[1].tokens))
    # the reuse run really did leave r1's stale KV in the slot beyond
    # r2's written range: the two engines' cache contents differ ...
    k_reuse = np.asarray(jnp.asarray(eng_reuse.caches[0]["p0"]["k"],
                                     jnp.float32))
    k_fresh = np.asarray(jnp.asarray(eng_fresh.caches[0]["p0"]["k"],
                                     jnp.float32))
    # r2 writes prefill rows [0, P) plus decode rows [P, P+max_new-1)
    depth = P + r2.max_new_tokens - 1
    assert not np.array_equal(k_reuse[:, :, depth:], k_fresh[:, :, depth:])
    # ... while the rows r2 actually wrote agree bitwise
    assert np.array_equal(k_reuse[:, :, :depth], k_fresh[:, :, :depth])


def test_eos_retirement(quant_setup):
    """eos_id retires a request the step the token appears."""
    from repro.serving import ServeEngine
    from repro.serving.scheduler import Request

    cfg, params, mesh = quant_setup
    P, GEN, MAXLEN = 16, 8, 48
    prompts = _prompts(cfg, 1, P, seed=3)
    req = Request(rid=0, tokens=prompts[0], max_new_tokens=GEN)
    eng = ServeEngine(cfg, params, mesh, num_slots=1, max_len=MAXLEN,
                      prefill_len=P)
    full = eng.run([req])[0]
    assert full.finish_reason == "length" and len(full.tokens) == GEN

    eos = full.tokens[2]
    eng2 = ServeEngine(cfg, params, mesh, num_slots=1, max_len=MAXLEN,
                       prefill_len=P, eos_id=int(eos))
    early = eng2.run([req])[0]
    assert early.finish_reason == "eos"
    assert len(early.tokens) <= 3
    assert early.tokens == full.tokens[:len(early.tokens)]


def test_engine_rejects_state_carrying_archs(quant_setup):
    from repro.serving.engine import _validate_config

    rwkv = scaled_config(get_config("rwkv6-7b"), 0.005)
    with pytest.raises(ValueError, match="causal attention"):
        _validate_config(rwkv)


# -------------------------------------------------------------- scheduler
def test_scheduler_freelist_and_stalls():
    from repro.kernels.registry import TRACE_COUNTS
    from repro.serving.scheduler import Request, Scheduler

    sched = Scheduler(num_slots=2, max_len=32, prefill_len=8)
    reqs = [Request(rid=i, tokens=np.zeros(4, np.int32), max_new_tokens=4,
                    arrival_time=float(i)) for i in range(3)]
    for r in reqs:
        sched.submit(r)
    assert sched.counters["submitted"] == 3

    # nothing has arrived at t<0 -- not a stall, just no work yet
    assert sched.next_admission(-1.0) is None
    assert sched.counters["queue_full_stalls"] == 0

    s0, r0 = sched.next_admission(0.0)
    assert (s0, r0.rid) == (0, 0)
    s1, r1 = sched.next_admission(1.0)
    assert (s1, r1.rid) == (1, 1)
    # arrived head + all slots busy = a counted stall
    stalls0 = TRACE_COUNTS[("serving", "queue_full_stall")]
    assert sched.next_admission(2.0) is None
    assert sched.counters["queue_full_stalls"] == 1
    assert TRACE_COUNTS[("serving", "queue_full_stall")] == stalls0 + 1

    # LIFO free list: the just-retired slot is reused immediately
    sched.retire(s1, "length", 3.0)
    s2, r2 = sched.next_admission(3.0)
    assert (s2, r2.rid) == (1, 2)
    assert sched.occupancy == 1.0
    sched.retire(s0, "length", 4.0)
    sched.retire(s2, "eos", 4.0)
    assert not sched.has_work()
    assert sorted(sched.free) == [0, 1]
    assert sched.counters["admitted"] == 3 and sched.counters["retired"] == 3


def test_scheduler_validates_requests():
    from repro.serving.scheduler import Request, Scheduler

    sched = Scheduler(num_slots=1, max_len=16, prefill_len=8)
    with pytest.raises(ValueError, match="prompt_len"):
        sched.submit(Request(0, np.zeros(9, np.int32), 2))
    with pytest.raises(ValueError, match="max_len"):
        sched.submit(Request(0, np.zeros(8, np.int32), 9))
    with pytest.raises(ValueError, match="prefill_len"):
        Scheduler(num_slots=1, max_len=8, prefill_len=16)


# ------------------------------------------------------------ env hardening
def test_harden_host_env_sets_flags(tmp_path, monkeypatch):
    from repro.launch import env as env_mod

    lib = tmp_path / "libtcmalloc.so.4"
    lib.write_bytes(b"")
    monkeypatch.setattr(env_mod, "_TCMALLOC_CANDIDATES", (str(lib),))
    env = {}
    applied = env_mod.harden_host_env(environ=env)
    assert env["TF_CPP_MIN_LOG_LEVEL"] == "4"
    assert env["LD_PRELOAD"] == str(lib)
    assert env[env_mod._MARKER] == "1"
    assert set(applied) == {"TF_CPP_MIN_LOG_LEVEL",
                            "TCMALLOC_LARGE_ALLOC_REPORT_THRESHOLD",
                            "LD_PRELOAD"}
    # idempotent: marker blocks a second preload mutation
    assert "LD_PRELOAD" not in env_mod.harden_host_env(environ=env)


def test_harden_host_env_opt_out_and_preservation(monkeypatch):
    from repro.launch import env as env_mod

    assert env_mod.harden_host_env(
        environ={"REPRO_NO_ENV_HARDEN": "1"}) == {}

    monkeypatch.setattr(env_mod, "_TCMALLOC_CANDIDATES", ())
    env = {"TF_CPP_MIN_LOG_LEVEL": "0",
           "REPRO_XLA_HOST_DEVICES": "4", "XLA_FLAGS": "--foo"}
    applied = env_mod.harden_host_env(environ=env)
    assert env["TF_CPP_MIN_LOG_LEVEL"] == "0"          # user's value wins
    assert env["XLA_FLAGS"] == \
        "--foo --xla_force_host_platform_device_count=4"
    assert "LD_PRELOAD" not in env                     # no tcmalloc found
    assert "XLA_FLAGS" in applied


# ------------------------------------------------------------- CLI + bench
def test_serve_loop_cli_runs(capsys):
    from repro.launch.serve_loop import main

    main(["--arch", "llama3-8b", "--scale", "0.004", "--slots", "2",
          "--max-len", "32", "--prefill-len", "8", "--requests", "3",
          "--rate", "1.0", "--prompt-min", "4", "--gen-min", "3",
          "--gen-max", "5", "--quant", "int8", "--rotate", "hadamard"])
    out = capsys.readouterr().out
    assert "pre-quantized once at load" in out
    assert "warmup:" in out
    assert "tok/s" in out and "p50" in out and "p99" in out
    assert "decode_executables=1" in out
    assert "quantize_weight_calls=0" in out


def test_bench_serve_loop_smoke():
    from benchmarks import bench_serve_loop

    csv, records = [], []
    bench_serve_loop.run(csv, smoke=True, records=records)
    assert any("serve_loop" in line for line in csv)
    assert all({"bench", "shape", "dtype", "backend", "ms", "gbps"}
               <= set(r) for r in records)
    assert all(r["ms"] > 0 for r in records)
    modes = {r["bench"] for r in records}
    assert modes == {"serve_loop_none", "serve_loop_int8",
                     "serve_loop_overload"}
    # the overload flood must actually overload: every disposition class
    # is recorded, and load was genuinely shed/rejected
    ov = next(r for r in records if r["bench"] == "serve_loop_overload")
    assert {"ok", "timed_out", "rejected", "degraded", "shed",
            "p99_ms"} <= set(ov)
    assert ov["rejected"] > 0 and ov["timed_out"] > 0
    assert ov["ok"] + ov["timed_out"] + ov["rejected"] + ov["degraded"] == 10
