"""The kernel contract linter (PR 9): shared jaxpr walkers, the rule
registry, every rule against healthy sites, the mutation fixture (the
rules must flag the committed broken kernels), and the CLI."""
import json

import jax
import jax.numpy as jnp
import pytest

from repro.analysis import (Report, Violation, all_rules,
                            count_pallas_calls, dots_by_region,
                            dots_outside_pallas, kernel_jaxpr,
                            kernel_sites, model_sites, run_rules,
                            stream_events)
from repro.analysis.mutations import mutant_sites
from repro.analysis.sites import Site

EXPECTED_RULES = {"fusion-contract", "rotate-once-contract", "dma-safety",
                  "dtype-flow", "vmem-budget", "donation",
                  "deprecated-shim-in-trace"}


# ------------------------------------------------------------ registry
def test_rule_registry_carries_every_contract():
    assert EXPECTED_RULES <= set(all_rules())


def test_register_rule_is_open():
    from repro.analysis.rules import _RULES, Rule, register_rule

    @register_rule
    class _Probe(Rule):
        name = "probe-rule"

        def applies(self, site):
            return True

        def check(self, site):
            return [self._v(site, "probed")]

    try:
        rep = run_rules([Site(name="s", kind="kernel")],
                        rules=["probe-rule"])
        assert [v.rule for v in rep.violations] == ["probe-rule"]
        assert rep.checked == [("s", "probe-rule")]
    finally:
        del _RULES["probe-rule"]


# ------------------------------------------------------- report model
def test_report_round_trips_json():
    rep = Report(checked=[("s", "r")],
                 violations=[Violation("r", "s", "broken")])
    d = json.loads(rep.to_json())
    assert d["ok"] is False and d["violations"][0]["rule"] == "r"
    assert not rep.ok and "broken" in rep.format_text()
    clean = Report(checked=[("s", "r")])
    assert clean.ok and json.loads(clean.to_json())["ok"] is True


# ---------------------------------------------------- shared walkers
def test_walkers_see_through_pjit_and_cond():
    def f(x):
        return jax.jit(lambda a: jax.lax.cond(
            a.sum() > 0, lambda b: b @ b, lambda b: b + b, a))(x)

    jaxpr = jax.make_jaxpr(f)(jnp.ones((4, 4)))
    assert count_pallas_calls(jaxpr) == 0
    assert dots_outside_pallas(jaxpr) == 1  # the cond-branch matmul
    with pytest.raises(AssertionError):
        kernel_jaxpr(jaxpr)


# ------------------------------------------------- rules on main
@pytest.mark.parametrize("schedule", ["rotate_once", "streamed"])
def test_kernel_sites_lint_clean(schedule):
    """Main's 2-D and 3-D fused kernels pass every rule, and the
    expected rules actually RAN (not vacuously skipped)."""
    sites = kernel_sites("llama3_8b", schedule)
    rep = run_rules(sites)
    assert rep.ok, rep.format_text()
    ran = {r for _, r in rep.checked}
    want = {"fusion-contract", "rotate-once-contract", "vmem-budget",
            "dtype-flow"}
    if schedule == "streamed":
        want.add("dma-safety")
    assert want <= ran
    # and the structural facts the rules checked are the known ones
    kj = kernel_jaxpr(sites[0].jaxpr)
    assert dots_by_region(kj) == (1, sites[0].plan.num_passes)
    if schedule == "streamed":
        assert stream_events(kj).count("dot") == 1


def test_model_site_lints_clean():
    rep = run_rules(model_sites("llama3_8b"))
    assert rep.ok, rep.format_text()


# ------------------------------------------------- mutation fixture
def test_mutants_are_flagged():
    """The committed broken kernels MUST fail the lint -- the unguarded
    rotate trips rotate-once-contract, the dangling DMA trips
    dma-safety (unmatched start + unguarded start)."""
    sites = mutant_sites()
    rep = run_rules(sites)
    by_site = {}
    for v in rep.violations:
        by_site.setdefault(v.site, set()).add(v.rule)
    assert "rotate-once-contract" in by_site.get(
        "mutant[unguarded_rotate]", set())
    assert "dma-safety" in by_site.get("mutant[dangling_dma]", set())
    msgs = " ".join(v.message for v in rep.violations
                    if v.site == "mutant[dangling_dma]")
    assert "NO dma_wait" in msgs and "unguarded" in msgs


def test_vmem_rule_has_teeth():
    """An inflated BlockDecision charge is NOT flagged (planner may
    over-charge), but a decision claiming fewer bytes than the jaxpr's
    VMEM residents is."""
    from repro.kernels.quant_dot import BlockDecision

    site = kernel_sites("llama3_8b", "rotate_once")[0]
    dec = site.decision
    site.decision = BlockDecision(dec.block_m, dec.block_n, dec.schedule,
                                  64)
    rep = run_rules([site], rules=["vmem-budget"])
    assert not rep.ok
    assert "vmem_bytes" in rep.violations[0].message


def test_dtype_flow_flags_cache_dequant():
    """A decode-shaped trace that materializes the cache as f32 (wider
    than the bf16 io dtype) is flagged; the io-dtype convert the real
    attention path performs is not."""
    cache = jnp.zeros((2, 8, 1, 16), jnp.float8_e4m3fn)

    def bad(c):
        return c.astype(jnp.float32) * 2.0

    def good(c):
        # the real decode path: convert to the io dtype, never wider
        return c.astype(jnp.bfloat16) * jnp.bfloat16(2)

    leaves = ((tuple(cache.shape), str(cache.dtype)),)
    mk = lambda fn: Site(name="t", kind="serving",
                         jaxpr=jax.make_jaxpr(fn)(cache),
                         io_dtype=jnp.dtype(jnp.bfloat16),
                         cache_leaves=leaves)
    assert not run_rules([mk(bad)], rules=["dtype-flow"]).ok
    assert run_rules([mk(good)], rules=["dtype-flow"]).ok


def test_deprecated_shim_rule_fires_on_shim_trace():
    from repro.analysis.sites import traced
    from repro.kernels.fused_quant import fused_hadamard_quantize

    jaxpr, qw, shim = traced(fused_hadamard_quantize,
                             jnp.ones((4, 64), jnp.float32))
    site = Site(name="shimmed", kind="model", jaxpr=jaxpr,
                qw_calls=qw, shim_calls=shim, expect_fused=False)
    rep = run_rules([site], rules=["deprecated-shim-in-trace"])
    assert not rep.ok and "fused_quant" in rep.violations[0].message


# ----------------------------------------------------------- CLI
def test_cli_mutation_mode_exits_nonzero(tmp_path):
    from repro.analysis.lint import main

    out = tmp_path / "mut.json"
    rc = main(["--mutation", "--json", str(out)])
    assert rc != 0
    d = json.loads(out.read_text())
    flagged = {v["site"] for v in d["violations"]}
    assert {"mutant[unguarded_rotate]", "mutant[dangling_dma]"} <= flagged


def test_cli_kernel_sites_pass_and_list_rules(tmp_path, capsys):
    from repro.analysis.lint import main

    assert main(["--list-rules"]) == 0
    assert "dma-safety" in capsys.readouterr().out
    out = tmp_path / "lint.json"
    rc = main(["--config", "llama3_8b", "--schedule", "streamed",
               "--no-serving", "--json", str(out)])
    assert rc == 0
    d = json.loads(out.read_text())
    assert d["ok"] is True and len(d["checked"]) > 0
    assert main(["--rule", "not-a-rule"]) == 2
