"""Per-kernel correctness: hadacore (Pallas, interpret) and the factored
XLA path against the pure-jnp FWHT oracle and explicit Hadamard matmul,
swept over shapes and dtypes (the paper's unit-test methodology)."""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.hadamard import factorize, grouped_hadamard, hadamard_transform
from repro.kernels.fused_quant import fused_hadamard_quantize, ref_fused
from repro.kernels.hadacore import hadacore
from repro.kernels.ops import hadamard
from repro.kernels.ref import fwht, hadamard_matrix

SIZES = [2, 8, 16, 64, 128, 256, 512, 1024, 2048, 4096, 8192, 16384, 32768]


@pytest.mark.parametrize("n", SIZES)
def test_hadacore_matches_explicit_matmul(n):
    rng = np.random.default_rng(n)
    rows = 3 if n >= 8192 else 9
    x = rng.standard_normal((rows, n)).astype(np.float32)
    want = x @ hadamard_matrix(n)
    got = np.asarray(hadacore(jnp.asarray(x), scale=None))
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-4 * math.sqrt(n))


@pytest.mark.parametrize("n", [128, 512, 4096])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16, jnp.float16])
def test_hadacore_dtypes(n, dtype):
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((17, n)), dtype=dtype)
    got = hadacore(x, scale="ortho").astype(jnp.float32)
    want = fwht(x.astype(jnp.float32), scale=1.0 / math.sqrt(n))
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=tol, atol=tol * 10)
    assert hadacore(x).dtype == dtype


@pytest.mark.parametrize("n", SIZES)
def test_xla_factored_path(n):
    rng = np.random.default_rng(n)
    x = rng.standard_normal((5, n)).astype(np.float32)
    got = np.asarray(hadamard_transform(jnp.asarray(x), scale=None))
    want = np.asarray(fwht(jnp.asarray(x)))
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=1e-3)


@pytest.mark.parametrize("batch_shape", [(1,), (4, 3), (2, 2, 5)])
def test_leading_dims(batch_shape):
    rng = np.random.default_rng(1)
    x = rng.standard_normal(batch_shape + (256,)).astype(np.float32)
    got = np.asarray(hadacore(jnp.asarray(x)))
    want = np.asarray(fwht(jnp.asarray(x), scale=1 / 16.0))
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=1e-4)


def test_in_place_aliasing():
    rng = np.random.default_rng(2)
    x = jnp.asarray(rng.standard_normal((64, 1024)), dtype=jnp.float32)
    np.testing.assert_allclose(np.asarray(hadacore(x, in_place=True)),
                               np.asarray(hadacore(x)), rtol=0, atol=0)


def test_block_m_variants():
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.standard_normal((70, 512)), dtype=jnp.float32)  # pad path
    want = np.asarray(hadacore(x))
    for bm in (8, 16, 64):
        np.testing.assert_allclose(np.asarray(hadacore(x, block_m=bm)), want,
                                   rtol=1e-6, atol=1e-6)


def test_kernel_size_cap():
    with pytest.raises(ValueError):
        hadacore(jnp.zeros((2, 65536)))
    # ...but the factored path covers it
    y = hadamard_transform(jnp.zeros((2, 65536)))
    assert y.shape == (2, 65536)


def test_factorize():
    assert factorize(128) == (1, 1)
    assert factorize(256) == (1, 2)
    assert factorize(16384) == (2, 1)
    assert factorize(32768) == (2, 2)
    assert factorize(64) == (0, 64)
    with pytest.raises(ValueError):
        factorize(96)


# --------------------------------------------------------------- properties
@settings(deadline=None, max_examples=25)
@given(logn=st.integers(1, 12), seed=st.integers(0, 2**31 - 1))
def test_property_self_inverse(logn, seed):
    """H orthonormal and symmetric => had(had(x)) == x."""
    n = 2 ** logn
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal((4, n)), dtype=jnp.float32)
    y = hadamard(hadamard(x))
    np.testing.assert_allclose(np.asarray(y), np.asarray(x), rtol=1e-4, atol=1e-4)


@settings(deadline=None, max_examples=25)
@given(logn=st.integers(1, 12), seed=st.integers(0, 2**31 - 1))
def test_property_norm_preservation(logn, seed):
    """Orthonormal transform preserves L2 norms (it is a rotation)."""
    n = 2 ** logn
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal((4, n)), dtype=jnp.float32)
    y = hadamard(x)
    np.testing.assert_allclose(np.linalg.norm(np.asarray(y), axis=-1),
                               np.linalg.norm(np.asarray(x), axis=-1),
                               rtol=1e-4)


@settings(deadline=None, max_examples=20)
@given(logn=st.integers(1, 10), seed=st.integers(0, 2**31 - 1),
       a=st.floats(-3, 3), b=st.floats(-3, 3))
def test_property_linearity(logn, seed, a, b):
    n = 2 ** logn
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal((2, n)), dtype=jnp.float32)
    z = jnp.asarray(rng.standard_normal((2, n)), dtype=jnp.float32)
    lhs = hadamard(a * x + b * z)
    rhs = a * hadamard(x) + b * hadamard(z)
    np.testing.assert_allclose(np.asarray(lhs), np.asarray(rhs),
                               rtol=1e-3, atol=1e-3)


@settings(deadline=None, max_examples=15)
@given(g=st.integers(1, 9), logp=st.integers(1, 8), seed=st.integers(0, 1000))
def test_property_grouped_orthogonal(g, logp, seed):
    """Grouped transform (non-pow2 dims) is still orthogonal."""
    p = 2 ** logp
    n = g * p
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal((3, n)), dtype=jnp.float32)
    y = grouped_hadamard(x, group=p)
    z = grouped_hadamard(y, group=p)
    np.testing.assert_allclose(np.asarray(z), np.asarray(x), rtol=1e-4, atol=1e-4)


def test_gradient_is_self_adjoint():
    rng = np.random.default_rng(5)
    x = jnp.asarray(rng.standard_normal((4, 512)), dtype=jnp.float32)
    g = jax.grad(lambda a: jnp.sum(hadamard(a) ** 2))(x)
    # d/dx ||xH||^2 = 2 x H H^T = 2x for orthonormal H
    np.testing.assert_allclose(np.asarray(g), 2 * np.asarray(x), rtol=1e-4, atol=1e-4)


# ------------------------------------------------------------- fused kernel
@pytest.mark.parametrize("n", [128, 512, 2048, 4096])
def test_fused_hadamard_quantize(n):
    rng = np.random.default_rng(n)
    x = jnp.asarray(rng.standard_normal((13, n)), dtype=jnp.float32)
    q, s = fused_hadamard_quantize(x)
    qr, sr = ref_fused(x)
    np.testing.assert_allclose(np.asarray(s), np.asarray(sr), rtol=1e-5)
    # int8 grids may differ by 1 ulp at rounding boundaries
    assert np.mean(np.abs(np.asarray(q, np.int32) - np.asarray(qr, np.int32))) < 0.01
    # dequantized result approximates the rotation
    deq = np.asarray(q, np.float32) * np.asarray(s)
    want = np.asarray(fwht(x, scale=1.0 / math.sqrt(n)))
    np.testing.assert_allclose(deq, want, atol=np.abs(want).max() / 100)
