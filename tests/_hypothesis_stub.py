"""Deterministic fallback for the tiny slice of `hypothesis` this suite
uses, installed into ``sys.modules`` by conftest.py ONLY when the real
library is absent (the pinned CI/container image does not ship it).

The real hypothesis is strictly better (shrinking, example database,
coverage-guided generation) and is used automatically when installed; the
fallback just draws a fixed number of seeded pseudo-random examples per
test so property tests still exercise many (shape, seed, value)
combinations instead of being skipped. Supported surface:

    from hypothesis import given, settings, strategies as st
    @settings(deadline=None, max_examples=N)
    @given(a=st.integers(lo, hi), b=st.floats(lo, hi))
    def test_...(a, b): ...
"""
from __future__ import annotations

import random
import types

_DEFAULT_EXAMPLES = 10


class _Strategy:
    def __init__(self, draw):
        self._draw = draw

    def example_from(self, rng: random.Random):
        return self._draw(rng)


def integers(min_value: int, max_value: int) -> _Strategy:
    return _Strategy(lambda rng: rng.randint(min_value, max_value))


def floats(min_value: float, max_value: float, **_kw) -> _Strategy:
    return _Strategy(lambda rng: rng.uniform(min_value, max_value))


def booleans() -> _Strategy:
    return _Strategy(lambda rng: rng.random() < 0.5)


def sampled_from(options) -> _Strategy:
    opts = list(options)
    return _Strategy(lambda rng: opts[rng.randrange(len(opts))])


def given(**strategies):
    def decorate(fn):
        def wrapper():
            # seeded per test name: deterministic across runs/machines
            rng = random.Random(fn.__name__)
            n = getattr(wrapper, "_max_examples", _DEFAULT_EXAMPLES)
            for _ in range(n):
                kwargs = {k: s.example_from(rng) for k, s in strategies.items()}
                try:
                    fn(**kwargs)
                except Exception as e:  # attach the falsifying example
                    raise AssertionError(
                        f"falsifying example (hypothesis fallback): {kwargs}"
                    ) from e

        wrapper.__name__ = fn.__name__
        wrapper.__doc__ = fn.__doc__
        wrapper.__module__ = fn.__module__
        wrapper._hypothesis_fallback = True
        return wrapper

    return decorate


def settings(max_examples: int = _DEFAULT_EXAMPLES, **_ignored):
    def decorate(fn):
        if hasattr(fn, "_hypothesis_fallback"):
            fn._max_examples = max_examples
        return fn

    return decorate


def build_module() -> types.ModuleType:
    """Assemble a module tree mimicking `hypothesis` + `hypothesis.strategies`."""
    mod = types.ModuleType("hypothesis")
    mod.given = given
    mod.settings = settings
    st = types.ModuleType("hypothesis.strategies")
    st.integers = integers
    st.floats = floats
    st.booleans = booleans
    st.sampled_from = sampled_from
    mod.strategies = st
    return mod
