"""Rotation-based quantization: the paper's deployment semantics.

Checks: (i) offline fusion is exact in full precision, (ii) online rotation
reduces INT8/FP8 quantization error on outlier-heavy activations (the
QuaRot premise the paper's kernel serves), (iii) rotated FP8 attention
matches unrotated full-precision attention closely (section 4.2 proxy)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.quant import QuantConfig, quant_dot, quantize
from repro.core.rotations import (
    fuse_rotation_lhs,
    online_hadamard,
    rotation_matrix,
)
from repro.models import attention as A
from repro.configs import get_config


def _outlier_acts(rng, rows, d, k=8, mag=40.0):
    x = rng.standard_normal((rows, d)).astype(np.float32)
    idx = rng.choice(d, k, replace=False)
    x[:, idx] *= mag
    return x


@pytest.mark.parametrize("d", [1024, 4096, 14336])  # incl. non-pow2 (7*2048)
def test_rotation_reduces_int8_quant_error(d):
    """INT8's fixed grid suffers badly from outliers: rotation must cut the
    quantized-matmul error at least in half (QuaRot's core claim)."""
    rng = np.random.default_rng(0)
    x = _outlier_acts(rng, 64, d)
    w = (rng.standard_normal((d, 256)) * 0.02).astype(np.float32)
    ref = x @ w
    cfg_q = QuantConfig(mode="int8")
    cfg_qr = QuantConfig(mode="int8", rotate="hadamard", backend="xla")
    err_plain = np.abs(np.asarray(quant_dot(jnp.asarray(x), jnp.asarray(w), cfg_q)) - ref).mean()
    Q = rotation_matrix(d)
    xr = online_hadamard(jnp.asarray(x), cfg_qr)
    wr = fuse_rotation_lhs(jnp.asarray(w), Q)
    err_rot = np.abs(np.asarray(quant_dot(xr, wr, cfg_qr)) - ref).mean()
    assert err_rot * 2.0 < err_plain, (err_plain, err_rot)


@pytest.mark.parametrize("d", [1024, 4096])
def test_rotation_fp8_error_bounded(d):
    """FP8 is a *relative*-precision format: quantization noise energy is
    rotation-invariant for unstructured weights, so rotation neither helps
    nor hurts the matmul error much (the paper's own FP8 MMLU deltas are
    fractions of a point). Assert boundedness, not improvement -- and
    record the measured ratio in EXPERIMENTS.md."""
    rng = np.random.default_rng(0)
    x = _outlier_acts(rng, 64, d, mag=2000.0)
    w = (rng.standard_normal((d, 256)) * 0.02).astype(np.float32)
    ref = x @ w
    cfg_q = QuantConfig(mode="fp8_e4m3")
    cfg_qr = QuantConfig(mode="fp8_e4m3", rotate="hadamard", backend="xla")
    err_plain = np.abs(np.asarray(quant_dot(jnp.asarray(x), jnp.asarray(w), cfg_q)) - ref).mean()
    Q = rotation_matrix(d)
    xr = online_hadamard(jnp.asarray(x), cfg_qr)
    wr = fuse_rotation_lhs(jnp.asarray(w), Q)
    err_rot = np.abs(np.asarray(quant_dot(xr, wr, cfg_qr)) - ref).mean()
    assert err_rot < err_plain * 2.0, (err_plain, err_rot)


@settings(deadline=None, max_examples=10)
@given(logd=st.integers(5, 10), seed=st.integers(0, 10**6))
def test_offline_fusion_exactness(logd, seed):
    """x Q @ Q^T W == x W exactly (rotation cancels in full precision)."""
    d = 2 ** logd
    rng = np.random.default_rng(seed)
    x = rng.standard_normal((8, d)).astype(np.float32)
    w = rng.standard_normal((d, 32)).astype(np.float32)
    key = jax.random.PRNGKey(seed)
    Q = rotation_matrix(d, key=key)
    got = (jnp.asarray(x) @ Q) @ fuse_rotation_lhs(jnp.asarray(w), Q)
    np.testing.assert_allclose(np.asarray(got), x @ w, rtol=2e-3, atol=2e-3)


def test_rotated_qk_preserves_attention_scores():
    """had(q) . had(k) == q . k -- the reason FP8 attention can rotate."""
    rng = np.random.default_rng(1)
    q = jnp.asarray(rng.standard_normal((2, 16, 4, 128)), dtype=jnp.float32)
    k = jnp.asarray(rng.standard_normal((2, 16, 4, 128)), dtype=jnp.float32)
    cfg = QuantConfig(rotate="hadamard", backend="xla")
    qr, kr = online_hadamard(q, cfg), online_hadamard(k, cfg)
    s0 = jnp.einsum("bshd,bthd->bhst", q, k)
    s1 = jnp.einsum("bshd,bthd->bhst", qr, kr)
    np.testing.assert_allclose(np.asarray(s0), np.asarray(s1), rtol=1e-3, atol=1e-3)


def test_fp8_attention_with_rotation_close_to_fp16(

):
    """Paper section 4.2 microcosm: FP8 attention + rotation stays close to
    the full-precision attention output (the paper's claim is comparable
    accuracy, not strict dominance -- its HadaCore MMLU is 65.09 vs 64.40
    unrotated and 65.45 for the reference kernel)."""
    rng = np.random.default_rng(2)
    cfg16 = get_config("llama3_8b").scaled_down()
    B, S, H, KH, hd = 2, 32, cfg16.num_heads, cfg16.num_kv_heads, cfg16.head_dim
    q = rng.standard_normal((B, S, H, hd)).astype(np.float32)
    k = rng.standard_normal((B, S, KH, hd)).astype(np.float32)
    k[..., 3] *= 30.0  # outlier head-dim channel (the QuaRot scenario)
    v = rng.standard_normal((B, S, KH, hd)).astype(np.float32)
    mask = A._causal_mask(cfg16, S, S)

    ref = A._sdpa(cfg16, jnp.asarray(q), jnp.asarray(k), jnp.asarray(v), mask)

    def fp8_attn(rotate):
        qq, kk = jnp.asarray(q), jnp.asarray(k)
        if rotate:
            c = QuantConfig(mode="fp8_e4m3", rotate="hadamard", backend="xla")
            qq, kk = online_hadamard(qq, c), online_hadamard(kk, c)
        qq = quantize(qq, "fp8_e4m3", axis=-1)
        kk = quantize(kk, "fp8_e4m3", axis=-1)
        return A._sdpa(cfg16, qq, kk, jnp.asarray(v), mask)

    scale = np.abs(np.asarray(ref)).mean()
    err_plain = np.abs(np.asarray(fp8_attn(False)) - np.asarray(ref)).mean()
    err_rot = np.abs(np.asarray(fp8_attn(True)) - np.asarray(ref)).mean()
    # "comparable accuracy": both within a few % of the fp16 output scale.
    # Which variant wins is data-dependent at matmul level (fp8 noise is
    # rotation-invariant); the paper's end-to-end gain shows up on real
    # LLM activations -- measured in benchmarks/bench_quant_accuracy.py.
    assert err_rot < 0.1 * scale, (err_rot, scale)
    assert err_plain < 0.1 * scale, (err_plain, scale)


def test_quantize_shapes_and_range():
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.standard_normal((4, 7, 33)) * 100, dtype=jnp.float32)
    for mode in ("int8", "fp8_e4m3", "fp8_e5m2"):
        y = quantize(x, mode, axis=-1)
        assert y.shape == x.shape and y.dtype == x.dtype
        rel = np.abs(np.asarray(y - x)).mean() / np.abs(np.asarray(x)).mean()
        assert rel < 0.05, (mode, rel)
    assert quantize(x, "none") is x
