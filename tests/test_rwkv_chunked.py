"""Perf iteration A (EXPERIMENTS.md): the chunked RWKV6 time-mix must be
numerically equivalent to the exact per-token recurrence."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs import get_config
from repro.launch.shapes import ShapeSpec, make_batch
from repro.models import init_lm, lm_loss
from repro.models.rwkv import _tmix_chunked, _tmix_scan


def _inputs(seed, B, S, H, K, decay_scale=2.0):
    rng = np.random.default_rng(seed)
    r, k, v = (jnp.asarray(rng.standard_normal((B, S, H, K)), jnp.float32)
               for _ in range(3))
    w = jnp.asarray(np.exp(-np.exp(rng.standard_normal((B, S, H, K)) * decay_scale)),
                    jnp.float32)
    u = jnp.asarray(rng.standard_normal((H, K)) * 0.1, jnp.float32)
    return r, k, v, w, u


@settings(deadline=None, max_examples=8)
@given(seed=st.integers(0, 10**6), s_mult=st.integers(1, 4),
       decay_scale=st.floats(0.5, 3.0))
def test_chunked_equals_scan(seed, s_mult, decay_scale):
    B, S, H, K = 2, 32 * s_mult, 2, 16
    r, k, v, w, u = _inputs(seed, B, S, H, K, decay_scale)
    o1, s1 = _tmix_scan(B, S, H, K, r, k, v, w, u)
    o2, s2 = _tmix_chunked(B, S, H, K, r, k, v, w, u)
    scale = float(jnp.abs(o1).max()) + 1e-9
    assert float(jnp.abs(o1 - o2).max()) / scale < 1e-4
    sscale = float(jnp.abs(s1).max()) + 1e-9
    assert float(jnp.abs(s1 - s2).max()) / sscale < 1e-4


def test_chunked_extreme_decay_no_nan():
    """Near-zero decays (flushed denormals) must not produce NaN/Inf --
    the regime that breaks ratio-based chunked forms."""
    B, S, H, K = 1, 64, 2, 16
    r, k, v, w, u = _inputs(0, B, S, H, K)
    w = w.at[:, ::3].set(1e-45)  # below f32 denormal after FTZ
    o2, s2 = _tmix_chunked(B, S, H, K, r, k, v, w, u)
    assert bool(jnp.isfinite(o2).all()) and bool(jnp.isfinite(s2).all())
    o1, s1 = _tmix_scan(B, S, H, K, r, k, v, w, u)
    assert float(jnp.abs(o1 - o2).max()) / (float(jnp.abs(o1).max()) + 1e-9) < 1e-3


def test_chunked_gradients_finite():
    B, S, H, K = 2, 64, 2, 16
    r, k, v, w, u = _inputs(1, B, S, H, K)
    g = jax.grad(lambda a, b: (_tmix_chunked(B, S, H, K, a, b, v, w, u)[0] ** 2).sum(),
                 argnums=(0, 1))(r, k)
    assert all(bool(jnp.isfinite(x).all()) for x in g)


def test_model_level_impl_equivalence():
    cfg_c = get_config("rwkv6_7b").scaled_down()
    cfg_s = dataclasses.replace(cfg_c, rwkv_impl="scan")
    batch = make_batch(cfg_c, ShapeSpec("t", "train", 64, 2))
    params = init_lm(jax.random.PRNGKey(0), cfg_c)
    lc, _ = lm_loss(cfg_c, params, batch)
    ls, _ = lm_loss(cfg_s, params, batch)
    assert abs(float(lc) - float(ls)) < 1e-3
