"""Quantized weight storage: the QTensor pytree node (Perf C4/C4', PR 4)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.quant import QuantConfig
from repro.core.wquant import (
    QTensor,
    dequant_tree,
    is_qleaf,
    quantize_lm_weights,
    quantize_weight,
)
from repro.launch.shapes import ShapeSpec, make_batch
from repro.models import init_lm, lm_loss, lm_param_specs
from repro.models.lm import pad_kv_caches, lm_prefill, lm_decode_step


def test_quantize_roundtrip_error_bounded():
    rng = np.random.default_rng(0)
    w = jnp.asarray(rng.standard_normal((512, 384)) * 0.05, jnp.bfloat16)
    q = quantize_lm_weights({"groups": [{"p0": {"attn": {"wq": w}}}]})
    leaf = q["groups"][0]["p0"]["attn"]["wq"]
    assert is_qleaf(leaf) and leaf.q.dtype == jnp.int8 and leaf.mode == "int8"
    back = dequant_tree(leaf, jnp.float32)
    err = np.abs(np.asarray(back) - np.asarray(w, np.float32)).max()
    assert err < float(jnp.abs(w.astype(jnp.float32)).max()) / 100


def test_small_leaves_not_quantized():
    p = {"norm1": {"scale": jnp.ones((512,))},
         "bias": jnp.zeros((128,)),
         "big": jnp.ones((512, 512), jnp.bfloat16)}
    q = quantize_lm_weights(p)
    assert not is_qleaf(q["norm1"]["scale"]) and not is_qleaf(q["bias"])
    assert is_qleaf(q["big"])


def test_qtensor_is_a_pytree_node():
    """q/scale are children (jit/scan/device_put see through the node);
    mode/axes are static aux data; legacy (q, scale) unpack works."""
    qt = quantize_weight(jnp.ones((64, 32)) * 0.5, "fp8_e4m3",
                         axes=("dff", "fsdp"))
    leaves, treedef = jax.tree.flatten(qt)
    assert [l.shape for l in leaves] == [(64, 32), (1, 32)]
    back = jax.tree.unflatten(treedef, leaves)
    assert back.mode == "fp8_e4m3" and back.axes == ("dff", "fsdp")
    out = jax.jit(lambda t: t.dequant(jnp.float32))(qt)
    assert out.shape == (64, 32)
    q, s = qt  # legacy tuple unpack
    assert q is qt.q and s is qt.scale
    # scan slices both children together (the layer-stacked form)
    stacked = QTensor(q=jnp.zeros((3, 8, 4), jnp.int8),
                      scale=jnp.ones((3, 1, 4)), mode="int8")
    _, sliced = jax.lax.scan(lambda c, t: (c, t.dequant(jnp.float32)),
                             0, stacked)
    assert sliced.shape == (3, 8, 4)


def test_consumer_leaves_stored_in_serving_mode():
    """With a rotating+quantizing config, down-proj weights (the
    quant_dot consumers) store in cfg.quant.mode regardless of size;
    everything else stores int8."""
    quant = QuantConfig(mode="fp8_e4m3", rotate="hadamard", backend="xla")
    cfg = dataclasses.replace(
        get_config("llama3_8b").scaled_down().with_quant(quant),
        weight_quant="int8")
    params = init_lm(jax.random.PRNGKey(0), cfg)
    qp = quantize_lm_weights(params, cfg, lm_param_specs(cfg))
    wd = qp["groups"][0]["p0"]["mlp"]["w_down"]
    assert is_qleaf(wd) and wd.mode == "fp8_e4m3"
    assert wd.q.dtype == jnp.float8_e4m3fn
    assert wd.axes == ("layers", "dff", "fsdp")   # attached from specs
    emb = qp["emb"]
    assert is_qleaf(emb) and emb.mode == "int8"


@pytest.mark.parametrize("arch", ["llama3_8b", "mixtral_8x7b", "rwkv6_7b"])
def test_int8_weights_model_close(arch):
    cfg0 = get_config(arch).scaled_down()
    cfg = dataclasses.replace(cfg0, weight_quant="int8")
    batch = make_batch(cfg0, ShapeSpec("t", "train", 32, 2))
    params = init_lm(jax.random.PRNGKey(0), cfg0)
    l0, _ = lm_loss(cfg0, params, batch)
    l1, _ = lm_loss(cfg, quantize_lm_weights(params), batch)
    assert abs(float(l0) - float(l1)) < 0.25, (float(l0), float(l1))


def test_int8_weights_decode_path():
    cfg0 = get_config("llama3_8b").scaled_down()
    cfg = dataclasses.replace(cfg0, weight_quant="int8")
    batch = make_batch(cfg0, ShapeSpec("t", "train", 32, 2))
    qparams = quantize_lm_weights(init_lm(jax.random.PRNGKey(0), cfg0))
    logits, caches = lm_prefill(cfg, qparams, batch)
    caches = pad_kv_caches(cfg, caches, 40)
    tok = jnp.argmax(logits[..., :cfg.vocab_size], -1).astype(jnp.int32)
    lg, _ = lm_decode_step(cfg, qparams, caches, tok, jnp.asarray(32, jnp.int32))
    assert np.isfinite(np.asarray(lg[..., :cfg.vocab_size], np.float32)).all()
