"""Weight-only INT8 storage (Perf iteration C4/C4')."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.wquant import dequant_tree, is_qleaf, quantize_lm_weights
from repro.launch.shapes import ShapeSpec, make_batch
from repro.models import init_lm, lm_loss
from repro.models.lm import pad_kv_caches, lm_prefill, lm_decode_step


def test_quantize_roundtrip_error_bounded():
    rng = np.random.default_rng(0)
    w = jnp.asarray(rng.standard_normal((512, 384)) * 0.05, jnp.bfloat16)
    q = quantize_lm_weights({"groups": [{"p0": {"attn": {"wq": w}}}]})
    leaf = q["groups"][0]["p0"]["attn"]["wq"]
    assert is_qleaf(leaf) and leaf["wq"].dtype == jnp.int8
    back = dequant_tree(leaf, jnp.float32)
    err = np.abs(np.asarray(back) - np.asarray(w, np.float32)).max()
    assert err < float(jnp.abs(w.astype(jnp.float32)).max()) / 100


def test_small_leaves_not_quantized():
    p = {"norm1": {"scale": jnp.ones((512,))},
         "bias": jnp.zeros((128,)),
         "big": jnp.ones((512, 512), jnp.bfloat16)}
    q = quantize_lm_weights(p)
    assert not is_qleaf(q["norm1"]["scale"]) and not is_qleaf(q["bias"])
    assert is_qleaf(q["big"])


@pytest.mark.parametrize("arch", ["llama3_8b", "mixtral_8x7b", "rwkv6_7b"])
def test_int8_weights_model_close(arch):
    cfg0 = get_config(arch).scaled_down()
    cfg = dataclasses.replace(cfg0, weight_quant="int8")
    batch = make_batch(cfg0, ShapeSpec("t", "train", 32, 2))
    params = init_lm(jax.random.PRNGKey(0), cfg0)
    l0, _ = lm_loss(cfg0, params, batch)
    l1, _ = lm_loss(cfg, quantize_lm_weights(params), batch)
    assert abs(float(l0) - float(l1)) < 0.25, (float(l0), float(l1))


def test_int8_weights_decode_path():
    cfg0 = get_config("llama3_8b").scaled_down()
    cfg = dataclasses.replace(cfg0, weight_quant="int8")
    batch = make_batch(cfg0, ShapeSpec("t", "train", 32, 2))
    qparams = quantize_lm_weights(init_lm(jax.random.PRNGKey(0), cfg0))
    logits, caches = lm_prefill(cfg, qparams, batch)
    caches = pad_kv_caches(cfg, caches, 40)
    tok = jnp.argmax(logits[..., :cfg.vocab_size], -1).astype(jnp.int32)
    lg, _ = lm_decode_step(cfg, qparams, caches, tok, jnp.asarray(32, jnp.int32))
    assert np.isfinite(np.asarray(lg[..., :cfg.vocab_size], np.float32)).all()
