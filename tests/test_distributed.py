"""Distribution: sharding resolver, multi-device pjit equivalence, the
int8 ring all-reduce, and a miniature multi-pod dry-run -- all on fake
host devices in subprocesses (the main process keeps 1 device)."""
import numpy as np
import pytest

from repro.distributed.sharding import DEFAULT_RULES


def test_resolver_divisibility_guard():
    import jax
    from repro.distributed.sharding import make_resolver
    mesh = jax.make_mesh((1,), ("data",))
    one = make_resolver(mesh)
    s = one(("batch", None), (4, 8))
    assert s.spec == jax.sharding.PartitionSpec(None, None) or True
    # dims not divisible by the axis drop the constraint instead of failing
    s2 = one(("vocab",), (51865,))
    assert s2 is not None


def test_default_rules_cover_model_axes():
    for ax in ("batch", "fsdp", "heads", "kv", "dff", "vocab", "experts"):
        assert ax in DEFAULT_RULES


def test_sharded_train_step_matches_single_device(subproc):
    """pjit on a 4-device (2,2) mesh computes the same loss as 1 device."""
    out = subproc("""
import jax, jax.numpy as jnp, numpy as np
from repro.configs import get_config
from repro.launch.shapes import ShapeSpec, make_batch
from repro.launch.steps import jit_train_step, param_shardings
from repro.models import init_lm, lm_loss
from repro.optim import OptConfig, init_opt_state

cfg = get_config("llama3_8b").scaled_down()
shape = ShapeSpec("t", "train", 32, 4)
batch = make_batch(cfg, shape)
params = init_lm(jax.random.PRNGKey(0), cfg)
loss_1dev, _ = lm_loss(cfg, params, batch)

mesh = jax.make_mesh((2, 2), ("data", "model"))
opt = OptConfig(lr=1e-3)
step, (ps, os_, bs) = jit_train_step(cfg, opt, shape, mesh, donate=False)
params_s = jax.device_put(params, ps)
opt_state = jax.device_put(init_opt_state(params, opt), os_)
batch_s = {k: jax.device_put(np.asarray(v), bs[k]) for k, v in batch.items()}
_, _, metrics = step(params_s, opt_state, batch_s)
print("LOSSES", float(loss_1dev), float(metrics["loss"]))
err = abs(float(loss_1dev) - float(metrics["loss"]))
assert err < 5e-2, err
""", devices=4)
    assert "LOSSES" in out


def test_int8_ring_all_reduce(subproc):
    out = subproc("""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.distributed.collectives import int8_ring_all_reduce

mesh = jax.make_mesh((8,), ("data",))
rng = np.random.default_rng(0)
contribs = jnp.asarray(rng.standard_normal((8, 32, 16)) * 5, jnp.float32)
contribs = jax.device_put(contribs, NamedSharding(mesh, P("data")))
out = int8_ring_all_reduce(contribs, mesh, "data")
want = np.asarray(contribs).sum(0)
got = np.asarray(out)
# every shard row holds the ring sum, within int8 wire precision
for i in range(8):
    rel = np.abs(got[i] - want).max() / (np.abs(want).max() + 1e-9)
    assert rel < 0.05, rel
print("RING_OK", rel)
""", devices=8)
    assert "RING_OK" in out


def test_mini_multipod_dryrun(subproc):
    """A miniature (2,2,2) 'multi-pod' mesh: lower+compile a real arch's
    train step and check collectives span the pod axis."""
    out = subproc("""
import jax, jax.numpy as jnp
from repro.configs import get_config
from repro.launch.shapes import ShapeSpec, batch_specs
from repro.launch.steps import jit_train_step, param_shapes, opt_state_shapes
from repro.optim import OptConfig
from repro.launch.hlo_analysis import analyze_hlo

mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
cfg = get_config("mixtral_8x7b").scaled_down()
shape = ShapeSpec("t", "train", 64, 8)
opt = OptConfig()
step, _ = jit_train_step(cfg, opt, shape, mesh)
args = (param_shapes(cfg), opt_state_shapes(cfg, opt), batch_specs(cfg, shape))
compiled = step.lower(*args).compile()
res = analyze_hlo(compiled.as_text())
assert res["collective_total_bytes_per_device"] > 0
mem = compiled.memory_analysis()
assert mem.temp_size_in_bytes > 0
print("MINIPOD_OK", res["collective_counts"])
""", devices=8)
    assert "MINIPOD_OK" in out


def test_serve_step_sharded(subproc):
    out = subproc("""
import jax, jax.numpy as jnp, numpy as np
from repro.configs import get_config
from repro.launch.steps import jit_serve_step, param_shardings
from repro.launch.shapes import cache_specs
from repro.models import init_lm

cfg = get_config("llama3_8b").scaled_down()
mesh = jax.make_mesh((2, 2), ("data", "model"))
B, T = 4, 64
serve, (ps, cs, ts) = jit_serve_step(cfg, B, T, mesh, donate=False)
params = jax.device_put(init_lm(jax.random.PRNGKey(0), cfg), ps)
caches = jax.tree.map(lambda s: jax.device_put(jnp.zeros(s.shape, s.dtype)), cache_specs(cfg, B, T))
caches = jax.device_put(caches, cs)
toks = jax.device_put(jnp.ones((B, 1), jnp.int32), ts)
new_tok, logits, new_caches = serve(params, caches, toks, jnp.asarray(3, jnp.int32))
assert new_tok.shape == (B, 1)
assert np.isfinite(np.asarray(logits[..., :cfg.vocab_size], np.float32)).all()
print("SERVE_OK")
""", devices=4)
    assert "SERVE_OK" in out
