"""Distribution: sharding resolver, multi-device pjit equivalence, the
int8 ring all-reduce, and a miniature multi-pod dry-run -- all on fake
host devices in subprocesses (the main process keeps 1 device)."""
import numpy as np
import pytest

from repro.distributed.sharding import DEFAULT_RULES


def test_resolver_divisibility_guard():
    import jax
    from repro.distributed.sharding import make_resolver
    mesh = jax.make_mesh((1,), ("data",))
    one = make_resolver(mesh)
    s = one(("batch", None), (4, 8))
    assert s.spec == jax.sharding.PartitionSpec(None, None) or True
    # dims not divisible by the axis drop the constraint instead of failing
    s2 = one(("vocab",), (51865,))
    assert s2 is not None


def test_default_rules_cover_model_axes():
    for ax in ("batch", "fsdp", "heads", "kv", "dff", "vocab", "experts"):
        assert ax in DEFAULT_RULES


def test_sharded_train_step_matches_single_device(subproc):
    """pjit on a 4-device (2,2) mesh computes the same loss as 1 device."""
    out = subproc("""
import jax, jax.numpy as jnp, numpy as np
from repro.configs import get_config
from repro.launch.shapes import ShapeSpec, make_batch
from repro.launch.steps import jit_train_step, param_shardings
from repro.models import init_lm, lm_loss
from repro.optim import OptConfig, init_opt_state

cfg = get_config("llama3_8b").scaled_down()
shape = ShapeSpec("t", "train", 32, 4)
batch = make_batch(cfg, shape)
params = init_lm(jax.random.PRNGKey(0), cfg)
loss_1dev, _ = lm_loss(cfg, params, batch)

mesh = jax.make_mesh((2, 2), ("data", "model"))
opt = OptConfig(lr=1e-3)
step, (ps, os_, bs) = jit_train_step(cfg, opt, shape, mesh, donate=False)
params_s = jax.device_put(params, ps)
opt_state = jax.device_put(init_opt_state(params, opt), os_)
batch_s = {k: jax.device_put(np.asarray(v), bs[k]) for k, v in batch.items()}
_, _, metrics = step(params_s, opt_state, batch_s)
print("LOSSES", float(loss_1dev), float(metrics["loss"]))
err = abs(float(loss_1dev) - float(metrics["loss"]))
assert err < 5e-2, err
""", devices=4)
    assert "LOSSES" in out


def test_int8_ring_all_reduce(subproc):
    out = subproc("""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.distributed.collectives import int8_ring_all_reduce

mesh = jax.make_mesh((8,), ("data",))
rng = np.random.default_rng(0)
contribs = jnp.asarray(rng.standard_normal((8, 32, 16)) * 5, jnp.float32)
contribs = jax.device_put(contribs, NamedSharding(mesh, P("data")))
out = int8_ring_all_reduce(contribs, mesh, "data")
want = np.asarray(contribs).sum(0)
got = np.asarray(out)
# every shard row holds the ring sum, within int8 wire precision
for i in range(8):
    rel = np.abs(got[i] - want).max() / (np.abs(want).max() + 1e-9)
    assert rel < 0.05, rel
print("RING_OK", rel)
""", devices=8)
    assert "RING_OK" in out


def test_mini_multipod_dryrun(subproc):
    """A miniature (2,2,2) 'multi-pod' mesh: lower+compile a real arch's
    train step and check collectives span the pod axis."""
    out = subproc("""
import jax, jax.numpy as jnp
from repro.configs import get_config
from repro.launch.shapes import ShapeSpec, batch_specs
from repro.launch.steps import jit_train_step, param_shapes, opt_state_shapes
from repro.optim import OptConfig
from repro.launch.hlo_analysis import analyze_hlo

mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
cfg = get_config("mixtral_8x7b").scaled_down()
shape = ShapeSpec("t", "train", 64, 8)
opt = OptConfig()
step, _ = jit_train_step(cfg, opt, shape, mesh)
args = (param_shapes(cfg), opt_state_shapes(cfg, opt), batch_specs(cfg, shape))
compiled = step.lower(*args).compile()
res = analyze_hlo(compiled.as_text())
assert res["collective_total_bytes_per_device"] > 0
mem = compiled.memory_analysis()
assert mem.temp_size_in_bytes > 0
print("MINIPOD_OK", res["collective_counts"])
""", devices=8)
    assert "MINIPOD_OK" in out


def test_serve_step_sharded(subproc):
    out = subproc("""
import jax, jax.numpy as jnp, numpy as np
from repro.configs import get_config
from repro.launch.steps import jit_serve_step, param_shardings
from repro.launch.shapes import cache_specs
from repro.models import init_lm

cfg = get_config("llama3_8b").scaled_down()
mesh = jax.make_mesh((2, 2), ("data", "model"))
B, T = 4, 64
serve, (ps, cs, ts) = jit_serve_step(cfg, B, T, mesh, donate=False)
params = jax.device_put(init_lm(jax.random.PRNGKey(0), cfg), ps)
caches = jax.tree.map(lambda s: jax.device_put(jnp.zeros(s.shape, s.dtype)), cache_specs(cfg, B, T))
caches = jax.device_put(caches, cs)
toks = jax.device_put(jnp.ones((B, 1), jnp.int32), ts)
new_tok, logits, new_caches = serve(params, caches, toks, jnp.asarray(3, jnp.int32))
assert new_tok.shape == (B, 1)
assert np.isfinite(np.asarray(logits[..., :cfg.vocab_size], np.float32)).all()
print("SERVE_OK")
""", devices=4)
    assert "SERVE_OK" in out


def test_sharded_quant_dot_matches_single_device(subproc):
    """PR 4 acceptance: a 2-device mesh quant_dot (shard_map dispatch,
    per-shard weight scales, mesh axes in the plan cache key) matches the
    single-device output -- bitwise for int8, allclose for fp8."""
    out = subproc("""
import jax, jax.numpy as jnp, numpy as np
from repro.core.api import QuantDotSpec, QuantEpilogue, plan_for, quant_dot
from repro.core.quant import QuantConfig
from repro.core.wquant import quantize_weight
from repro.distributed import sharding as shd

rng = np.random.default_rng(0)
x = jnp.asarray(rng.standard_normal((16, 256)), jnp.float32)
w = jnp.asarray(rng.standard_normal((256, 128)) * 0.05, jnp.float32)
mesh = jax.make_mesh((2,), ("model",))

for mode, exact in (("int8", True), ("fp8_e4m3", False)):
    qt = quantize_weight(w, mode)
    ref = quant_dot(x, qt, mode=mode, backend="xla")          # no mesh
    with shd.sharding_rules(mesh):
        spec = QuantDotSpec.for_config(
            256, QuantConfig(mode=mode, rotate="hadamard", backend="xla"),
            weight_axes=(None, "dff"))                        # out dim -> model
        plan = spec.plan(jnp.float32, d=128)
        assert plan.mesh_axes == ("model",), plan.mesh_axes   # in the cache key
        assert plan is not plan_for(256, backend="xla",
                                    epilogue=QuantEpilogue(mode))
        sharded = spec.bind(qt)(x)
    a, b = np.asarray(sharded, np.float32), np.asarray(ref, np.float32)
    if exact:
        assert (a == b).all(), np.abs(a - b).max()            # bitwise int8
    else:
        np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-6)

# per-shard scales are genuinely used: perturbing the second shard's
# scale slice changes only that shard's output columns
qt = quantize_weight(w, "int8")
sw2 = qt.scale.at[:, 64:].mul(2.0)
with shd.sharding_rules(mesh):
    o1 = quant_dot(x, (qt.q, qt.scale), mode="int8", backend="xla",
                   weight_axes=(None, "dff"))
    o2 = quant_dot(x, (qt.q, sw2), mode="int8", backend="xla",
                   weight_axes=(None, "dff"))
assert (np.asarray(o1[:, :64]) == np.asarray(o2[:, :64])).all()
assert not (np.asarray(o1[:, 64:]) == np.asarray(o2[:, 64:])).all()

# the grouped (non-power-of-2) transform shards too
xg = jnp.asarray(rng.standard_normal((8, 96)), jnp.float32)
wg = quantize_weight(jnp.asarray(rng.standard_normal((96, 64)) * 0.05,
                                 jnp.float32), "int8")
refg = quant_dot(xg, wg, mode="int8", backend="xla")
with shd.sharding_rules(mesh):
    outg = quant_dot(xg, wg, mode="int8", backend="xla",
                     weight_axes=(None, "dff"))
assert (np.asarray(outg) == np.asarray(refg)).all()
print("SHARDED_QD_OK")
""", devices=2)
    assert "SHARDED_QD_OK" in out


def test_serve_step_sharded_prequant_qtensor(subproc):
    """The full serving stack on a (2,2) mesh with pre-quantized QTensor
    weights: QTensor-structured param shardings resolve, the scanned
    forward consumes q/scale shards directly (shard_map inside the layer
    scan), and decode logits stay finite."""
    out = subproc("""
import dataclasses
import jax, jax.numpy as jnp, numpy as np
from repro.configs import get_config
from repro.core.quant import QuantConfig
from repro.launch.shapes import cache_specs
from repro.launch.steps import jit_serve_step, make_param_init

quant = QuantConfig(mode="int8", rotate="hadamard", backend="xla",
                    kv_quant=True)
cfg = dataclasses.replace(
    get_config("llama3_8b").scaled_down().with_quant(quant),
    weight_quant="int8")
mesh = jax.make_mesh((2, 2), ("data", "model"))
B, T = 4, 64
serve, (ps, cs, ts) = jit_serve_step(cfg, B, T, mesh, donate=False)
params = jax.jit(make_param_init(cfg), out_shardings=ps)(
    jax.random.PRNGKey(0))
caches = jax.tree.map(lambda s: jax.device_put(jnp.zeros(s.shape, s.dtype)),
                      cache_specs(cfg, B, T))
caches = jax.device_put(caches, cs)
toks = jax.device_put(jnp.ones((B, 1), jnp.int32), ts)
new_tok, logits, _ = serve(params, caches, toks, jnp.asarray(3, jnp.int32))
assert new_tok.shape == (B, 1)
assert np.isfinite(np.asarray(logits[..., :cfg.vocab_size], np.float32)).all()
print("SERVE_QTENSOR_OK")
""", devices=4)
    assert "SERVE_QTENSOR_OK" in out


def test_sharded_quant_dot_fused_shard_local_2dev(subproc):
    """PR 5 acceptance: on a 2-device mesh the shard-local compute is the
    FUSED rotate-once Pallas kernel (not the unfused xla oracle) with the
    activation row-sharded over the data axes, bitwise-int8 vs the
    single-device kernel, per-shard weight scales preserved."""
    out = subproc("""
import jax, jax.numpy as jnp, numpy as np
from repro.core import api
from repro.core.api import quant_dot
from repro.core.wquant import quantize_weight
from repro.distributed import sharding as shd
from repro.kernels import registry

rng = np.random.default_rng(0)
x = jnp.asarray(rng.standard_normal((16, 256)), jnp.float32)
w = jnp.asarray(rng.standard_normal((256, 128)) * 0.05, jnp.float32)
qt = quantize_weight(w, "int8")
ref = quant_dot(x, qt, mode="int8", backend="pallas")     # single device
mesh = jax.make_mesh((1, 2), ("data", "model"))
unfused_before = registry.TRACE_COUNTS[("sharded_quant_dot", "unfused_local")]
kernel_before = registry.TRACE_COUNTS[("pallas", "quant_dot")]
with shd.sharding_rules(mesh):
    out = quant_dot(x, qt, mode="int8", backend="pallas",
                    weight_axes=(None, "dff"))
assert (np.asarray(out) == np.asarray(ref)).all()         # bitwise int8
disp = api._LAST_SHARDED_DISPATCH
assert disp["fused"] and disp["backend"] == "pallas", disp
assert disp["mesh_axes"] == ("model",), disp
assert disp["row_axes"] == ("data",), disp                # row-sharded in_spec
# the fused kernel really traced shard-locally; no unfused fallback count
assert registry.TRACE_COUNTS[("pallas", "quant_dot")] == kernel_before + 1
assert registry.TRACE_COUNTS[("sharded_quant_dot", "unfused_local")] == unfused_before

# per-shard weight scales are genuinely used on the fused path too
sw2 = qt.scale.at[:, 64:].mul(2.0)
with shd.sharding_rules(mesh):
    o1 = quant_dot(x, (qt.q, qt.scale), mode="int8", backend="pallas",
                   weight_axes=(None, "dff"))
    o2 = quant_dot(x, (qt.q, sw2), mode="int8", backend="pallas",
                   weight_axes=(None, "dff"))
assert (np.asarray(o1[:, :64]) == np.asarray(o2[:, :64])).all()
assert not (np.asarray(o1[:, 64:]) == np.asarray(o2[:, 64:])).all()
print("FUSED_SHARD_LOCAL_OK")
""", devices=2)
    assert "FUSED_SHARD_LOCAL_OK" in out


def test_sharded_quant_dot_row_sharded_4dev(subproc):
    """(2,2) mesh: rows genuinely split over the data axis (2 shards x 8
    rows) while the weight splits over model -- each device rotates only
    its rows and the assembled output is bitwise the single-device int8
    result. Rows not divisible by the data axis drop the row constraint
    (divisibility guard) but still compute correctly."""
    out = subproc("""
import jax, jax.numpy as jnp, numpy as np
from repro.core import api
from repro.core.api import quant_dot
from repro.core.wquant import quantize_weight
from repro.distributed import sharding as shd

rng = np.random.default_rng(1)
w = jnp.asarray(rng.standard_normal((256, 128)) * 0.05, jnp.float32)
qt = quantize_weight(w, "int8")
mesh = jax.make_mesh((2, 2), ("data", "model"))
for rows, want_axes in ((16, ("data",)), (9, ())):
    x = jnp.asarray(rng.standard_normal((rows, 256)), jnp.float32)
    ref = quant_dot(x, qt, mode="int8", backend="pallas")
    with shd.sharding_rules(mesh):
        out = quant_dot(x, qt, mode="int8", backend="pallas",
                        weight_axes=(None, "dff"))
    assert (np.asarray(out) == np.asarray(ref)).all(), rows
    assert api._LAST_SHARDED_DISPATCH["row_axes"] == want_axes, (
        rows, api._LAST_SHARDED_DISPATCH)
print("ROW_SHARDED_OK")
""", devices=4)
    assert "ROW_SHARDED_OK" in out


def test_sharded_quant_dot_fallbacks_are_observable(subproc):
    """Satellite: a mesh plan silently losing the sharded/fused hot path
    warns once per process per reason and bumps a TRACE_COUNTS counter
    every time -- both for unfused shard-local compute (xla backend) and
    for a plan whose mesh axes the current mesh does not provide."""
    out = subproc("""
import warnings
import jax, jax.numpy as jnp, numpy as np
from repro.core.api import QuantEpilogue, plan_for, quant_dot
from repro.core.wquant import quantize_weight
from repro.distributed import sharding as shd
from repro.kernels import registry

rng = np.random.default_rng(2)
x = jnp.asarray(rng.standard_normal((8, 256)), jnp.float32)
qt = quantize_weight(
    jnp.asarray(rng.standard_normal((256, 128)) * 0.05, jnp.float32), "int8")
mesh = jax.make_mesh((2,), ("model",))

key_u = ("sharded_quant_dot", "unfused_local")
with warnings.catch_warnings(record=True) as wl:
    warnings.simplefilter("always")
    before = registry.TRACE_COUNTS[key_u]
    with shd.sharding_rules(mesh):
        quant_dot(x, qt, mode="int8", backend="xla", weight_axes=(None, "dff"))
        quant_dot(x.astype(jnp.float32) * 2, qt, mode="int8", backend="xla",
                  weight_axes=(None, "dff"))
# counted at every dispatch (eager calls dispatch per call; under jit,
# once per trace) -- but WARNED only once
assert registry.TRACE_COUNTS[key_u] == before + 2
msgs = [str(v.message) for v in wl if "unfused_local" in str(v.message)]
assert len(msgs) == 1 and "xla" in msgs[0], msgs   # warn-once

key_m = ("sharded_quant_dot", "mesh_mismatch")
plan = plan_for(256, backend="pallas", epilogue=QuantEpilogue("int8"),
                mesh_axes=("model",))
ref = quant_dot(x, qt, mode="int8", backend="pallas")
with warnings.catch_warnings(record=True) as wl:
    warnings.simplefilter("always")
    before = registry.TRACE_COUNTS[key_m]
    out = quant_dot(x, (qt.q, qt.scale), plan)     # no active mesh
assert registry.TRACE_COUNTS[key_m] == before + 1
assert any("mesh_mismatch" in str(v.message) for v in wl)
assert (np.asarray(out) == np.asarray(ref)).all()  # fallback is correct

# per-tensor scales can't shard_map: the mesh plan must record the
# unshardable site instead of silently running replicated
key_s = ("sharded_quant_dot", "unshardable_site")
plan_pt = plan_for(256, backend="xla",
                   epilogue=QuantEpilogue("int8", per_token=False),
                   mesh_axes=("model",))
with warnings.catch_warnings(record=True) as wl:
    warnings.simplefilter("always")
    before = registry.TRACE_COUNTS[key_s]
    with shd.sharding_rules(mesh):
        outp = quant_dot(x, (qt.q, qt.scale), plan_pt)
assert registry.TRACE_COUNTS[key_s] == before + 1
assert any("unshardable_site" in str(v.message) for v in wl)
assert np.isfinite(np.asarray(outp, np.float32)).all()
print("FALLBACK_OBSERVABLE_OK")
""", devices=2)
    assert "FALLBACK_OBSERVABLE_OK" in out


def test_sharded_quant_dot_in_main_process():
    """Main-process multi-device coverage (the CI tier1-multidevice job:
    XLA_FLAGS device_count=2 on the pytest process itself, no subprocess
    indirection): skipped on single-device runs."""
    import jax

    if len(jax.devices()) < 2:
        pytest.skip("needs >=2 devices in the main process "
                    "(tier1-multidevice CI job)")
    import jax.numpy as jnp
    from repro.core.api import quant_dot
    from repro.core.wquant import quantize_weight
    from repro.distributed import sharding as shd

    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.standard_normal((8, 128)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((128, 64)) * 0.05, jnp.float32)
    qt = quantize_weight(w, "int8")
    ref = quant_dot(x, qt, mode="int8", backend="xla")
    mesh = jax.make_mesh((2,), ("model",))
    with shd.sharding_rules(mesh):
        out = quant_dot(x, qt, mode="int8", backend="xla",
                        weight_axes=(None, "dff"))
    assert (np.asarray(out) == np.asarray(ref)).all()
