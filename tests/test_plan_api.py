"""The plan-based unified Hadamard API (DESIGN.md section 5): plan
caching, backend registry selection, composable quantize epilogues
against the extended oracle, custom_vjp through fused and unfused paths,
and the end-to-end claim -- a quantized+rotated model forward routes the
down-projection input through ONE fused pallas_call."""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.api import (
    HadamardPlan,
    QuantEpilogue,
    hadamard,
    make_plan,
    plan_for,
)
from repro.core.hadamard import grouped_hadamard, hadamard_transform
from repro.core.quant import QuantConfig, quantize
from repro.core.rotations import online_hadamard_quantize
from repro.kernels import registry
from repro.kernels.fused_quant import fused_hadamard_quantize, ref_fused
from repro.kernels.ref import fwht


def _x(shape, seed=0, dtype=jnp.float32):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.standard_normal(shape), dtype=dtype)


# ------------------------------------------------------------- plan cache
def test_plan_cache_returns_same_object():
    p1 = plan_for(1024, backend="pallas")
    p2 = plan_for(1024, backend="pallas")
    assert p1 is p2
    assert make_plan is plan_for or make_plan(1024, backend="pallas") is p1


def test_repeated_shapes_do_not_recompile(monkeypatch):
    # pin auto-selection (the CI matrix runs the suite under a backend
    # env override; this test is about the plan/jit caches, not dispatch)
    monkeypatch.delenv(registry.BACKEND_ENV_VAR, raising=False)
    x = _x((16, 256))
    hadamard(x)  # warm: plan + jit cache
    key = ("pallas", "transform")
    before = registry.TRACE_COUNTS[key]
    for seed in range(3):
        hadamard(_x((16, 256), seed=seed))
    assert registry.TRACE_COUNTS[key] == before  # same plan, no retrace
    hadamard(_x((16, 512)))  # different shape -> exactly one new trace
    assert registry.TRACE_COUNTS[("pallas", "transform")] == before + 1


def test_plan_precomputes_factorization():
    p = plan_for(32768, backend="pallas")
    assert (p.k, p.r) == (2, 2)
    assert p.mats.shape[0] == 3 and p.mats.shape[-1] == 128
    small = plan_for(64, backend="pallas")
    assert (small.k, small.r) == (0, 64)
    assert small.mats.shape == (1, 64, 64)
    grouped = plan_for(14336)  # 7 * 2048
    assert grouped.grouped and grouped.p == 2048
    assert isinstance(grouped, HadamardPlan)


# ----------------------------------------------------------- registry
def test_backend_auto_selection_by_size(monkeypatch):
    monkeypatch.delenv(registry.BACKEND_ENV_VAR, raising=False)
    assert plan_for(2048).backend == "pallas"  # kernel cap covers it
    assert plan_for(65536).backend == "xla"    # above 2^15: factored path


def test_backend_env_override(monkeypatch):
    monkeypatch.setenv(registry.BACKEND_ENV_VAR, "xla")
    plan = plan_for(4096)
    assert plan.backend == "xla"
    # explicit argument beats the env var
    assert plan_for(4096, backend="pallas").backend == "pallas"
    monkeypatch.setenv(registry.BACKEND_ENV_VAR, "nope")
    with pytest.raises(ValueError):
        plan_for(8192)


def test_unknown_backend_raises():
    with pytest.raises(ValueError):
        plan_for(256, backend="cuda")


def test_ref_backend_matches_oracle_but_never_auto():
    x = _x((4, 256))
    y = hadamard(x, backend="ref")
    np.testing.assert_allclose(np.asarray(y),
                               np.asarray(fwht(x, 1 / 16.0)), rtol=1e-6)
    assert "ref" not in {plan_for(n).backend for n in (64, 1024, 65536)}


# ----------------------------------------------------------- validation
def test_scale_typo_raises_everywhere():
    x = _x((4, 128))
    for fn in (lambda: hadamard(x, scale="orth"),
               lambda: hadamard_transform(x, scale="orth"),
               lambda: plan_for(128, scale="orth")):
        with pytest.raises(ValueError):
            fn()
    # None stays explicitly accepted (the +-1 transform)
    np.testing.assert_allclose(np.asarray(hadamard(x, scale=None)),
                               np.asarray(fwht(x)), rtol=2e-5, atol=1e-3)


def test_unknown_epilogue_mode_raises():
    with pytest.raises(ValueError):
        QuantEpilogue("int4")


def test_plan_shape_mismatch_raises():
    plan = plan_for(256)
    with pytest.raises(ValueError):
        hadamard(_x((4, 128)), plan)
    with pytest.raises(ValueError):
        hadamard(_x((4, 256), dtype=jnp.bfloat16), plan)


def test_plan_with_conflicting_kwargs_raises():
    plan = plan_for(256)
    x = _x((4, 256))
    with pytest.raises(ValueError, match="explicit plan"):
        hadamard(x, plan, epilogue=QuantEpilogue("int8"))
    with pytest.raises(ValueError, match="explicit plan"):
        hadamard(x, plan, scale=None)


def test_legacy_op_rejects_non_pow2():
    from repro.kernels.ops import hadamard as old_hadamard

    with pytest.raises(ValueError):  # grouped transform is plan-API opt-in
        old_hadamard(_x((4, 24)))


# ----------------------------------------------------------- epilogues
def test_int8_epilogue_bitwise_matches_legacy_shim():
    x = _x((13, 2048), seed=3)
    q, s = hadamard(x, epilogue=QuantEpilogue("int8"), backend="pallas")
    q_old, s_old = fused_hadamard_quantize(x)
    assert q.dtype == jnp.int8
    assert (np.asarray(q) == np.asarray(q_old)).all()
    assert (np.asarray(s) == np.asarray(s_old)).all()


@pytest.mark.parametrize("mode", ["int8", "fp8_e4m3", "fp8_e5m2"])
@pytest.mark.parametrize("n", [128, 1024])
def test_fused_epilogues_match_ref_oracle(mode, n):
    x = _x((9, n), seed=n)
    q, s = hadamard(x, epilogue=QuantEpilogue(mode), backend="pallas")
    qr, sr = ref_fused(x, mode=mode)
    assert q.dtype == qr.dtype
    np.testing.assert_allclose(np.asarray(s), np.asarray(sr), rtol=1e-5)
    # grids may differ by 1 ulp at rounding boundaries
    dq = np.abs(np.asarray(q, np.float32) - np.asarray(qr, np.float32))
    denom = max(np.abs(np.asarray(qr, np.float32)).max(), 1.0)
    assert np.mean(dq) / denom < 0.01
    # dequantized result approximates the rotation; tolerance tracks the
    # grid's relative step (e5m2: 2 mantissa bits -> ~12.5% per-value)
    rel_tol = {"int8": 1 / 50, "fp8_e4m3": 1 / 20, "fp8_e5m2": 1 / 7}[mode]
    deq = np.asarray(q, np.float32) * np.asarray(s)
    want = np.asarray(fwht(x, scale=1.0 / math.sqrt(n)))
    assert np.abs(deq - want).max() < np.abs(want).max() * rel_tol


def test_dequant_epilogue_matches_two_step_fake_quant():
    x = _x((8, 512), seed=5)
    for mode in ("int8", "fp8_e4m3", "fp8_e5m2"):
        fused = hadamard(
            x, epilogue=QuantEpilogue(mode, dequant=True), backend="pallas")
        two = quantize(hadamard_transform(x), mode, axis=-1)
        np.testing.assert_allclose(np.asarray(fused), np.asarray(two),
                                   rtol=1e-4, atol=1e-4)


def test_grouped_epilogue_keeps_per_full_token_scales():
    x = _x((6, 1536), seed=7)  # 1536 = 3 * 512: grouped transform
    q, s = hadamard(x, epilogue=QuantEpilogue("int8"))
    assert q.shape == x.shape and s.shape == (6, 1)
    want_q, want_s = (
        np.asarray(t) for t in _quant_ref(grouped_hadamard(x)))
    np.testing.assert_allclose(np.asarray(s), want_s, rtol=1e-5)
    assert np.mean(np.asarray(q, np.int32) != want_q) < 0.01


def _quant_ref(y):
    s = jnp.maximum(jnp.max(jnp.abs(y), axis=-1, keepdims=True), 1e-8) / 127.0
    q = jnp.clip(jnp.round(y / s), -127, 127).astype(jnp.int8)
    return q, s


def test_per_tensor_epilogue():
    x = _x((4, 256), seed=9)
    q, s = hadamard(x, epilogue=QuantEpilogue("int8", per_token=False))
    y = np.asarray(hadamard_transform(x), np.float32)
    np.testing.assert_allclose(float(np.ravel(np.asarray(s))[0]),
                               max(np.abs(y).max(), 1e-8) / 127.0, rtol=1e-5)


# ------------------------------------------------------------- autodiff
def test_transform_vjp_self_adjoint():
    x = _x((4, 512), seed=11)
    g = jax.grad(lambda a: jnp.sum(hadamard(a) ** 2))(x)
    np.testing.assert_allclose(np.asarray(g), 2 * np.asarray(x),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("mode", ["int8", "fp8_e4m3", "fp8_e5m2"])
def test_fused_dequant_vjp_is_straight_through(mode):
    x = _x((4, 256), seed=13)
    w = _x((4, 256), seed=14)
    epi = QuantEpilogue(mode, dequant=True)
    g = jax.grad(lambda a: jnp.sum(hadamard(a, epilogue=epi) * w))(x)
    # STE: quantize behaves as identity in the pullback, so the gradient
    # is exactly the (self-adjoint) rotation of w.
    np.testing.assert_allclose(np.asarray(g), np.asarray(hadamard(w)),
                               rtol=1e-5, atol=1e-5)


def test_fused_qs_vjp_scale_branch_is_zero():
    # The (q, scales) form quantizes to an integer grid: its quantized
    # branch is non-differentiable (use dequant=True for training); the
    # scale branch is defined as a statistic with zero pullback.
    x = _x((4, 256), seed=15)
    g = jax.grad(
        lambda a: jnp.sum(hadamard(a, epilogue=QuantEpilogue("int8"))[1]))(x)
    assert g.shape == x.shape
    assert float(jnp.abs(g).max()) == 0.0


def test_model_helper_vjp_flows():
    cfg = QuantConfig(mode="int8", rotate="hadamard", backend="pallas")
    x = _x((2, 3, 512), seed=17)
    g = jax.grad(lambda a: jnp.sum(online_hadamard_quantize(a, cfg) ** 2))(x)
    assert bool(jnp.all(jnp.isfinite(g))) and float(jnp.abs(g).max()) > 0


# ----------------------------------------------------- end-to-end model
# shared with the lint rules: tests and CI assert one implementation
from repro.analysis import count_pallas_calls as _count_pallas_calls


def test_model_down_proj_routes_through_single_fused_kernel():
    """QuantConfig(mode='int8', rotate='hadamard', backend='pallas') must
    rotate + quantize the down-projection input in ONE pallas_call, and
    match the unfused xla-backend forward."""
    from repro.configs import get_config
    from repro.models.mlp import apply_mlp, init_mlp

    cfg = get_config("llama3_8b").scaled_down(
        d_ff=512, dtype="float32").with_quant(
        QuantConfig(mode="int8", rotate="hadamard", backend="pallas"))
    p = init_mlp(jax.random.PRNGKey(0), cfg)
    x = _x((2, 4, cfg.d_model), seed=19)

    jaxpr = jax.make_jaxpr(lambda a: apply_mlp(cfg, p, a))(x)
    assert _count_pallas_calls(jaxpr.jaxpr) == 1

    y_fused = apply_mlp(cfg, p, x)
    cfg_xla = cfg.with_quant(
        QuantConfig(mode="int8", rotate="hadamard", backend="xla"))
    y_two = apply_mlp(cfg_xla, p, x)
    scale = float(jnp.abs(y_two).max())
    np.testing.assert_allclose(np.asarray(y_fused), np.asarray(y_two),
                               atol=2e-3 * scale, rtol=1e-3)


def test_rotation_only_model_path_has_no_quantize_fallback():
    # rotate without quantization still goes through the plan API
    from repro.configs import get_config
    from repro.models.mlp import apply_mlp, init_mlp

    cfg = get_config("llama3_8b").scaled_down(d_ff=512, dtype="float32")
    cfg = cfg.with_quant(QuantConfig(rotate="hadamard", backend="pallas"))
    p = init_mlp(jax.random.PRNGKey(1), cfg)
    x = _x((2, 4, cfg.d_model), seed=21)
    jaxpr = jax.make_jaxpr(lambda a: apply_mlp(cfg, p, a))(x)
    assert _count_pallas_calls(jaxpr.jaxpr) == 1


# --------------------------------------------------------------- shims
def test_legacy_entry_points_importable_and_consistent():
    from repro.kernels.fused_quant import fused_hadamard_quantize as fhq
    from repro.kernels.ops import hadamard as old_hadamard

    x = _x((4, 1024), seed=23)
    np.testing.assert_allclose(np.asarray(old_hadamard(old_hadamard(x))),
                               np.asarray(x), rtol=1e-4, atol=1e-4)
    q, s = fhq(x)
    qr, sr = ref_fused(x)
    np.testing.assert_allclose(np.asarray(s), np.asarray(sr), rtol=1e-5)
    with pytest.raises(ValueError):
        fhq(_x((2, 96)))  # non-power-of-2 still rejected by the shim
