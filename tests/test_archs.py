"""Per-architecture smoke tests: every assigned arch instantiates a reduced
config of the same family and runs forward/train/prefill/decode on CPU,
asserting output shapes and finiteness. Also: rotation+quant variants run
through the same model code, and decode continues prefill consistently."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, all_configs, get_config
from repro.core.quant import QuantConfig
from repro.launch.shapes import ShapeSpec, make_batch
from repro.models import init_lm, lm_loss, lm_prefill, lm_decode_step
from repro.models.lm import pad_kv_caches

SMOKE_SEQ = 32


def _smoke_batch(cfg, seq=SMOKE_SEQ, batch=2):
    S = seq + (cfg.vlm_patches if cfg.family == "vlm" else 0)
    return make_batch(cfg, ShapeSpec("smoke", "train", S, batch))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_arch_smoke_train_step(arch):
    cfg = get_config(arch).scaled_down()
    batch = _smoke_batch(cfg)
    params = init_lm(jax.random.PRNGKey(0), cfg)
    loss, metrics = lm_loss(cfg, params, batch)
    assert np.isfinite(float(loss)) and float(loss) > 0
    grads = jax.grad(lambda p: lm_loss(cfg, p, batch)[0])(params)
    gn = sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in jax.tree.leaves(grads))
    assert np.isfinite(float(gn)) and float(gn) > 0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_arch_smoke_prefill_decode(arch):
    cfg = get_config(arch).scaled_down()
    if not cfg.has_decoder:
        pytest.skip("encoder-only")
    batch = _smoke_batch(cfg)
    params = init_lm(jax.random.PRNGKey(0), cfg)
    logits, caches = lm_prefill(cfg, params, batch)
    B = batch["tokens"].shape[0]
    assert logits.shape == (B, 1, cfg.padded_vocab)
    caches = pad_kv_caches(cfg, caches, SMOKE_SEQ + 16)
    tok = jnp.argmax(logits[..., :cfg.vocab_size], -1).astype(jnp.int32)
    pos = SMOKE_SEQ + (cfg.vlm_patches if cfg.family == "vlm" else 0)
    for i in range(3):
        logits, caches = lm_decode_step(cfg, params, caches, tok,
                                        jnp.asarray(pos + i, jnp.int32))
        tok = jnp.argmax(logits[..., :cfg.vocab_size], -1)[:, 0:1].astype(jnp.int32)
        assert np.isfinite(np.asarray(logits[..., :cfg.vocab_size], np.float32)).all()


@pytest.mark.parametrize("arch", ["llama3_8b", "mixtral_8x7b", "rwkv6_7b"])
def test_arch_with_rotation_quant(arch):
    """The paper's feature engaged end-to-end: fp8 + hadamard rotation on a
    model 'trained' without it, with the offline fusion applied (the
    post-training-quantization deployment). Loss must match closely."""
    from repro.core.rotations import fuse_down_proj_rotations
    q = QuantConfig(mode="fp8_e4m3", rotate="hadamard", backend="xla", kv_quant=True)
    cfg = get_config(arch).scaled_down().with_quant(q)
    batch = _smoke_batch(cfg)
    params = init_lm(jax.random.PRNGKey(0), cfg)
    loss, _ = lm_loss(cfg, fuse_down_proj_rotations(params), batch)
    assert np.isfinite(float(loss))
    cfg0 = get_config(arch).scaled_down()
    loss0, _ = lm_loss(cfg0, params, batch)
    assert abs(float(loss) - float(loss0)) < 0.15, (float(loss), float(loss0))


@pytest.mark.parametrize("arch", ["llama3_8b", "rwkv6_7b"])
def test_offline_fusion_exact_without_quant(arch):
    """Rotation + fused weights with NO quantization must be numerically
    identical to the unrotated model (the rotation cancels exactly)."""
    from repro.core.rotations import fuse_down_proj_rotations
    cfg0 = get_config(arch).scaled_down()
    cfg_r = cfg0.with_quant(QuantConfig(mode="none", rotate="hadamard",
                                        backend="xla"))
    batch = _smoke_batch(cfg0)
    params = init_lm(jax.random.PRNGKey(2), cfg0)
    loss0, _ = lm_loss(cfg0, params, batch)
    loss1, _ = lm_loss(cfg_r, fuse_down_proj_rotations(params), batch)
    assert abs(float(loss0) - float(loss1)) < 2e-2, (float(loss0), float(loss1))


def test_pallas_rotation_backend_matches_xla():
    """hadacore (interpret) inside a real model == factored XLA path."""
    base = get_config("llama3_8b").scaled_down()
    batch = _smoke_batch(base)
    params = init_lm(jax.random.PRNGKey(0), base)
    outs = {}
    for backend in ("xla", "pallas"):
        q = QuantConfig(mode="none", rotate="hadamard", backend=backend)
        cfg = base.with_quant(q)
        outs[backend], _ = lm_loss(cfg, params, batch)
    assert abs(float(outs["xla"]) - float(outs["pallas"])) < 1e-3


def test_decode_matches_prefill_logits():
    """Teacher-forced decode reproduces the prefill's next-token logits."""
    cfg = get_config("llama3_8b").scaled_down()
    S = 16
    batch = _smoke_batch(cfg, seq=S)
    params = init_lm(jax.random.PRNGKey(1), cfg)
    from repro.models import lm_forward
    full_logits, _, _ = lm_forward(cfg, params, batch)

    # prefill on the first S-4 tokens, then decode the next 4 teacher-forced
    cut = S - 4
    b0 = {k: (v[:, :cut] if k in ("tokens", "labels") else v) for k, v in batch.items()}
    logits, caches = lm_prefill(cfg, params, b0)
    caches = pad_kv_caches(cfg, caches, S + 4)
    np.testing.assert_allclose(
        np.asarray(logits[:, -1, :cfg.vocab_size], np.float32),
        np.asarray(full_logits[:, cut - 1, :cfg.vocab_size], np.float32),
        rtol=2e-2, atol=2e-2)
    for i in range(3):
        tok = batch["tokens"][:, cut + i][:, None]
        logits, caches = lm_decode_step(cfg, params, caches, tok,
                                        jnp.asarray(cut + i, jnp.int32))
        np.testing.assert_allclose(
            np.asarray(logits[:, -1, :cfg.vocab_size], np.float32),
            np.asarray(full_logits[:, cut + i, :cfg.vocab_size], np.float32),
            rtol=5e-2, atol=5e-2)


def test_config_registry_complete():
    cfgs = all_configs()
    assert len(cfgs) == 11
    fams = {c.family for c in cfgs.values()}
    assert {"dense", "moe", "ssm", "hybrid", "audio", "vlm"} <= fams
    # published dims spot-checks
    assert cfgs["llama3_405b"].num_layers == 126
    assert cfgs["zamba2_7b"].num_layers == 81
    assert cfgs["llama4_maverick_400b_a17b"].num_layers == 48
    assert cfgs["mixtral_8x7b"].num_experts == 8
    assert cfgs["qwen2_vl_7b"].mrope


def test_param_counts_match_published_class():
    """Total parameter counts land in the right class for key archs."""
    from repro.launch.flops import count_params
    expect = {"llama3_405b": (380e9, 430e9),
              "mixtral_8x7b": (44e9, 50e9),
              "llama4_maverick_400b_a17b": (320e9, 480e9),
              "phi4_mini_3_8b": (3.0e9, 4.8e9),
              "starcoder2_15b": (13e9, 17e9),
              "rwkv6_7b": (6e9, 9e9),
              "zamba2_7b": (6e9, 9.5e9),
              "qwen2_vl_7b": (6e9, 9e9)}
    for arch, (lo, hi) in expect.items():
        n = count_params(get_config(arch))["total"]
        assert lo < n < hi, (arch, n)
    act = count_params(get_config("llama4_maverick_400b_a17b"))["active"]
    assert 12e9 < act < 25e9, act  # "a17b"
