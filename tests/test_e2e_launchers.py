"""End-to-end launcher tests: train (with checkpoint/restart + preemption
semantics), serve, and the fault-tolerance contract."""
import os

import numpy as np
import pytest


def test_train_runs_and_checkpoints(tmp_path, capsys):
    from repro.launch.train import main
    ck = str(tmp_path / "ckpt")
    main(["--arch", "llama3-8b", "--scale", "0.005", "--steps", "6",
          "--seq", "32", "--batch", "4", "--ckpt-every", "3",
          "--ckpt-dir", ck, "--log-every", "2"])
    out = capsys.readouterr().out
    assert "step     5" in out
    from repro.checkpoint import latest_step
    assert latest_step(ck) == 6


def test_train_restart_resumes_identically(tmp_path, capsys):
    """Fault-tolerance contract: a run killed at step 4 and restarted
    produces the same final loss as an uninterrupted run (stateless data
    pipeline + checkpointed params/optimizer)."""
    from repro.launch.train import main
    args = ["--arch", "llama3-8b", "--scale", "0.005", "--seq", "32",
            "--batch", "4", "--log-every", "1"]
    # uninterrupted 8-step run
    main(args + ["--steps", "8"])
    out_full = capsys.readouterr().out
    # interrupted at 4 + resumed
    ck = str(tmp_path / "ckpt2")
    main(args + ["--steps", "4", "--ckpt-every", "4", "--ckpt-dir", ck])
    capsys.readouterr()
    main(args + ["--steps", "8", "--ckpt-every", "4", "--ckpt-dir", ck])
    out_resumed = capsys.readouterr().out
    assert "restoring checkpoint step 4" in out_resumed

    def last_loss(txt):
        lines = [l for l in txt.splitlines() if l.startswith("step")]
        return float(lines[-1].split("loss")[1].split()[0])

    assert abs(last_loss(out_full) - last_loss(out_resumed)) < 2e-2


def test_train_with_rotation_quant_and_tricks(capsys):
    """All the distributed-optimization features on at once."""
    from repro.launch.train import main
    main(["--arch", "mixtral-8x7b", "--scale", "0.004", "--steps", "3",
          "--seq", "32", "--batch", "2", "--quant", "int8",
          "--rotate", "hadamard", "--opt-state", "int8",
          "--grad-compression", "int8_ef", "--log-every", "1"])
    out = capsys.readouterr().out
    losses = [float(l.split("loss")[1].split()[0])
              for l in out.splitlines() if l.startswith("step")]
    assert all(np.isfinite(losses))


def test_serve_runs(capsys):
    from repro.launch.serve import main
    main(["--arch", "llama3-8b", "--scale", "0.005", "--batch", "2",
          "--prompt-len", "16", "--gen", "5",
          "--quant", "fp8_e4m3", "--rotate", "hadamard"])
    out = capsys.readouterr().out
    assert "decode:" in out and "tok/s" in out


def test_dryrun_importable_without_512_devices():
    """dryrun.py sets XLA_FLAGS at import; here we only check the module
    parses and its roofline helpers work (the full 512-dev run is the
    background artifact job)."""
    import importlib.util
    spec = importlib.util.find_spec("repro.launch.dryrun")
    assert spec is not None
    from repro.launch.flops import model_flops, count_params
    from repro.configs import get_config
    from repro.launch.shapes import SHAPES
    cfg = get_config("llama3_405b")
    n = count_params(cfg)["total"]
    assert 3.8e11 < n < 4.3e11
    f_train = model_flops(cfg, SHAPES["train_4k"])
    assert f_train > 6 * n * 4096 * 256  # at least 6ND
    f_dec = model_flops(cfg, SHAPES["decode_32k"])
    assert f_dec < f_train
