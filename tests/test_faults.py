"""Hardened serving (PR 8): fault-injected recovery paths.

Every recovery path gets a test that injects the triggering fault into a
real serve run and asserts (a) the run completes without crashing,
(b) every request carries the right ``Completion.status``, and (c) the
``ok`` requests' token streams are bitwise identical to a fault-free
run -- degradation and guards must never change healthy outputs.
"""
import dataclasses

import numpy as np
import pytest

import jax

from repro.configs import get_config
from repro.core.quant import QuantConfig
from repro.kernels.registry import TRACE_COUNTS, WARN_ONCE_SEEN
from repro.launch.train import scaled_config
from repro.testing.faults import (FaultPlan, InjectedKernelError,
                                  arrival_flood, inject)

P, MAXLEN = 8, 32


# --------------------------------------------------------------- fixtures
def _setup(backend):
    from repro.launch.mesh import make_local_mesh
    from repro.launch.steps import make_param_init, param_shardings

    quant = QuantConfig(mode="fp8_e4m3", rotate="hadamard", backend=backend,
                        kv_quant=True)
    cfg = scaled_config(get_config("llama3-8b"), 0.004).with_quant(quant)
    cfg = dataclasses.replace(cfg, weight_quant="int8")
    mesh = make_local_mesh(1)
    with mesh:
        ps = param_shardings(cfg, mesh)
        params = jax.jit(make_param_init(cfg), out_shardings=ps)(
            jax.random.PRNGKey(0))
    return cfg, params, mesh


@pytest.fixture(scope="module")
def xla_setup():
    return _setup("xla")


@pytest.fixture(scope="module")
def auto_setup():
    """backend='auto' resolves to the XLA path on CPU but carries the
    full degradation ladder (auto/streamed -> rotate_once -> xla), so
    ladder re-warms are exercised at XLA speed."""
    return _setup("auto")


def _engine(setup, **kw):
    from repro.serving import ServeEngine

    cfg, params, mesh = setup
    kw.setdefault("num_slots", 2)
    kw.setdefault("max_len", MAXLEN)
    kw.setdefault("prefill_len", P)
    return ServeEngine(cfg, params, mesh, **kw)


def _reqs(cfg, n, gen=4, seed=1, **kw):
    return arrival_flood(n, prompt_len=P, max_new_tokens=gen,
                         vocab=cfg.vocab_size, seed=seed, **kw)


def _reference_tokens(setup, reqs):
    """Fault-free run of the same requests (deadlines stripped):
    rid -> token tuple."""
    plain = [dataclasses.replace(r, deadline=None) for r in reqs]
    comps = _engine(setup).run(plain)
    assert all(c.status == "ok" for c in comps)
    return {c.rid: c.tokens for c in comps}


# ---------------------------------------------------- scheduler (host-only)
def test_clock_jump_does_not_stall_admission():
    """Regression: a backwards `now` used to make the arrival check fail
    forever. The monotonic clamp admits from the high-water mark."""
    from repro.serving.scheduler import Request, Scheduler

    sched = Scheduler(num_slots=2, max_len=32, prefill_len=8)
    sched.submit(Request(0, np.zeros(4, np.int32), 4, arrival_time=5.0))
    assert sched.next_admission(5.0) is not None       # clock now at 5
    sched.submit(Request(1, np.zeros(4, np.int32), 4, arrival_time=5.0))
    # wall clock jumps BACKWARDS; pre-fix this returned None forever
    adm = sched.next_admission(1.0)
    assert adm is not None and adm[1].rid == 1
    assert sched._clock == 5.0


def test_bounded_queue_rejects_with_backpressure():
    from repro.serving.scheduler import Request, Scheduler

    sched = Scheduler(num_slots=1, max_len=32, prefill_len=8, max_queue=2)
    before = TRACE_COUNTS[("serving", "queue_reject")]
    assert sched.submit(Request(0, np.zeros(4, np.int32), 4)) is None
    assert sched.submit(Request(1, np.zeros(4, np.int32), 4)) is None
    c = sched.submit(Request(2, np.zeros(4, np.int32), 4))
    assert c is not None and c.status == "rejected" \
        and c.finish_reason == "queue_full" and c.tokens == ()
    assert sched.counters["rejected"] == 1
    assert TRACE_COUNTS[("serving", "queue_reject")] == before + 1
    # invalid requests still raise, full queue or not
    with pytest.raises(ValueError, match="prompt_len"):
        sched.submit(Request(3, np.zeros(9, np.int32), 2))


def test_shed_expired_scans_whole_queue():
    from repro.serving.scheduler import Request, Scheduler

    sched = Scheduler(num_slots=1, max_len=32, prefill_len=8)
    sched.submit(Request(0, np.zeros(4, np.int32), 4))            # no TTL
    sched.submit(Request(1, np.zeros(4, np.int32), 4, deadline=2.0))
    sched.submit(Request(2, np.zeros(4, np.int32), 4, deadline=9.0))
    shed = sched.shed_expired(5.0)
    assert [c.rid for c in shed] == [1]
    assert shed[0].status == "timed_out" \
        and shed[0].finish_reason == "deadline_shed"
    assert [r.rid for r in sched.queue] == [0, 2]   # FCFS order kept
    assert sched.counters["shed"] == 1


# ------------------------------------------------------------ engine paths
def test_deadline_shed_and_inflight_timeout(xla_setup):
    """One slot: a long request holds it; a queued request's TTL expires
    behind it (shed, never admitted); the long request itself has a TTL
    shorter than its generation (retired in-flight as timed_out with the
    tokens produced so far)."""
    cfg, _, _ = xla_setup
    r_long, r_queued = _reqs(cfg, 2, gen=12)
    r_long = dataclasses.replace(r_long, deadline=5.0)
    r_queued = dataclasses.replace(r_queued, deadline=3.0)

    before = TRACE_COUNTS[("serving", "deadline_shed")]
    comps = {c.rid: c for c in _engine(
        xla_setup, num_slots=1).run([r_long, r_queued])}
    long_c, queued_c = comps[r_long.rid], comps[r_queued.rid]
    assert long_c.status == "timed_out" \
        and long_c.finish_reason == "deadline"
    assert 0 < len(long_c.tokens) < 12      # partial output, not silence
    assert queued_c.status == "timed_out" \
        and queued_c.finish_reason == "deadline_shed" \
        and queued_c.tokens == () and queued_c.admitted_step == -1
    assert TRACE_COUNTS[("serving", "deadline_shed")] == before + 1


def test_kernel_raise_retried_once_bitwise(xla_setup):
    """A transient decode failure is retried on intact caches (the fault
    fires before the donated dispatch): same tokens, same single decode
    executable, status ok."""
    cfg, _, _ = xla_setup
    reqs = _reqs(cfg, 2, gen=5)
    ref = _reference_tokens(xla_setup, reqs)

    eng = _engine(xla_setup)
    with inject(FaultPlan(kernel_raise_at_step=1, kernel_raise_count=1)):
        comps = eng.run(reqs)
    s = eng.summary()
    assert all(c.status == "ok" for c in comps)
    assert all(c.tokens == ref[c.rid] for c in comps)
    assert s["step_retries"] == 1
    assert s["decode_executables"] == 1 and s.get("degrades", 0) == 0


def test_persistent_failure_degrades_and_rewarm_bitwise(auto_setup):
    """Two consecutive dispatch failures exhaust the retry and re-warm
    one ladder rung down (schedule pinned to rotate_once). The re-warmed
    engine finishes the stream with BITWISE-identical tokens, and the
    decode executable count grows by exactly the re-warm."""
    cfg, _, _ = auto_setup
    reqs = _reqs(cfg, 2, gen=5)
    ref = _reference_tokens(auto_setup, reqs)

    WARN_ONCE_SEEN.discard(("serving", "degrade_rotate_once"))
    before = TRACE_COUNTS[("serving", "degrade_rotate_once")]
    eng = _engine(auto_setup)
    with pytest.warns(RuntimeWarning, match="degraded to rung"), \
            inject(FaultPlan(kernel_raise_at_step=1, kernel_raise_count=2)):
        comps = eng.run(reqs)
    s = eng.summary()
    assert all(c.status == "ok" for c in comps)
    assert all(c.tokens == ref[c.rid] for c in comps)
    assert s["rung"] == 1 and s["degrades"] == 1
    assert s["decode_executables"] == 2     # exactly one re-warm
    assert TRACE_COUNTS[("serving", "degrade_rotate_once")] == before + 1


def test_rewarmed_executable_still_passes_lint(auto_setup):
    """PR 9 linter x PR 8 ladder: after a persistent failure re-warms
    the engine one rung down, the RE-WARMED decode/insert executables
    still satisfy the fusion and donation contracts -- degradation must
    never trade away cache donation or reintroduce per-step weight
    quantization."""
    from repro.analysis import run_rules, serving_sites

    cfg, _, _ = auto_setup
    reqs = _reqs(cfg, 2, gen=5)
    eng = _engine(auto_setup)
    WARN_ONCE_SEEN.discard(("serving", "degrade_rotate_once"))
    with pytest.warns(RuntimeWarning, match="degraded to rung"), \
            inject(FaultPlan(kernel_raise_at_step=1, kernel_raise_count=2)):
        comps = eng.run(reqs)
    assert eng.summary()["rung"] == 1    # genuinely re-warmed
    assert all(c.status == "ok" for c in comps)

    sites = serving_sites(cfg.name, engine=eng)
    assert any("rung1" in s.name for s in sites)
    rep = run_rules(sites, rules=["fusion-contract", "donation"])
    ran = {r for _, r in rep.checked}
    assert {"fusion-contract", "donation"} <= ran
    assert rep.ok, rep.format_text()


def test_ladder_exhaustion_fails_loudly_not_crashily(xla_setup):
    """On a single-rung (xla) config a persistent failure cannot degrade:
    in-flight requests retire as ``degraded``/engine_failed and queued
    work is drained -- the caller never sees the raise."""
    cfg, _, _ = xla_setup
    reqs = _reqs(cfg, 3, gen=5)
    WARN_ONCE_SEEN.discard(("serving", "ladder_exhausted"))
    eng = _engine(xla_setup, num_slots=2)
    with pytest.warns(RuntimeWarning, match="ladder exhausted"), \
            inject(FaultPlan(kernel_raise_at_step=1, kernel_raise_count=99)):
        comps = {c.rid: c for c in eng.run(reqs)}
    assert all(c.status == "degraded" for c in comps.values())
    inflight = [c for c in comps.values()
                if c.finish_reason == "engine_failed"]
    drained = [c for c in comps.values()
               if c.finish_reason == "shed_engine_failed"]
    assert len(inflight) == 2 and len(drained) == 1


def test_watchdog_trips_on_slow_steps(xla_setup):
    """Artificial step latency trips the post-hoc watchdog twice in a
    row; the slow steps' results are still used (tokens unchanged) and
    on a single-rung config the degrade attempt is a warn, not a crash."""
    cfg, _, _ = xla_setup
    reqs = _reqs(cfg, 2, gen=5)
    ref = _reference_tokens(xla_setup, reqs)

    before = TRACE_COUNTS[("serving", "watchdog_trip")]
    WARN_ONCE_SEEN.discard(("serving", "ladder_exhausted"))
    eng = _engine(xla_setup, watchdog_ms=40.0)
    with pytest.warns(RuntimeWarning, match="ladder exhausted"), \
            inject(FaultPlan(step_delay_s=0.1, delay_at_steps=(1, 2))):
        comps = eng.run(reqs)
    s = eng.summary()
    assert all(c.status == "ok" for c in comps)
    assert all(c.tokens == ref[c.rid] for c in comps)
    assert s["watchdog_trips"] >= 2
    assert TRACE_COUNTS[("serving", "watchdog_trip")] >= before + 2


# --------------------------------------------------------- numeric guards
def test_nan_poke_retires_only_the_poisoned_slot(xla_setup, monkeypatch):
    """NaN injected into a live slot's KV row trips the logits guard at
    the next step: that slot retires as ``degraded`` (no poisoned tokens
    emitted); the co-resident slot finishes bitwise clean."""
    monkeypatch.setenv("REPRO_NUMERIC_GUARDS", "1")
    cfg, _, _ = xla_setup
    reqs = _reqs(cfg, 2, gen=6)
    ref = _reference_tokens(xla_setup, reqs)  # guard-off engine

    before = TRACE_COUNTS[("serving", "guard_trip")]
    eng = _engine(xla_setup)
    with inject(FaultPlan(nan_poke_step=2, nan_poke_slot=0)):
        comps = {c.rid: c for c in eng.run(reqs)}
    poisoned = comps[reqs[0].rid]             # slot 0 = first admission
    clean = comps[reqs[1].rid]
    assert poisoned.status == "degraded" \
        and poisoned.finish_reason == "nan_guard"
    assert len(poisoned.tokens) < 6           # cut short, not completed
    # the emitted prefix (pre-poke) is still the correct stream prefix
    assert poisoned.tokens == ref[poisoned.rid][:len(poisoned.tokens)]
    assert clean.status == "ok" and clean.tokens == ref[clean.rid]
    assert TRACE_COUNTS[("serving", "guard_trip")] >= before + 1
    assert eng.summary()["guards_enabled"] == 1


def test_guards_on_is_bitwise_guard_off(xla_setup, monkeypatch):
    """No-fault run with guards enabled: identical tokens, all ok --
    guards observe, never perturb."""
    cfg, _, _ = xla_setup
    reqs = _reqs(cfg, 3, gen=5)
    ref = _reference_tokens(xla_setup, reqs)  # guards off

    monkeypatch.setenv("REPRO_NUMERIC_GUARDS", "1")
    comps = _engine(xla_setup).run(reqs)
    assert all(c.status == "ok" for c in comps)
    assert all(c.tokens == ref[c.rid] for c in comps)


# ------------------------------------------------------ combined acceptance
def test_combined_chaos_run(auto_setup, monkeypatch):
    """The ISSUE's acceptance scenario in one run: guards on, kernel
    raise at step N forcing a ladder re-warm, a deadline-expired queued
    request, and queue overflow -- completes without crashing, statuses
    correct per request, ok outputs bitwise vs fault-free, decode
    executables grow only by the re-warm."""
    monkeypatch.setenv("REPRO_NUMERIC_GUARDS", "1")
    cfg, _, _ = auto_setup
    r = _reqs(cfg, 6, gen=4)
    r[0] = dataclasses.replace(r[0], max_new_tokens=6)
    r[2] = dataclasses.replace(r[2], deadline=2.0)   # expires queued
    ok_rids = {r[0].rid, r[1].rid, r[3].rid}
    ref = _reference_tokens(auto_setup, [r[0], r[1], r[3]])

    eng = _engine(auto_setup, max_queue=4)
    with inject(FaultPlan(kernel_raise_at_step=1, kernel_raise_count=2)):
        comps = {c.rid: c for c in eng.run(r)}
    s = eng.summary()

    assert len(comps) == 6
    for rid in ok_rids:
        assert comps[rid].status == "ok"
        assert comps[rid].tokens == ref[rid]
    assert comps[r[2].rid].status == "timed_out" \
        and comps[r[2].rid].finish_reason == "deadline_shed"
    assert comps[r[4].rid].status == "rejected"
    assert comps[r[5].rid].status == "rejected"
    assert s["decode_executables"] == 2 and s["rung"] == 1
    assert s["status_ok"] == 3 and s["status_rejected"] == 2 \
        and s["status_timed_out"] == 1
    assert s.get("guard_trips", 0) == 0     # healthy numerics, no trips


# ------------------------------------------------------- ABFT SDC detection
def test_abft_healthy_run_bitwise_and_health_dict(xla_setup, monkeypatch):
    """No-fault run with ABFT on: identical tokens, zero trips -- the
    checksums observe, never perturb (the false-positive acceptance
    bar); ``summary()`` exposes the structured health sub-dict."""
    cfg, _, _ = xla_setup
    reqs = _reqs(cfg, 3, gen=5)
    ref = _reference_tokens(xla_setup, reqs)   # abft off

    monkeypatch.setenv("REPRO_ABFT", "1")
    eng = _engine(xla_setup)
    comps = eng.run(reqs)
    assert all(c.status == "ok" for c in comps)
    assert all(c.tokens == ref[c.rid] for c in comps)
    h = eng.summary()["health"]
    assert h["abft_enabled"] == 1
    assert h["abft_sdc_detections"] == 0 and h["abft_kv_trips"] == 0
    assert h["abft_params_checks"] == 0        # zero steady-state audits


def test_abft_weight_bitflip_retires_as_sdc(xla_setup, monkeypatch):
    """A silent bit flip in a checksum-covered weight at step N: finite,
    plausible logits -- invisible to the numeric guards -- but the kernel
    checksum trips that same step, the weight audit attributes it, every
    affected slot retires ``sdc_detected``, no corrupt token is emitted,
    and the engine survives to keep serving."""
    monkeypatch.setenv("REPRO_ABFT", "1")
    cfg, _, _ = xla_setup
    reqs = _reqs(cfg, 2, gen=6)
    monkeypatch.delenv("REPRO_ABFT")
    ref = _reference_tokens(xla_setup, reqs)
    monkeypatch.setenv("REPRO_ABFT", "1")

    before = TRACE_COUNTS[("abft", "sdc_detected")]
    WARN_ONCE_SEEN.discard(("serving", "ladder_exhausted"))
    eng = _engine(xla_setup)
    with inject(FaultPlan(corrupt_at_step=2, corrupt_kind="weight")) as plan:
        comps = {c.rid: c for c in eng.run(reqs)}
    assert plan.log == [(2, "corrupt_weight")]
    sdc = [c for c in comps.values() if c.finish_reason == "sdc_detected"]
    assert sdc, "weight bit flip went undetected"
    for c in sdc:
        assert c.status == "degraded"
        # detected within the affected step: only the clean prefix left
        assert c.tokens == ref[c.rid][:len(c.tokens)]
        assert len(c.tokens) < 6
    h = eng.summary()["health"]
    assert h["abft_sdc_detections"] >= 1
    assert h["abft_params_checks"] >= 1        # audit ran (once per step)
    assert TRACE_COUNTS[("abft", "sdc_detected")] >= before + 1

    # the detection never crashed the process: a fresh engine over the
    # pristine params (the flip hit the old engine's copy only) serves
    # the same stream bitwise clean
    comps2 = _engine(xla_setup).run(
        [dataclasses.replace(r) for r in reqs])
    assert all(c.status == "ok" and c.tokens == ref[c.rid] for c in comps2)


def test_abft_kv_corruption_retires_only_that_slot(xla_setup, monkeypatch):
    """A finite perturbation of an already-written KV row -- plausible
    values, nothing for the NaN guards -- breaks the per-slot KV
    conservation law at the next step: that slot retires
    ``sdc_detected``; the co-resident slot finishes bitwise clean."""
    monkeypatch.setenv("REPRO_ABFT", "1")
    cfg, _, _ = xla_setup
    reqs = _reqs(cfg, 2, gen=6, seed=5)
    monkeypatch.delenv("REPRO_ABFT")
    ref = _reference_tokens(xla_setup, reqs)
    monkeypatch.setenv("REPRO_ABFT", "1")

    before = TRACE_COUNTS[("abft", "kv_trip")]
    eng = _engine(xla_setup)
    with inject(FaultPlan(corrupt_at_step=2, corrupt_kind="kv",
                          kv_corrupt_slot=0)):
        comps = {c.rid: c for c in eng.run(reqs)}
    poisoned = comps[reqs[0].rid]
    clean = comps[reqs[1].rid]
    assert poisoned.status == "degraded" \
        and poisoned.finish_reason == "sdc_detected"
    assert poisoned.tokens == ref[poisoned.rid][:len(poisoned.tokens)]
    assert clean.status == "ok" and clean.tokens == ref[clean.rid]
    assert TRACE_COUNTS[("abft", "kv_trip")] >= before + 1
    assert eng.summary()["health"]["abft_kv_trips"] >= 1


def test_fault_plan_is_context_scoped():
    from repro.testing import faults

    plan = FaultPlan(kernel_raise_at_step=0)
    assert faults.active() is None
    with inject(plan):
        assert faults.active() is plan
        with pytest.raises(InjectedKernelError):
            plan.maybe_raise(0)
    assert faults.active() is None
    assert plan.log == [(0, "kernel_raise")]
