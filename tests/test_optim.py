"""Optimizer: AdamW semantics, schedules, 8-bit state, EF compression."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.optim import OptConfig, apply_updates, init_opt_state
from repro.optim.adamw import compress_grads, schedule
from repro.optim.qstate import dequantize_state, quantize_state


def _toy_problem(state_dtype="f32", compression="none", steps=60):
    rng = np.random.default_rng(0)
    X = jnp.asarray(rng.standard_normal((128, 16)), dtype=jnp.float32)
    w_true = jnp.asarray(rng.standard_normal((16, 4)), dtype=jnp.float32)
    y = X @ w_true
    params = {"w": jnp.zeros((16, 4), jnp.float32)}
    cfg = OptConfig(lr=5e-2, weight_decay=0.0, warmup_steps=5, total_steps=steps,
                    state_dtype=state_dtype, grad_compression=compression)
    state = init_opt_state(params, cfg)

    def loss_fn(p):
        return jnp.mean((X @ p["w"] - y) ** 2)

    losses = []
    for _ in range(steps):
        g = jax.grad(loss_fn)(params)
        params, state, m = apply_updates(params, g, state, cfg)
        losses.append(float(loss_fn(params)))
    return losses


def test_adamw_converges():
    losses = _toy_problem()
    assert losses[-1] < losses[0] * 0.05


def test_adamw_int8_state_converges():
    losses = _toy_problem(state_dtype="int8")
    assert losses[-1] < losses[0] * 0.1


def test_adamw_ef_compression_converges():
    losses = _toy_problem(compression="int8_ef")
    assert losses[-1] < losses[0] * 0.1


def test_schedule_shape():
    cfg = OptConfig(lr=1e-3, warmup_steps=10, total_steps=100, min_lr_frac=0.1)
    s = [float(schedule(cfg, jnp.asarray(i))) for i in range(101)]
    assert s[0] < s[9] < s[10]            # warmup ramps
    assert abs(s[10] - 1e-3) < 1e-9       # peak at end of warmup
    assert s[100] == pytest.approx(1e-4, rel=1e-3)  # decays to min_lr


@settings(deadline=None, max_examples=20)
@given(shape=st.sampled_from([(7,), (16, 4), (3, 5, 257), (1, 1024)]),
       seed=st.integers(0, 10**6), scale=st.floats(1e-6, 1e4))
def test_qstate_roundtrip_error_bounded(shape, seed, scale):
    """Blockwise int8 roundtrip error < 1/127 of per-block absmax."""
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal(shape) * scale, dtype=jnp.float32)
    q = quantize_state(x)
    back = dequantize_state(q, shape)
    assert back.shape == shape
    err = np.abs(np.asarray(back) - np.asarray(x))
    assert err.max() <= scale * 6.0 / 127.0 + 1e-7


def test_ef_compression_invariant():
    """Error feedback: quantized grads + residual == original grads."""
    rng = np.random.default_rng(1)
    g = {"a": jnp.asarray(rng.standard_normal((32, 8)) * 3, jnp.float32)}
    ef = {"a": jnp.asarray(rng.standard_normal((32, 8)) * 0.1, jnp.float32)}
    gq, ef_new = compress_grads(g, ef)
    lhs = np.asarray(gq["a"]) + np.asarray(ef_new["a"])
    rhs = np.asarray(g["a"]) + np.asarray(ef["a"])
    np.testing.assert_allclose(lhs, rhs, rtol=1e-5, atol=1e-5)


def test_weight_decay_applies_to_matrices_only():
    cfg = OptConfig(lr=1e-2, weight_decay=0.5, warmup_steps=0, total_steps=10)
    params = {"w": jnp.ones((4, 4)), "b": jnp.ones((4,))}
    state = init_opt_state(params, cfg)
    zero_g = jax.tree.map(jnp.zeros_like, params)
    new_p, _, _ = apply_updates(params, zero_g, state, cfg)
    assert float(jnp.max(jnp.abs(new_p["b"] - 1.0))) < 1e-6   # no decay on 1D
    assert float(jnp.max(new_p["w"])) < 1.0                   # decayed
